//! End-to-end driver: an emulated 8-node edge cluster serving ResNet-50.
//!
//! This is the repo's full-system validation (see EXPERIMENTS.md): the
//! edge-profile ResNet-50 is partitioned 8 ways, distributed over REAL TCP
//! loopback sockets with gigabit-Ethernet link emulation, and serves a
//! stream of inference requests. It reports throughput, latency
//! percentiles, per-node energy and wire payloads, and cross-checks the
//! pipeline output against the Python reference — proving all three layers
//! (Pallas kernel -> JAX partition HLO -> rust chain) compose.
//!
//! ```text
//! make artifacts
//! cargo run --release --example edge_cluster [frames] [nodes]
//! ```

use defer::config::DeferConfig;
use defer::coordinator::baseline::SingleDevice;
use defer::coordinator::chain::ChainRunner;
use defer::netem::LinkSpec;
use defer::runtime::Engine;
use defer::util::{fmt_bytes, fmt_duration};

fn main() -> defer::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let frames: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(24);
    let nodes: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);

    let mut cfg = DeferConfig::default();
    cfg.profile = "edge".into();
    cfg.model = "resnet50".into();
    cfg.nodes = nodes;
    cfg.tcp = true;
    // Pin the port range CORE-style; omit for ephemeral binds.
    cfg.base_port = Some(47_800);
    cfg.link = LinkSpec::gigabit_lan();
    // Edge-device speed emulation (see DESIGN.md §Substitutions): floor
    // stage compute to a 50-MFLOPS device, the paper's TF-on-edge-CPU
    // regime. Deterministic: host contention cannot perturb stage times.
    cfg.emulated_mflops = 50.0;

    println!("== DEFER edge cluster: {} x ResNet-50/{} over TCP+gigabit ==", nodes, cfg.profile);
    let engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());

    // Baseline first: the whole model on one device (paper's comparison).
    let mut base_cfg = cfg.clone();
    base_cfg.tcp = false;
    let baseline = SingleDevice::with_engine(base_cfg, engine.clone())?;
    let base = baseline.run_frames(frames)?;
    println!(
        "single device : {:.3} cycles/s | {:.5} J/cycle | p50 {}",
        base.throughput,
        base.energy_per_node_per_cycle(),
        fmt_duration(base.latency_p50),
    );

    // The DEFER chain.
    let runner = ChainRunner::with_engine(cfg, engine)?;
    let t0 = std::time::Instant::now();
    let report = runner.run_frames(frames)?;
    println!(
        "DEFER {} nodes : {:.3} cycles/s | {:.5} J/node/cycle | p50 {} | p99 {}",
        nodes,
        report.throughput,
        report.energy_per_node_per_cycle(),
        fmt_duration(report.latency_p50),
        fmt_duration(report.latency_p99),
    );
    println!(
        "config step   : {} ({} arch + {} weights on the wire)",
        fmt_duration(report.config_time),
        fmt_bytes(report.architecture_bytes),
        fmt_bytes(report.weights_bytes),
    );
    println!(
        "inference     : {} frames in {} | {} activation traffic",
        report.cycles,
        fmt_duration(t0.elapsed()),
        fmt_bytes(report.data_bytes),
    );
    if let Some(err) = report.reference_error {
        println!("numerics      : max |err| vs python reference {err:.3e}");
    }

    let speedup = report.throughput / base.throughput;
    let energy_ratio =
        report.energy_per_node_per_cycle() / base.energy_per_node_per_cycle();
    println!(
        "vs single device: {:.2}x throughput, {:.2}x per-node energy",
        speedup, energy_ratio
    );
    println!(
        "(paper, 8 nodes, ResNet50: +53% throughput, -63% per-node energy)"
    );
    Ok(())
}
