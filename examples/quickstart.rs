//! Quickstart: partition ResNet-50 (tiny profile) across two compute nodes
//! and run a few inference cycles through the DEFER chain.
//!
//! ```text
//! make artifacts             # once: AOT-compile the partitions
//! cargo run --release --example quickstart
//! ```

use defer::config::DeferConfig;
use defer::coordinator::chain::ChainRunner;
use defer::util::{fmt_bytes, fmt_duration};

fn main() -> defer::Result<()> {
    // 1. Configure: tiny-profile ResNet-50, 2 compute nodes, in-process
    //    transport, the paper's recommended codecs (ZFP+LZ4 for tensors,
    //    plain JSON for the architecture).
    let mut cfg = DeferConfig::default();
    cfg.profile = "tiny".into();
    cfg.model = "resnet50".into();
    cfg.nodes = 2;

    // 2. Build the chain: loads the AOT artifacts, spawns a thread per
    //    compute node, runs DEFER's configuration step (architecture +
    //    weights distribution over the wire).
    let runner = ChainRunner::new(cfg)?;
    println!(
        "chain ready: {} partitions, {:.1} MFLOPs total",
        runner.plan().parts.len(),
        runner.plan().total_flops() as f64 / 1e6
    );

    // 3. Run 16 inference cycles through the pipeline.
    let report = runner.run_frames(16)?;

    println!("throughput:   {:.2} cycles/s", report.throughput);
    println!("latency p50:  {}", fmt_duration(report.latency_p50));
    println!(
        "payload:      arch {} | weights {} | data {}",
        fmt_bytes(report.architecture_bytes),
        fmt_bytes(report.weights_bytes),
        fmt_bytes(report.data_bytes)
    );
    println!(
        "energy/node/cycle: {:.6} J",
        report.energy_per_node_per_cycle()
    );
    if let Some(err) = report.reference_error {
        println!("max |err| vs python reference: {err:.3e}");
    }
    Ok(())
}
