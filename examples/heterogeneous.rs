//! Pipeline-balance study — the paper's future-work direction
//! ("heterogeneous model partitions ... for higher inference throughput").
//!
//! Part 1 runs the chain at several node counts, measures each stage's
//! busy time (its compute energy divided by TDP), and reports the
//! pipeline imbalance factor: bottleneck-stage time / mean-stage time.
//! A perfectly balanced chain scores 1.0; the paper's layer-count-
//! balanced partitioner (which the artifacts use) leaves measurable
//! imbalance that heterogeneous FLOPs-aware partitioning would remove —
//! quantified here per node count.
//!
//! Part 2 acts on that imbalance with the topology layer (the SEIFER /
//! placement-paper direction): the cluster gets heterogeneous per-hop
//! links (wifi dispatcher uplink, gigabit inside) and the bottleneck
//! stage is replicated across two round-robin workers, lifting pipeline
//! throughput under deterministic edge-device emulation while results
//! stay in FIFO order.
//!
//! Part 3 retires the hand-picking: `defer::placement` (the arXiv
//! 2210.12219-style planner, `--auto-place` on the CLI) derives the
//! replica counts and per-hop links from stage FLOPs, boundary bytes
//! and a worker budget, and the chain runs the emitted topology
//! unchanged.
//!
//! ```text
//! make artifacts
//! cargo run --release --example heterogeneous [frames]
//! ```

use defer::bench::Table;
use defer::config::DeferConfig;
use defer::coordinator::chain::ChainRunner;
use defer::netem::LinkSpec;
use defer::runtime::Engine;

fn main() -> defer::Result<()> {
    let frames: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let engine = Engine::cpu()?;

    let mut table = Table::new(&[
        "nodes",
        "throughput (cycles/s)",
        "imbalance (max/mean stage busy)",
        "bottleneck stage",
        "stage busy times (ms/frame)",
    ]);

    for nodes in [2usize, 4, 6, 8] {
        let mut cfg = DeferConfig::default();
        cfg.profile = "tiny".into();
        cfg.model = "resnet50".into();
        cfg.nodes = nodes;
        // tiny artifacts only ship 1/2/4-way plans; 6/8 exist in edge.
        if nodes > 4 {
            cfg.profile = "edge".into();
        }
        let runner = match ChainRunner::with_engine(cfg, engine.clone()) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("skipping {nodes} nodes: {e}");
                continue;
            }
        };
        let report = runner.run_frames(frames)?;
        let tdp = defer::energy::DEFAULT_TDP_WATTS;
        let busy_ms: Vec<f64> = report
            .node_energy
            .iter()
            .map(|e| e.compute_j / tdp / frames as f64 * 1e3)
            .collect();
        let mean = busy_ms.iter().sum::<f64>() / busy_ms.len() as f64;
        let (bottleneck, max) = busy_ms
            .iter()
            .enumerate()
            .fold((0usize, 0.0f64), |acc, (i, v)| {
                if *v > acc.1 {
                    (i, *v)
                } else {
                    acc
                }
            });
        table.row(&[
            nodes.to_string(),
            format!("{:.3}", report.throughput),
            format!("{:.2}", max / mean.max(1e-9)),
            format!("p{bottleneck}"),
            busy_ms
                .iter()
                .map(|v| format!("{v:.1}"))
                .collect::<Vec<_>>()
                .join(" "),
        ]);
    }
    print!("{}", table.render());
    println!();
    println!("imbalance > 1 quantifies the headroom the paper's future-work");
    println!("heterogeneous partitioning would recover (throughput is set by");
    println!("the bottleneck stage in a FIFO pipeline).");

    // ---- Part 2: replicate the bottleneck over heterogeneous links ----
    println!();
    println!("== replicating the bottleneck stage (wifi uplink, gigabit cluster) ==");
    let stages = 4usize;
    let mut base = DeferConfig::default();
    base.profile = "tiny".into();
    base.model = "resnet50".into();
    base.nodes = stages;
    // Wifi from the dispatcher into the cluster, gigabit between stages
    // and on the return link.
    let mut links = vec![LinkSpec::gigabit_lan(); stages + 1];
    links[0] = LinkSpec::wifi();
    base.per_hop_links = links;
    // Deterministic edge-device emulation: stage time is a constant of
    // the plan, so the replication speedup is reproducible.
    base.emulated_mflops = 50.0;

    let uniform = match ChainRunner::with_engine(base.clone(), engine.clone()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("skipping part 2: {e}");
            return Ok(());
        }
    };
    // The FIFO pipeline's rate is set by the stage with the most FLOPs.
    let (bottleneck, _) = uniform
        .plan()
        .parts
        .iter()
        .enumerate()
        .fold((0usize, 0u64), |acc, (i, p)| {
            if p.flops > acc.1 {
                (i, p.flops)
            } else {
                acc
            }
        });
    let r_uni = uniform.run_frames(frames)?;

    let mut replicated = base.clone();
    replicated.replicas = vec![1; stages];
    replicated.replicas[bottleneck] = 2;
    let r_rep =
        ChainRunner::with_engine(replicated, engine.clone())?.run_frames(frames)?;

    println!(
        "uniform chain      : {:.3} cycles/s ({} workers)",
        r_uni.throughput, r_uni.workers
    );
    println!(
        "stage p{bottleneck} replicated x2: {:.3} cycles/s ({} workers, {:+.0}%)",
        r_rep.throughput,
        r_rep.workers,
        (r_rep.throughput / r_uni.throughput - 1.0) * 100.0
    );
    if let Some(err) = r_rep.reference_error {
        println!("max |err| vs reference (order preserved): {err:.3e}");
    }

    // ---- Part 3: let the placement planner choose the topology ----
    // `--auto-place` in example form: instead of hand-picking which
    // stage to replicate, hand the planner the stage costs (FLOPs +
    // boundary bytes, already in the partition plan), the device model
    // (here: the same 50 MFLOP/s emulated edge devices) and a worker
    // budget, and run whatever Topology it emits.
    println!();
    println!("== auto-placement (planner chooses replicas + links) ==");
    let mut auto = base;
    auto.auto_place = true;
    auto.workers_budget = stages + 2;
    let runner = ChainRunner::with_engine(auto.clone(), engine)?;
    let problem = defer::placement::PlacementProblem::from_config(&auto, runner.plan())?;
    let placed = defer::placement::plan(&problem)?;
    print!("{}", placed.render());
    let r_auto = runner.run_frames(frames)?;
    println!(
        "planned topology   : {:.3} cycles/s ({} workers, {:+.0}% vs uniform)",
        r_auto.throughput,
        r_auto.workers,
        (r_auto.throughput / r_uni.throughput - 1.0) * 100.0
    );
    Ok(())
}
