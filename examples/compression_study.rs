//! Compression/serialization study on live chain traffic — the workload
//! behind the paper's Tables I and II, runnable as one binary.
//!
//! Sweeps {JSON, ZFP} x {LZ4, Uncompressed} over the weights and data
//! sockets of a ResNet-50 / 4-node chain and prints payload, overhead,
//! energy, and end-to-end throughput per configuration.
//!
//! ```text
//! make artifacts
//! cargo run --release --example compression_study [frames]
//! ```

use defer::bench::Table;
use defer::config::DeferConfig;
use defer::coordinator::chain::ChainRunner;
use defer::energy::EnergyModel;
use defer::runtime::Engine;
use defer::serial::Codec;
use defer::util::{fmt_bytes, fmt_duration};

fn main() -> defer::Result<()> {
    let frames: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let engine = Engine::cpu()?;
    let energy = EnergyModel::default();

    let mut table = Table::new(&[
        "Serialization",
        "Compression",
        "Throughput (cycles/s)",
        "Weights payload",
        "Data payload",
        "Overhead",
        "Codec energy (J)",
    ]);

    for codec in Codec::paper_sweep() {
        let mut cfg = DeferConfig::default();
        cfg.profile = "edge".into();
        cfg.model = "resnet50".into();
        cfg.nodes = 4;
        // Paper regime: communication-bound 100 Mbit links + edge devices.
        cfg.link = defer::netem::LinkSpec::fast_edge();
        cfg.emulated_mflops = 400.0;
        cfg.codecs.weights = codec;
        cfg.codecs.data = codec;
        let report = ChainRunner::with_engine(cfg, engine.clone())?.run_frames(frames)?;
        let overhead = report.config_overhead + report.data_overhead;
        table.row(&[
            codec.serialization.name().into(),
            codec.compression.name().into(),
            format!("{:.3}", report.throughput),
            fmt_bytes(report.weights_bytes),
            fmt_bytes(report.data_bytes),
            fmt_duration(overhead),
            format!("{:.5}", energy.compute_energy(overhead)),
        ]);
    }
    print!("{}", table.render());
    println!();
    println!("Paper Table II (ResNet50, 4 nodes): JSON+LZ4 0.477, JSON 0.493,");
    println!("ZFP+LZ4 0.673, ZFP 0.5 cycles/s — ZFP+LZ4 wins on throughput;");
    println!("compare the ranking above (absolute numbers differ by testbed).");
    Ok(())
}
