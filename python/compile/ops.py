"""L2 primitive layer ops: shape inference, parameter init, apply.

Every FLOP-heavy op bottoms out in the L1 Pallas kernels:
- conv2d  -> im2col patches (pure data movement, XLA fuses it) -> Pallas
             fused matmul(+bias)(+ReLU)
- dense   -> Pallas fused matmul(+bias)(+ReLU)
- bn      -> Pallas fused scale/shift(+ReLU) (inference-folded batch norm)
- addrelu -> Pallas fused residual add(+ReLU)

Data layout is NHWC throughout (TPU-native). All tensors f32.

Each op defines three functions dispatched by name:
  infer_<op>(attrs, in_shapes)            -> out_shape
  init_<op>(attrs, in_shapes, key)        -> {param_name: array}  (ordered)
  apply_<op>(attrs, params, xs)           -> array
plus ``flops_<op>`` used by the FLOPs-balancing partitioner.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import elementwise, matmul

Shape = tuple[int, ...]
Attrs = dict[str, Any]

# ---------------------------------------------------------------- input


def infer_input(attrs: Attrs, in_shapes: list[Shape]) -> Shape:
    return tuple(attrs["shape"])


def init_input(attrs, in_shapes, key):
    return {}


def apply_input(attrs, params, xs):
    raise RuntimeError("input nodes are never applied")


def flops_input(attrs, in_shapes) -> int:
    return 0


# ---------------------------------------------------------------- conv2d


def _conv_out_hw(h: int, w: int, kh: int, kw: int, stride: int, padding: str):
    if padding == "same":
        oh = math.ceil(h / stride)
        ow = math.ceil(w / stride)
    elif padding == "valid":
        oh = (h - kh) // stride + 1
        ow = (w - kw) // stride + 1
    else:
        raise ValueError(f"bad padding {padding!r}")
    return oh, ow


def infer_conv(attrs: Attrs, in_shapes: list[Shape]) -> Shape:
    (n, h, w, c) = in_shapes[0]
    kh, kw = attrs["kernel"]
    oh, ow = _conv_out_hw(h, w, kh, kw, attrs["stride"], attrs["padding"])
    return (n, oh, ow, attrs["filters"])


def init_conv(attrs: Attrs, in_shapes: list[Shape], key) -> dict[str, jax.Array]:
    (_, _, _, c) = in_shapes[0]
    kh, kw = attrs["kernel"]
    f = attrs["filters"]
    fan_in = kh * kw * c
    k1, _ = jax.random.split(key)
    w = jax.random.normal(k1, (fan_in, f), jnp.float32) * jnp.sqrt(2.0 / fan_in)
    b = jnp.zeros((f,), jnp.float32)
    return {"w": w, "b": b}


def apply_conv(attrs: Attrs, params, xs) -> jax.Array:
    (x,) = xs
    n, h, w_, c = x.shape
    kh, kw = attrs["kernel"]
    stride = attrs["stride"]
    padding = attrs["padding"].upper()
    f = attrs["filters"]
    # im2col: [N, OH, OW, C*KH*KW] patch tensor — pure data movement.
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    _, oh, ow, patch_dim = patches.shape
    flat = patches.reshape(n * oh * ow, patch_dim)
    # conv_general_dilated_patches yields features ordered (C, KH, KW)-major;
    # our weights are stored [C*KH*KW, F] in exactly that order, so the
    # matmul below is the convolution (verified against lax.conv in tests).
    act = attrs.get("activation", "none")
    out = matmul.matmul_bias_act(flat, params["w"], params["b"], activation=act)
    return out.reshape(n, oh, ow, f)


def flops_conv(attrs: Attrs, in_shapes: list[Shape]) -> int:
    (n, h, w, c) = in_shapes[0]
    kh, kw = attrs["kernel"]
    oh, ow = _conv_out_hw(h, w, kh, kw, attrs["stride"], attrs["padding"])
    return 2 * n * oh * ow * kh * kw * c * attrs["filters"]


# ---------------------------------------------------------------- dense


def infer_dense(attrs, in_shapes):
    (n, d) = in_shapes[0]
    return (n, attrs["units"])


def init_dense(attrs, in_shapes, key):
    (_, d) = in_shapes[0]
    u = attrs["units"]
    k1, _ = jax.random.split(key)
    w = jax.random.normal(k1, (d, u), jnp.float32) * jnp.sqrt(2.0 / d)
    b = jnp.zeros((u,), jnp.float32)
    return {"w": w, "b": b}


def apply_dense(attrs, params, xs):
    (x,) = xs
    act = attrs.get("activation", "none")
    return matmul.matmul_bias_act(x, params["w"], params["b"], activation=act)


def flops_dense(attrs, in_shapes):
    (n, d) = in_shapes[0]
    return 2 * n * d * attrs["units"]


# ---------------------------------------------------------------- bn (inference-folded)


def infer_bn(attrs, in_shapes):
    return in_shapes[0]


def init_bn(attrs, in_shapes, key):
    c = in_shapes[0][-1]
    k1, k2 = jax.random.split(key)
    # Folded inference BN: y = x * scale + shift. Seeded non-trivial values
    # so tests catch mis-wiring (identity scale would mask bugs).
    scale = 1.0 + 0.1 * jax.random.normal(k1, (c,), jnp.float32)
    shift = 0.1 * jax.random.normal(k2, (c,), jnp.float32)
    return {"scale": scale, "shift": shift}


def apply_bn(attrs, params, xs):
    (x,) = xs
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    act = attrs.get("activation", "none")
    out = elementwise.scale_shift_act(
        flat, params["scale"], params["shift"], activation=act
    )
    return out.reshape(shape)


def flops_bn(attrs, in_shapes):
    return 2 * math.prod(in_shapes[0])


# ---------------------------------------------------------------- relu


def infer_relu(attrs, in_shapes):
    return in_shapes[0]


def init_relu(attrs, in_shapes, key):
    return {}


def apply_relu(attrs, params, xs):
    (x,) = xs
    return jnp.maximum(x, 0.0)


def flops_relu(attrs, in_shapes):
    return math.prod(in_shapes[0])


# ---------------------------------------------------------------- add / addrelu (residual merge)


def infer_add(attrs, in_shapes):
    a, b = in_shapes
    if a != b:
        raise ValueError(f"add shape mismatch {a} vs {b}")
    return a


def init_add(attrs, in_shapes, key):
    return {}


def apply_add(attrs, params, xs):
    a, b = xs
    shape = a.shape
    act = attrs.get("activation", "none")
    out = elementwise.add_act(
        a.reshape(-1, shape[-1]), b.reshape(-1, shape[-1]), activation=act
    )
    return out.reshape(shape)


def flops_add(attrs, in_shapes):
    return math.prod(in_shapes[0])


# ---------------------------------------------------------------- maxpool


def infer_maxpool(attrs, in_shapes):
    (n, h, w, c) = in_shapes[0]
    k = attrs["pool"]
    s = attrs.get("stride", k)
    return (n, (h - k) // s + 1, (w - k) // s + 1, c)


def init_maxpool(attrs, in_shapes, key):
    return {}


def apply_maxpool(attrs, params, xs):
    (x,) = xs
    k = attrs["pool"]
    s = attrs.get("stride", k)
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, s, s, 1), "VALID"
    )


def flops_maxpool(attrs, in_shapes):
    return math.prod(in_shapes[0])


# ---------------------------------------------------------------- global average pool


def infer_gap(attrs, in_shapes):
    (n, h, w, c) = in_shapes[0]
    return (n, c)


def init_gap(attrs, in_shapes, key):
    return {}


def apply_gap(attrs, params, xs):
    (x,) = xs
    return jnp.mean(x, axis=(1, 2))


def flops_gap(attrs, in_shapes):
    return math.prod(in_shapes[0])


# ---------------------------------------------------------------- flatten


def infer_flatten(attrs, in_shapes):
    s = in_shapes[0]
    return (s[0], math.prod(s[1:]))


def init_flatten(attrs, in_shapes, key):
    return {}


def apply_flatten(attrs, params, xs):
    (x,) = xs
    return x.reshape(x.shape[0], -1)


def flops_flatten(attrs, in_shapes):
    return 0


# ---------------------------------------------------------------- dispatch

_OPS = (
    "input",
    "conv",
    "dense",
    "bn",
    "relu",
    "add",
    "maxpool",
    "gap",
    "flatten",
)


def _dispatch(prefix: str, op: str):
    if op not in _OPS:
        raise ValueError(f"unknown op {op!r}")
    return globals()[f"{prefix}_{op}"]


def infer_shape(op: str, attrs: Attrs, in_shapes: list[Shape]) -> Shape:
    return tuple(_dispatch("infer", op)(attrs, in_shapes))


def init_params(op: str, attrs: Attrs, in_shapes: list[Shape], key) -> dict[str, jax.Array]:
    return _dispatch("init", op)(attrs, in_shapes, key)


def apply_op(op: str, attrs: Attrs, params: dict[str, jax.Array], xs: list[jax.Array]) -> jax.Array:
    return _dispatch("apply", op)(attrs, params, xs)


def flops(op: str, attrs: Attrs, in_shapes: list[Shape]) -> int:
    return int(_dispatch("flops", op)(attrs, in_shapes))
