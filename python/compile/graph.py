"""Layer-DAG representation + traversal, mirroring DEFER's Keras-DAG walk.

The paper partitions a Keras model by traversing its layer DAG and emitting
a new DAG per partition. We keep the same structure: a ``Graph`` is an
insertion-ordered (and therefore topologically ordered, enforced at add
time) set of named ``Node``s, each naming its input nodes. The partitioner
(``partitioner.py``) cuts the graph at *single-tensor frontier* points —
topological prefixes whose edge cut to the suffix is exactly one activation
tensor — which is precisely the set of places a sequential DEFER chain can
be split.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any


@dataclass
class Node:
    """One layer in the DAG."""

    name: str
    op: str
    attrs: dict[str, Any] = field(default_factory=dict)
    inputs: list[str] = field(default_factory=list)


class Graph:
    """Insertion-ordered layer DAG with a single input and single output."""

    def __init__(self, name: str):
        self.name = name
        self.nodes: dict[str, Node] = {}
        self.output: str | None = None

    def add(self, name: str, op: str, inputs: list[str] | None = None, **attrs) -> str:
        if name in self.nodes:
            raise ValueError(f"duplicate node {name!r}")
        inputs = list(inputs or [])
        for inp in inputs:
            if inp not in self.nodes:
                raise ValueError(
                    f"node {name!r} references unknown input {inp!r} "
                    "(nodes must be added in topological order)"
                )
        self.nodes[name] = Node(name=name, op=op, attrs=dict(attrs), inputs=inputs)
        self.output = name
        return name

    @property
    def order(self) -> list[str]:
        """Topological order (== insertion order, by construction)."""
        return list(self.nodes)

    @property
    def input_name(self) -> str:
        first = next(iter(self.nodes.values()))
        if first.op != "input":
            raise ValueError("graph does not start with an input node")
        return first.name

    def consumers(self) -> dict[str, list[str]]:
        out: dict[str, list[str]] = {n: [] for n in self.nodes}
        for node in self.nodes.values():
            for inp in node.inputs:
                out[inp].append(node.name)
        return out

    def cut_points(self) -> list[int]:
        """Indices ``i`` (1 <= i < len) such that splitting the topological
        order into ``order[:i]`` / ``order[i:]`` crosses exactly ONE tensor:
        the output of ``order[i-1]``.

        These are the valid DEFER chain boundaries: the predecessor partition
        ships a single activation to the successor. For plain-sequential
        models (VGG) every boundary qualifies; for ResNet only the points
        between residual blocks qualify.
        """
        order = self.order
        index = {n: i for i, n in enumerate(order)}
        cuts: list[int] = []
        for i in range(1, len(order)):
            crossing: set[str] = set()
            for suffix_name in order[i:]:
                for inp in self.nodes[suffix_name].inputs:
                    if index[inp] < i:
                        crossing.add(inp)
            if crossing == {order[i - 1]}:
                cuts.append(i)
        return cuts

    def subgraph(
        self, start: int, end: int, input_shape: tuple[int, ...] | None = None
    ) -> "Graph":
        """Extract ``order[start:end]`` as a standalone graph.

        ``start`` must be 0 or a valid cut point; the boundary activation
        becomes the new graph's input node with shape ``input_shape``.
        """
        order = self.order
        sub = Graph(f"{self.name}[{start}:{end}]")
        if start == 0:
            mapping: dict[str, str] = {}
        else:
            if input_shape is None:
                raise ValueError("input_shape required when start > 0")
            boundary = order[start - 1]
            # Unique name: must not collide with the original graph's
            # "input" node, or severed-edge detection silently passes.
            sub.add("_boundary_input", "input", shape=tuple(input_shape))
            mapping = {boundary: "_boundary_input"}
        for name in order[start:end]:
            node = self.nodes[name]
            if node.op == "input":
                sub.add(name, "input", **node.attrs)
                continue
            inputs = [mapping.get(i, i) for i in node.inputs]
            for inp in inputs:
                if inp not in sub.nodes:
                    raise ValueError(
                        f"subgraph [{start}:{end}) severs edge {inp} -> {name}; "
                        "start is not a valid cut point"
                    )
            sub.add(name, node.op, inputs, **node.attrs)
        return sub

    def validate(self) -> None:
        """Cheap structural invariants used by tests."""
        if not self.nodes:
            raise ValueError("empty graph")
        order = self.order
        if self.nodes[order[0]].op != "input":
            raise ValueError("first node must be the input")
        for i, name in enumerate(order):
            node = self.nodes[name]
            if node.op == "input":
                if i != 0:
                    raise ValueError("interior input node")
                continue
            if not node.inputs:
                raise ValueError(f"non-input node {name!r} has no inputs")
        sinks = [n for n, cs in self.consumers().items() if not cs]
        if sinks != [self.output]:
            raise ValueError(f"graph must have exactly one sink, got {sinks}")
