"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is pinned against the function of the same
name here, by python/tests/test_kernel.py, before it is allowed into an AOT
artifact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _act(x: jax.Array, activation: str) -> jax.Array:
    if activation == "relu":
        return jnp.maximum(x, 0.0)
    if activation == "none":
        return x
    raise ValueError(f"unknown activation {activation!r}")


def matmul_bias_act(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array | None = None,
    *,
    activation: str = "none",
) -> jax.Array:
    out = jnp.matmul(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return _act(out, activation)


def scale_shift_act(
    x: jax.Array,
    scale: jax.Array,
    shift: jax.Array,
    *,
    activation: str = "none",
) -> jax.Array:
    return _act(x.astype(jnp.float32) * scale + shift, activation)


def add_act(a: jax.Array, b: jax.Array, *, activation: str = "none") -> jax.Array:
    return _act(a.astype(jnp.float32) + b.astype(jnp.float32), activation)
