"""L1 Pallas kernels: fused elementwise epilogues.

Two kernels used by the L2 layer library:

- ``scale_shift_act``: inference-mode batch-norm folded to ``y = x*s + t``
  with optional fused ReLU. ResNet50's BN layers become this after folding
  (see ``python/compile/ops.py``).
- ``add_act``: residual merge ``y = act(a + b)`` for ResNet shortcut joins.

Both are row-blocked so the channel vector (scale/shift) stays resident in
VMEM while row tiles stream through — the TPU analogue of keeping the
per-channel constants in GPU shared memory. interpret=True as everywhere
(see matmul.py docstring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def _scale_shift_kernel(x_ref, s_ref, t_ref, o_ref, *, activation: str):
    y = x_ref[...] * s_ref[...] + t_ref[...]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


def _add_kernel(a_ref, b_ref, o_ref, *, activation: str):
    y = a_ref[...] + b_ref[...]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


def _row_pad(x: jax.Array, block_rows: int) -> jax.Array:
    rem = (-x.shape[0]) % block_rows
    if rem == 0:
        return x
    return jnp.pad(x, ((0, rem), (0, 0)))


@functools.partial(jax.jit, static_argnames=("activation", "block_rows"))
def scale_shift_act(
    x: jax.Array,
    scale: jax.Array,
    shift: jax.Array,
    *,
    activation: str = "none",
    block_rows: int = DEFAULT_BLOCK_ROWS,
) -> jax.Array:
    """``act(x * scale + shift)`` — x: [M, C], scale/shift: [C]."""
    if x.ndim != 2:
        raise ValueError(f"expected 2-D input, got {x.shape}")
    m, c = x.shape
    if scale.shape != (c,) or shift.shape != (c,):
        raise ValueError(
            f"scale/shift must be [{c}], got {scale.shape}/{shift.shape}"
        )
    br = min(block_rows, max(1, m))
    xp = _row_pad(x.astype(jnp.float32), br)
    grid = (xp.shape[0] // br,)
    out = pl.pallas_call(
        functools.partial(_scale_shift_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float32),
        interpret=True,
    )(xp, scale.reshape(1, c).astype(jnp.float32), shift.reshape(1, c).astype(jnp.float32))
    return out[:m]


@functools.partial(jax.jit, static_argnames=("activation", "block_rows"))
def add_act(
    a: jax.Array,
    b: jax.Array,
    *,
    activation: str = "none",
    block_rows: int = DEFAULT_BLOCK_ROWS,
) -> jax.Array:
    """``act(a + b)`` — a, b: [M, C] (residual merge)."""
    if a.shape != b.shape or a.ndim != 2:
        raise ValueError(f"expected matching 2-D inputs, got {a.shape}/{b.shape}")
    m, c = a.shape
    br = min(block_rows, max(1, m))
    ap = _row_pad(a.astype(jnp.float32), br)
    bp = _row_pad(b.astype(jnp.float32), br)
    grid = (ap.shape[0] // br,)
    out = pl.pallas_call(
        functools.partial(_add_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(ap.shape, jnp.float32),
        interpret=True,
    )(ap, bp)
    return out[:m]
