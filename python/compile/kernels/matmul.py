"""L1 Pallas kernel: blocked fused matmul (+bias) (+ReLU).

This is the compute hot-spot of DEFER's partitions: every convolution is
lowered to im2col patches (L2) feeding this kernel, and every dense layer
calls it directly.

TPU adaptation (see DESIGN.md §Hardware-Adaptation): the kernel is tiled for
a (128, 128) MXU-friendly block shape with accumulation kept resident in the
output VMEM block across the K grid dimension (the out BlockSpec index map
ignores `k`, so the same block is revisited for every K step — the Pallas
revisiting guarantee). Bias add and ReLU are fused into the epilogue on the
last K step so activations never round-trip HBM between matmul and
activation.

Lowered with ``interpret=True`` everywhere: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret mode lowers the kernel to plain HLO that
any backend executes. Correctness is pinned against ``ref.py`` by
``python/tests/test_kernel.py`` (hypothesis sweeps shapes/dtypes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-oriented tile. f32 on CPU-interpret uses the same shapes; on a
# real TPU these would be the bf16 systolic-array native tiles.
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 128

VALID_ACTIVATIONS = ("none", "relu")


def _matmul_kernel(x_ref, w_ref, *rest, nk: int, has_bias: bool, activation: str):
    """Grid = (M/bm, N/bn, K/bk); K is the minor (sequential) dimension."""
    if has_bias:
        b_ref, o_ref = rest
    else:
        (o_ref,) = rest

    @pl.when(pl.program_id(2) == 0)
    def _zero_acc():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        acc = o_ref[...]
        if has_bias:
            acc = acc + b_ref[...]
        if activation == "relu":
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc


def _pad_to(x: jax.Array, multiples: tuple[int, ...]) -> jax.Array:
    pads = []
    for dim, mult in zip(x.shape, multiples):
        rem = (-dim) % mult
        pads.append((0, rem))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_m", "block_n", "block_k"),
)
def matmul_bias_act(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array | None = None,
    *,
    activation: str = "none",
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """``act(x @ w + bias)`` via the blocked Pallas kernel.

    x: [M, K] f32, w: [K, N] f32, bias: [N] f32 or None.
    Shapes that do not divide the block sizes are zero-padded (zero K padding
    is exact for matmul; M/N padding is sliced off the result).
    """
    if activation not in VALID_ACTIVATIONS:
        raise ValueError(f"activation must be one of {VALID_ACTIVATIONS}")
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"expected 2-D operands, got {x.shape} @ {w.shape}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    if bias is not None and bias.shape != (n,):
        raise ValueError(f"bias shape {bias.shape} != ({n},)")

    bm = min(block_m, max(8, m))
    bn = min(block_n, max(8, n))
    bk = min(block_k, max(8, k))

    xp = _pad_to(x.astype(jnp.float32), (bm, bk))
    wp = _pad_to(w.astype(jnp.float32), (bk, bn))
    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    inputs = [xp, wp]
    if bias is not None:
        bp = _pad_to(bias.astype(jnp.float32).reshape(1, n), (1, bn))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        inputs.append(bp)

    kernel = functools.partial(
        _matmul_kernel,
        nk=grid[2],
        has_bias=bias is not None,
        activation=activation,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(*inputs)
    return out[:m, :n]


def vmem_footprint_bytes(
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    has_bias: bool = True,
    dtype_bytes: int = 4,
) -> int:
    """Estimated VMEM residency for one grid step (operand tiles + out tile).

    Used by the §Perf analysis — interpret mode gives no hardware signal, so
    block-shape tuning is driven by this estimate + MXU utilization.
    """
    tiles = block_m * block_k + block_k * block_n + block_m * block_n
    if has_bias:
        tiles += block_n
    return tiles * dtype_bytes


def mxu_utilization_estimate(
    m: int,
    n: int,
    k: int,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    mxu: int = 128,
) -> float:
    """Fraction of MXU lanes doing useful work, accounting for padding.

    A (128x128) systolic array is fully utilized only when the padded tile
    is a multiple of the MXU edge; ragged edges waste lanes.
    """

    def _eff(dim: int, block: int) -> float:
        b = min(block, max(8, dim))
        padded = ((dim + b - 1) // b) * b
        hw = ((padded + mxu - 1) // mxu) * mxu if padded % mxu else padded
        return dim / max(hw, 1)

    return _eff(m, block_m) * _eff(n, block_n) * _eff(k, block_k)
