"""Model partitioner: split a layer DAG into N sequential sub-networks.

Implements the paper's Model Partitioning Step (§III-A): traverse the DAG,
pick N-1 cut points, and emit one sub-graph per partition such that the
chain  dispatcher -> p0 -> p1 -> ... -> p{N-1} -> dispatcher  computes the
original model exactly (bit-identical up to XLA scheduling).

Two balancing strategies:
- ``layers``: equalize layer counts per partition (what the paper describes:
  "partitioning layers were selected based on what would split the model up
  into a similar number of layers for each partition").
- ``flops``:  equalize estimated FLOPs per partition (better pipeline
  balance; used by the heterogeneous-nodes extension, examples/heterogeneous).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax

from . import ops
from .graph import Graph


@dataclass
class Partition:
    """One chain stage: a sub-graph plus its boundary shapes + param manifest."""

    index: int
    count: int
    graph: Graph
    input_shape: tuple[int, ...]
    output_shape: tuple[int, ...]
    # (node_name, param_name, shape) in deterministic apply order
    weight_manifest: list[tuple[str, str, tuple[int, ...]]] = field(default_factory=list)
    flops: int = 0
    layer_names: list[str] = field(default_factory=list)


def shape_map(g: Graph) -> dict[str, tuple[int, ...]]:
    """Forward shape inference over the DAG."""
    shapes: dict[str, tuple[int, ...]] = {}
    for name in g.order:
        node = g.nodes[name]
        in_shapes = [shapes[i] for i in node.inputs]
        shapes[name] = ops.infer_shape(node.op, node.attrs, in_shapes)
    return shapes


def graph_flops(g: Graph) -> dict[str, int]:
    shapes = shape_map(g)
    out: dict[str, int] = {}
    for name in g.order:
        node = g.nodes[name]
        in_shapes = [shapes[i] for i in node.inputs]
        out[name] = ops.flops(node.op, node.attrs, in_shapes)
    return out


def init_graph_params(g: Graph, seed: int = 0) -> dict[str, dict[str, jax.Array]]:
    """Deterministic (seeded) parameter init for every node, keyed by name.

    The fold-in by position keeps parameters identical regardless of how the
    graph is later partitioned — crucial for chain == single-device
    equivalence tests.
    """
    shapes = shape_map(g)
    key = jax.random.PRNGKey(seed)
    params: dict[str, dict[str, jax.Array]] = {}
    for pos, name in enumerate(g.order):
        node = g.nodes[name]
        in_shapes = [shapes[i] for i in node.inputs]
        node_key = jax.random.fold_in(key, pos)
        p = ops.init_params(node.op, node.attrs, in_shapes, node_key)
        if p:
            params[name] = p
    return params


def apply_graph(
    g: Graph,
    params: dict[str, dict[str, jax.Array]],
    x: jax.Array,
) -> jax.Array:
    """Execute the DAG with an activation cache (the paper's inference walk)."""
    acts: dict[str, jax.Array] = {g.input_name: x}
    for name in g.order:
        node = g.nodes[name]
        if node.op == "input":
            continue
        xs = [acts[i] for i in node.inputs]
        acts[name] = ops.apply_op(node.op, node.attrs, params.get(name, {}), xs)
        # Free activations with no remaining consumers? Build-time only; skip.
    return acts[g.output]


def choose_cuts(g: Graph, n_parts: int, strategy: str = "layers") -> list[int]:
    """Pick ``n_parts - 1`` cut indices from ``g.cut_points()``.

    Greedy walk: aim each boundary at the ideal cumulative weight
    (layers or FLOPs) and take the closest available cut point.
    """
    if n_parts < 1:
        raise ValueError("n_parts must be >= 1")
    if n_parts == 1:
        return []
    cuts_avail = g.cut_points()
    if len(cuts_avail) < n_parts - 1:
        raise ValueError(
            f"{g.name}: only {len(cuts_avail)} cut points; cannot make {n_parts} partitions"
        )
    order = g.order
    if strategy == "layers":
        weights = {name: 1.0 for name in order}
    elif strategy == "flops":
        fl = graph_flops(g)
        # Floor at 1 so zero-FLOP layers still carry positional weight.
        weights = {name: float(max(fl[name], 1)) for name in order}
    else:
        raise ValueError(f"unknown strategy {strategy!r}")

    prefix = []
    total = 0.0
    for name in order:
        total += weights[name]
        prefix.append(total)

    chosen: list[int] = []
    remaining = sorted(cuts_avail)
    for part in range(1, n_parts):
        target = total * part / n_parts
        # Candidates strictly after the previous cut, leaving enough cut
        # points for the partitions still to come.
        lo = chosen[-1] if chosen else 0
        cands = [c for c in remaining if c > lo]
        needed_after = n_parts - 1 - part
        if needed_after:
            cands = cands[: len(cands) - needed_after] or cands[:1]
        if not cands:
            raise ValueError(f"{g.name}: ran out of cut points at partition {part}")
        best = min(cands, key=lambda c: abs(prefix[c - 1] - target))
        chosen.append(best)
    return chosen


def partition(g: Graph, n_parts: int, strategy: str = "layers") -> list[Partition]:
    """Split ``g`` into ``n_parts`` chain stages."""
    shapes = shape_map(g)
    fl = graph_flops(g)
    cuts = choose_cuts(g, n_parts, strategy)
    bounds = [0] + cuts + [len(g.order)]
    order = g.order
    parts: list[Partition] = []
    for i in range(n_parts):
        start, end = bounds[i], bounds[i + 1]
        in_shape = shapes[g.input_name] if start == 0 else shapes[order[start - 1]]
        sub = g.subgraph(start, end, input_shape=None if start == 0 else in_shape)
        out_shape = shapes[order[end - 1]]
        manifest: list[tuple[str, str, tuple[int, ...]]] = []
        # Weight manifest comes from shape inference (no allocation here).
        sub_shapes = shape_map(sub)
        key = jax.random.PRNGKey(0)  # shapes only; values discarded
        for name in sub.order:
            node = sub.nodes[name]
            if node.op == "input":
                continue
            in_shapes = [sub_shapes[x] for x in node.inputs]
            p = ops.init_params(node.op, node.attrs, in_shapes, key)
            for pname, arr in p.items():
                manifest.append((name, pname, tuple(arr.shape)))
        parts.append(
            Partition(
                index=i,
                count=n_parts,
                graph=sub,
                input_shape=tuple(in_shape),
                output_shape=tuple(out_shape),
                weight_manifest=manifest,
                flops=sum(fl[n] for n in order[start:end]),
                layer_names=list(order[start:end]),
            )
        )
    return parts


def partition_fn(part: Partition):
    """Build ``fn(x, *weights) -> (y,)`` for AOT lowering.

    Weights are *arguments* (HLO parameters), matching DEFER's configuration
    step where the dispatcher ships weights separately from the architecture.
    """
    g = part.graph
    manifest = part.weight_manifest

    def fn(x, *weights):
        if len(weights) != len(manifest):
            raise ValueError(f"expected {len(manifest)} weights, got {len(weights)}")
        params: dict[str, dict[str, jax.Array]] = {}
        for (node, pname, _), w in zip(manifest, weights):
            params.setdefault(node, {})[pname] = w
        return (apply_graph(g, params, x),)

    return fn


def flatten_params(
    part: Partition, params: dict[str, dict[str, jax.Array]]
) -> list[jax.Array]:
    """Order a node->params dict per the partition's weight manifest."""
    out = []
    for node, pname, shape in part.weight_manifest:
        arr = params[node][pname]
        if tuple(arr.shape) != shape:
            raise ValueError(f"{node}.{pname}: shape {arr.shape} != manifest {shape}")
        out.append(arr)
    return out
