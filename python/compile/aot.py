"""AOT lowering: partitions -> HLO text + weights.bin + meta.json.

The compile-path half of the three-layer architecture. Runs once at build
time (``make artifacts``); the Rust coordinator consumes the outputs and
Python never appears on the request path.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (behind
the rust `xla` crate) rejects; the text parser reassigns ids and round-trips
cleanly. Lowered with ``return_tuple=True`` — the rust side unwraps with
``to_tuple1()``.

Per (model, profile, n-parts) the output layout is::

    artifacts/<profile>/<model>/p<i>of<N>.hlo.txt      partition HLO
    artifacts/<profile>/<model>/p<i>of<N>.meta.json    shapes + manifest
    artifacts/<profile>/<model>/p<i>of<N>.weights.bin  raw f32 LE weights
    artifacts/manifest.json                            index of everything

Usage::

    python -m compile.aot --out-dir ../artifacts \
        --profile tiny --models resnet50 --parts 1,2,4
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import models, partitioner

# Artifact sets keyed by profile. "tiny" feeds unit/integration tests;
# "edge" feeds the paper benches (Figs 2-3, Tables I-II); "full" is the
# paper's exact scale, built on demand.
DEFAULT_SETS: dict[str, dict] = {
    "tiny": {"models": ["resnet50", "vgg16"], "parts": [1, 2, 4]},
    "edge": {
        "models": ["resnet50", "vgg16", "vgg19"],
        "parts": [1, 4, 6, 8],
    },
    "full": {"models": ["resnet50"], "parts": [1, 8]},
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_partition(part: partitioner.Partition) -> str:
    fn = partitioner.partition_fn(part)
    x_spec = jax.ShapeDtypeStruct(part.input_shape, jnp.float32)
    w_specs = [
        jax.ShapeDtypeStruct(shape, jnp.float32)
        for (_, _, shape) in part.weight_manifest
    ]
    lowered = jax.jit(fn).lower(x_spec, *w_specs)
    return to_hlo_text(lowered)


def build_artifacts(
    out_dir: str,
    profile: str,
    model_names: list[str],
    part_counts: list[int],
    strategy: str = "layers",
    seed: int = 0,
    verbose: bool = True,
) -> list[dict]:
    """Build every (model, n_parts) artifact for one profile. Returns index rows."""
    rows: list[dict] = []
    for model_name in model_names:
        g = models.build(model_name, profile)
        params = partitioner.init_graph_params(g, seed=seed)
        shapes = partitioner.shape_map(g)
        model_dir = os.path.join(out_dir, profile, model_name)
        os.makedirs(model_dir, exist_ok=True)
        for n in part_counts:
            parts = partitioner.partition(g, n, strategy=strategy)
            for part in parts:
                t0 = time.time()
                stem = f"p{part.index}of{n}"
                hlo_path = os.path.join(model_dir, f"{stem}.hlo.txt")
                meta_path = os.path.join(model_dir, f"{stem}.meta.json")
                weights_path = os.path.join(model_dir, f"{stem}.weights.bin")

                hlo = lower_partition(part)
                with open(hlo_path, "w") as f:
                    f.write(hlo)

                flat = partitioner.flatten_params(part, params)
                raw = b"".join(
                    np.asarray(w, dtype="<f4").tobytes(order="C") for w in flat
                )
                with open(weights_path, "wb") as f:
                    f.write(raw)

                meta = {
                    "model": model_name,
                    "profile": profile,
                    "strategy": strategy,
                    "part_index": part.index,
                    "part_count": n,
                    "input_shape": list(part.input_shape),
                    "output_shape": list(part.output_shape),
                    "flops": part.flops,
                    "layers": part.layer_names,
                    "weights": [
                        {
                            "node": node,
                            "param": pname,
                            "shape": list(shape),
                            "elements": int(np.prod(shape)),
                        }
                        for (node, pname, shape) in part.weight_manifest
                    ],
                    "weights_bytes": len(raw),
                    "weights_sha256": hashlib.sha256(raw).hexdigest(),
                    "hlo_file": os.path.basename(hlo_path),
                    "weights_file": os.path.basename(weights_path),
                }
                with open(meta_path, "w") as f:
                    json.dump(meta, f, indent=1)
                rows.append(
                    {
                        "profile": profile,
                        "model": model_name,
                        "part_index": part.index,
                        "part_count": n,
                        "dir": os.path.relpath(model_dir, out_dir),
                        "stem": stem,
                        "flops": part.flops,
                        "weights_bytes": len(raw),
                        "layers": len(part.layer_names),
                    }
                )
                if verbose:
                    dt = time.time() - t0
                    print(
                        f"[aot] {profile}/{model_name}/{stem}: "
                        f"{len(part.layer_names)} layers, "
                        f"{part.flops/1e6:.1f} MFLOPs, "
                        f"{len(raw)/1e6:.2f} MB weights, "
                        f"{len(hlo)/1e3:.0f} kB HLO ({dt:.1f}s)",
                        flush=True,
                    )

        # Reference input/output for the whole model: the rust integration
        # tests replay this through the chain and require bitwise-close
        # agreement, proving chain == single-device.
        ref_key = jax.random.PRNGKey(seed + 1)
        x = jax.random.normal(ref_key, shapes[g.input_name], jnp.float32)
        y = partitioner.apply_graph(g, params, x)
        np.asarray(x, dtype="<f4").tofile(os.path.join(model_dir, "ref_input.bin"))
        np.asarray(y, dtype="<f4").tofile(os.path.join(model_dir, "ref_output.bin"))
        with open(os.path.join(model_dir, "ref_meta.json"), "w") as f:
            json.dump(
                {
                    "input_shape": list(x.shape),
                    "output_shape": list(np.asarray(y).shape),
                },
                f,
            )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--profile", default="tiny", choices=sorted(models.PROFILES))
    ap.add_argument("--models", default=None, help="comma list; default per profile")
    ap.add_argument("--parts", default=None, help="comma list; default per profile")
    ap.add_argument("--strategy", default="layers", choices=["layers", "flops"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = DEFAULT_SETS[args.profile]
    model_names = args.models.split(",") if args.models else cfg["models"]
    part_counts = (
        [int(p) for p in args.parts.split(",")] if args.parts else cfg["parts"]
    )

    t0 = time.time()
    rows = build_artifacts(
        args.out_dir, args.profile, model_names, part_counts, args.strategy, args.seed
    )

    # Merge into the global manifest.
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest: dict = {"artifacts": []}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
    keep = [
        r
        for r in manifest["artifacts"]
        if not any(
            r["profile"] == n["profile"]
            and r["model"] == n["model"]
            and r["part_count"] == n["part_count"]
            and r["part_index"] == n["part_index"]
            for n in rows
        )
    ]
    manifest["artifacts"] = keep + rows
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(rows)} artifacts in {time.time()-t0:.1f}s -> {args.out_dir}")


if __name__ == "__main__":
    main()
