"""Model zoo: VGG16, VGG19, ResNet50 as layer DAGs (NHWC, inference mode).

Architectures follow the originals (Simonyan & Zisserman 2014; He et al.
2016) structurally — conv stacks / bottleneck residual blocks, same depths,
same stride placement — with two scale knobs used by the reproduction
profiles (see DESIGN.md §Model fidelity):

- ``input_size``:  spatial resolution of the (1, S, S, 3) input
- ``width_mult``:  multiplier on every channel/unit count

``width_mult=1.0, input_size=224`` is the paper's exact configuration.
Batch norm is inference-folded (scale/shift), as a deployed edge pipeline
would run it.
"""

from __future__ import annotations

from .graph import Graph

PROFILES: dict[str, dict] = {
    "tiny": {"input_size": 32, "width_mult": 0.125},
    "edge": {"input_size": 64, "width_mult": 0.25},
    "full": {"input_size": 224, "width_mult": 1.0},
}


def _w(width_mult: float, ch: int) -> int:
    return max(8, int(round(ch * width_mult)))


# ------------------------------------------------------------------ VGG


def _build_vgg(name: str, conv_plan: list[list[int]], input_size: int, width_mult: float) -> Graph:
    g = Graph(name)
    prev = g.add("input", "input", shape=(1, input_size, input_size, 3))
    for bi, block in enumerate(conv_plan, start=1):
        for ci, ch in enumerate(block, start=1):
            prev = g.add(
                f"block{bi}_conv{ci}",
                "conv",
                [prev],
                filters=_w(width_mult, ch),
                kernel=(3, 3),
                stride=1,
                padding="same",
                activation="relu",
            )
        prev = g.add(f"block{bi}_pool", "maxpool", [prev], pool=2, stride=2)
    prev = g.add("flatten", "flatten", [prev])
    for i in (1, 2):
        prev = g.add(
            f"fc{i}",
            "dense",
            [prev],
            units=_w(width_mult, 4096),
            activation="relu",
        )
    g.add("predictions", "dense", [prev], units=_w(width_mult, 1000), activation="none")
    g.validate()
    return g


def build_vgg16(input_size: int = 224, width_mult: float = 1.0) -> Graph:
    plan = [[64, 64], [128, 128], [256, 256, 256], [512, 512, 512], [512, 512, 512]]
    return _build_vgg("vgg16", plan, input_size, width_mult)


def build_vgg19(input_size: int = 224, width_mult: float = 1.0) -> Graph:
    plan = [
        [64, 64],
        [128, 128],
        [256, 256, 256, 256],
        [512, 512, 512, 512],
        [512, 512, 512, 512],
    ]
    return _build_vgg("vgg19", plan, input_size, width_mult)


# ------------------------------------------------------------------ ResNet50


def _bottleneck(
    g: Graph,
    prev: str,
    name: str,
    filters: int,
    stride: int,
    project: bool,
) -> str:
    """He-style bottleneck: 1x1 reduce -> 3x3 -> 1x1 expand, + shortcut."""
    expanded = filters * 4
    shortcut = prev
    if project:
        shortcut = g.add(
            f"{name}_proj_conv",
            "conv",
            [prev],
            filters=expanded,
            kernel=(1, 1),
            stride=stride,
            padding="same",
            activation="none",
        )
        shortcut = g.add(f"{name}_proj_bn", "bn", [shortcut], activation="none")
    x = g.add(
        f"{name}_conv1",
        "conv",
        [prev],
        filters=filters,
        kernel=(1, 1),
        stride=1,
        padding="same",
        activation="none",
    )
    x = g.add(f"{name}_bn1", "bn", [x], activation="relu")
    x = g.add(
        f"{name}_conv2",
        "conv",
        [x],
        filters=filters,
        kernel=(3, 3),
        stride=stride,
        padding="same",
        activation="none",
    )
    x = g.add(f"{name}_bn2", "bn", [x], activation="relu")
    x = g.add(
        f"{name}_conv3",
        "conv",
        [x],
        filters=expanded,
        kernel=(1, 1),
        stride=1,
        padding="same",
        activation="none",
    )
    x = g.add(f"{name}_bn3", "bn", [x], activation="none")
    return g.add(f"{name}_add", "add", [x, shortcut], activation="relu")


def build_resnet50(input_size: int = 224, width_mult: float = 1.0) -> Graph:
    g = Graph("resnet50")
    prev = g.add("input", "input", shape=(1, input_size, input_size, 3))
    prev = g.add(
        "conv1",
        "conv",
        [prev],
        filters=_w(width_mult, 64),
        kernel=(7, 7),
        stride=2,
        padding="same",
        activation="none",
    )
    prev = g.add("conv1_bn", "bn", [prev], activation="relu")
    prev = g.add("pool1", "maxpool", [prev], pool=2, stride=2)

    stage_plan = [  # (blocks, filters, first-stride) — canonical ResNet50
        (3, 64, 1),
        (4, 128, 2),
        (6, 256, 2),
        (3, 512, 2),
    ]
    for si, (blocks, filters, stride) in enumerate(stage_plan, start=2):
        f = _w(width_mult, filters)
        for b in range(1, blocks + 1):
            prev = _bottleneck(
                g,
                prev,
                f"stage{si}_block{b}",
                f,
                stride=stride if b == 1 else 1,
                project=(b == 1),
            )
    prev = g.add("avg_pool", "gap", [prev])
    g.add("predictions", "dense", [prev], units=_w(width_mult, 1000), activation="none")
    g.validate()
    return g


BUILDERS = {
    "vgg16": build_vgg16,
    "vgg19": build_vgg19,
    "resnet50": build_resnet50,
}


def build(model: str, profile: str = "edge") -> Graph:
    if model not in BUILDERS:
        raise ValueError(f"unknown model {model!r}; have {sorted(BUILDERS)}")
    if profile not in PROFILES:
        raise ValueError(f"unknown profile {profile!r}; have {sorted(PROFILES)}")
    return BUILDERS[model](**PROFILES[profile])
