"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, block sizes, bias-presence and activations; the
kernels must match the oracle within blocked-accumulation float tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import elementwise, matmul, ref

TOL = dict(rtol=2e-4, atol=2e-4)


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ------------------------------------------------------------- matmul


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 150),
    k=st.integers(1, 150),
    n=st.integers(1, 150),
    bias=st.booleans(),
    act=st.sampled_from(["none", "relu"]),
)
def test_matmul_matches_ref(m, k, n, bias, act):
    x = _rand(m * 7 + 1, (m, k))
    w = _rand(k * 13 + 2, (k, n))
    b = _rand(n * 17 + 3, (n,)) if bias else None
    got = matmul.matmul_bias_act(x, w, b, activation=act)
    want = ref.matmul_bias_act(x, w, b, activation=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([8, 32, 128]),
    bn=st.sampled_from([8, 32, 128]),
    bk=st.sampled_from([8, 32, 128]),
)
def test_matmul_block_shape_invariance(bm, bn, bk):
    """The result must not depend on the tiling."""
    x = _rand(1, (96, 80))
    w = _rand(2, (80, 72))
    b = _rand(3, (72,))
    got = matmul.matmul_bias_act(
        x, w, b, activation="relu", block_m=bm, block_n=bn, block_k=bk
    )
    want = ref.matmul_bias_act(x, w, b, activation="relu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_matmul_exact_block_multiple():
    x = _rand(4, (256, 128))
    w = _rand(5, (128, 256))
    got = matmul.matmul_bias_act(x, w, None)
    want = ref.matmul_bias_act(x, w, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_matmul_rejects_bad_shapes():
    x = _rand(6, (4, 5))
    w = _rand(7, (6, 3))
    with pytest.raises(Exception):
        matmul.matmul_bias_act(x, w, None)


def test_matmul_rejects_bad_activation():
    x = _rand(8, (4, 4))
    with pytest.raises(Exception):
        matmul.matmul_bias_act(x, x, None, activation="gelu")


def test_matmul_relu_clamps():
    x = -jnp.ones((16, 16), jnp.float32)
    w = jnp.eye(16, dtype=jnp.float32)
    out = matmul.matmul_bias_act(x, w, None, activation="relu")
    assert float(np.asarray(out).max()) == 0.0


# ------------------------------------------------------------- elementwise


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 500),
    c=st.integers(1, 64),
    act=st.sampled_from(["none", "relu"]),
)
def test_scale_shift_matches_ref(m, c, act):
    x = _rand(m + 11, (m, c))
    s = _rand(c + 12, (c,))
    t = _rand(c + 13, (c,))
    got = elementwise.scale_shift_act(x, s, t, activation=act)
    want = ref.scale_shift_act(x, s, t, activation=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 500),
    c=st.integers(1, 64),
    act=st.sampled_from(["none", "relu"]),
)
def test_add_matches_ref(m, c, act):
    a = _rand(m + 21, (m, c))
    b = _rand(m + 22, (m, c))
    got = elementwise.add_act(a, b, activation=act)
    want = ref.add_act(a, b, activation=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_elementwise_shape_errors():
    a = _rand(1, (4, 4))
    b = _rand(2, (5, 4))
    with pytest.raises(Exception):
        elementwise.add_act(a, b)
    with pytest.raises(Exception):
        elementwise.scale_shift_act(a, _rand(3, (5,)), _rand(4, (4,)))


# ------------------------------------------------------------- perf estimators


def test_vmem_footprint_fits_budget():
    """Default tile must fit comfortably in a 16 MiB VMEM."""
    fp = matmul.vmem_footprint_bytes()
    assert fp < 16 * 1024 * 1024 / 4  # <25% of VMEM: double-buffer headroom


def test_mxu_utilization_bounds():
    full = matmul.mxu_utilization_estimate(1024, 1024, 1024)
    ragged = matmul.mxu_utilization_estimate(129, 129, 129)
    tiny = matmul.mxu_utilization_estimate(1, 1, 1)
    assert full == pytest.approx(1.0)
    assert 0.0 < ragged < full
    assert 0.0 < tiny < 0.01
