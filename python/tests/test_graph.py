"""Graph DAG invariants: construction, cut points, subgraph extraction."""

import pytest

from compile.graph import Graph
from compile import models


def _linear_graph(n=5):
    g = Graph("lin")
    prev = g.add("input", "input", shape=(1, 8, 8, 3))
    for i in range(n):
        prev = g.add(f"conv{i}", "conv", [prev], filters=8, kernel=(3, 3), stride=1, padding="same")
    g.validate()
    return g


def test_insertion_requires_topological_order():
    g = Graph("bad")
    g.add("input", "input", shape=(1, 4, 4, 3))
    with pytest.raises(ValueError):
        g.add("a", "relu", ["nonexistent"])


def test_duplicate_node_rejected():
    g = Graph("dup")
    g.add("input", "input", shape=(1, 4, 4, 3))
    with pytest.raises(ValueError):
        g.add("input", "relu", ["input"])


def test_linear_graph_all_boundaries_are_cuts():
    g = _linear_graph(5)
    assert g.cut_points() == [1, 2, 3, 4, 5]


def test_residual_graph_cuts_only_between_blocks():
    g = Graph("res")
    prev = g.add("input", "input", shape=(1, 8, 8, 16))
    a = g.add("conv_a", "conv", [prev], filters=16, kernel=(3, 3), stride=1, padding="same")
    merged = g.add("add", "add", [a, prev])
    g.add("tail", "relu", [merged])
    g.validate()
    # Cutting between conv_a and add would sever the skip edge input->add.
    # Valid cuts: after input (only the input tensor crosses) and after the
    # residual merge.
    assert g.cut_points() == [1, 3]


def test_subgraph_severed_edge_rejected():
    g = Graph("res")
    prev = g.add("input", "input", shape=(1, 8, 8, 16))
    a = g.add("conv_a", "conv", [prev], filters=16, kernel=(3, 3), stride=1, padding="same")
    g.add("add", "add", [a, prev])
    with pytest.raises(ValueError):
        g.subgraph(2, 3, input_shape=(1, 8, 8, 16))


def test_subgraph_prefix_and_suffix():
    g = _linear_graph(4)
    pre = g.subgraph(0, 3)
    pre.validate()
    assert pre.order[0] == "input"
    suf = g.subgraph(3, 5, input_shape=(1, 8, 8, 8))
    suf.validate()
    assert suf.nodes[suf.input_name].attrs["shape"] == (1, 8, 8, 8)
    assert len(suf.order) == 3  # new input + 2 convs


def test_subgraph_requires_shape_for_interior_start():
    g = _linear_graph(3)
    with pytest.raises(ValueError):
        g.subgraph(1, 3)


def test_validate_rejects_multi_sink():
    g = Graph("multi")
    prev = g.add("input", "input", shape=(1, 4, 4, 3))
    g.add("a", "relu", [prev])
    g.add("b", "relu", [prev])
    with pytest.raises(ValueError):
        g.validate()


def test_model_graphs_validate():
    for name in ("vgg16", "vgg19", "resnet50"):
        g = models.build(name, "tiny")
        g.validate()
        assert len(g.cut_points()) >= 7, f"{name} must support 8-way partitioning"
