"""Model zoo structure checks: depths, shapes, profiles."""

import pytest

from compile import models, partitioner


def _count(g, op):
    return sum(1 for n in g.nodes.values() if n.op == op)


def test_vgg16_depth():
    g = models.build("vgg16", "tiny")
    assert _count(g, "conv") == 13
    assert _count(g, "dense") == 3
    assert _count(g, "maxpool") == 5


def test_vgg19_depth():
    g = models.build("vgg19", "tiny")
    assert _count(g, "conv") == 16
    assert _count(g, "dense") == 3


def test_resnet50_depth():
    g = models.build("resnet50", "tiny")
    # 1 stem + 3*3 + 4*3 + 6*3 + 3*3 bottleneck convs + 4 projections = 53
    assert _count(g, "conv") == 53
    assert _count(g, "dense") == 1
    assert _count(g, "add") == 16


@pytest.mark.parametrize("model", ["vgg16", "vgg19", "resnet50"])
@pytest.mark.parametrize("profile", ["tiny", "edge"])
def test_output_is_classifier_head(model, profile):
    g = models.build(model, profile)
    shapes = partitioner.shape_map(g)
    out = shapes[g.output]
    assert len(out) == 2 and out[0] == 1
    cfg = models.PROFILES[profile]
    assert out[1] == max(8, round(1000 * cfg["width_mult"]))


def test_full_profile_matches_paper_scale():
    g = models.build("resnet50", "full")
    shapes = partitioner.shape_map(g)
    assert shapes[g.input_name] == (1, 224, 224, 3)
    assert shapes[g.output] == (1, 1000)
    # ~25.5M params at width 1.0
    n_params = sum(
        e["elements"] if isinstance(e, dict) else 0 for e in []
    )  # placeholder: counted below via manifest
    (p,) = partitioner.partition(g, 1)
    total = sum(
        int(__import__("math").prod(shape)) for (_, _, shape) in p.weight_manifest
    )
    assert 20_000_000 < total < 30_000_000


def test_resnet_flops_dominated_by_conv():
    g = models.build("resnet50", "edge")
    fl = partitioner.graph_flops(g)
    conv_fl = sum(v for k, v in fl.items() if g.nodes[k].op == "conv")
    assert conv_fl > 0.9 * sum(fl.values())


def test_unknown_model_and_profile():
    with pytest.raises(ValueError):
        models.build("alexnet", "tiny")
    with pytest.raises(ValueError):
        models.build("vgg16", "huge")
