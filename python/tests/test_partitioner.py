"""Partitioner invariants: chain equivalence, manifests, balancing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import models, partitioner

TOL = dict(rtol=3e-4, atol=3e-4)


def _run_chain(g, params, parts, x):
    act = x
    for p in parts:
        fn = partitioner.partition_fn(p)
        ws = partitioner.flatten_params(
            p, {n: params[n] for n in p.layer_names if n in params}
        )
        (act,) = fn(act, *ws)
    return act


@pytest.mark.parametrize("model", ["vgg16", "resnet50"])
@pytest.mark.parametrize("n", [1, 2, 4])
def test_chain_equals_single_device(model, n):
    """The headline invariant: DEFER preserves the exact model output."""
    g = models.build(model, "tiny")
    params = partitioner.init_graph_params(g)
    shapes = partitioner.shape_map(g)
    x = jax.random.normal(jax.random.PRNGKey(9), shapes[g.input_name], jnp.float32)
    want = partitioner.apply_graph(g, params, x)
    parts = partitioner.partition(g, n)
    got = _run_chain(g, params, parts, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(1, 8), strategy=st.sampled_from(["layers", "flops"]))
def test_partition_structure_invariants(n, strategy):
    g = models.build("resnet50", "tiny")
    parts = partitioner.partition(g, n, strategy=strategy)
    assert len(parts) == n
    # Partitions tile the layer list exactly, in order.
    names = [nm for p in parts for nm in p.layer_names]
    assert names == g.order
    # Boundary shapes chain.
    for a, b in zip(parts, parts[1:]):
        assert a.output_shape == b.input_shape
    # FLOPs conserved.
    assert sum(p.flops for p in parts) == sum(partitioner.graph_flops(g).values())


def test_flops_strategy_balances_better_than_worst_case():
    g = models.build("resnet50", "tiny")
    parts = partitioner.partition(g, 4, strategy="flops")
    fl = [p.flops for p in parts]
    total = sum(fl)
    assert max(fl) < 0.6 * total, f"flops balancing failed: {fl}"


def test_too_many_partitions_rejected():
    g = models.build("vgg16", "tiny")
    with pytest.raises(ValueError):
        partitioner.partition(g, 100)


def test_weight_manifest_matches_params():
    g = models.build("resnet50", "tiny")
    params = partitioner.init_graph_params(g)
    for p in partitioner.partition(g, 3):
        flat = partitioner.flatten_params(
            p, {n: params[n] for n in p.layer_names if n in params}
        )
        assert len(flat) == len(p.weight_manifest)
        for arr, (_, _, shape) in zip(flat, p.weight_manifest):
            assert tuple(arr.shape) == shape


def test_flatten_params_shape_mismatch_rejected():
    g = models.build("vgg16", "tiny")
    params = partitioner.init_graph_params(g)
    (p,) = partitioner.partition(g, 1)
    bad = {n: dict(v) for n, v in params.items()}
    first = p.weight_manifest[0]
    bad[first[0]][first[1]] = jnp.zeros((1, 1), jnp.float32)
    with pytest.raises(ValueError):
        partitioner.flatten_params(p, bad)


def test_params_independent_of_partitioning():
    """Seeded init must not depend on how the graph is later cut."""
    g1 = models.build("resnet50", "tiny")
    g2 = models.build("resnet50", "tiny")
    p1 = partitioner.init_graph_params(g1, seed=3)
    p2 = partitioner.init_graph_params(g2, seed=3)
    for node in p1:
        for name in p1[node]:
            np.testing.assert_array_equal(
                np.asarray(p1[node][name]), np.asarray(p2[node][name])
            )
