"""L2 op correctness: conv vs lax.conv, pooling, bn, shape/flops inference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import ops


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=12, deadline=None)
@given(
    hw=st.integers(4, 16),
    c=st.integers(1, 8),
    f=st.integers(1, 12),
    k=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["same", "valid"]),
)
def test_conv_matches_lax_conv(hw, c, f, k, stride, padding):
    """Our im2col+Pallas conv == XLA's native convolution."""
    attrs = {
        "filters": f,
        "kernel": (k, k),
        "stride": stride,
        "padding": padding,
        "activation": "none",
    }
    x = _rand(1, (1, hw, hw, c))
    params = ops.init_params("conv", attrs, [x.shape], jax.random.PRNGKey(7))
    got = ops.apply_op("conv", attrs, params, [x])
    # Patch features are (C, KH, KW)-major: w[C*KH*KW, F] -> HWIO.
    w_hwio = params["w"].reshape(c, k, k, f).transpose(1, 2, 0, 3)
    want = (
        jax.lax.conv_general_dilated(
            x,
            w_hwio,
            (stride, stride),
            padding.upper(),
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        + params["b"]
    )
    assert got.shape == tuple(ops.infer_shape("conv", attrs, [x.shape]))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_conv_relu_fused():
    attrs = {
        "filters": 4,
        "kernel": (3, 3),
        "stride": 1,
        "padding": "same",
        "activation": "relu",
    }
    x = _rand(2, (1, 6, 6, 3))
    params = ops.init_params("conv", attrs, [x.shape], jax.random.PRNGKey(8))
    out = ops.apply_op("conv", attrs, params, [x])
    assert float(np.asarray(out).min()) >= 0.0


def test_maxpool_matches_manual():
    x = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4, 1)
    attrs = {"pool": 2, "stride": 2}
    out = ops.apply_op("maxpool", attrs, {}, [x])
    want = np.array([[5, 7], [13, 15]], dtype=np.float32).reshape(1, 2, 2, 1)
    np.testing.assert_array_equal(np.asarray(out), want)
    assert ops.infer_shape("maxpool", attrs, [(1, 4, 4, 1)]) == (1, 2, 2, 1)


def test_gap_matches_mean():
    x = _rand(3, (1, 5, 5, 7))
    out = ops.apply_op("gap", {}, {}, [x])
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(x).mean(axis=(1, 2)), rtol=1e-6
    )


def test_bn_folded_inference():
    x = _rand(4, (1, 4, 4, 6))
    params = ops.init_params("bn", {}, [x.shape], jax.random.PRNGKey(9))
    out = ops.apply_op("bn", {"activation": "none"}, params, [x])
    want = np.asarray(x) * np.asarray(params["scale"]) + np.asarray(params["shift"])
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-5)


def test_add_shape_mismatch_rejected():
    with pytest.raises(ValueError):
        ops.infer_shape("add", {}, [(1, 2, 2, 3), (1, 2, 2, 4)])


def test_dense_shapes_and_flops():
    attrs = {"units": 10}
    assert ops.infer_shape("dense", attrs, [(1, 32)]) == (1, 10)
    assert ops.flops("dense", attrs, [(1, 32)]) == 2 * 32 * 10


def test_conv_flops_formula():
    attrs = {"filters": 8, "kernel": (3, 3), "stride": 1, "padding": "same"}
    # 2 * OH*OW * KH*KW*C * F
    assert ops.flops("conv", attrs, [(1, 4, 4, 3)]) == 2 * 16 * 9 * 3 * 8


def test_unknown_op_rejected():
    with pytest.raises(ValueError):
        ops.infer_shape("attention", {}, [(1, 2)])
