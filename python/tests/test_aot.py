"""AOT path: HLO text emission, weights.bin layout, manifest integrity."""

import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, models, partitioner

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_partition_emits_hlo_text():
    g = models.build("resnet50", "tiny")
    parts = partitioner.partition(g, 2)
    hlo = aot.lower_partition(parts[0])
    assert hlo.startswith("HloModule")
    assert "f32[1,32,32,3]" in hlo  # input parameter present
    # Weights must be HLO *parameters*, not giant constants: the entry
    # layout lists input + every manifest entry. (Plain "parameter(" also
    # appears inside fusion/while sub-computations, so count in the entry
    # layout only.)
    entry = hlo.split("entry_computation_layout={(", 1)[1].split(")->")[0]
    n_params = entry.count("f32[")
    assert n_params == 1 + len(parts[0].weight_manifest)


def test_lowered_partition_runs_and_matches_python():
    """Execute the lowered HLO via jax and compare to direct apply."""
    g = models.build("resnet50", "tiny")
    params = partitioner.init_graph_params(g)
    (part,) = partitioner.partition(g, 1)
    fn = partitioner.partition_fn(part)
    ws = partitioner.flatten_params(part, params)
    x = jax.random.normal(jax.random.PRNGKey(2), part.input_shape, jnp.float32)
    (want,) = fn(x, *ws)
    (got,) = jax.jit(fn)(x, *ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="run `make artifacts` first",
)
def test_built_artifacts_consistent():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["artifacts"], "empty manifest"
    seen = set()
    for row in manifest["artifacts"]:
        key = (row["profile"], row["model"], row["part_count"], row["part_index"])
        assert key not in seen, f"duplicate manifest row {key}"
        seen.add(key)
        d = os.path.join(ARTIFACTS, row["dir"])
        meta_path = os.path.join(d, f"{row['stem']}.meta.json")
        with open(meta_path) as f:
            meta = json.load(f)
        wpath = os.path.join(d, meta["weights_file"])
        raw = open(wpath, "rb").read()
        assert len(raw) == meta["weights_bytes"]
        assert hashlib.sha256(raw).hexdigest() == meta["weights_sha256"]
        assert meta["weights_bytes"] == 4 * sum(w["elements"] for w in meta["weights"])
        hpath = os.path.join(d, meta["hlo_file"])
        head = open(hpath).read(64)
        assert head.startswith("HloModule")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "tiny", "resnet50", "ref_meta.json")),
    reason="run `make artifacts` first",
)
def test_partition_metas_chain():
    """Boundary shapes must chain p0 -> p1 -> ... and span input -> output."""
    d = os.path.join(ARTIFACTS, "tiny", "resnet50")
    for n in (1, 2, 4):
        metas = []
        for i in range(n):
            with open(os.path.join(d, f"p{i}of{n}.meta.json")) as f:
                metas.append(json.load(f))
        for a, b in zip(metas, metas[1:]):
            assert a["output_shape"] == b["input_shape"]
        with open(os.path.join(d, "ref_meta.json")) as f:
            ref = json.load(f)
        assert metas[0]["input_shape"] == ref["input_shape"]
        assert metas[-1]["output_shape"] == ref["output_shape"]
