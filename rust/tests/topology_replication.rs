//! Frame-order preservation through the topology wiring layer.
//!
//! These tests drive the wiring without the PJRT engine: each worker
//! replica is emulated by a relay thread that forwards frames after a
//! random per-replica compute delay. The invariant under test is the
//! one the dispatcher relies on: whatever the topology (replicated
//! stages, uneven jitter, either transport, worker-owned or legacy
//! relay data plane), frames come back in exactly the order they went
//! in, followed by one `Shutdown`. Property-style: deterministic PRNG,
//! many random topologies (no proptest crate offline).
//!
//! Worker-owned wiring (the default) must additionally spawn **zero**
//! junction relay threads — each replica's [`MergeReceiver`] /
//! [`DealSender`] pair *is* the boundary — and a dead successor replica
//! must surface its peer label in the sender's error.

use std::time::Duration;

use defer::metrics::ByteCounter;
use defer::netem::{Link, LinkSpec};
use defer::threadpool::WorkerPool;
use defer::topology::{wiring, Topology};
use defer::util::prng::Rng;
use defer::wire::{Message, MessageType};

fn data_msg(frame: u64) -> Message {
    Message {
        msg_type: MessageType::Data,
        frame,
        serialized_len: 8,
        count: 0,
        batch: 1,
        payload: vec![frame as u8; 8],
    }
}

fn opts(tcp: bool, base_port: Option<u16>, relay: bool) -> wiring::TransportOptions {
    wiring::TransportOptions {
        tcp,
        base_port,
        pipe_depth: 2,
        relay_junctions: relay,
        recovery: None,
    }
}

/// Wire the topology, emulate every worker as a jittered relay, pump
/// `frames` frames through, and assert FIFO delivery end to end.
fn drive(topo: &Topology, tcp: bool, frames: u64, jitter_us: u64, seed: u64) {
    drive_with(topo, opts(tcp, None, false), frames, jitter_us, seed)
}

fn drive_with(
    topo: &Topology,
    transport: wiring::TransportOptions,
    frames: u64,
    jitter_us: u64,
    seed: u64,
) {
    let relay_mode = transport.relay_junctions;
    let wiring::Wiring {
        control,
        mut to_first,
        mut from_last,
        workers,
        junctions,
    } = wiring::build(topo, &transport).unwrap();
    drop(control); // no configuration phase in this harness
    if relay_mode {
        assert_eq!(
            junctions.is_empty(),
            topo.is_uniform(),
            "relay mode spawns a junction per replicated boundary"
        );
    } else {
        assert!(
            junctions.is_empty(),
            "worker-owned wiring must spawn zero junction relay threads"
        );
    }

    let mut pool = WorkerPool::new();
    for (w_i, wc) in workers.into_iter().enumerate() {
        let mut rng = Rng::new(seed ^ (w_i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        pool.spawn(&format!("relay-{}", wc.view.name), move || {
            let wiring::WorkerConns {
                mut data_in,
                mut data_out,
                ..
            } = wc;
            let null = ByteCounter::new();
            let link = Link::ideal();
            loop {
                let msg = data_in.recv(&null)?;
                if msg.msg_type == MessageType::Shutdown {
                    data_out.broadcast_shutdown(&link, &null)?;
                    return Ok(());
                }
                if jitter_us > 0 {
                    std::thread::sleep(Duration::from_micros(rng.below(jitter_us)));
                }
                data_out.send_data(&msg, &link, &null)?;
            }
        });
    }

    // Bounded pipes apply backpressure; send from a worker thread.
    pool.spawn("driver-sender", move || {
        let null = ByteCounter::new();
        let link = Link::ideal();
        for f in 0..frames {
            to_first.send_data(&data_msg(f), &link, &null)?;
        }
        to_first.broadcast_shutdown(&link, &null)?;
        Ok(())
    });

    let null = ByteCounter::new();
    for f in 0..frames {
        let msg = from_last.recv(&null).unwrap();
        assert_eq!(msg.msg_type, MessageType::Data);
        assert_eq!(msg.frame, f, "frame {f} arrived out of order");
    }
    assert_eq!(
        from_last.recv(&null).unwrap().msg_type,
        MessageType::Shutdown,
        "exactly one shutdown trails the last frame"
    );
    pool.join().unwrap();
    junctions.join().unwrap();
}

#[test]
fn uniform_chain_order_both_transports() {
    let topo = Topology::uniform_chain(3, LinkSpec::ideal()).unwrap();
    drive(&topo, false, 24, 0, 1);
    drive(&topo, true, 24, 0, 2);
}

#[test]
fn replicated_middle_stage_preserves_order_under_jitter() {
    // The SEIFER-style shape: a 3-replica bottleneck stage between two
    // sole stages, with per-replica compute jitter up to 400 us. This
    // is the worker-owned acceptance property (mirrors, and replaces in
    // the default data plane, the old junction order test).
    let topo = Topology::new(&[1, 3, 1], vec![LinkSpec::ideal(); 4]).unwrap();
    drive(&topo, false, 60, 400, 11);
    drive(&topo, true, 60, 400, 12);
}

#[test]
fn replicated_first_and_last_stages_preserve_order() {
    // The dispatcher deals straight onto the replicated first stage and
    // merges straight from the replicated last stage; both schedules
    // must line up with the interior ones.
    let topo = Topology::new(&[2, 1, 2], vec![LinkSpec::ideal(); 4]).unwrap();
    drive(&topo, false, 40, 200, 21);
    drive(&topo, true, 40, 200, 22);
}

#[test]
fn adjacent_replicated_stages_preserve_order() {
    // R -> R' boundary: a full u x d mesh with per-endpoint deal/merge
    // rotations replacing the single junction rotation pair.
    let topo = Topology::new(&[2, 3], vec![LinkSpec::ideal(); 3]).unwrap();
    drive(&topo, false, 50, 300, 31);
}

#[test]
fn prop_random_topologies_preserve_order() {
    // forall topologies (1..=4 stages, 1..=3 replicas each), jittered
    // relays: FIFO delivery holds under worker-owned deal/merge. 12
    // seeded cases, local transport.
    let mut rng = Rng::new(0xDEFE_0001);
    for case in 0..12u64 {
        let stages = rng.range(1, 4);
        let replicas: Vec<usize> = (0..stages).map(|_| rng.range(1, 3)).collect();
        let topo = Topology::new(&replicas, vec![LinkSpec::ideal(); stages + 1]).unwrap();
        let frames = rng.range(5, 40) as u64;
        let jitter = rng.below(500);
        drive(&topo, false, frames, jitter, 100 + case);
    }
}

#[test]
fn prop_relay_mode_still_preserves_order() {
    // The legacy A/B data plane keeps the same external contract: same
    // random-topology property through coordinator-side junctions.
    let mut rng = Rng::new(0xDEFE_0002);
    for case in 0..6u64 {
        let stages = rng.range(1, 4);
        let replicas: Vec<usize> = (0..stages).map(|_| rng.range(1, 3)).collect();
        let topo = Topology::new(&replicas, vec![LinkSpec::ideal(); stages + 1]).unwrap();
        let frames = rng.range(5, 40) as u64;
        let jitter = rng.below(500);
        drive_with(&topo, opts(false, None, true), frames, jitter, 200 + case);
    }
}

#[test]
fn frames_fewer_than_replicas_still_drain() {
    // Starved replicas see only the shutdown broadcast; every merge
    // schedule must still terminate cleanly.
    let topo = Topology::new(&[1, 4, 1], vec![LinkSpec::ideal(); 4]).unwrap();
    drive(&topo, false, 2, 0, 41);
    drive_with(&topo, opts(false, None, true), 2, 0, 42);
}

#[test]
fn zero_frames_clean_shutdown() {
    // Shutdown-only stream: the broadcast/drain protocol alone.
    let topo = Topology::new(&[2, 2], vec![LinkSpec::ideal(); 3]).unwrap();
    drive(&topo, false, 0, 0, 51);
}

#[test]
fn tcp_base_port_override_allocates_sequentially() {
    // Unlikely-to-collide range; exercises the PortAlloc override path.
    // Worker-owned wiring allocates exactly 3 ports per worker plus the
    // return port — no junction ingress ports.
    let topo = Topology::new(&[1, 2], vec![LinkSpec::ideal(); 3]).unwrap();
    drive_with(&topo, opts(true, Some(45_731), false), 5, 0, 61);
    // Relay mode still allocates its junction ports past the block.
    drive_with(&topo, opts(true, Some(45_831), true), 5, 0, 62);
}

/// The CI smoke for the tentpole: a replicated-stage deployment over
/// real TCP sockets runs with **zero** junction relay threads in the
/// process, on both the interior and the dispatcher boundaries.
#[test]
fn worker_owned_tcp_replicated_smoke_zero_junctions() {
    let topo = Topology::new(&[2, 3, 2], vec![LinkSpec::ideal(); 4]).unwrap();
    let wiring = wiring::build(&topo, &opts(true, None, false)).unwrap();
    assert!(wiring.junctions.is_empty(), "junction thread spawned");
    assert_eq!(wiring.to_first.fan(), 2);
    assert_eq!(wiring.from_last.fan(), 2);
    drop(wiring);
    // And the full FIFO property holds over TCP with that shape.
    drive(&topo, true, 30, 200, 71);
}

/// A dead successor replica must be *named* in the sender's error — the
/// peer label travels with the connection set.
#[test]
fn dead_successor_replica_surfaces_peer_label() {
    let topo = Topology::new(&[1, 2], vec![LinkSpec::ideal(); 3]).unwrap();
    let wiring::Wiring {
        control,
        to_first,
        from_last,
        mut workers,
        junctions,
    } = wiring::build(&topo, &opts(false, None, false)).unwrap();
    drop(control);
    drop(from_last);
    drop(to_first);
    // Kill replica node1.1 (stage 1, replica 1) outright.
    let victim = workers
        .iter()
        .position(|wc| wc.view.name == "node1.1")
        .unwrap();
    drop(workers.remove(victim));
    // node0 deals round-robin over [node1.0, node1.1]; its second frame
    // targets the dead replica and must error with its label.
    let node0 = workers
        .iter_mut()
        .find(|wc| wc.view.name == "node0")
        .unwrap();
    let null = ByteCounter::new();
    let link = Link::ideal();
    node0.data_out.send_data(&data_msg(0), &link, &null).unwrap();
    let err = node0.data_out.send_data(&data_msg(1), &link, &null).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("node1.1"), "peer not named: {msg}");
    junctions.join().unwrap();
}
