//! Frame-order preservation through the topology wiring layer.
//!
//! These tests drive the wiring (junctions included) without the PJRT
//! engine: each worker replica is emulated by a relay thread that
//! forwards frames after a random per-replica compute delay. The
//! invariant under test is the one the dispatcher relies on: whatever
//! the topology (replicated stages, uneven jitter, either transport),
//! frames come back in exactly the order they went in, followed by one
//! `Shutdown`. Property-style: deterministic PRNG, many random
//! topologies (no proptest crate offline).

use std::time::Duration;

use defer::metrics::ByteCounter;
use defer::netem::{Link, LinkSpec};
use defer::threadpool::WorkerPool;
use defer::topology::{wiring, Topology};
use defer::util::prng::Rng;
use defer::wire::{Message, MessageType};

fn data_msg(frame: u64) -> Message {
    Message {
        msg_type: MessageType::Data,
        frame,
        serialized_len: 8,
        count: 0,
        payload: vec![frame as u8; 8],
    }
}

/// Wire the topology, emulate every worker as a jittered relay, pump
/// `frames` frames through, and assert FIFO delivery end to end.
fn drive(topo: &Topology, tcp: bool, frames: u64, jitter_us: u64, seed: u64) {
    drive_with_ports(topo, tcp, None, frames, jitter_us, seed)
}

fn drive_with_ports(
    topo: &Topology,
    tcp: bool,
    base_port: Option<u16>,
    frames: u64,
    jitter_us: u64,
    seed: u64,
) {
    let wiring::Wiring {
        control,
        mut to_first,
        mut from_last,
        workers,
        junctions,
    } = wiring::build(
        topo,
        &wiring::TransportOptions {
            tcp,
            base_port,
            pipe_depth: 2,
        },
    )
    .unwrap();
    drop(control); // no configuration phase in this harness

    let mut pool = WorkerPool::new();
    for (w_i, wc) in workers.into_iter().enumerate() {
        let mut rng = Rng::new(seed ^ (w_i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        pool.spawn(&format!("relay-{}", wc.view.name), move || {
            let wiring::WorkerConns {
                mut data_in,
                mut data_out,
                ..
            } = wc;
            let null = ByteCounter::new();
            let link = Link::ideal();
            loop {
                let msg = data_in.recv(&null)?;
                let stop = msg.msg_type == MessageType::Shutdown;
                if !stop && jitter_us > 0 {
                    std::thread::sleep(Duration::from_micros(rng.below(jitter_us)));
                }
                data_out.send(&msg, &link, &null)?;
                if stop {
                    return Ok(());
                }
            }
        });
    }

    // Bounded pipes apply backpressure; send from a worker thread.
    pool.spawn("driver-sender", move || {
        let null = ByteCounter::new();
        let link = Link::ideal();
        for f in 0..frames {
            to_first.send(&data_msg(f), &link, &null)?;
        }
        to_first.send(&Message::control(MessageType::Shutdown), &link, &null)?;
        Ok(())
    });

    let null = ByteCounter::new();
    for f in 0..frames {
        let msg = from_last.recv(&null).unwrap();
        assert_eq!(msg.msg_type, MessageType::Data);
        assert_eq!(msg.frame, f, "frame {f} arrived out of order");
    }
    assert_eq!(
        from_last.recv(&null).unwrap().msg_type,
        MessageType::Shutdown,
        "exactly one shutdown trails the last frame"
    );
    pool.join().unwrap();
    junctions.join().unwrap();
}

#[test]
fn uniform_chain_order_both_transports() {
    let topo = Topology::uniform_chain(3, LinkSpec::ideal()).unwrap();
    drive(&topo, false, 24, 0, 1);
    drive(&topo, true, 24, 0, 2);
}

#[test]
fn replicated_middle_stage_preserves_order_under_jitter() {
    // The SEIFER-style shape: a 3-replica bottleneck stage between two
    // sole stages, with per-replica compute jitter up to 400 us.
    let topo = Topology::new(&[1, 3, 1], vec![LinkSpec::ideal(); 4]).unwrap();
    drive(&topo, false, 60, 400, 11);
    drive(&topo, true, 60, 400, 12);
}

#[test]
fn replicated_first_and_last_stages_preserve_order() {
    // Junctions also sit on the dispatcher uplink (1 -> R deal) and the
    // return link (R -> 1 merge); both rotations must line up.
    let topo = Topology::new(&[2, 1, 2], vec![LinkSpec::ideal(); 4]).unwrap();
    drive(&topo, false, 40, 200, 21);
}

#[test]
fn adjacent_replicated_stages_preserve_order() {
    // R -> R' boundary: one junction merges U inputs and deals to D
    // outputs in a single rotation pair.
    let topo = Topology::new(&[2, 3], vec![LinkSpec::ideal(); 3]).unwrap();
    drive(&topo, false, 50, 300, 31);
}

#[test]
fn prop_random_topologies_preserve_order() {
    // forall topologies (1..=4 stages, 1..=3 replicas each), jittered
    // relays: FIFO delivery holds. 12 seeded cases, local transport.
    let mut rng = Rng::new(0xDEFE_0001);
    for case in 0..12u64 {
        let stages = rng.range(1, 4);
        let replicas: Vec<usize> = (0..stages).map(|_| rng.range(1, 3)).collect();
        let topo = Topology::new(&replicas, vec![LinkSpec::ideal(); stages + 1]).unwrap();
        let frames = rng.range(5, 40) as u64;
        let jitter = rng.below(500);
        drive(&topo, false, frames, jitter, 100 + case);
    }
}

#[test]
fn frames_fewer_than_replicas_still_drain() {
    // Starved replicas see only the shutdown broadcast; the merge must
    // still terminate cleanly.
    let topo = Topology::new(&[1, 4, 1], vec![LinkSpec::ideal(); 4]).unwrap();
    drive(&topo, false, 2, 0, 41);
}

#[test]
fn tcp_base_port_override_allocates_sequentially() {
    // Unlikely-to-collide range; exercises the PortAlloc override path
    // (including junction ingress ports past the worker block).
    let topo = Topology::new(&[1, 2], vec![LinkSpec::ideal(); 3]).unwrap();
    drive_with_ports(&topo, true, Some(45_731), 5, 0, 51);
}
