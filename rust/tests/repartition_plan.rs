//! Repartition-planner golden tests: synthetic finest-granularity
//! partition costs in, exact cuts + replicas + render bytes out. No
//! artifacts, no RNG, no clocks — the planner is a pure function.

use defer::netem::LinkSpec;
use defer::placement::{self, BatchCost, CodecCost, DeviceProfile, PlacementProblem, StageCost};
use defer::repartition::{plan, PartCost, RepartitionProblem};

fn homogeneous(n: usize, mflops: f64) -> Vec<DeviceProfile> {
    (0..n)
        .map(|i| DeviceProfile {
            name: format!("edge{i}"),
            mflops,
        })
        .collect()
}

fn part(flops: u64, input_bytes: u64, output_bytes: u64, weights_bytes: u64) -> PartCost {
    PartCost {
        flops,
        input_bytes,
        output_bytes,
        weights_bytes,
    }
}

/// The acceptance scenario in miniature: wifi uplink, gigabit cluster,
/// a 3x-heavy middle partition, a memory cap that allows fusing at most
/// two partitions, and budget for one extra worker per stage. The joint
/// planner must cut so the heavy run gets the replicas.
fn acceptance_problem(budget: usize) -> RepartitionProblem {
    RepartitionProblem {
        parts: vec![
            part(100_000_000, 40_000, 20_000, 4_000),
            part(300_000_000, 20_000, 20_000, 4_000),
            part(100_000_000, 20_000, 4_000, 4_000),
        ],
        devices: homogeneous(budget, 100.0),
        worker_budget: budget,
        device_memory: Some(8_000),
        uplink: LinkSpec::wifi(),
        interconnect: vec![LinkSpec::gigabit_lan()],
        codec: CodecCost::default(),
        batch: BatchCost::ZERO,
        relay_junctions: false,
    }
}

#[test]
fn joint_plan_gives_the_heavy_run_the_replicas() {
    let rp = plan(&acceptance_problem(4)).unwrap();
    // Fusing p1+p2 (400 MFLOP) against p0 alone and pouring three
    // workers into the heavy run gates at 4.000232/3 s — better than
    // the balanced cuts [0, 2, 3] (whose heavy run carries the larger
    // 20 kB egress) and than any 3-stage split under this budget.
    assert_eq!(rp.cuts, vec![0, 1, 3]);
    assert_eq!(rp.replica_counts(), vec![1, 3]);
    assert_eq!(rp.num_workers(), 4);
    assert_eq!(rp.stages[1].flops, 400_000_000);
    assert_eq!(rp.stages[1].weights_bytes, 8_000);
    assert_eq!(rp.stages[1].elided_bytes, 20_000);
    // It materializes as a chain-runner-ready topology.
    let topo = rp.topology().unwrap();
    assert_eq!(topo.num_stages(), 2);
    assert_eq!(topo.num_workers(), 4);
    assert_eq!(topo.hop_link(0), LinkSpec::wifi());
    assert_eq!(topo.hop_link(1), LinkSpec::gigabit_lan());
}

/// The artifact-time coarse split (heavy front stage, one worker each)
/// against the joint fine-grained plan: the repartition pass must win by
/// well over the acceptance bar on the modeled numbers.
#[test]
fn repartition_beats_coarse_uniform_chain_in_the_model() {
    let rp = plan(&acceptance_problem(4)).unwrap();
    // Coarse chain: the fixed 2-stage artifact split [p0+p1 | p2], one
    // replica per stage (same links, same devices).
    let coarse = placement::plan(&PlacementProblem {
        stages: vec![
            StageCost {
                flops: 400_000_000,
                input_bytes: 40_000,
                output_bytes: 20_000,
            },
            StageCost {
                flops: 100_000_000,
                input_bytes: 20_000,
                output_bytes: 4_000,
            },
        ],
        devices: homogeneous(2, 100.0),
        worker_budget: 2,
        uplink: LinkSpec::wifi(),
        interconnect: vec![LinkSpec::gigabit_lan()],
        codec: CodecCost::default(),
        batch: BatchCost::ZERO,
        relay_junctions: false,
    })
    .unwrap();
    let speedup = rp.predicted_throughput() / coarse.predicted_throughput;
    assert!(
        speedup >= 1.2,
        "joint plan only {speedup:.2}x over the coarse chain"
    );
}

/// Byte-identical output across repeated runs: the goldens surface.
#[test]
fn render_golden() {
    let rp = plan(&acceptance_problem(4)).unwrap();
    let expected = "repartition plan: 3 partition(s) fused into 2 stage(s), cuts [0, 1, 3]\n\
                    \x20 stage 0 = p0: 100.000 MFLOP, weights 4000 B, elided boundary 0 B\n\
                    \x20 stage 1 = p1..p2: 400.000 MFLOP, weights 8000 B, elided boundary 20000 B\n\
                    placement plan: 2 stage(s), 4 worker(s), predicted 0.750 cycles/s\n\
                    \x20 hop 0 uplink wifi (9.900 ms/frame)\n\
                    \x20 stage 0: x1 on [edge3] via gigabit, compute 1000.000 ms + \
                    egress 0.360 ms -> service 1000.360 ms/frame\n\
                    \x20 stage 1: x3 on [edge0, edge1, edge2] via gigabit, compute 4000.000 ms + \
                    egress 0.232 ms -> service 1333.411 ms/frame, bottleneck\n";
    assert_eq!(rp.render(), expected);
    // And it is deterministic across repeated plans.
    assert_eq!(rp.render(), plan(&acceptance_problem(4)).unwrap().render());
}

/// Without budget headroom the planner still balances the cuts instead
/// of replicating: 3 workers, one per stage, minmax boundary search.
#[test]
fn tight_budget_degenerates_to_balanced_pipeline() {
    let rp = plan(&acceptance_problem(3)).unwrap();
    // One worker per stage: 3 single-partition stages gate at the heavy
    // 3 s partition; fusing anywhere only raises the max. But 2 stages
    // x [1..2] workers can reach 2.0 s by pairing a light partition
    // with the heavy one and replicating... under budget 3 the search
    // settles on the best of all of those.
    assert!(rp.num_workers() <= 3);
    assert!(rp.num_stages() >= 2, "memory cap forces >= 2 stages");
    // Whatever shape it picked must beat the naive 3-stage no-replica
    // pipeline (gated by the 3 s partition).
    assert!(rp.predicted_throughput() >= 1.0 / 3.1);
}

/// An uplink-bound problem: repartitioning cannot shrink hop 0, so the
/// planner keeps workers minimal and placement reports the uplink gate.
#[test]
fn uplink_bound_problem_stays_lean() {
    let p = RepartitionProblem {
        parts: vec![
            part(1_000_000, 60_000_000, 1_000, 1_000),
            part(1_000_000, 1_000, 1_000, 1_000),
        ],
        devices: homogeneous(6, 500.0),
        worker_budget: 6,
        device_memory: Some(1_000),
        uplink: LinkSpec::wifi(),
        interconnect: vec![LinkSpec::gigabit_lan()],
        codec: CodecCost::default(),
        batch: BatchCost::ZERO,
        relay_junctions: false,
    };
    let rp = plan(&p).unwrap();
    assert_eq!(rp.cuts, vec![0, 1, 2]);
    assert_eq!(rp.replica_counts(), vec![1, 1]);
    assert_eq!(
        rp.placement.bottleneck,
        defer::placement::Bottleneck::Uplink
    );
}
