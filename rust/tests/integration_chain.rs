//! End-to-end chain integration (in-process transport): the DEFER
//! dispatcher + compute-node pipeline against the Python ground truth.
//! Requires `make artifacts` (tiny profile).

use std::path::PathBuf;

use defer::compress::Compression;
use defer::config::DeferConfig;
use defer::coordinator::baseline::SingleDevice;
use defer::coordinator::chain::ChainRunner;
use defer::runtime::Engine;
use defer::serial::{Codec, Serialization};

fn cfg(model: &str, nodes: usize) -> DeferConfig {
    let mut c = DeferConfig::default();
    c.artifacts_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    c.profile = "tiny".into();
    c.model = model.into();
    c.nodes = nodes;
    c
}

fn have_artifacts() -> bool {
    let ok = cfg("resnet50", 1).artifacts_dir.join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

fn lossless_codecs(c: &mut DeferConfig) {
    let codec = Codec::new(Serialization::Binary, Compression::Lz4);
    c.codecs.weights = codec;
    c.codecs.data = codec;
}

#[test]
fn chain_matches_reference_lossless() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    for nodes in [1usize, 2, 4] {
        let mut c = cfg("resnet50", nodes);
        lossless_codecs(&mut c);
        let report = ChainRunner::with_engine(c, engine.clone())
            .unwrap()
            .run_frames(3)
            .unwrap();
        assert_eq!(report.cycles, 3);
        let err = report.reference_error.expect("reference checked");
        // Lossless transport: the only difference vs python is XLA
        // scheduling noise, already bounded by the runtime tests.
        assert!(err < 0.05, "{nodes}-node chain: max |err| {err}");
    }
}

#[test]
fn chain_with_paper_codecs_stays_accurate() {
    if !have_artifacts() {
        return;
    }
    // ZFP(24)+LZ4 weights/data (the paper's recommended config) is lossy
    // but must stay inference-grade.
    let report = ChainRunner::new(cfg("resnet50", 4)).unwrap().run_frames(2).unwrap();
    let err = report.reference_error.expect("reference checked");
    let scale = 300.0; // tiny-profile logits are O(100)
    assert!(err < 0.02 * scale, "zfp+lz4 chain err {err}");
}

#[test]
fn chain_reports_complete_accounting() {
    if !have_artifacts() {
        return;
    }
    let report = ChainRunner::new(cfg("resnet50", 2)).unwrap().run_frames(4).unwrap();
    // Payload accounting: every class saw traffic.
    assert!(report.architecture_bytes > 0);
    assert!(report.weights_bytes > 0);
    assert!(report.data_bytes > 0);
    // Data traffic: dispatcher->n0, n0->n1, n1->dispatcher = 3 hops x 4
    // frames (+1 shutdown per hop); each frame's wire size is >= header.
    assert!(report.data_bytes > 3 * 4 * 44);
    // Node energy present for both nodes, every component populated.
    assert_eq!(report.node_energy.len(), 2);
    for e in &report.node_energy {
        assert!(e.compute_j > 0.0, "compute energy must accrue");
        assert!(e.network_j > 0.0, "tx energy must accrue");
    }
    assert!(report.dispatcher_energy.network_j > 0.0);
    assert!(report.throughput > 0.0);
    assert!(report.latency_mean > std::time::Duration::ZERO);
    assert!(report.config_time > std::time::Duration::ZERO);
    assert!(report.data_overhead > std::time::Duration::ZERO);
}

#[test]
fn single_device_baseline_runs() {
    if !have_artifacts() {
        return;
    }
    let report = SingleDevice::new(cfg("resnet50", 1)).unwrap().run_frames(4).unwrap();
    assert_eq!(report.nodes, 1);
    assert_eq!(report.cycles, 4);
    // No network in the baseline.
    assert_eq!(report.total_payload_bytes(), 0);
    assert!(report.node_energy[0].compute_j > 0.0);
    assert_eq!(report.node_energy[0].network_j, 0.0);
    let err = report.reference_error.expect("reference checked");
    assert!(err < 0.05, "baseline err {err}");
}

#[test]
fn vgg16_chain_works() {
    if !have_artifacts() {
        return;
    }
    let mut c = cfg("vgg16", 2);
    lossless_codecs(&mut c);
    let report = ChainRunner::new(c).unwrap().run_frames(2).unwrap();
    assert!(report.reference_error.unwrap() < 0.05);
}

#[test]
fn all_paper_codec_configs_run_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    for codec in Codec::paper_sweep() {
        let mut c = cfg("resnet50", 2);
        c.codecs.data = codec;
        c.codecs.weights = codec;
        let report = ChainRunner::with_engine(c, engine.clone())
            .unwrap()
            .run_frames(2)
            .unwrap();
        assert_eq!(report.cycles, 2, "codec {}", codec.label());
    }
}

#[test]
fn shaped_link_still_correct() {
    if !have_artifacts() {
        return;
    }
    let mut c = cfg("resnet50", 2);
    c.link = defer::netem::LinkSpec::gigabit_lan();
    lossless_codecs(&mut c);
    let report = ChainRunner::new(c).unwrap().run_frames(2).unwrap();
    assert!(report.reference_error.unwrap() < 0.05);
}

#[test]
fn pipelining_beats_sequential_sum() {
    if !have_artifacts() {
        return;
    }
    // The FIFO pipeline must overlap stages: chain wall-clock for K frames
    // should be well under K x (sum of stage times) once warm. We proxy
    // this by checking throughput(4 nodes) > 0.5 x throughput(1 node-chain)
    // — a weak but deterministic bound (tiny models are coordination-bound).
    let engine = Engine::cpu().unwrap();
    let mut c1 = cfg("resnet50", 1);
    lossless_codecs(&mut c1);
    let r1 = ChainRunner::with_engine(c1, engine.clone()).unwrap().run_frames(8).unwrap();
    let mut c4 = cfg("resnet50", 4);
    lossless_codecs(&mut c4);
    let r4 = ChainRunner::with_engine(c4, engine).unwrap().run_frames(8).unwrap();
    assert!(
        r4.throughput > 0.3 * r1.throughput,
        "4-node pipeline collapsed: {} vs {}",
        r4.throughput,
        r1.throughput
    );
}
