//! TCP-loopback chain integration: the same pipeline as
//! `integration_chain.rs` but over real kernel sockets — the deployment
//! shape the paper ran under CORE. Listeners bind ephemeral ports, so
//! these tests can run in parallel without port coordination (the old
//! fixed `base_port` arithmetic was flaky under concurrent runs).
//! Requires `make artifacts`.

use std::path::PathBuf;

use defer::compress::Compression;
use defer::config::DeferConfig;
use defer::coordinator::chain::ChainRunner;
use defer::serial::{Codec, Serialization};

fn cfg(nodes: usize) -> DeferConfig {
    let mut c = DeferConfig::default();
    c.artifacts_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    c.profile = "tiny".into();
    c.model = "resnet50".into();
    c.nodes = nodes;
    c.tcp = true;
    c.codecs.weights = Codec::new(Serialization::Binary, Compression::Lz4);
    c.codecs.data = Codec::new(Serialization::Binary, Compression::Lz4);
    c
}

fn have_artifacts() -> bool {
    let ok = cfg(1).artifacts_dir.join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

#[test]
fn tcp_chain_matches_reference() {
    if !have_artifacts() {
        return;
    }
    let report = ChainRunner::new(cfg(2)).unwrap().run_frames(3).unwrap();
    assert_eq!(report.cycles, 3);
    assert!(report.reference_error.unwrap() < 0.05);
}

#[test]
fn tcp_four_node_chain() {
    if !have_artifacts() {
        return;
    }
    let report = ChainRunner::new(cfg(4)).unwrap().run_frames(4).unwrap();
    assert_eq!(report.cycles, 4);
    assert!(report.reference_error.unwrap() < 0.05);
    assert_eq!(report.node_energy.len(), 4);
    assert_eq!(report.workers, 4);
}

#[test]
fn tcp_with_shaped_gigabit_link() {
    if !have_artifacts() {
        return;
    }
    let mut c = cfg(2);
    c.link = defer::netem::LinkSpec::gigabit_lan();
    let report = ChainRunner::new(c).unwrap().run_frames(2).unwrap();
    assert!(report.reference_error.unwrap() < 0.05);
    // Shaped link implies nonzero latency floor.
    assert!(report.latency_mean > std::time::Duration::from_micros(200));
}

#[test]
fn tcp_base_port_override_still_works() {
    if !have_artifacts() {
        return;
    }
    // CORE-style deployments can pin the port range; ports are allocated
    // sequentially from the base.
    let mut c = cfg(2);
    c.base_port = Some(48_650);
    let report = ChainRunner::new(c).unwrap().run_frames(2).unwrap();
    assert_eq!(report.cycles, 2);
}

#[test]
fn tcp_and_local_payloads_agree() {
    if !have_artifacts() {
        return;
    }
    // The wire accounting must be transport-independent.
    let r_tcp = ChainRunner::new(cfg(2)).unwrap().run_frames(2).unwrap();
    let mut c_local = cfg(2);
    c_local.tcp = false;
    let r_local = ChainRunner::new(c_local).unwrap().run_frames(2).unwrap();
    assert_eq!(r_tcp.architecture_bytes, r_local.architecture_bytes);
    assert_eq!(r_tcp.weights_bytes, r_local.weights_bytes);
    assert_eq!(r_tcp.data_bytes, r_local.data_bytes);
}
