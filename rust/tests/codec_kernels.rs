//! Kernel-equivalence property suite (PR 8).
//!
//! The batched lane-parallel ZFP kernel must be *byte-identical* to the
//! scalar reference coder on every input — the wire format is frozen by
//! the DFCK/plan goldens, so the SIMD-friendly rewrite is only admissible
//! if no downstream consumer can tell the kernels apart. These tests
//! hammer that invariant across random shapes/rates and the adversarial
//! exponent edges where a bit-level exponent extraction could diverge
//! from the float it replaces, then do the same word-vs-bit check for
//! the u64-accumulator bit I/O underneath.

use defer::compress::lz4;
use defer::serial::bits::{BitReader, BitWriter};
use defer::serial::zfp::{self, ZfpRate};
use defer::serial::CodecKernel;
use defer::util::prng::Rng;

const RATES: [u8; 7] = [3, 4, 7, 8, 16, 24, 32];

/// Encode with both kernels, demand identical bytes, then demand that
/// both kernels decode those bytes to identical bit patterns.
fn assert_kernels_agree(data: &[f32], rate: u8) {
    let rate = ZfpRate(rate);
    let mut scalar = Vec::new();
    let mut batched = Vec::new();
    zfp::encode_into_kernel(data, rate, &mut scalar, CodecKernel::Scalar).unwrap();
    zfp::encode_into_kernel(data, rate, &mut batched, CodecKernel::Batched).unwrap();
    assert_eq!(
        scalar, batched,
        "wire bytes diverged (n={}, rate={})",
        data.len(),
        rate.0
    );
    let d_scalar = zfp::decode_kernel(&scalar, CodecKernel::Scalar).unwrap();
    let d_batched = zfp::decode_kernel(&scalar, CodecKernel::Batched).unwrap();
    let s_bits: Vec<u32> = d_scalar.iter().map(|x| x.to_bits()).collect();
    let b_bits: Vec<u32> = d_batched.iter().map(|x| x.to_bits()).collect();
    assert_eq!(
        s_bits, b_bits,
        "decoded values diverged (n={}, rate={})",
        data.len(),
        rate.0
    );
}

#[test]
fn random_shapes_and_rates_are_bit_identical() {
    let mut rng = Rng::new(8101);
    for _ in 0..60 {
        let n = rng.range(0, 2000);
        let scale = (rng.f32() * 60.0 - 30.0).exp2();
        let data: Vec<f32> = (0..n).map(|_| rng.normal_f32() * scale).collect();
        let rate = RATES[rng.below(RATES.len() as u64) as usize];
        assert_kernels_agree(&data, rate);
    }
}

#[test]
fn group_boundary_shapes_are_bit_identical() {
    // GROUP_BLOCKS = 16 blocks of 4 values → the batched kernel's group
    // is 64 values; probe every alignment around that boundary.
    let mut rng = Rng::new(8102);
    for n in [1usize, 3, 4, 5, 63, 64, 65, 127, 128, 129, 1024, 1027] {
        let data: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        for rate in RATES {
            assert_kernels_agree(&data, rate);
        }
    }
}

/// Exponent edges: exact powers of two and the ulp on either side are
/// exactly where a `log2`-based exponent would misclassify.
#[test]
fn power_of_two_edges_are_bit_identical() {
    let mut edges = Vec::new();
    for k in -140i32..=120 {
        let p = (k as f32).exp2();
        if p == 0.0 || p.is_infinite() {
            continue;
        }
        edges.push(p);
        edges.push(f32::from_bits(p.to_bits() + 1));
        if p.to_bits() > 0 {
            edges.push(f32::from_bits(p.to_bits() - 1));
        }
        edges.push(-p);
    }
    for rate in RATES {
        assert_kernels_agree(&edges, rate);
    }
}

#[test]
fn subnormals_zeros_and_specials_are_bit_identical() {
    let specials = [
        0.0f32,
        -0.0,
        f32::MIN_POSITIVE,                   // smallest normal
        -f32::MIN_POSITIVE,
        f32::from_bits(1),                   // smallest subnormal
        f32::from_bits(0x8000_0001),         // -smallest subnormal
        f32::from_bits(0x007F_FFFF),         // largest subnormal
        f32::from_bits(0x0040_0000),         // mid subnormal
        f32::NAN,
        -f32::NAN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::MAX,
        f32::MIN,
        1.0,
        -1.0,
    ];
    for rate in RATES {
        assert_kernels_agree(&specials, rate);
    }
    // Interleave specials with ordinary values so sanitize and max-abs
    // see mixed lanes inside one block.
    let mut rng = Rng::new(8103);
    for _ in 0..20 {
        let data: Vec<f32> = (0..97)
            .map(|i| {
                if rng.below(4) == 0 {
                    specials[i % specials.len()]
                } else {
                    rng.normal_f32() * 1e4
                }
            })
            .collect();
        for rate in [3u8, 8, 32] {
            assert_kernels_agree(&data, rate);
        }
    }
}

/// Values whose quantized magnitude brushes the ±2^30 clamp, plus blocks
/// whose shared exponent saturates the 8-bit biased-exponent field.
#[test]
fn clamp_and_exponent_saturation_are_bit_identical() {
    let mut rng = Rng::new(8104);
    for _ in 0..20 {
        let huge: Vec<f32> = (0..64)
            .map(|_| {
                let m = 1.0 + rng.f32();
                let s = if rng.below(2) == 0 { 1.0 } else { -1.0 };
                // Spread across the top of the exponent range so some
                // blocks clamp the biased exponent and some quantized
                // lanes hit the i32 clamp.
                s * m * ((rng.range(100, 128) as f32).exp2())
            })
            .collect();
        for rate in RATES {
            assert_kernels_agree(&huge, rate);
        }
        let tiny: Vec<f32> = (0..64)
            .map(|_| rng.normal_f32() * (-(rng.range(120, 149) as f32)).exp2())
            .collect();
        for rate in RATES {
            assert_kernels_agree(&tiny, rate);
        }
    }
}

// ---------------------------------------------------------------------
// Bit I/O: word-accumulator writer/reader vs a bit-at-a-time reference.
// ---------------------------------------------------------------------

/// Dead-simple reference model: one bool per bit.
#[derive(Default)]
struct RefBits {
    bits: Vec<bool>,
}

impl RefBits {
    fn write(&mut self, v: u64, n: u8) {
        for i in (0..n).rev() {
            self.bits.push((v >> i) & 1 == 1);
        }
    }

    fn pad_to(&mut self, target: usize) {
        while self.bits.len() < target {
            self.bits.push(false);
        }
    }

    fn bytes(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.bits.len().div_ceil(8)];
        for (i, &b) in self.bits.iter().enumerate() {
            if b {
                out[i / 8] |= 0x80 >> (i % 8);
            }
        }
        out
    }

    fn read(&self, pos: &mut usize, n: u8) -> u64 {
        let mut v = 0u64;
        for _ in 0..n {
            let bit = self.bits.get(*pos).copied().unwrap_or(false);
            v = (v << 1) | bit as u64;
            *pos += 1;
        }
        v
    }
}

#[test]
fn bit_writer_matches_bit_at_a_time_reference() {
    let mut rng = Rng::new(8105);
    for round in 0..40 {
        let mut w = BitWriter::new();
        let mut model = RefBits::default();
        for _ in 0..rng.range(1, 400) {
            match rng.below(10) {
                0 => {
                    // Occasional pad to a random future boundary.
                    let target = w.bit_len() + rng.range(0, 70);
                    w.pad_to(target);
                    model.pad_to(target);
                }
                1 => {
                    let bit = rng.below(2) == 1;
                    w.write_bit(bit);
                    model.write(bit as u64, 1);
                }
                _ => {
                    let n = rng.range(1, 64) as u8;
                    let v = if n == 64 {
                        rng.next_u64()
                    } else {
                        rng.next_u64() & ((1u64 << n) - 1)
                    };
                    w.write(v, n);
                    model.write(v, n);
                }
            }
            assert_eq!(w.bit_len(), model.bits.len(), "round {round}");
        }
        assert_eq!(w.into_bytes(), model.bytes(), "round {round}");
    }
}

#[test]
fn bit_reader_matches_bit_at_a_time_reference() {
    let mut rng = Rng::new(8106);
    for _ in 0..40 {
        let buf = rng.bytes(rng.range(0, 200));
        let mut model = RefBits::default();
        for &b in &buf {
            model.write(b as u64, 8);
        }
        let mut r = BitReader::new(&buf);
        let mut pos = 0usize;
        // Read well past the end: the reader zero-fills, like the model.
        while pos < buf.len() * 8 + 130 {
            if rng.below(8) == 0 {
                // Random seek within (and slightly past) the buffer.
                let target = rng.range(0, buf.len() * 8 + 64);
                r.seek(target);
                pos = target;
            }
            let n = rng.range(1, 64) as u8;
            let expect = model.read(&mut pos, n);
            assert_eq!(r.read(n), expect);
            assert_eq!(r.bit_pos(), pos);
        }
    }
}

// ---------------------------------------------------------------------
// LZ4 scratch pool: steady state must be allocation-free (no re-zeroed
// hash tables) once warm, without changing output bytes.
// ---------------------------------------------------------------------

#[test]
fn scratch_pool_steady_state_is_allocation_free() {
    let mut rng = Rng::new(8107);
    let pool = lz4::ScratchPool::new();
    let frames: Vec<Vec<u8>> = (0..8).map(|_| rng.compressible_bytes(40_000)).collect();

    // Warm-up: the first take per concurrency level builds a table.
    for f in &frames {
        let mut scratch = pool.take();
        let mut out = Vec::new();
        lz4::compress_with(f, &mut out, &mut scratch);
        pool.put(scratch);
        assert_eq!(out, lz4::compress(f), "pooled output must match fresh");
    }
    let warm_misses = pool.misses();
    assert!(warm_misses >= 1);
    assert_eq!(pool.pooled(), 1, "serial use should park exactly one table");

    // Steady state: hundreds of frames, zero further table builds.
    for round in 0..300 {
        let f = &frames[round % frames.len()];
        let mut scratch = pool.take();
        let mut out = Vec::new();
        lz4::compress_with(f, &mut out, &mut scratch);
        pool.put(scratch);
    }
    assert_eq!(
        pool.misses(),
        warm_misses,
        "steady state allocated a fresh hash table"
    );
}
