//! Property-based tests over coordinator and codec invariants, driven by
//! the crate's deterministic PRNG (no proptest crate offline; same
//! generate-and-check discipline, fixed seeds for reproducibility).

use defer::compress::{lz4, Compression};
use defer::serial::{json, zfp, Codec, Serialization};
use defer::tensor::Tensor;
use defer::threadpool::pipe;
use defer::util::prng::Rng;
use defer::wire::{crc32::crc32, read_message, write_message, Message, MessageType};
use defer::metrics::ByteCounter;
use defer::netem::Link;

const CASES: usize = 120;

#[test]
fn prop_codec_stack_round_trips() {
    // forall tensors t, codecs c: decode(encode(t)) == t (lossless) or
    // within the zfp error bound (lossy).
    let mut rng = Rng::new(1001);
    let codecs = [
        Codec::new(Serialization::Binary, Compression::None),
        Codec::new(Serialization::Binary, Compression::Lz4),
        Codec::new(Serialization::Json, Compression::None),
        Codec::new(Serialization::Json, Compression::Lz4),
        Codec::new(Serialization::Zfp(zfp::ZfpRate(32)), Compression::Lz4),
        Codec::new(Serialization::Zfp(zfp::ZfpRate(16)), Compression::None),
    ];
    for i in 0..CASES {
        let n = rng.range(1, 3000);
        let scale = (rng.f32() * 16.0 - 8.0).exp2();
        let data: Vec<f32> = (0..n).map(|_| rng.normal_f32() * scale).collect();
        let codec = codecs[i % codecs.len()];
        let (wire, mid) = codec.encode_f32s(&data, None);
        let out = codec.decode_f32s(&wire, mid, n, None).unwrap();
        assert_eq!(out.len(), n);
        if codec.serialization.is_lossless() {
            assert_eq!(out, data, "{} case {i}", codec.label());
        } else {
            for (chunk_in, chunk_out) in data.chunks(4).zip(out.chunks(4)) {
                let max_abs = chunk_in.iter().fold(0.0f32, |m, x| m.max(x.abs()));
                let rate = match codec.serialization {
                    Serialization::Zfp(r) => r,
                    _ => unreachable!(),
                };
                let bound = zfp::error_bound(max_abs, rate);
                for (a, b) in chunk_in.iter().zip(chunk_out) {
                    assert!((a - b).abs() <= bound, "{}: |{a}-{b}| > {bound}", codec.label());
                }
            }
        }
    }
}

#[test]
fn prop_lz4_never_corrupts() {
    let mut rng = Rng::new(1002);
    for _ in 0..CASES {
        let n = rng.range(0, 100_000);
        let data = match rng.below(3) {
            0 => rng.bytes(n),
            1 => rng.compressible_bytes(n.max(1)),
            _ => {
                // f32 tensor bytes
                Tensor::random(vec![n / 4 + 1], rng.next_u64()).to_le_bytes()
            }
        };
        let c = lz4::compress(&data);
        assert_eq!(lz4::decompress(&c, data.len()).unwrap(), data);
    }
}

#[test]
fn prop_lz4_rejects_mutations() {
    // Mutating the compressed stream must never return wrong data silently
    // *of the advertised length*: either an error, or (rarely) a valid
    // parse that still decodes — in which case the wire CRC catches it.
    // Here we only require no panic and no wrong-length success.
    let mut rng = Rng::new(1003);
    let data = rng.compressible_bytes(5000);
    let c = lz4::compress(&data);
    for _ in 0..CASES {
        let mut bad = c.clone();
        let pos = rng.range(0, bad.len() - 1);
        bad[pos] ^= 1 + (rng.next_u64() as u8 & 0x7F);
        match lz4::decompress(&bad, data.len()) {
            Ok(out) => assert_eq!(out.len(), data.len()),
            Err(_) => {}
        }
    }
}

#[test]
fn prop_wire_messages_survive_any_payload() {
    let mut rng = Rng::new(1004);
    for _ in 0..CASES {
        let n = rng.range(0, 50_000);
        let msg = Message {
            msg_type: MessageType::Data,
            frame: rng.next_u64(),
            serialized_len: rng.next_u64() % (1 << 40),
            count: rng.next_u64() % (1 << 40),
            batch: 1 + rng.next_u64() as u32 % 1024,
            payload: rng.bytes(n),
        };
        let mut buf = Vec::new();
        write_message(&mut buf, &msg, &Link::ideal(), &ByteCounter::new()).unwrap();
        let got = read_message(&mut buf.as_slice(), &ByteCounter::new()).unwrap();
        assert_eq!(got, msg);
    }
}

#[test]
fn prop_wire_detects_any_single_byte_flip() {
    let mut rng = Rng::new(1005);
    let msg = Message {
        msg_type: MessageType::Data,
        frame: 7,
        serialized_len: 100,
        count: 25,
        batch: 5,
        payload: rng.bytes(100),
    };
    let mut buf = Vec::new();
    write_message(&mut buf, &msg, &Link::ideal(), &ByteCounter::new()).unwrap();
    for _ in 0..CASES {
        let mut bad = buf.clone();
        let pos = rng.range(0, bad.len() - 1);
        let flip = 1u8 << rng.range(0, 7);
        bad[pos] ^= flip;
        match read_message(&mut bad.as_slice(), &ByteCounter::new()) {
            // Header length fields may make the reader want more bytes
            // (io error), or magic/type/crc checks fire. A clean parse must
            // only happen if the flip cancelled out — impossible for 1 bit.
            Ok(got) => {
                // Flips in the *ignored pad bytes* of the header are the one
                // place a parse may still succeed; the message content must
                // then be identical.
                assert_eq!(got, msg, "silent corruption at byte {pos} bit {flip}");
            }
            Err(_) => {}
        }
    }
}

#[test]
fn prop_crc32_linearity() {
    // crc(a) != crc(b) for random a != b (sanity, not a proof).
    let mut rng = Rng::new(1006);
    for _ in 0..CASES {
        let n = rng.range(1, 1000);
        let a = rng.bytes(n);
        let mut b = a.clone();
        let pos = rng.range(0, b.len() - 1);
        b[pos] ^= 0x01;
        assert_ne!(crc32(&a), crc32(&b));
    }
}

#[test]
fn prop_pipe_preserves_fifo_under_concurrency() {
    // forall interleavings: receiver sees exactly 0..n in order (the chain's
    // FIFO guarantee that keeps DEFER results ordered).
    let mut rng = Rng::new(1007);
    for _ in 0..20 {
        let n = rng.range(1, 500) as u64;
        let depth = rng.range(1, 8);
        let (tx, rx) = pipe::<u64>(depth);
        let h = std::thread::spawn(move || {
            for i in 0..n {
                tx.send(i).unwrap();
            }
        });
        let mut expect = 0u64;
        while expect < n {
            assert_eq!(rx.recv(), Some(expect));
            expect += 1;
        }
        h.join().unwrap();
    }
}

#[test]
fn prop_json_parser_never_panics_on_garbage() {
    let mut rng = Rng::new(1008);
    for _ in 0..CASES * 4 {
        let n = rng.range(0, 200);
        let bytes = rng.bytes(n);
        if let Ok(text) = std::str::from_utf8(&bytes) {
            let _ = json::parse(text); // must not panic
        }
        // Mutate a valid document too.
        let mut doc = br#"{"a": [1, 2.5, "x"], "b": {"c": null}}"#.to_vec();
        let pos = rng.range(0, doc.len() - 1);
        doc[pos] = rng.next_u64() as u8;
        if let Ok(text) = std::str::from_utf8(&doc) {
            let _ = json::parse(text);
        }
    }
}

#[test]
fn prop_json_value_round_trip() {
    // Random JSON trees survive to_string -> parse exactly.
    fn gen(rng: &mut Rng, depth: usize) -> json::Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => json::Json::Null,
            1 => json::Json::Bool(rng.below(2) == 0),
            2 => json::Json::Num((rng.next_u64() % 1_000_000) as f64 / 8.0),
            3 => json::Json::Str(format!("s{}", rng.next_u64() % 10_000)),
            4 => json::Json::Arr((0..rng.range(0, 4)).map(|_| gen(rng, depth - 1)).collect()),
            _ => json::Json::Obj(
                (0..rng.range(0, 4))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Rng::new(1009);
    for _ in 0..CASES {
        let v = gen(&mut rng, 3);
        let text = json::to_string(&v);
        assert_eq!(json::parse(&text).unwrap(), v);
    }
}

#[test]
fn prop_zfp_rate_size_monotonic() {
    // Higher rate -> larger payload, lower error, for the same data.
    let mut rng = Rng::new(1010);
    for _ in 0..30 {
        let n = rng.range(16, 2000);
        let data: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let mut last_size = 0usize;
        for rate in [4u8, 8, 16, 24, 32] {
            let enc = zfp::encode(&data, zfp::ZfpRate(rate)).unwrap();
            assert!(enc.len() > last_size);
            last_size = enc.len();
        }
    }
}
