//! Deterministic fuzz-corpus replay (PR 8).
//!
//! `rust/fuzz/` carries real cargo-fuzz targets for the parsers on the
//! hostile-input boundary (wire headers, frame assembly, the DFCK chunk
//! container, the recovery NACK/retry control frames, ZFP and LZ4
//! decode). CI cannot run a coverage-guided
//! fuzzer, so this test regenerates the seed corpus those targets start
//! from — valid artifacts plus systematic truncations and deterministic
//! byte/bit flips — and replays every case through the same entry
//! points. The contract under replay is crash-freedom: every input must
//! come back `Ok` or `Err`, never a panic, out-of-bounds, or runaway
//! allocation.

use defer::compress::{lz4, Compression};
use defer::serial::chunked::{self, CodecRuntime};
use defer::serial::zfp::{self, ZfpRate};
use defer::serial::{Codec, CodecKernel, Serialization};
use defer::util::prng::Rng;
use defer::wire::{
    crc32, parse_chunk_control, FrameAssembler, Header, Message, MessageType, HEADER_SIZE,
};

/// Refuse to let a mutated length field turn the replay into an OOM:
/// corpus cases whose parsed payload length exceeds this are still fed
/// to `Header::parse` (which must not allocate) but not to the
/// allocating assembler. The real fuzz targets apply the same guard.
const MAX_REPLAY_PAYLOAD: u64 = 1 << 20;

/// Mirror of the wire header layout (see `wire::encode_header`): the
/// corpus builder must not depend on the code under test for framing.
fn build_wire_frame(
    msg_type: u8,
    frame: u64,
    batch_minus_1: u32,
    count: u64,
    payload: &[u8],
) -> Vec<u8> {
    let mut h = [0u8; HEADER_SIZE];
    h[0..4].copy_from_slice(&0x4445_4652u32.to_le_bytes()); // "DEFR"
    h[4] = msg_type;
    h[5..8].copy_from_slice(&batch_minus_1.to_le_bytes()[..3]);
    h[8..16].copy_from_slice(&frame.to_le_bytes());
    h[16..24].copy_from_slice(&(payload.len() as u64).to_le_bytes());
    h[24..32].copy_from_slice(&(payload.len() as u64).to_le_bytes());
    h[32..40].copy_from_slice(&count.to_le_bytes());
    let crc = crc32::finish(crc32::update(
        crc32::update(crc32::init(), &h[0..40]),
        payload,
    ));
    h[40..44].copy_from_slice(&crc.to_le_bytes());
    let mut out = h.to_vec();
    out.extend_from_slice(payload);
    out
}

/// Systematic mutations of one seed: the seed itself, truncations at
/// structurally interesting lengths, single-byte flips at every offset
/// (for short seeds) or rng-chosen offsets (for long ones), and a few
/// multi-flip cases.
fn mutations(seed: &[u8], rng: &mut Rng) -> Vec<Vec<u8>> {
    let mut out = vec![seed.to_vec()];
    let cuts: Vec<usize> = if seed.len() <= 64 {
        (0..seed.len()).collect()
    } else {
        let mut c: Vec<usize> = (0..48).map(|_| rng.range(0, seed.len())).collect();
        c.extend([0, 1, 3, 4, 11, 12, 43, 44, seed.len() - 1]);
        c
    };
    for cut in cuts {
        out.push(seed[..cut.min(seed.len())].to_vec());
    }
    let flips: Vec<usize> = if seed.len() <= 96 {
        (0..seed.len()).collect()
    } else {
        (0..96).map(|_| rng.range(0, seed.len() - 1)).collect()
    };
    for pos in flips {
        let mut m = seed.to_vec();
        m[pos] ^= 1 << rng.below(8);
        out.push(m);
    }
    for _ in 0..16 {
        let mut m = seed.to_vec();
        for _ in 0..rng.range(2, 8) {
            if m.is_empty() {
                break;
            }
            let pos = rng.range(0, m.len() - 1);
            m[pos] = rng.next_u64() as u8;
        }
        out.push(m);
    }
    out
}

/// Mirror of `fuzz_targets/fuzz_wire_header.rs`.
fn replay_wire_header(case: &[u8]) {
    if case.len() < HEADER_SIZE {
        return;
    }
    let raw: [u8; HEADER_SIZE] = case[..HEADER_SIZE].try_into().unwrap();
    if let Ok(h) = Header::parse(&raw) {
        if h.wire_len <= MAX_REPLAY_PAYLOAD {
            let _ = h.into_message(case[HEADER_SIZE..].to_vec());
        }
    }
}

/// Mirror of `fuzz_targets/fuzz_frame_assembler.rs`: feed the stream in
/// adversarially sized slices with interleaved WouldBlock events.
fn replay_frame_assembler(case: &[u8]) {
    if case.len() >= HEADER_SIZE {
        let raw: [u8; HEADER_SIZE] = case[..HEADER_SIZE].try_into().unwrap();
        if let Ok(h) = Header::parse(&raw) {
            if h.wire_len > MAX_REPLAY_PAYLOAD {
                return;
            }
        }
    }
    let mut asm = FrameAssembler::new();
    let cursor = std::cell::Cell::new(0usize);
    let block_next = std::cell::Cell::new(false);
    let mut read = |buf: &mut [u8]| -> std::io::Result<usize> {
        if block_next.replace(false) {
            return Err(std::io::ErrorKind::WouldBlock.into());
        }
        let at = cursor.get();
        if at >= case.len() {
            return Ok(0); // EOF — the assembler must surface an error
        }
        let n = buf.len().min(case.len() - at).min(7);
        buf[..n].copy_from_slice(&case[at..at + n]);
        cursor.set(at + n);
        block_next.set(true);
        Ok(n)
    };
    // Drain until the assembler errors (EOF or protocol) or the stream
    // is exhausted with a clean boundary.
    for _ in 0..case.len() * 2 + 8 {
        match asm.poll(&mut read, None) {
            Ok(Some(_)) => {}
            Ok(None) => {}
            Err(_) => break,
        }
        if cursor.get() >= case.len() && asm.at_boundary() {
            break;
        }
    }
}

fn replay_chunk_container(
    case: &[u8],
    codec: &Codec,
    rt: &CodecRuntime,
    serialized_len: usize,
    count: usize,
) {
    // serialized_len / count cross-checks come from the outer header in
    // production; replay with the truthful values (so mutations reach
    // the per-chunk CRC and codec layers) and with lying ones.
    let _ = chunked::decode_frame(codec, case, serialized_len, count, rt, None);
    let _ = chunked::decode_frame(codec, case, case.len(), 1024, rt, None);
    let _ = chunked::decode_frame(codec, case, 1, 7, rt, None);
}

/// Mirror of `fuzz_targets/fuzz_chunk_control.rs`: the NACK/retry
/// control-frame parser plus the chunk span cutter it feeds, via both
/// the CRC-gated wire path and the in-process direct path.
fn replay_chunk_control(case: &[u8]) {
    if case.len() >= HEADER_SIZE {
        let raw: [u8; HEADER_SIZE] = case[..HEADER_SIZE].try_into().unwrap();
        if let Ok(h) = Header::parse(&raw) {
            if h.wire_len <= MAX_REPLAY_PAYLOAD {
                if let Ok(msg) = h.into_message(case[HEADER_SIZE..].to_vec()) {
                    if let Ok((idx, span)) = parse_chunk_control(&msg) {
                        let _ = chunked::chunk_payload_span(span, idx as usize);
                    }
                }
            }
        }
    }
    if case.len() >= 13 {
        let msg_type = if case[0] & 1 == 0 {
            MessageType::ChunkNack
        } else {
            MessageType::ChunkRetry
        };
        let msg = Message {
            msg_type,
            frame: u64::from_le_bytes(case[1..9].try_into().unwrap()),
            serialized_len: 0,
            count: 0,
            batch: 1,
            payload: case[9..].to_vec(),
        };
        if let Ok((idx, span)) = parse_chunk_control(&msg) {
            let _ = chunked::chunk_payload_span(span, idx as usize);
        }
    }
}

fn replay_zfp(case: &[u8]) {
    for kernel in [CodecKernel::Scalar, CodecKernel::Batched] {
        let _ = zfp::decode_kernel(case, kernel);
    }
}

fn replay_lz4(case: &[u8]) {
    for expected in [0usize, 1, 100, 4096, 100_000] {
        let _ = lz4::decompress(case, expected);
    }
}

#[test]
fn wire_header_and_assembler_survive_corpus() {
    let mut rng = Rng::new(8201);
    let mut seeds = Vec::new();
    // Valid frames across message types, batches, and payload shapes.
    for (ty, batch_m1, n) in [
        (3u8, 0u32, 0usize),
        (3, 0, 1),
        (3, 7, 4096),
        (1, 0, 300),
        (2, 0, 64),
        (4, 0, 17),
        (5, 0, 0),
        (6, 0, 0),
        (9, 0, 16), // invalid type survives as a parse error
    ] {
        let payload = rng.bytes(n);
        seeds.push(build_wire_frame(ty, rng.next_u64(), batch_m1, n as u64 / 4, &payload));
    }
    // Raw noise never shaped like a frame at all.
    seeds.push(rng.bytes(200));
    seeds.push(vec![0u8; HEADER_SIZE]);
    for seed in &seeds {
        for case in mutations(seed, &mut rng) {
            replay_wire_header(&case);
            replay_frame_assembler(&case);
        }
    }
}

#[test]
fn chunk_container_survives_corpus() {
    let mut rng = Rng::new(8202);
    let rt = CodecRuntime::chunked(1024, None).unwrap();
    for codec in Codec::paper_sweep() {
        let count = 3000usize;
        let data: Vec<f32> = (0..count).map(|_| rng.normal_f32()).collect();
        let (container, mid) = chunked::encode_frame(&codec, &data, &rt, None);
        let seeds = vec![container, rng.bytes(100)];
        for seed in &seeds {
            for case in mutations(seed, &mut rng) {
                replay_chunk_container(&case, &codec, &rt, mid, count);
            }
        }
    }
}

#[test]
fn chunk_control_frames_survive_corpus() {
    let mut rng = Rng::new(8205);
    // A genuine retry answers a NACK with the retained wire bytes of
    // exactly one chunk — cut a real span so the unmutated seed drives
    // the accepted path end to end.
    let rt = CodecRuntime::chunked(256, None).unwrap();
    let codec = Codec::new(Serialization::Binary, Compression::None);
    let data: Vec<f32> = (0..600).map(|_| rng.normal_f32()).collect();
    let (container, _mid) = chunked::encode_frame(&codec, &data, &rt, None);
    let span = chunked::chunk_payload_span(&container, 1).unwrap();
    let mut retry_payload = 1u32.to_le_bytes().to_vec();
    retry_payload.extend_from_slice(&container[span.clone()]);

    // Positive path: the parser recovers the index and span verbatim.
    let msg = defer::wire::chunk_retry(9, 1, &container[span.clone()]);
    let (idx, bytes) = parse_chunk_control(&msg).unwrap();
    assert_eq!(idx, 1);
    assert_eq!(bytes, &container[span]);

    let mut seeds = Vec::new();
    // Wire-framed NACK (type 7) and retry (type 8).
    seeds.push(build_wire_frame(7, 3, 0, 0, &1u32.to_le_bytes()));
    seeds.push(build_wire_frame(8, 3, 0, 0, &retry_payload));
    // Direct-path seeds: selector byte + frame id + control payload.
    let mut direct = vec![1u8];
    direct.extend_from_slice(&3u64.to_le_bytes());
    direct.extend_from_slice(&retry_payload);
    seeds.push(direct);
    // A retry whose trailing bytes are a whole container (index aimed at
    // the span cutter's bounds checks), and raw noise.
    let mut whole = vec![1u8];
    whole.extend_from_slice(&3u64.to_le_bytes());
    whole.extend_from_slice(&u32::MAX.to_le_bytes());
    whole.extend_from_slice(&container);
    seeds.push(whole);
    seeds.push(rng.bytes(64));
    for seed in &seeds {
        for case in mutations(seed, &mut rng) {
            replay_chunk_control(&case);
        }
    }
}

#[test]
fn zfp_and_lz4_decode_survive_corpus() {
    let mut rng = Rng::new(8203);
    let mut seeds = Vec::new();
    for (n, rate) in [(0usize, 8u8), (5, 3), (1000, 8), (257, 32)] {
        let data: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 100.0).collect();
        let mut enc = Vec::new();
        zfp::encode_into_kernel(&data, ZfpRate(rate), &mut enc, CodecKernel::Batched).unwrap();
        seeds.push(enc);
    }
    for seed in &seeds {
        for case in mutations(seed, &mut rng) {
            replay_zfp(&case);
        }
    }

    let lz_seeds = vec![
        lz4::compress(&rng.compressible_bytes(5000)),
        lz4::compress(&rng.bytes(700)),
        lz4::compress(b""),
        rng.bytes(300),
    ];
    for seed in &lz_seeds {
        for case in mutations(seed, &mut rng) {
            replay_lz4(&case);
        }
    }
}

/// Round-trip sanity so the corpus is known to contain *accepted* cases
/// too — a replay suite that only ever exercises rejection paths would
/// silently stop covering the happy path.
#[test]
fn unmutated_seeds_still_parse() {
    let mut rng = Rng::new(8204);
    let payload = rng.bytes(512);
    let frame = build_wire_frame(3, 42, 0, 128, &payload);
    let raw: [u8; HEADER_SIZE] = frame[..HEADER_SIZE].try_into().unwrap();
    let h = Header::parse(&raw).unwrap();
    assert_eq!(h.wire_len, 512);
    let msg = h.into_message(frame[HEADER_SIZE..].to_vec()).unwrap();
    assert_eq!(msg.frame, 42);
    assert_eq!(msg.count, 128);

    let mut asm = FrameAssembler::new();
    let mut cursor = 0usize;
    let mut read = |buf: &mut [u8]| -> std::io::Result<usize> {
        let n = buf.len().min(frame.len() - cursor).min(13);
        buf[..n].copy_from_slice(&frame[cursor..cursor + n]);
        cursor += n;
        Ok(n)
    };
    let msg = loop {
        if let Some(m) = asm.poll(&mut read, None).unwrap() {
            break m;
        }
    };
    assert_eq!(msg.payload, payload);
    assert!(asm.at_boundary());
}
