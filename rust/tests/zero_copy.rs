//! Zero-copy data-plane acceptance suite (artifact-free).
//!
//! PR contract: after a short warm-up the steady-state frame path
//! performs **no** payload memcpy between the encoder's output buffer
//! and the socket (or the receiver's decoder), and **no** fresh
//! allocation — every buffer comes from and returns to a bounded
//! [`BufPool`]. Coverage:
//!
//! 1. Steady state: the same mesh run at two very different frame
//!    counts records exactly zero payload copies at either length, and
//!    pool misses stay under a frame-count-independent warm-up ceiling
//!    (misses track the in-flight high-water mark, which backpressure
//!    caps at the mesh's pipe capacity) — on both transports and both
//!    I/O planes.
//! 2. Syscall bill: on the reactor+TCP plane every egressed message
//!    leaves in ~one `writev` (header + payload gathered), so the
//!    syscall counter tracks the message count, not twice it.
//! 3. Partial-write resume: `wire::write_all_vectored` survives short
//!    writes mid-header, mid-payload, and exactly at the iovec
//!    boundary, plus `Interrupted` retries and `Ok(0)` surfacing as
//!    `WriteZero`.
//!
//! The copy/syscall/pool counters are process-global
//! ([`defer::metrics::zerocopy`]), so every test that reads them holds
//! one shared lock and scopes its reading with snapshot deltas.

use std::io::{IoSlice, Write};
use std::sync::{Arc, Mutex, MutexGuard};

use defer::compress::Compression;
use defer::coordinator::dispatcher::{run_inference, DispatcherStats, InferenceOptions};
use defer::coordinator::pipeline::{run_codec_pipeline, PipelineCtx};
use defer::energy::EnergyModel;
use defer::metrics::{zerocopy, ByteCounter};
use defer::netem::{Link, LinkSpec};
use defer::netio::Reactor;
use defer::serial::{Codec, CodecRuntime, Serialization};
use defer::tensor::Tensor;
use defer::threadpool::pipe;
use defer::topology::wiring::{build, FrameSink, FrameSource, TransportOptions, Wiring, WorkerConns};
use defer::topology::Topology;
use defer::util::bufpool::BufPool;
use defer::util::timer::SharedTimer;
use defer::wire::{write_all_vectored, Message, MessageType, SharedPayload, WireFrame};

const ELEMS: usize = 64;
const PIPE_DEPTH: usize = 4;

/// The zero-copy counters are process-global; tests that read them must
/// not interleave. (Poison recovery: a failed test must not cascade.)
static COUNTER_LOCK: Mutex<()> = Mutex::new(());

fn counter_lock() -> MutexGuard<'static, ()> {
    COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------
// Steady-state: zero copies, warm-up-bounded pool misses.
// ---------------------------------------------------------------------

/// Spawn one synthetic worker (elementwise `v -> 2v + 1`) wired exactly
/// like `compute_node`'s inference phase: one bounded buffer pool shared
/// by the boundary reader (pooled receive) and the codec runtime (pooled
/// encode scratch + decode return).
fn spawn_worker(
    wc: WorkerConns,
    codec: Codec,
    reactor: Option<Arc<Reactor>>,
) -> std::thread::JoinHandle<defer::Result<()>> {
    std::thread::spawn(move || {
        let WorkerConns {
            view,
            config: _config,
            weights: _weights,
            data_in,
            data_out,
        } = wc;
        let pool = Arc::new(BufPool::new(PIPE_DEPTH + 2));
        let (tx, rx) = pipe::<Message>(PIPE_DEPTH);
        let mut reader = None;
        let out: FrameSink = match &reactor {
            Some(r) => {
                r.register_ingress(data_in, tx, Some(Arc::clone(&pool)))?;
                r.register_egress(data_out, PIPE_DEPTH)?.into()
            }
            None => {
                let mut in_conn = data_in;
                let reader_pool = Arc::clone(&pool);
                reader = Some(std::thread::spawn(move || loop {
                    match in_conn.recv_pooled(&ByteCounter::new(), Some(&reader_pool)) {
                        Ok(msg) => {
                            let stop = msg.msg_type == MessageType::Shutdown;
                            if tx.send(msg).is_err() || stop {
                                return;
                            }
                        }
                        Err(_) => return,
                    }
                }));
                data_out.into()
            }
        };
        let ctx = PipelineCtx {
            name: view.name.clone(),
            codec,
            rt: CodecRuntime::serial().with_buffers(Arc::clone(&pool)),
            overhead: SharedTimer::new(),
            data_tx: ByteCounter::new(),
            frames: ByteCounter::new(),
            out_link: Arc::new(Link::ideal()),
            pipelined: true,
            pipe_depth: PIPE_DEPTH,
            payload_pool: Some(pool),
            recovery: None,
        };
        let result = run_codec_pipeline(rx, out, ctx, |values, _batch| {
            Ok(values.iter().map(|v| v * 2.0 + 1.0).collect())
        });
        if let Some(h) = reader {
            h.join().expect("reader thread");
        }
        result
    })
}

/// Run `frames` cycles through a [1, 1] mesh on the given transport and
/// plane; returns the counter movement this run caused. Caller holds
/// [`counter_lock`].
fn run_counted(tcp: bool, blocking: bool, frames: u64) -> zerocopy::Snapshot {
    let before = zerocopy::snapshot();
    let reactor = if blocking {
        None
    } else {
        Some(Reactor::new(2).unwrap())
    };
    let replicas = [1usize, 1];
    let topo = Topology::new(&replicas, vec![LinkSpec::ideal(); replicas.len() + 1]).unwrap();
    let Wiring {
        control,
        to_first,
        from_last,
        workers,
        junctions,
    } = build(
        &topo,
        &TransportOptions {
            tcp,
            base_port: None,
            pipe_depth: PIPE_DEPTH,
            relay_junctions: false,
            recovery: None,
        },
    )
    .unwrap();
    drop(control);
    let codec = Codec::new(Serialization::Binary, Compression::None);
    let workers: Vec<_> = workers
        .into_iter()
        .map(|wc| spawn_worker(wc, codec, reactor.clone()))
        .collect();

    let input = Tensor::new(vec![ELEMS], vec![3.0; ELEMS]).unwrap();
    // Two stages of v -> 2v + 1.
    let expected = Tensor::new(vec![ELEMS], vec![(3.0f32 * 2.0 + 1.0) * 2.0 + 1.0; ELEMS]).unwrap();
    let stats = Arc::new(DispatcherStats::new(EnergyModel::default()));
    let opts = InferenceOptions {
        pipelined: true,
        pipe_depth: PIPE_DEPTH,
        ..InferenceOptions::default()
    };
    match &reactor {
        Some(r) => {
            let sink: FrameSink = r.register_egress(to_first, PIPE_DEPTH).unwrap().into();
            let (res_tx, res_rx) = pipe::<Message>(PIPE_DEPTH);
            let err = r.register_ingress(from_last, res_tx, None).unwrap();
            let source = FrameSource::Queued { rx: res_rx, err };
            run_inference(
                input,
                frames,
                sink,
                source,
                opts,
                Arc::new(Link::ideal()),
                Arc::clone(&stats),
                Some(expected),
                vec![ELEMS],
            )
            .unwrap();
        }
        None => {
            run_inference(
                input,
                frames,
                to_first,
                from_last,
                opts,
                Arc::new(Link::ideal()),
                Arc::clone(&stats),
                Some(expected),
                vec![ELEMS],
            )
            .unwrap();
        }
    }
    for w in workers {
        w.join().unwrap().unwrap();
    }
    junctions.join().unwrap();
    // The frame path must also stay bit-exact while not copying.
    assert_eq!(*stats.reference_error.lock().unwrap(), Some(0.0));
    drop(reactor);
    zerocopy::snapshot().since(&before)
}

/// Pool misses track the high-water mark of in-flight buffers, which
/// hard backpressure caps at the mesh's total pipe capacity — a
/// constant of the topology, *not* of the frame count. A generous
/// ceiling for the [1, 1] mesh at `PIPE_DEPTH = 4` (every pipe full,
/// every pool ahead by its retention bound, both directions).
const WARMUP_MISS_CEILING: u64 = 96;

/// The core steady-state property, per (transport, plane) combination:
/// a 6x longer run moves 6x the frames but pays zero payload copies at
/// any length, and its allocation bill stays under the warm-up ceiling
/// instead of scaling with traffic.
fn assert_steady_state(tcp: bool, blocking: bool) {
    let _guard = counter_lock();
    let short_frames = 40u64;
    let long_frames = 240u64;
    let short = run_counted(tcp, blocking, short_frames);
    let long = run_counted(tcp, blocking, long_frames);
    for (delta, label) in [(&short, "short"), (&long, "long")] {
        assert_eq!(
            delta.payload_copies, 0,
            "{label} run copied payloads (tcp={tcp}, blocking={blocking}): {delta:?}"
        );
        assert!(
            delta.pool_misses <= WARMUP_MISS_CEILING,
            "{label} run allocated past the warm-up ceiling \
             (tcp={tcp}, blocking={blocking}): {delta:?}"
        );
    }
    // 6x the frames, same allocation ceiling: misses must not have
    // moved with traffic (small slack for in-flight high-water jitter).
    assert!(
        long.pool_misses <= short.pool_misses + 32,
        "pool misses scale with traffic — not warm-up-bounded \
         (tcp={tcp}, blocking={blocking}): short {short:?} vs long {long:?}"
    );
    // Steady state is pool-served: at least dispatcher encode + one
    // encode per stage per frame come from the free lists.
    assert!(
        long.pool_hits >= 2 * long_frames,
        "steady state barely hit the pool (tcp={tcp}, blocking={blocking}): {long:?}"
    );
    if blocking || !tcp {
        // Vectored-egress syscalls are only counted by the reactor's
        // TCP write machine.
        assert_eq!(short.egress_syscalls, 0, "unexpected syscall count source");
        assert_eq!(long.egress_syscalls, 0, "unexpected syscall count source");
    }
}

#[test]
fn steady_state_zero_copy_local_blocking() {
    assert_steady_state(false, true);
}

#[test]
fn steady_state_zero_copy_local_reactor() {
    assert_steady_state(false, false);
}

#[test]
fn steady_state_zero_copy_tcp_blocking() {
    assert_steady_state(true, true);
}

#[test]
fn steady_state_zero_copy_tcp_reactor() {
    assert_steady_state(true, false);
}

#[test]
fn reactor_tcp_egress_is_one_syscall_per_message() {
    let _guard = counter_lock();
    let frames = 24u64;
    let delta = run_counted(true, false, frames);
    // Reactor-registered egress endpoints: the dispatcher sink plus one
    // per worker (2 stages), each shipping `frames` data messages and
    // one shutdown.
    let messages = 3 * (frames + 1);
    assert!(
        delta.egress_syscalls >= messages,
        "every message needs at least one write: {} < {messages}",
        delta.egress_syscalls
    );
    // One gathered writev per message at steady state; small frames on
    // loopback leave a little slack for the rare short write / EAGAIN
    // retry, but nowhere near the 2x of a split header+payload path.
    assert!(
        delta.egress_syscalls <= messages + frames,
        "vectored egress regressed toward split writes: {} syscalls for \
         {messages} messages",
        delta.egress_syscalls
    );
    assert_eq!(delta.payload_copies, 0);
}

// ---------------------------------------------------------------------
// Partial-write resume across the header|payload iovec boundary.
// ---------------------------------------------------------------------

/// A sink that accepts at most a scripted number of bytes per call (the
/// script cycles), optionally failing with `Interrupted` at scripted
/// call indices — a deterministic stand-in for a socket under pressure.
struct ShortWriter {
    out: Vec<u8>,
    caps: Vec<usize>,
    call: usize,
    interrupt_at: Vec<usize>,
}

impl ShortWriter {
    fn new(caps: &[usize]) -> ShortWriter {
        ShortWriter {
            out: Vec::new(),
            caps: caps.to_vec(),
            call: 0,
            interrupt_at: Vec::new(),
        }
    }

    fn cap(&mut self) -> std::io::Result<usize> {
        let i = self.call;
        self.call += 1;
        if self.interrupt_at.contains(&i) {
            return Err(std::io::ErrorKind::Interrupted.into());
        }
        Ok(self.caps[i % self.caps.len()])
    }
}

impl Write for ShortWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let cap = self.cap()?;
        let n = buf.len().min(cap);
        if n == 0 && !buf.is_empty() {
            return Ok(0);
        }
        self.out.extend_from_slice(&buf[..n]);
        Ok(n)
    }

    fn write_vectored(&mut self, bufs: &[IoSlice<'_>]) -> std::io::Result<usize> {
        let mut budget = self.cap()?;
        let total: usize = bufs.iter().map(|b| b.len()).sum();
        if budget == 0 && total > 0 {
            return Ok(0);
        }
        let mut n = 0;
        for b in bufs {
            let take = b.len().min(budget);
            self.out.extend_from_slice(&b[..take]);
            n += take;
            budget -= take;
            if budget == 0 {
                break;
            }
        }
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

fn frame_bytes() -> (WireFrame, Vec<u8>) {
    let payload: Vec<u8> = (0..200u16).map(|i| (i % 251) as u8).collect();
    let wf = WireFrame::new(
        MessageType::Data,
        5,
        1,
        payload.len() as u64,
        50,
        SharedPayload::from_vec(payload, None),
    )
    .unwrap();
    let wire = wf.to_wire_bytes();
    (wf, wire)
}

/// Drive `write_all_vectored` through a cap script and check the sink
/// holds exactly `head || body` afterwards.
fn assert_resumes(caps: &[usize]) {
    let (wf, wire) = frame_bytes();
    let mut w = ShortWriter::new(caps);
    write_all_vectored(&mut w, wf.header_bytes(), wf.payload_bytes()).unwrap();
    assert_eq!(w.out, wire, "resume with caps {caps:?} corrupted the stream");
}

#[test]
fn vectored_write_resumes_mid_header() {
    // Header is 44 bytes; 10-byte calls stall inside it four times.
    assert_resumes(&[10]);
}

#[test]
fn vectored_write_resumes_at_iovec_boundary() {
    // First call takes exactly the header, the next ones the payload.
    assert_resumes(&[44, 60]);
}

#[test]
fn vectored_write_resumes_mid_payload() {
    assert_resumes(&[50, 7, 1000]);
}

#[test]
fn vectored_write_single_call_fast_path() {
    assert_resumes(&[usize::MAX]);
}

#[test]
fn vectored_write_retries_interrupted() {
    let (wf, wire) = frame_bytes();
    let mut w = ShortWriter::new(&[13]);
    w.interrupt_at = vec![0, 3];
    write_all_vectored(&mut w, wf.header_bytes(), wf.payload_bytes()).unwrap();
    assert_eq!(w.out, wire);
}

#[test]
fn vectored_write_zero_surfaces_write_zero() {
    let (wf, _) = frame_bytes();
    let mut w = ShortWriter::new(&[16, 0]);
    let err = write_all_vectored(&mut w, wf.header_bytes(), wf.payload_bytes())
        .expect_err("a sink that accepts nothing must fail");
    assert_eq!(err.kind(), std::io::ErrorKind::WriteZero);
}

#[test]
fn wireframe_write_to_matches_wire_image() {
    let (wf, wire) = frame_bytes();
    let mut w = ShortWriter::new(&[31]);
    wf.write_to(&mut w).unwrap();
    assert_eq!(w.out, wire);
}

// ---------------------------------------------------------------------
// Shared-frame fan-out: clones share bytes, the last reference migrates.
// ---------------------------------------------------------------------

#[test]
fn shared_frames_fan_out_without_copying() {
    let _guard = counter_lock();
    let pool = Arc::new(BufPool::new(4));
    let mut buf = pool.take();
    buf.extend_from_slice(&[7u8; 4096]);
    let before = zerocopy::snapshot();
    let wf = WireFrame::new(
        MessageType::Data,
        0,
        1,
        4096,
        1024,
        SharedPayload::from_vec(buf, Some(Arc::clone(&pool))),
    )
    .unwrap();
    // Fan-out: egress queue + retention ring + failover reroute all
    // clone the frame, never the bytes.
    let a = wf.clone();
    let b = wf.clone();
    assert_eq!(a.payload_bytes().as_ptr(), b.payload_bytes().as_ptr());
    drop(a);
    drop(b);
    // Last reference: the buffer migrates out with no copy...
    let payload = wf.into_message().payload;
    assert_eq!(payload.len(), 4096);
    assert_eq!(zerocopy::snapshot().since(&before).payload_copies, 0);
    // ...so the pool gets it back only from the final consumer.
    assert_eq!(pool.pooled(), 0);
    pool.put(payload);
    assert_eq!(pool.pooled(), 1);
}
