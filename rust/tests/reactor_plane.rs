//! Reactor-vs-blocking data-plane acceptance suite (artifact-free).
//!
//! The reactor (`netio::Reactor`) must be an *invisible* replacement
//! for the thread-per-connection plane: same wire bytes, same frame
//! order, same error labels, fewer parked threads. Coverage:
//!
//! 1. Bit-identity: the same inference run on both planes records
//!    exactly 0.0 reference error and identical byte totals — at the
//!    dispatcher and at every worker — on both transports, through
//!    replicated meshes.
//! 2. FIFO: hand-built mixed-size batches through a replicated mesh
//!    driven end-to-end by reactor endpoints come back in global frame
//!    order, with the merged shutdown marker trailing.
//! 3. Failure labels: a dead peer surfaces as `send to {peer}` /
//!    `recv from {peer}`, exactly like the blocking plane — including a
//!    peer that dies *mid-run*, which must fail fast with exactly one
//!    root-cause error naming the peer and the last healthy frame.
//! 4. Teardown: a zero-frame run drains its shutdown broadcast cleanly.
//! 5. Thread bill: a u=d=4 mesh runs on 2 shards where the blocking
//!    plane parks one reader per worker.

use std::sync::Arc;
use std::time::Duration;

use defer::compress::Compression;
use defer::coordinator::dispatcher::{run_inference, DispatcherStats, InferenceOptions};
use defer::coordinator::pipeline::{run_codec_pipeline, PipelineCtx};
use defer::energy::EnergyModel;
use defer::metrics::ByteCounter;
use defer::netem::{Link, LinkSpec};
use defer::netio::Reactor;
use defer::serial::{Codec, CodecRuntime, Serialization};
use defer::tensor::Tensor;
use defer::threadpool::pipe;
use defer::topology::wiring::{
    build, DealSender, FrameSink, FrameSource, MergeReceiver, TransportOptions, Wiring,
    WorkerConns,
};
use defer::topology::Topology;
use defer::util::timer::SharedTimer;
use defer::wire::{Message, MessageType};

const ELEMS: usize = 64;

/// Spawn one synthetic worker (elementwise `v -> 2v + 1`). On the
/// blocking plane it parks a boundary-reader thread, exactly like the
/// legacy compute node; on the reactor plane the same pipe is fed by a
/// shard-owned ingress machine and the egress deal retires through a
/// queued sink — mirroring `compute_node`'s two branches. When
/// `die_after` is set, the compute closure fails once that many frames
/// have been processed — the mid-run death fixture.
fn spawn_worker(
    wc: WorkerConns,
    codec: Codec,
    rt: CodecRuntime,
    data_tx: ByteCounter,
    reactor: Option<Arc<Reactor>>,
    die_after: Option<u64>,
) -> std::thread::JoinHandle<defer::Result<()>> {
    std::thread::spawn(move || {
        let WorkerConns {
            view,
            config: _config,
            weights: _weights,
            data_in,
            data_out,
        } = wc;
        let (tx, rx) = pipe::<Message>(4);
        let mut ingress_err = None;
        let mut reader = None;
        let out: FrameSink = match &reactor {
            Some(r) => {
                ingress_err = Some(r.register_ingress(data_in, tx, None)?);
                r.register_egress(data_out, 4)?.into()
            }
            None => {
                let mut in_conn = data_in;
                reader = Some(std::thread::spawn(move || loop {
                    match in_conn.recv(&ByteCounter::new()) {
                        Ok(msg) => {
                            let stop = msg.msg_type == MessageType::Shutdown;
                            if tx.send(msg).is_err() || stop {
                                return;
                            }
                        }
                        Err(_) => return,
                    }
                }));
                data_out.into()
            }
        };
        let replica = view.replica;
        let ctx = PipelineCtx {
            name: view.name.clone(),
            codec,
            rt,
            overhead: SharedTimer::new(),
            data_tx,
            frames: ByteCounter::new(),
            out_link: Arc::new(Link::ideal()),
            pipelined: true,
            pipe_depth: 4,
            payload_pool: None,
            recovery: None,
        };
        let mut healthy = 0u64;
        let result = run_codec_pipeline(rx, out, ctx, move |values, batch| {
            if let Some(k) = die_after {
                if healthy >= k {
                    return Err(defer::DeferError::Runtime(format!(
                        "synthetic mid-run death after {k} frames"
                    )));
                }
            }
            healthy += batch.max(1) as u64;
            assert_eq!(values.len() % ELEMS, 0, "partial frame in batch");
            // Jitter per replica so a lost ordering guarantee would
            // actually scramble arrivals.
            std::thread::sleep(Duration::from_micros((replica as u64 % 3) * 400));
            Ok(values.iter().map(|v| v * 2.0 + 1.0).collect())
        });
        if let Some(h) = reader {
            h.join().expect("reader thread");
        }
        // A reactor ingress failure reaches the pipeline as a bare
        // closed-pipe error; prefer the labelled root cause.
        if result.is_err() {
            let stashed = ingress_err.as_ref().and_then(|s| s.lock().unwrap().take());
            if let Some(e) = stashed {
                return Err(e);
            }
        }
        result
    })
}

struct Harness {
    to_first: DealSender,
    from_last: MergeReceiver,
    workers: Vec<std::thread::JoinHandle<defer::Result<()>>>,
    junctions: defer::threadpool::WorkerPool,
    /// Per-worker data-egress byte counters, in spawn order.
    worker_tx: Vec<ByteCounter>,
    stages: usize,
}

fn harness(replicas: &[usize], tcp: bool, reactor: Option<&Arc<Reactor>>) -> Harness {
    harness_with(replicas, tcp, reactor, None)
}

fn harness_with(
    replicas: &[usize],
    tcp: bool,
    reactor: Option<&Arc<Reactor>>,
    die_after: Option<u64>,
) -> Harness {
    let hop_links = vec![LinkSpec::ideal(); replicas.len() + 1];
    let topo = Topology::new(replicas, hop_links).unwrap();
    let Wiring {
        control,
        to_first,
        from_last,
        workers,
        junctions,
    } = build(
        &topo,
        &TransportOptions {
            tcp,
            base_port: None,
            pipe_depth: 4,
            relay_junctions: false,
            recovery: None,
        },
    )
    .unwrap();
    drop(control); // no configuration phase for synthetic workers
    let codec = Codec::new(Serialization::Binary, Compression::None);
    let mut worker_tx = Vec::new();
    let workers: Vec<_> = workers
        .into_iter()
        .map(|wc| {
            let counter = ByteCounter::new();
            worker_tx.push(counter.clone());
            spawn_worker(
                wc,
                codec,
                CodecRuntime::serial(),
                counter,
                reactor.cloned(),
                die_after,
            )
        })
        .collect();
    Harness {
        to_first,
        from_last,
        workers,
        junctions,
        worker_tx,
        stages: replicas.len(),
    }
}

/// Each stage applies v -> 2v + 1; fold that over the chain depth.
fn expect_value(input: f32, stages: usize) -> f32 {
    let mut v = input;
    for _ in 0..stages {
        v = v * 2.0 + 1.0;
    }
    v
}

/// Run `run_inference` end to end on one plane. Returns the dispatcher
/// stats, the per-worker egress byte totals (spawn order), and the
/// reactor (when one drove the run) for shard-level assertions.
fn run_plane(
    replicas: &[usize],
    tcp: bool,
    blocking: bool,
    io_threads: usize,
    frames: u64,
    batch: usize,
) -> (Arc<DispatcherStats>, Vec<u64>, Option<Arc<Reactor>>) {
    let reactor = if blocking {
        None
    } else {
        Some(Reactor::new(io_threads).unwrap())
    };
    let Harness {
        to_first,
        from_last,
        workers,
        junctions,
        worker_tx,
        stages,
    } = harness(replicas, tcp, reactor.as_ref());
    let input = Tensor::new(vec![ELEMS], vec![3.0; ELEMS]).unwrap();
    let expected =
        Tensor::new(vec![ELEMS], vec![expect_value(3.0, stages); ELEMS]).unwrap();
    let stats = Arc::new(DispatcherStats::new(EnergyModel::default()));
    let opts = InferenceOptions {
        pipelined: true,
        pipe_depth: 4,
        batch,
        batch_adaptive: false,
        ..InferenceOptions::default()
    };
    match &reactor {
        Some(r) => {
            // Mirror the deployment chain: dispatcher egress becomes a
            // queued sink, dispatcher ingress a machine-fed pipe.
            let sink: FrameSink = r.register_egress(to_first, 4).unwrap().into();
            let (res_tx, res_rx) = pipe::<Message>(4);
            let err = r.register_ingress(from_last, res_tx, None).unwrap();
            let source = FrameSource::Queued { rx: res_rx, err };
            run_inference(
                input,
                frames,
                sink,
                source,
                opts,
                Arc::new(Link::ideal()),
                Arc::clone(&stats),
                Some(expected),
                vec![ELEMS],
            )
            .unwrap();
        }
        None => {
            run_inference(
                input,
                frames,
                to_first,
                from_last,
                opts,
                Arc::new(Link::ideal()),
                Arc::clone(&stats),
                Some(expected),
                vec![ELEMS],
            )
            .unwrap();
        }
    }
    for w in workers {
        w.join().unwrap().unwrap();
    }
    junctions.join().unwrap();
    let tx_totals = worker_tx.iter().map(|c| c.total()).collect();
    (stats, tx_totals, reactor)
}

/// The acceptance property: both planes must produce bit-identical
/// results (0.0 recorded reference error) and move *exactly* the same
/// bytes at every endpoint.
fn assert_planes_identical(replicas: &[usize], tcp: bool, frames: u64, batch: usize) {
    let (blocking, blocking_tx, _) = run_plane(replicas, tcp, true, 0, frames, batch);
    let (reactor, reactor_tx, _) = run_plane(replicas, tcp, false, 2, frames, batch);
    for (stats, plane) in [(&blocking, "blocking"), (&reactor, "reactor")] {
        assert_eq!(stats.clock.cycles(), frames, "{plane} cycles");
        assert_eq!(stats.latency.count(), frames, "{plane} latency count");
        assert_eq!(
            *stats.reference_error.lock().unwrap(),
            Some(0.0),
            "{plane} plane not bit-exact"
        );
    }
    assert_eq!(
        blocking.data_tx.total(),
        reactor.data_tx.total(),
        "dispatcher byte totals diverge across planes"
    );
    assert_eq!(
        blocking_tx, reactor_tx,
        "worker byte totals diverge across planes"
    );
}

#[test]
fn reactor_matches_blocking_on_local_pipes() {
    assert_planes_identical(&[1, 3, 2], false, 24, 2);
}

#[test]
fn reactor_matches_blocking_over_tcp() {
    assert_planes_identical(&[2, 2], true, 12, 3);
}

#[test]
fn zero_frames_drain_the_reactor_plane() {
    let (stats, _, _) = run_plane(&[1, 2], false, false, 2, 0, 4);
    assert_eq!(stats.clock.cycles(), 0);
    assert_eq!(stats.latency.count(), 0);
    assert_eq!(*stats.reference_error.lock().unwrap(), None);
}

#[test]
fn reactor_replaces_parked_readers_at_u4_d4() {
    // Blocking would park one reader thread per worker (8 at u=d=4)
    // plus the dispatcher's result reader; the reactor runs the same
    // mesh on 2 shards, and both shards actually move traffic.
    let workers: usize = [4usize, 4].iter().sum();
    let (stats, _, reactor) = run_plane(&[4, 4], false, false, 2, 16, 1);
    assert_eq!(*stats.reference_error.lock().unwrap(), Some(0.0));
    let reactor = reactor.expect("reactor plane");
    assert_eq!(reactor.io_threads(), 2);
    assert!(reactor.io_threads() < workers + 1, "no thread reduction");
    let shards = reactor.shard_stats();
    assert_eq!(shards.len(), 2);
    let (wakeups, dispatches) = shards
        .iter()
        .fold((0, 0), |(w, d), s| (w + s.0, d + s.1));
    assert!(wakeups > 0, "shards never woke");
    assert!(dispatches > 0, "shards never stepped a machine");
}

// ---------------------------------------------------------------------
// FIFO through a replicated mesh, reactor endpoints end to end.
// ---------------------------------------------------------------------

#[test]
fn mixed_batches_preserve_fifo_through_reactor_mesh() {
    let pattern = [1usize, 2, 3];
    let frames = 24u64;
    let reactor = Reactor::new(2).unwrap();
    let Harness {
        to_first,
        from_last,
        workers,
        junctions,
        worker_tx: _,
        stages,
    } = harness(&[1, 3, 2], false, Some(&reactor));
    let mut sink = reactor.register_egress(to_first, 4).unwrap();
    let (res_tx, res_rx) = pipe::<Message>(4);
    let err = reactor.register_ingress(from_last, res_tx, None).unwrap();
    let mut source = FrameSource::Queued { rx: res_rx, err };

    let codec = Codec::new(Serialization::Binary, Compression::None);
    let rt = CodecRuntime::serial();
    let link = Link::ideal();
    let counter = ByteCounter::new();

    let mut sent = 0u64;
    let mut step = 0usize;
    while sent < frames {
        let b = pattern[step % pattern.len()]
            .min((frames - sent) as usize)
            .max(1);
        step += 1;
        // Stack b frames, each filled with its own frame id.
        let mut values = Vec::with_capacity(ELEMS * b);
        for f in sent..sent + b as u64 {
            values.extend(std::iter::repeat(f as f32).take(ELEMS));
        }
        let (payload, mid) = codec.encode_frame(&values, &rt, None);
        sink.send_data(
            &Message {
                msg_type: MessageType::Data,
                frame: sent,
                serialized_len: mid as u64,
                count: values.len() as u64,
                batch: b as u32,
                payload,
            },
            &link,
            &counter,
        )
        .unwrap();
        sent += b as u64;
    }
    sink.broadcast_shutdown(&link, &counter).unwrap();

    // Frames must come back in global FIFO order, whole batches intact.
    let mut next = 0u64;
    while next < frames {
        let msg = source.recv(&counter).unwrap();
        assert_eq!(msg.msg_type, MessageType::Data);
        assert_eq!(msg.frame, next, "batches out of order");
        let b = msg.batch.max(1) as usize;
        let values = codec
            .decode_frame(
                &msg.payload,
                msg.serialized_len as usize,
                msg.count as usize,
                &rt,
                None,
            )
            .unwrap();
        assert_eq!(values.len(), ELEMS * b);
        for (i, sub) in values.chunks(ELEMS).enumerate() {
            let expect = expect_value((next + i as u64) as f32, stages);
            assert_eq!(sub, vec![expect; ELEMS], "frame {}", next + i as u64);
        }
        next += b as u64;
    }
    // The ingress machine drains the mesh and forwards one merged marker.
    assert_eq!(
        source.recv(&counter).unwrap().msg_type,
        MessageType::Shutdown
    );
    for h in workers {
        h.join().unwrap().unwrap();
    }
    junctions.join().unwrap();
}

// ---------------------------------------------------------------------
// Dead peers surface with the blocking plane's labels.
// ---------------------------------------------------------------------

#[test]
fn dead_egress_peer_error_names_the_peer() {
    let topo = Topology::new(&[1], vec![LinkSpec::ideal(); 2]).unwrap();
    let Wiring {
        control,
        to_first,
        from_last,
        workers,
        junctions,
    } = build(
        &topo,
        &TransportOptions {
            tcp: false,
            base_port: None,
            pipe_depth: 4,
            relay_junctions: false,
            recovery: None,
        },
    )
    .unwrap();
    drop(control);
    drop(workers); // the peer dies before reading anything
    drop(from_last);
    let reactor = Reactor::new(1).unwrap();
    let mut sink = reactor.register_egress(to_first, 4).unwrap();
    let codec = Codec::new(Serialization::Binary, Compression::None);
    let values = vec![1.0f32; ELEMS];
    let (payload, mid) = codec.encode_frame(&values, &CodecRuntime::serial(), None);
    let msg = Message {
        msg_type: MessageType::Data,
        frame: 0,
        serialized_len: mid as u64,
        count: values.len() as u64,
        batch: 1,
        payload,
    };
    let link = Link::ideal();
    let counter = ByteCounter::new();
    let mut last = Ok(());
    for _ in 0..64 {
        last = sink.send_data(&msg, &link, &counter);
        if last.is_err() {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let err = last.expect_err("dead peer must surface an error");
    let text = format!("{err}");
    assert!(
        text.contains("send to node0 data socket"),
        "unlabelled error: {text}"
    );
    junctions.join().unwrap();
}

#[test]
fn dead_ingress_peer_error_names_the_peer() {
    let topo = Topology::new(&[1], vec![LinkSpec::ideal(); 2]).unwrap();
    let Wiring {
        control,
        to_first,
        from_last,
        workers,
        junctions,
    } = build(
        &topo,
        &TransportOptions {
            tcp: false,
            base_port: None,
            pipe_depth: 4,
            relay_junctions: false,
            recovery: None,
        },
    )
    .unwrap();
    drop(control);
    drop(workers); // the peer dies without sending anything
    drop(to_first);
    let reactor = Reactor::new(1).unwrap();
    let (res_tx, res_rx) = pipe::<Message>(4);
    let err = reactor.register_ingress(from_last, res_tx, None).unwrap();
    let mut source = FrameSource::Queued { rx: res_rx, err };
    let e = source
        .recv(&ByteCounter::new())
        .expect_err("dead peer must surface an error");
    let text = format!("{e}");
    assert!(
        text.contains("recv from node0 data socket"),
        "unlabelled error: {text}"
    );
    junctions.join().unwrap();
}

// ---------------------------------------------------------------------
// Mid-run death, fail-fast mode (no recovery): one root cause, named.
// ---------------------------------------------------------------------

/// A worker that dies *mid-run* without recovery enabled must abort the
/// whole inference with exactly one root-cause error — the first in
/// dispatcher spawn order — that names the dead peer's data socket and
/// carries the last-healthy-frame context, the operator's breadcrumb
/// for a restart point. Exercised on both transports and both planes.
fn mid_run_death_names_peer(tcp: bool, blocking: bool) {
    let reactor = if blocking {
        None
    } else {
        Some(Reactor::new(1).unwrap())
    };
    let Harness {
        to_first,
        from_last,
        workers,
        junctions,
        worker_tx: _,
        stages: _,
    } = harness_with(&[1], tcp, reactor.as_ref(), Some(3));
    let input = Tensor::new(vec![ELEMS], vec![3.0; ELEMS]).unwrap();
    let stats = Arc::new(DispatcherStats::new(EnergyModel::default()));
    let opts = InferenceOptions {
        pipelined: true,
        pipe_depth: 4,
        batch: 1,
        batch_adaptive: false,
        ..InferenceOptions::default()
    };
    let frames = 24u64;
    let err = match &reactor {
        Some(r) => {
            let sink: FrameSink = r.register_egress(to_first, 4).unwrap().into();
            let (res_tx, res_rx) = pipe::<Message>(4);
            let ingress_err = r.register_ingress(from_last, res_tx, None).unwrap();
            let source = FrameSource::Queued {
                rx: res_rx,
                err: ingress_err,
            };
            run_inference(
                input,
                frames,
                sink,
                source,
                opts,
                Arc::new(Link::ideal()),
                Arc::clone(&stats),
                None,
                vec![ELEMS],
            )
            .expect_err("mid-run death must abort the run")
        }
        None => run_inference(
            input,
            frames,
            to_first,
            from_last,
            opts,
            Arc::new(Link::ideal()),
            Arc::clone(&stats),
            None,
            vec![ELEMS],
        )
        .expect_err("mid-run death must abort the run"),
    };
    let text = format!("{err}");
    assert!(
        text.contains("node0 data socket"),
        "root cause does not name the dead peer: {text}"
    );
    assert!(
        text.contains("(after frame"),
        "root cause lacks the last-healthy-frame context: {text}"
    );
    // The worker itself failed (the synthetic death, or the closed-pipe
    // wake it triggers); either way the harness must not hang on join.
    for w in workers {
        w.join().unwrap().unwrap_err();
    }
    junctions.join().unwrap();
    drop(reactor);
}

#[test]
fn mid_run_death_names_peer_local_blocking() {
    mid_run_death_names_peer(false, true);
}

#[test]
fn mid_run_death_names_peer_tcp_blocking() {
    mid_run_death_names_peer(true, true);
}

#[test]
fn mid_run_death_names_peer_local_reactor() {
    mid_run_death_names_peer(false, false);
}

#[test]
fn mid_run_death_names_peer_tcp_reactor() {
    mid_run_death_names_peer(true, false);
}
