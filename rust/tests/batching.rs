//! Micro-batching acceptance suite (artifact-free).
//!
//! Two layers of coverage, both driving real topology wiring with
//! synthetic pipeline workers standing in for PJRT executables:
//!
//! 1. Wire-level: hand-built batched messages (mixed batch sizes,
//!    short tails) through replicated stages on both transports — the
//!    frames must come back FIFO with correct per-frame values, because
//!    the deal/merge schedule rotates per *message* and is
//!    batch-size-blind.
//! 2. Dispatcher-level: the real `run_inference` batcher end to end —
//!    batched runs must be bit-identical to unbatched ones (the
//!    reference check records exactly 0.0 error), per-frame metrics
//!    must stay batch-size-invariant, tails flush short, zero frames
//!    terminate cleanly, and adaptive mode completes.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use defer::compress::Compression;
use defer::coordinator::dispatcher::{run_inference, DispatcherStats, InferenceOptions};
use defer::coordinator::pipeline::{run_codec_pipeline, PipelineCtx};
use defer::energy::EnergyModel;
use defer::metrics::ByteCounter;
use defer::netem::{Link, LinkSpec};
use defer::serial::{Codec, CodecRuntime, Serialization};
use defer::tensor::Tensor;
use defer::threadpool::pipe;
use defer::topology::wiring::{build, TransportOptions, WorkerConns};
use defer::topology::Topology;
use defer::util::timer::SharedTimer;
use defer::wire::{Message, MessageType};

const ELEMS: usize = 64;

/// Spawn one synthetic worker: a boundary-reader thread feeding the
/// real codec pipeline, with an elementwise `v -> 2v + 1` standing in
/// for the fused executables. Records the largest batch size it was
/// handed, so tests can assert coalescing actually happened.
fn spawn_worker(
    wc: WorkerConns,
    codec: Codec,
    rt: CodecRuntime,
    max_batch_seen: Arc<AtomicUsize>,
) -> std::thread::JoinHandle<defer::Result<()>> {
    std::thread::spawn(move || {
        let WorkerConns {
            view,
            config: _config,
            weights: _weights,
            data_in,
            data_out,
        } = wc;
        let (tx, rx) = pipe::<Message>(4);
        let mut in_conn = data_in;
        let reader = std::thread::spawn(move || loop {
            match in_conn.recv(&ByteCounter::new()) {
                Ok(msg) => {
                    let stop = msg.msg_type == MessageType::Shutdown;
                    if tx.send(msg).is_err() || stop {
                        return;
                    }
                }
                Err(_) => return,
            }
        });
        let replica = view.replica;
        let ctx = PipelineCtx {
            name: view.name.clone(),
            codec,
            rt,
            overhead: SharedTimer::new(),
            data_tx: ByteCounter::new(),
            frames: ByteCounter::new(),
            out_link: Arc::new(Link::ideal()),
            pipelined: true,
            pipe_depth: 4,
            payload_pool: None,
            recovery: None,
        };
        let result = run_codec_pipeline(rx, data_out, ctx, move |values, batch| {
            // A batch arrives as one stacked payload: b whole frames.
            assert_eq!(values.len(), ELEMS * batch, "partial frame in batch");
            max_batch_seen.fetch_max(batch, Ordering::Relaxed);
            // Jitter per replica so a lost ordering guarantee would
            // actually scramble arrivals.
            std::thread::sleep(std::time::Duration::from_micros(
                (replica as u64 % 3) * 400,
            ));
            Ok(values.iter().map(|v| v * 2.0 + 1.0).collect())
        });
        reader.join().expect("reader thread");
        result
    })
}

struct Harness {
    to_first: defer::topology::wiring::DealSender,
    from_last: defer::topology::wiring::MergeReceiver,
    workers: Vec<std::thread::JoinHandle<defer::Result<()>>>,
    junctions: defer::threadpool::WorkerPool,
    max_batch_seen: Arc<AtomicUsize>,
    stages: usize,
}

fn harness(replicas: &[usize], tcp: bool) -> Harness {
    let hop_links = vec![LinkSpec::ideal(); replicas.len() + 1];
    let topo = Topology::new(replicas, hop_links).unwrap();
    let defer::topology::wiring::Wiring {
        control,
        to_first,
        from_last,
        workers,
        junctions,
    } = build(
        &topo,
        &TransportOptions {
            tcp,
            base_port: None,
            pipe_depth: 4,
            relay_junctions: false,
            recovery: None,
        },
    )
    .unwrap();
    drop(control); // no configuration phase for synthetic workers
    let codec = Codec::new(Serialization::Binary, Compression::None);
    let max_batch_seen = Arc::new(AtomicUsize::new(0));
    let workers: Vec<_> = workers
        .into_iter()
        .map(|wc| {
            spawn_worker(
                wc,
                codec,
                CodecRuntime::serial(),
                Arc::clone(&max_batch_seen),
            )
        })
        .collect();
    Harness {
        to_first,
        from_last,
        workers,
        junctions,
        max_batch_seen,
        stages: replicas.len(),
    }
}

impl Harness {
    fn join(self) {
        for h in self.workers {
            h.join().unwrap().unwrap();
        }
        self.junctions.join().unwrap();
    }
}

/// Each stage applies v -> 2v + 1; fold that over the chain depth.
fn expect_value(input: f32, stages: usize) -> f32 {
    let mut v = input;
    for _ in 0..stages {
        v = v * 2.0 + 1.0;
    }
    v
}

// ---------------------------------------------------------------------
// Layer 1: hand-built batched wire messages, FIFO through replication.
// ---------------------------------------------------------------------

/// Send `frames` frames coalesced per the cycling `pattern` of batch
/// sizes; assert the dispatcher side gets every frame back in FIFO
/// order with the per-frame transform applied.
fn run_batched_wire(replicas: &[usize], tcp: bool, pattern: &[usize], frames: u64) {
    let mut h = harness(replicas, tcp);
    let codec = Codec::new(Serialization::Binary, Compression::None);
    let rt = CodecRuntime::serial();
    let link = Link::ideal();
    let counter = ByteCounter::new();

    let mut sent = 0u64;
    let mut step = 0usize;
    while sent < frames {
        let b = pattern[step % pattern.len()].min((frames - sent) as usize).max(1);
        step += 1;
        // Stack b frames, each filled with its own frame id.
        let mut values = Vec::with_capacity(ELEMS * b);
        for f in sent..sent + b as u64 {
            values.extend(std::iter::repeat(f as f32).take(ELEMS));
        }
        let (payload, mid) = codec.encode_frame(&values, &rt, None);
        h.to_first
            .send_data(
                &Message {
                    msg_type: MessageType::Data,
                    frame: sent,
                    serialized_len: mid as u64,
                    count: values.len() as u64,
                    batch: b as u32,
                    payload,
                },
                &link,
                &counter,
            )
            .unwrap();
        sent += b as u64;
    }
    h.to_first.broadcast_shutdown(&link, &counter).unwrap();

    // Frames must come back in global FIFO order, whole batches intact.
    let mut next = 0u64;
    while next < frames {
        let msg = h.from_last.recv(&counter).unwrap();
        assert_eq!(msg.msg_type, MessageType::Data);
        assert_eq!(msg.frame, next, "batches out of order");
        let b = msg.batch.max(1) as usize;
        let values = codec
            .decode_frame(
                &msg.payload,
                msg.serialized_len as usize,
                msg.count as usize,
                &rt,
                None,
            )
            .unwrap();
        assert_eq!(values.len(), ELEMS * b);
        for (i, sub) in values.chunks(ELEMS).enumerate() {
            let expect = expect_value((next + i as u64) as f32, h.stages);
            assert_eq!(sub, vec![expect; ELEMS], "frame {}", next + i as u64);
        }
        next += b as u64;
    }
    assert_eq!(
        h.from_last.recv(&counter).unwrap().msg_type,
        MessageType::Shutdown
    );
    h.join();
}

#[test]
fn mixed_batches_preserve_fifo_across_replicated_stages() {
    run_batched_wire(&[1, 3, 2], false, &[1, 2, 3], 24);
}

#[test]
fn batched_wire_over_tcp_with_short_tail() {
    // 12 frames in batches of 5: 5, 5, 2 — the tail flushes short.
    run_batched_wire(&[2], true, &[5], 12);
}

#[test]
fn single_frame_batches_are_plain_legacy_traffic() {
    run_batched_wire(&[2, 2], false, &[1], 10);
}

// ---------------------------------------------------------------------
// Layer 2: the real dispatcher batcher, end to end.
// ---------------------------------------------------------------------

/// Run `run_inference` against synthetic workers; return the stats and
/// the largest batch any worker saw.
fn run_dispatcher(
    replicas: &[usize],
    tcp: bool,
    pipelined: bool,
    frames: u64,
    batch: usize,
    adaptive: bool,
) -> (Arc<DispatcherStats>, usize) {
    let h = harness(replicas, tcp);
    let input = Tensor::new(vec![ELEMS], vec![3.0; ELEMS]).unwrap();
    let expected =
        Tensor::new(vec![ELEMS], vec![expect_value(3.0, h.stages); ELEMS]).unwrap();
    let stats = Arc::new(DispatcherStats::new(EnergyModel::default()));
    let opts = InferenceOptions {
        pipelined,
        pipe_depth: 4,
        batch,
        batch_adaptive: adaptive,
        ..InferenceOptions::default()
    };
    run_inference(
        input,
        frames,
        h.to_first,
        h.from_last,
        opts,
        Arc::new(Link::ideal()),
        Arc::clone(&stats),
        Some(expected),
        vec![ELEMS],
    )
    .unwrap();
    let max_seen = h.max_batch_seen.load(Ordering::Relaxed);
    for w in h.workers {
        w.join().unwrap().unwrap();
    }
    h.junctions.join().unwrap();
    (stats, max_seen)
}

#[test]
fn batched_run_is_bit_identical_to_unbatched() {
    // The acceptance property: with the same input, batch = 4 must
    // produce exactly the frames batch = 1 does. The dispatcher checks
    // every frame against the expected tensor — 0.0 recorded error is
    // bitwise equality, and per-frame metrics stay batch-invariant.
    for (batch, want_coalesced) in [(1usize, 1usize), (4, 4)] {
        let (stats, max_seen) = run_dispatcher(&[1, 2], false, true, 20, batch, false);
        assert_eq!(stats.clock.cycles(), 20, "batch={batch}");
        assert_eq!(stats.latency.count(), 20, "batch={batch}");
        assert_eq!(
            *stats.reference_error.lock().unwrap(),
            Some(0.0),
            "batch={batch}"
        );
        assert_eq!(max_seen, want_coalesced, "batch={batch}");
    }
}

#[test]
fn tail_shorter_than_batch_flushes() {
    // 10 frames at batch 4: 4, 4, 2. Every frame must complete.
    let (stats, max_seen) = run_dispatcher(&[2], false, true, 10, 4, false);
    assert_eq!(stats.clock.cycles(), 10);
    assert_eq!(stats.latency.count(), 10);
    assert_eq!(*stats.reference_error.lock().unwrap(), Some(0.0));
    assert_eq!(max_seen, 4);
}

#[test]
fn zero_frames_terminates_cleanly() {
    let (stats, _) = run_dispatcher(&[1, 2], false, true, 0, 4, false);
    assert_eq!(stats.clock.cycles(), 0);
    assert_eq!(stats.latency.count(), 0);
    assert_eq!(*stats.reference_error.lock().unwrap(), None);
}

#[test]
fn batched_dispatcher_over_tcp() {
    let (stats, max_seen) = run_dispatcher(&[2], true, true, 12, 3, false);
    assert_eq!(stats.clock.cycles(), 12);
    assert_eq!(*stats.reference_error.lock().unwrap(), Some(0.0));
    assert_eq!(max_seen, 3);
}

#[test]
fn inline_mode_batches_with_fixed_size() {
    // The inline (non-pipelined) path has no send queue: fixed batches.
    let (stats, max_seen) = run_dispatcher(&[1], false, false, 9, 3, false);
    assert_eq!(stats.clock.cycles(), 9);
    assert_eq!(stats.latency.count(), 9);
    assert_eq!(*stats.reference_error.lock().unwrap(), Some(0.0));
    assert_eq!(max_seen, 3);
}

#[test]
fn adaptive_mode_completes_and_respects_the_cap() {
    // Adaptive sizing is timing-dependent (it reads the live queue
    // depth), so assert the invariants, not a specific size: every
    // frame completes bit-exact and no batch exceeds the cap.
    let (stats, max_seen) = run_dispatcher(&[1, 2], false, true, 30, 8, true);
    assert_eq!(stats.clock.cycles(), 30);
    assert_eq!(stats.latency.count(), 30);
    assert_eq!(*stats.reference_error.lock().unwrap(), Some(0.0));
    assert!(max_seen >= 1 && max_seen <= 8, "max batch seen {max_seen}");
}
