//! Pipelined-compute-node ordering suite (artifact-free).
//!
//! Drives the software-pipelined codec path (`coordinator::pipeline`)
//! through real topology wiring — including replicated stages with
//! worker-owned deal/merge connection sets (and, for A/B, the legacy
//! junction relays) — using a synthetic compute closure instead of PJRT
//! executables. The contract under test: frames leave the deployment in
//! FIFO order with correct values, whatever the per-replica timing
//! jitter, and the chunk-parallel codec container works end to end
//! through the pipeline.

use std::sync::Arc;

use defer::compress::Compression;
use defer::coordinator::pipeline::{run_codec_pipeline, PipelineCtx};
use defer::metrics::ByteCounter;
use defer::netem::{Link, LinkSpec};
use defer::serial::{Codec, CodecRuntime, Serialization};
use defer::threadpool::{pipe, CodecPool};
use defer::topology::wiring::{build, TransportOptions, WorkerConns};
use defer::topology::Topology;
use defer::util::timer::SharedTimer;
use defer::wire::{Message, MessageType};

const ELEMS: usize = 64;

/// Spawn one synthetic worker: a boundary-reader thread feeding the
/// real codec pipeline, with `compute` standing in for the fused
/// executables. The pipeline's encode phase deals straight onto the
/// worker's successor set.
fn spawn_worker(
    wc: WorkerConns,
    codec: Codec,
    rt: CodecRuntime,
    pipelined: bool,
) -> std::thread::JoinHandle<defer::Result<()>> {
    std::thread::spawn(move || {
        let WorkerConns {
            view,
            config: _config,
            weights: _weights,
            data_in,
            data_out,
        } = wc;
        let (tx, rx) = pipe::<Message>(4);
        let mut in_conn = data_in;
        let reader = std::thread::spawn(move || loop {
            match in_conn.recv(&ByteCounter::new()) {
                Ok(msg) => {
                    let stop = msg.msg_type == MessageType::Shutdown;
                    if tx.send(msg).is_err() || stop {
                        return;
                    }
                }
                Err(_) => return,
            }
        });
        let replica = view.replica;
        let ctx = PipelineCtx {
            name: view.name.clone(),
            codec,
            rt,
            overhead: SharedTimer::new(),
            data_tx: ByteCounter::new(),
            frames: ByteCounter::new(),
            out_link: Arc::new(Link::ideal()),
            pipelined,
            pipe_depth: 4,
            payload_pool: None,
            recovery: None,
        };
        let result = run_codec_pipeline(rx, data_out, ctx, move |values, _batch| {
            // Jitter compute per frame & replica so a lost ordering
            // guarantee would actually scramble arrivals.
            let f = values[0] as u64;
            std::thread::sleep(std::time::Duration::from_micros(
                ((f * 7 + replica as u64 * 13) % 5) * 300,
            ));
            Ok(values.iter().map(|v| v * 2.0 + 1.0).collect())
        });
        reader.join().expect("reader thread");
        result
    })
}

/// Run `frames` frames through a topology of synthetic pipelined
/// workers; assert FIFO order and transformed values at the dispatcher.
/// Returns the decoded per-frame values for cross-mode comparison.
fn run_topology(
    replicas: &[usize],
    codec: Codec,
    rt: CodecRuntime,
    pipelined: bool,
    relay_junctions: bool,
    frames: u64,
) -> Vec<Vec<f32>> {
    let hop_links = vec![LinkSpec::ideal(); replicas.len() + 1];
    let topo = Topology::new(replicas, hop_links).unwrap();
    let defer::topology::wiring::Wiring {
        control,
        to_first,
        from_last,
        workers,
        junctions,
    } = build(
        &topo,
        &TransportOptions {
            tcp: false,
            base_port: None,
            pipe_depth: 4,
            relay_junctions,
            recovery: None,
        },
    )
    .unwrap();
    drop(control); // no configuration phase for synthetic workers
    if !relay_junctions {
        assert!(junctions.is_empty(), "junction thread in worker-owned mode");
    }
    let mut to_first = to_first;
    let mut from_last = from_last;
    let stages = replicas.len();

    let workers: Vec<_> = workers
        .into_iter()
        .map(|wc| spawn_worker(wc, codec, rt.clone(), pipelined))
        .collect();

    // Both ends of every data socket share one codec runtime (exactly
    // like a real deployment, where the config ships to all roles).
    let sender_rt = rt.clone();
    let sender = std::thread::spawn(move || {
        let link = Link::ideal();
        let counter = ByteCounter::new();
        let rt = sender_rt;
        for frame in 0..frames {
            let data = vec![frame as f32; ELEMS];
            let (payload, mid) = codec.encode_frame(&data, &rt, None);
            to_first
                .send_data(
                    &Message {
                        msg_type: MessageType::Data,
                        frame,
                        serialized_len: mid as u64,
                        count: ELEMS as u64,
                        batch: 1,
                        payload,
                    },
                    &link,
                    &counter,
                )
                .unwrap();
        }
        to_first.broadcast_shutdown(&link, &counter).unwrap();
    });

    let counter = ByteCounter::new();
    let mut results = Vec::with_capacity(frames as usize);
    for f in 0..frames {
        let msg = from_last.recv(&counter).unwrap();
        assert_eq!(msg.msg_type, MessageType::Data);
        assert_eq!(msg.frame, f, "frames out of order");
        let values = codec
            .decode_frame(
                &msg.payload,
                msg.serialized_len as usize,
                msg.count as usize,
                &rt,
                None,
            )
            .unwrap();
        // Each stage applies v -> 2v + 1.
        let mut expect = f as f32;
        for _ in 0..stages {
            expect = expect * 2.0 + 1.0;
        }
        assert_eq!(values, vec![expect; ELEMS], "frame {f}");
        results.push(values);
    }
    assert_eq!(
        from_last.recv(&counter).unwrap().msg_type,
        MessageType::Shutdown
    );
    sender.join().unwrap();
    for h in workers {
        h.join().unwrap().unwrap();
    }
    junctions.join().unwrap();
    results
}

#[test]
fn pipelined_single_stage_preserves_fifo() {
    run_topology(
        &[1],
        Codec::new(Serialization::Binary, Compression::None),
        CodecRuntime::serial(),
        true,
        false,
        50,
    );
}

#[test]
fn pipelined_replicated_stage_preserves_fifo() {
    // The acceptance property: worker-owned replication (round-robin
    // deal + schedule-merge, no relay threads) plus per-replica
    // pipelining still delivers frames in order.
    run_topology(
        &[3],
        Codec::new(Serialization::Binary, Compression::None),
        CodecRuntime::serial(),
        true,
        false,
        60,
    );
}

#[test]
fn pipelined_multi_stage_with_replication_preserves_fifo() {
    run_topology(
        &[1, 3, 2],
        Codec::new(Serialization::Binary, Compression::None),
        CodecRuntime::serial(),
        true,
        false,
        40,
    );
}

#[test]
fn relay_wiring_results_are_bit_identical_to_worker_owned() {
    // The A/B contract behind `--relay-junctions`: both data planes
    // produce the same frames in the same order, bit for bit.
    let codec = Codec::new(Serialization::Binary, Compression::None);
    let owned = run_topology(&[2, 3], codec, CodecRuntime::serial(), true, false, 30);
    let relay = run_topology(&[2, 3], codec, CodecRuntime::serial(), true, true, 30);
    assert_eq!(owned, relay);
}

#[test]
fn chunk_parallel_container_flows_through_pipeline() {
    // Chunked containers + shared codec pool + pipelining, end to end.
    let pool = Arc::new(CodecPool::new(3));
    let rt = CodecRuntime::chunked(16, Some(pool)).unwrap();
    run_topology(
        &[2],
        Codec::new(Serialization::Binary, Compression::Lz4),
        rt,
        true,
        false,
        30,
    );
}

#[test]
fn inline_mode_matches_pipelined_results() {
    run_topology(
        &[2],
        Codec::new(Serialization::Binary, Compression::None),
        CodecRuntime::serial(),
        false,
        false,
        30,
    );
}
