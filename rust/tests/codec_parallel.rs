//! Chunk-parallel codec equivalence suite (artifact-free).
//!
//! The chunked container's contract: bytes are a pure function of
//! `(codec, data, chunk_elems)` — worker count only changes wall-clock.
//! These tests pin that contract across every `Codec::paper_sweep()` arm
//! (plus the Binary ground-truth arms), odd sizes (0, 1,
//! non-block-multiples, many chunks), and both pool configurations, and
//! check that chunked round-trips agree with the legacy single-buffer
//! codec's values exactly.

use std::sync::Arc;

use defer::compress::Compression;
use defer::serial::{chunked, Codec, CodecRuntime, Serialization};
use defer::threadpool::CodecPool;
use defer::util::prng::Rng;

/// Paper sweep + the lossless Binary arms (weights ground truth).
fn all_codecs() -> Vec<Codec> {
    let mut codecs = Codec::paper_sweep();
    codecs.push(Codec::new(Serialization::Binary, Compression::None));
    codecs.push(Codec::new(Serialization::Binary, Compression::Lz4));
    codecs
}

const SIZES: &[usize] = &[0, 1, 2, 3, 4, 5, 255, 256, 257, 1024, 4095, 4096, 4097, 10_000];

#[test]
fn parallel_encode_bytes_equal_serial_encode_bytes() {
    // The golden acceptance property: for a fixed chunk size, the
    // parallel encode is byte-identical to the sequential encode.
    let pool = Arc::new(CodecPool::new(4));
    for codec in all_codecs() {
        for &n in SIZES {
            let data = Rng::new(1000 + n as u64).normal_vec(n);
            for chunk_elems in [4usize, 256, 4096] {
                let serial_rt = CodecRuntime::chunked(chunk_elems, None).unwrap();
                let par_rt =
                    CodecRuntime::chunked(chunk_elems, Some(Arc::clone(&pool))).unwrap();
                let (a, mid_a) = codec.encode_frame(&data, &serial_rt, None);
                let (b, mid_b) = codec.encode_frame(&data, &par_rt, None);
                assert_eq!(
                    a,
                    b,
                    "{} n={n} chunk={chunk_elems}: parallel bytes diverged",
                    codec.label()
                );
                assert_eq!(mid_a, mid_b);
            }
        }
    }
}

#[test]
fn chunked_round_trip_matches_legacy_values() {
    // decode(encode(x)) through the container must equal the legacy
    // path's decode(encode(x)) *exactly* — for lossless arms that is x
    // itself; for ZFP the chunk boundaries sit on 4-value blocks, so
    // the lossy reconstruction is also bit-identical to unchunked.
    let pool = Arc::new(CodecPool::new(3));
    for codec in all_codecs() {
        for &n in SIZES {
            let data = Rng::new(2000 + n as u64).normal_vec(n);
            let (legacy_wire, legacy_mid) = codec.encode_f32s(&data, None);
            let legacy = codec
                .decode_f32s(&legacy_wire, legacy_mid, n, None)
                .unwrap();
            let rt = CodecRuntime::chunked(256, Some(Arc::clone(&pool))).unwrap();
            let (wire, mid) = codec.encode_frame(&data, &rt, None);
            let chunked_back = codec.decode_frame(&wire, mid, n, &rt, None).unwrap();
            assert_eq!(
                chunked_back,
                legacy,
                "{} n={n}: chunked reconstruction diverged from legacy",
                codec.label()
            );
            if codec.serialization.is_lossless() {
                assert_eq!(chunked_back, data);
            }
        }
    }
}

#[test]
fn serial_runtime_is_byte_identical_to_legacy() {
    // chunk_elems = 0 (CodecRuntime::serial) must be the pre-container
    // wire format — deployments with chunking off are indistinguishable
    // from pre-refactor builds.
    let rt = CodecRuntime::serial();
    for codec in all_codecs() {
        let data = Rng::new(3000).normal_vec(4097);
        let (legacy, legacy_mid) = codec.encode_f32s(&data, None);
        let (frame, mid) = codec.encode_frame(&data, &rt, None);
        assert_eq!(legacy, frame, "{}", codec.label());
        assert_eq!(legacy_mid, mid);
        let back = codec.decode_frame(&frame, mid, 4097, &rt, None).unwrap();
        assert_eq!(
            back,
            codec.decode_f32s(&legacy, legacy_mid, 4097, None).unwrap()
        );
    }
}

#[test]
fn container_sizes_are_deterministic_for_zfp() {
    // The planner goldens rely on deterministic payload sizes; the
    // container must preserve that for the fixed-rate arm: header +
    // per-chunk headers + exact zfp chunk sizes.
    let rt = CodecRuntime::chunked(1024, None).unwrap();
    let codec = Codec::default(); // ZFP+LZ4 — LZ4 is data-dependent; use raw ZFP:
    let zfp_raw = Codec::new(codec.serialization, Compression::None);
    for n in [0usize, 1, 1024, 2048, 5000] {
        let a = zfp_raw.encode_frame(&Rng::new(7).normal_vec(n), &rt, None);
        let b = zfp_raw.encode_frame(&Rng::new(8).normal_vec(n), &rt, None);
        assert_eq!(a.0.len(), b.0.len(), "n={n}: zfp container size varies with data");
        assert_eq!(a.1, b.1);
    }
}

#[test]
fn one_pool_shared_by_many_threads() {
    // The deployment shares one CodecPool across every worker replica;
    // concurrent encodes must not corrupt or deadlock.
    let pool = Arc::new(CodecPool::new(4));
    let mut handles = Vec::new();
    for t in 0..6u64 {
        let pool = Arc::clone(&pool);
        handles.push(std::thread::spawn(move || {
            let codec = Codec::default();
            let data = Rng::new(t).normal_vec(8192);
            let rt = CodecRuntime::chunked(1024, Some(pool)).unwrap();
            let expect = codec.encode_frame(&data, &CodecRuntime::chunked(1024, None).unwrap(), None);
            for _ in 0..10 {
                let got = codec.encode_frame(&data, &rt, None);
                assert_eq!(got.0, expect.0);
                let back = codec
                    .decode_frame(&got.0, got.1, 8192, &rt, None)
                    .unwrap();
                assert_eq!(back.len(), 8192);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert!(pool.jobs_run() > 0);
}

#[test]
fn container_constants_documented() {
    // Layout constants the wire docs promise (per-chunk header grew a
    // crc32 field alongside wire_len and serialized_len).
    assert_eq!(chunked::CONTAINER_HEADER, 12);
    assert_eq!(chunked::PER_CHUNK_HEADER, 12);
    assert_eq!(chunked::DEFAULT_CHUNK_ELEMS % 4, 0);
    assert_eq!(chunked::DEFAULT_CHUNK_ELEMS * 4, 512 * 1024);
}
