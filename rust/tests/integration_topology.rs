//! Topology-layer integration against real artifacts: heterogeneous
//! per-hop links over both transports, and replicated bottleneck stages
//! under deterministic device-speed emulation. Requires `make artifacts`
//! (tiny profile).

use std::path::PathBuf;

use defer::compress::Compression;
use defer::config::DeferConfig;
use defer::coordinator::chain::ChainRunner;
use defer::netem::LinkSpec;
use defer::runtime::Engine;
use defer::serial::{Codec, Serialization};

fn cfg(nodes: usize) -> DeferConfig {
    let mut c = DeferConfig::default();
    c.artifacts_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    c.profile = "tiny".into();
    c.model = "resnet50".into();
    c.nodes = nodes;
    let codec = Codec::new(Serialization::Binary, Compression::Lz4);
    c.codecs.weights = codec;
    c.codecs.data = codec;
    c
}

fn have_artifacts() -> bool {
    let ok = cfg(1).artifacts_dir.join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

#[test]
fn heterogeneous_links_run_both_transports() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    // Wifi uplink into the cluster, gigabit inside, gigabit return.
    let links = vec![
        LinkSpec::wifi(),
        LinkSpec::gigabit_lan(),
        LinkSpec::gigabit_lan(),
    ];
    let mut reports = Vec::new();
    for tcp in [false, true] {
        let mut c = cfg(2);
        c.per_hop_links = links.clone();
        c.tcp = tcp;
        let r = ChainRunner::with_engine(c, engine.clone())
            .unwrap()
            .run_frames(3)
            .unwrap();
        assert_eq!(r.cycles, 3, "tcp={tcp}");
        assert!(r.reference_error.unwrap() < 0.05, "tcp={tcp}");
        // The wifi uplink's 3 ms latency floor must be visible.
        assert!(r.latency_mean > std::time::Duration::from_millis(3));
        reports.push(r);
    }
    // Byte accounting stays transport-independent with per-hop links.
    assert_eq!(reports[0].architecture_bytes, reports[1].architecture_bytes);
    assert_eq!(reports[0].weights_bytes, reports[1].weights_bytes);
    assert_eq!(reports[0].data_bytes, reports[1].data_bytes);
}

#[test]
fn explicit_uniform_topology_accounting_matches_default() {
    if !have_artifacts() {
        return;
    }
    // replicas=[1,1] and per_hop_links=[ideal;3] must be byte-identical
    // to the default chain: the topology layer is accounting-neutral.
    let engine = Engine::cpu().unwrap();
    let r_default = ChainRunner::with_engine(cfg(2), engine.clone())
        .unwrap()
        .run_frames(3)
        .unwrap();
    let mut c = cfg(2);
    c.replicas = vec![1, 1];
    c.per_hop_links = vec![LinkSpec::ideal(); 3];
    let r_explicit = ChainRunner::with_engine(c, engine)
        .unwrap()
        .run_frames(3)
        .unwrap();
    assert_eq!(r_default.architecture_bytes, r_explicit.architecture_bytes);
    assert_eq!(r_default.weights_bytes, r_explicit.weights_bytes);
    assert_eq!(r_default.data_bytes, r_explicit.data_bytes);
    assert_eq!(r_default.workers, 2);
    assert_eq!(r_explicit.workers, 2);
}

#[test]
fn replicated_bottleneck_stage_completes_and_speeds_up() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    // Deterministic device emulation makes compute the bottleneck: each
    // stage's frame time is floored to stage_flops / 20 MFLOPS, so the
    // pipeline rate is set by the slowest stage. Replicating a stage
    // halves its effective service time; throughput must rise.
    let frames = 8;
    let mut uni = cfg(2);
    uni.emulated_mflops = 20.0;
    let r_uni = ChainRunner::with_engine(uni, engine.clone())
        .unwrap()
        .run_frames(frames)
        .unwrap();

    // Replicate the stage with more FLOPs (the pipeline bottleneck).
    let plan = ChainRunner::with_engine(cfg(2), engine.clone()).unwrap();
    let bottleneck = if plan.plan().parts[0].flops >= plan.plan().parts[1].flops {
        0
    } else {
        1
    };
    let mut rep = cfg(2);
    rep.emulated_mflops = 20.0;
    rep.replicas = vec![1, 1];
    rep.replicas[bottleneck] = 2;
    let r_rep = ChainRunner::with_engine(rep, engine)
        .unwrap()
        .run_frames(frames)
        .unwrap();

    // All frames complete, in order (reference check would fail on
    // reordering because latency pairing keys on frame id).
    assert_eq!(r_rep.cycles, frames);
    assert!(r_rep.reference_error.unwrap() < 0.05);
    assert_eq!(r_rep.workers, 3);
    assert_eq!(r_rep.nodes, 2);
    assert_eq!(r_rep.node_energy.len(), 3);
    // Strictly higher throughput than the unreplicated equivalent.
    assert!(
        r_rep.throughput > r_uni.throughput,
        "replication did not help: {} vs {}",
        r_rep.throughput,
        r_uni.throughput
    );
}

#[test]
fn auto_place_beats_uniform_chain() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let frames = 8;
    // The acceptance scenario: wifi uplink into the cluster, gigabit
    // inside, deterministic 20 MFLOP/s edge devices making compute the
    // bottleneck, and a worker budget above the stage count.
    let mut base = cfg(2);
    base.emulated_mflops = 20.0;
    base.per_hop_links = vec![
        LinkSpec::wifi(),
        LinkSpec::gigabit_lan(),
        LinkSpec::gigabit_lan(),
    ];
    let r_uni = ChainRunner::with_engine(base.clone(), engine.clone())
        .unwrap()
        .run_frames(frames)
        .unwrap();

    let mut auto = base;
    auto.auto_place = true;
    auto.workers_budget = 4;
    let runner = ChainRunner::with_engine(auto.clone(), engine).unwrap();

    // The planner is deterministic: repeated plans are byte-identical.
    let problem =
        defer::placement::PlacementProblem::from_config(&auto, runner.plan()).unwrap();
    let p1 = defer::placement::plan(&problem).unwrap();
    let p2 = defer::placement::plan(&problem).unwrap();
    assert_eq!(p1.render(), p2.render());
    // It replicates the bottleneck stage (and only spends budget where
    // it pays: a 4th worker is trimmed if the FLOPs split makes [2,1]
    // already optimal).
    let topo = p1.topology().unwrap();
    assert_eq!(topo.num_stages(), 2);
    assert!(topo.num_workers() >= 3, "no stage was replicated");
    assert!(topo.num_workers() <= 4, "budget exceeded");
    assert!(topo.stages().iter().any(|s| s.replicas > 1));
    assert_eq!(topo.hop_link(0), LinkSpec::wifi());

    let r_auto = runner.run_frames(frames).unwrap();
    assert_eq!(r_auto.cycles, frames);
    assert!(r_auto.reference_error.unwrap() < 0.05);
    assert_eq!(r_auto.workers, topo.num_workers());
    // The replicated bottleneck roughly halves the gate: the measured
    // speedup over the uniform unreplicated chain must clear 1.3x (the
    // model predicts ~2x).
    assert!(
        r_auto.throughput >= 1.3 * r_uni.throughput,
        "auto-place speedup only {:.2}x ({:.3} vs {:.3} cycles/s)",
        r_auto.throughput / r_uni.throughput,
        r_auto.throughput,
        r_uni.throughput
    );
}

/// A compute node must execute a fused multi-partition stage end to end
/// with reference parity: budget 1 and no memory cap fuse the *entire*
/// finest partition set into one stage on one worker.
#[test]
fn fused_stage_executes_with_reference_parity() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let mut c = cfg(2); // nodes is ignored under auto_partition
    c.auto_partition = true;
    c.workers_budget = 1;
    c.emulated_mflops = 50.0;
    let runner = ChainRunner::with_engine(c, engine).unwrap();
    // The finest tiny artifact set is 4-way; everything fused into one
    // multi-partition stage.
    assert!(runner.plan().parts.len() >= 2, "finest set is not fine");
    assert_eq!(runner.stages().len(), 1);
    assert_eq!(runner.stages()[0].num_parts(), runner.plan().parts.len());
    let r = runner.run_frames(3).unwrap();
    assert_eq!(r.cycles, 3);
    assert_eq!(r.nodes, 1);
    assert_eq!(r.workers, 1);
    // Numerical parity with the Python reference through the fused run.
    assert!(r.reference_error.unwrap() < 0.05);
}

/// The acceptance scenario: wifi uplink, gigabit cluster, deterministic
/// 20 MFLOP/s devices, and a memory cap that forbids hosting the whole
/// model on one worker. `--auto-partition --auto-place` planning over
/// the finest artifact set must beat the coarse uniform 2-stage chain
/// by >= 1.2x measured.
#[test]
fn auto_partition_beats_coarse_uniform_chain() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let frames = 8;
    // Coarse baseline: the artifact-time 2-way split, one worker per
    // stage, same links and device emulation.
    let mut coarse = cfg(2);
    coarse.emulated_mflops = 20.0;
    coarse.per_hop_links = vec![
        LinkSpec::wifi(),
        LinkSpec::gigabit_lan(),
        LinkSpec::gigabit_lan(),
    ];
    let r_coarse = ChainRunner::with_engine(coarse, engine.clone())
        .unwrap()
        .run_frames(frames)
        .unwrap();

    // Joint repartitioning over the finest (4-way) set: cap the
    // per-worker resident weights so no single stage can hold the whole
    // model (>= 2 stages are forced), with budget for replication.
    let fine = defer::model::PartitionPlan::load(
        &cfg(1).artifacts_dir,
        "tiny",
        "resnet50",
        defer::model::finest_part_count(&cfg(1).artifacts_dir, "tiny", "resnet50").unwrap(),
    )
    .unwrap();
    let total: usize = fine.parts.iter().map(|p| p.weights_bytes).sum();
    let largest: usize = fine.parts.iter().map(|p| p.weights_bytes).max().unwrap();
    let mut auto = cfg(2);
    auto.emulated_mflops = 20.0;
    auto.per_hop_links = vec![LinkSpec::wifi(), LinkSpec::gigabit_lan()];
    auto.auto_place = true;
    auto.auto_partition = true;
    auto.workers_budget = 4;
    auto.device_memory = largest.max(total * 3 / 5) as u64;
    let runner = ChainRunner::with_engine(auto, engine).unwrap();
    // The memory cap split the model; the budget bought replicas.
    assert!(runner.stages().len() >= 2, "memory cap was ignored");
    assert!(runner.topology().num_workers() > runner.stages().len());
    assert!(runner.topology().num_workers() <= 4);
    assert_eq!(runner.topology().hop_link(0), LinkSpec::wifi());
    // The planner's report is byte-stable and names the cuts.
    let render = runner.plan_render().expect("planned run renders");
    assert!(render.contains("repartition plan:"), "{render}");

    let r_auto = runner.run_frames(frames).unwrap();
    assert_eq!(r_auto.cycles, frames);
    assert!(r_auto.reference_error.unwrap() < 0.05);
    assert!(
        r_auto.throughput >= 1.2 * r_coarse.throughput,
        "auto-partition speedup only {:.2}x ({:.3} vs {:.3} cycles/s)",
        r_auto.throughput / r_coarse.throughput,
        r_auto.throughput,
        r_coarse.throughput
    );
}

/// The tentpole's A/B acceptance on the shaped replicated-bottleneck
/// scenario: the worker-owned data plane (default) against the legacy
/// relay wiring (`--relay-junctions`). Results must be bit-identical,
/// byte accounting identical (the deal/merge protocol counts exactly
/// what the junction protocol counted), and measured throughput must
/// not regress below the relay baseline (small scheduling slack only —
/// dropping the relay thread can only remove work from the path).
#[test]
fn worker_owned_data_plane_matches_relay_wiring() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let frames = 8;
    // Replicate the heavier stage under deterministic device emulation
    // with shaped links — the replicated-bottleneck bench shape.
    let probe = ChainRunner::with_engine(cfg(2), engine.clone()).unwrap();
    let bottleneck = if probe.plan().parts[0].flops >= probe.plan().parts[1].flops {
        0
    } else {
        1
    };
    let mk = |relay: bool| {
        let mut c = cfg(2);
        c.emulated_mflops = 20.0;
        c.per_hop_links = vec![
            LinkSpec::wifi(),
            LinkSpec::gigabit_lan(),
            LinkSpec::gigabit_lan(),
        ];
        c.replicas = vec![1, 1];
        c.replicas[bottleneck] = 2;
        c.relay_junctions = relay;
        c
    };
    let r_owned = ChainRunner::with_engine(mk(false), engine.clone())
        .unwrap()
        .run_frames(frames)
        .unwrap();
    let r_relay = ChainRunner::with_engine(mk(true), engine)
        .unwrap()
        .run_frames(frames)
        .unwrap();
    assert_eq!(r_owned.cycles, frames);
    assert_eq!(r_relay.cycles, frames);
    // Bit-identical results (same codec, same artifacts, same order).
    assert_eq!(r_owned.reference_error, r_relay.reference_error);
    // Byte accounting is data-plane-invariant.
    assert_eq!(r_owned.architecture_bytes, r_relay.architecture_bytes);
    assert_eq!(r_owned.weights_bytes, r_relay.weights_bytes);
    assert_eq!(r_owned.data_bytes, r_relay.data_bytes);
    assert!(
        r_owned.throughput >= 0.9 * r_relay.throughput,
        "worker-owned data plane regressed: {:.3} vs relay {:.3} cycles/s",
        r_owned.throughput,
        r_relay.throughput
    );
}

#[test]
fn replicated_stage_over_tcp() {
    if !have_artifacts() {
        return;
    }
    let mut c = cfg(2);
    c.replicas = vec![2, 1];
    c.tcp = true;
    let r = ChainRunner::new(c).unwrap().run_frames(4).unwrap();
    assert_eq!(r.cycles, 4);
    assert_eq!(r.workers, 3);
    assert!(r.reference_error.unwrap() < 0.05);
}
