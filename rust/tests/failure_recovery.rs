//! Self-healing data-plane suite: injected churn (artifact-free).
//!
//! Drives full inference runs — real topology wiring, synthetic
//! pipelined workers, both transports, both I/O planes — under the
//! `netem` fault schedules, and asserts the recovery contract from the
//! module docs of `runtime::recovery`:
//!
//! * **Replica kill**: a scheduled replica death mid-run degrades the
//!   mesh to the survivors, the supervisor re-dispatches every frame
//!   the dead replica still owed, and the run completes with all frames
//!   bit-identical to a fault-free run (0.0 recorded reference error).
//! * **Chunk corruption**: a corrupt DFCK chunk is NACKed back to its
//!   producer, patched in place from the retention ring, and decoded
//!   within the retry budget — no frame loss, no re-dispatch needed.
//! * **Egress truncation**: a replica that writes half a message and
//!   dies surfaces as a mid-message EOF at its consumer and recovers
//!   exactly like a kill.
//! * **Inertness**: with recovery enabled but no faults scheduled, all
//!   recovery counters stay zero and the run is just a run.
//!
//! Fault schedules are deterministic (seeded), so each test is exactly
//! reproducible — no flaky churn.

use std::sync::Arc;

use defer::compress::Compression;
use defer::coordinator::dispatcher::{run_inference, DispatcherStats, InferenceOptions};
use defer::coordinator::pipeline::{run_codec_pipeline, PipelineCtx, PipelineRecovery};
use defer::energy::EnergyModel;
use defer::metrics::ByteCounter;
use defer::netem::{FaultPlan, Link, LinkSpec};
use defer::netio::Reactor;
use defer::runtime::recovery::RecoverySupervisor;
use defer::serial::{Codec, CodecRuntime, Serialization};
use defer::tensor::Tensor;
use defer::threadpool::pipe;
use defer::topology::wiring::{
    build, FrameSink, FrameSource, TransportOptions, Wiring, WorkerConns,
};
use defer::topology::Topology;
use defer::util::timer::SharedTimer;
use defer::wire::{Message, MessageType};

const ELEMS: usize = 64;

/// Spawn one synthetic worker (elementwise `v -> 2v + 1`) with the
/// self-healing hooks attached: the node name keys the fault schedule,
/// and the chunk-retry client (extracted from the merge set before the
/// conns move) lets its decode stage NACK corrupt chunks upstream. A
/// scheduled death ([`defer::error::DeferError::FaultInjected`]) is a
/// *planned* exit, not a failure — the worker reports success and lets
/// its dropped conns carry the EOF the survivors react to.
fn spawn_worker(
    wc: WorkerConns,
    codec: Codec,
    rt: CodecRuntime,
    sup: Arc<RecoverySupervisor>,
    reactor: Option<Arc<Reactor>>,
) -> std::thread::JoinHandle<defer::Result<()>> {
    std::thread::spawn(move || {
        let WorkerConns {
            view,
            config: _config,
            weights: _weights,
            data_in,
            data_out,
        } = wc;
        let client = data_in.chunk_client();
        let (tx, rx) = pipe::<Message>(4);
        let mut reader = None;
        let out: FrameSink = match &reactor {
            Some(r) => {
                r.register_ingress(data_in, tx, None)?;
                r.register_egress(data_out, 4)?.into()
            }
            None => {
                let mut in_conn = data_in;
                reader = Some(std::thread::spawn(move || loop {
                    match in_conn.recv(&ByteCounter::new()) {
                        Ok(msg) => {
                            let stop = msg.msg_type == MessageType::Shutdown;
                            if tx.send(msg).is_err() || stop {
                                return;
                            }
                        }
                        Err(_) => return,
                    }
                }));
                data_out.into()
            }
        };
        let ctx = PipelineCtx {
            name: view.name.clone(),
            codec,
            rt,
            overhead: SharedTimer::new(),
            data_tx: ByteCounter::new(),
            frames: ByteCounter::new(),
            out_link: Arc::new(Link::ideal()),
            pipelined: true,
            pipe_depth: 4,
            payload_pool: None,
            recovery: Some(PipelineRecovery {
                supervisor: sup,
                client,
            }),
        };
        let result = run_codec_pipeline(rx, out, ctx, |values, _batch| {
            Ok(values.iter().map(|v| v * 2.0 + 1.0).collect())
        });
        match result {
            // A scheduled kill/truncation is the test harness at work.
            Err(e) if e.is_fault_injection() => Ok(()),
            other => {
                if let Some(h) = reader {
                    h.join().expect("reader thread");
                }
                other
            }
        }
    })
}

/// Each stage applies v -> 2v + 1; fold that over the chain depth.
fn expect_value(input: f32, stages: usize) -> f32 {
    let mut v = input;
    for _ in 0..stages {
        v = v * 2.0 + 1.0;
    }
    v
}

/// Run one full recovery-mode inference under a fault schedule and
/// assert it completes every frame bit-identically (0.0 recorded
/// reference error). Returns the supervisor for counter assertions.
fn run_with_faults(
    replicas: &[usize],
    tcp: bool,
    blocking: bool,
    frames: u64,
    batch: usize,
    specs: &[&str],
    rt: CodecRuntime,
) -> Arc<RecoverySupervisor> {
    let specs: Vec<String> = specs.iter().map(|s| s.to_string()).collect();
    let plan = FaultPlan::parse(&specs).unwrap();
    let sup = RecoverySupervisor::new(8, plan);
    let reactor = if blocking {
        None
    } else {
        Some(Reactor::new(2).unwrap())
    };
    let hop_links = vec![LinkSpec::ideal(); replicas.len() + 1];
    let topo = Topology::new(replicas, hop_links).unwrap();
    let Wiring {
        control,
        to_first,
        from_last,
        workers,
        junctions,
    } = build(
        &topo,
        &TransportOptions {
            tcp,
            base_port: None,
            pipe_depth: 4,
            relay_junctions: false,
            recovery: Some(Arc::clone(&sup)),
        },
    )
    .unwrap();
    drop(control); // no configuration phase for synthetic workers
    let codec = Codec::new(Serialization::Binary, Compression::None);
    let workers: Vec<_> = workers
        .into_iter()
        .map(|wc| {
            spawn_worker(wc, codec, rt.clone(), Arc::clone(&sup), reactor.clone())
        })
        .collect();

    let stages = replicas.len();
    let input = Tensor::new(vec![ELEMS], vec![3.0; ELEMS]).unwrap();
    let expected =
        Tensor::new(vec![ELEMS], vec![expect_value(3.0, stages); ELEMS]).unwrap();
    let stats = Arc::new(DispatcherStats::new(EnergyModel::default()));
    // The dispatcher's own decode path NACKs corrupt result chunks to
    // the last stage through the merge set's retry client.
    let dispatcher_client = from_last.chunk_client();
    let opts = InferenceOptions {
        rt: rt.clone(),
        pipelined: true,
        pipe_depth: 4,
        batch,
        recovery: Some(PipelineRecovery {
            supervisor: Arc::clone(&sup),
            client: dispatcher_client,
        }),
        ..InferenceOptions::default()
    };
    match &reactor {
        Some(r) => {
            let sink: FrameSink = r.register_egress(to_first, 4).unwrap().into();
            let (res_tx, res_rx) = pipe::<Message>(4);
            let err = r.register_ingress(from_last, res_tx, None).unwrap();
            let source = FrameSource::Queued { rx: res_rx, err };
            run_inference(
                input,
                frames,
                sink,
                source,
                opts,
                Arc::new(Link::ideal()),
                Arc::clone(&stats),
                Some(expected),
                vec![ELEMS],
            )
            .unwrap();
        }
        None => {
            run_inference(
                input,
                frames,
                to_first,
                from_last,
                opts,
                Arc::new(Link::ideal()),
                Arc::clone(&stats),
                Some(expected),
                vec![ELEMS],
            )
            .unwrap();
        }
    }
    for w in workers {
        w.join().unwrap().unwrap();
    }
    // Reactor first: its retired machines hold the chunk-retry clients,
    // and the NACK responders in `junctions` exit only when those drop.
    drop(reactor);
    junctions.join().unwrap();

    // Every frame completed exactly once, bit-identical to fault-free.
    assert_eq!(stats.clock.cycles(), frames, "dropped or duplicated frames");
    assert_eq!(stats.latency.count(), frames, "latency samples");
    assert_eq!(
        *stats.reference_error.lock().unwrap(),
        Some(0.0),
        "recovered frames not bit-exact"
    );
    sup
}

// ---------------------------------------------------------------------
// Replica kill: u=2, one replica dies mid-run, all frames complete.
// ---------------------------------------------------------------------

/// The tentpole acceptance run: kill the second stage-0 replica once it
/// observes frame 6 of 16. Frames dealt to it and not yet merged must
/// be re-dispatched to the survivor, bit-identically.
fn kill_mid_run(tcp: bool, blocking: bool) {
    let sup = run_with_faults(
        &[2],
        tcp,
        blocking,
        16,
        1,
        &["kill:node0.1@frame=6"],
        CodecRuntime::serial(),
    );
    assert_eq!(sup.replicas_lost(), 1, "death not detected");
    assert!(
        sup.frames_redispatched() >= 1,
        "the killed replica's owed frames were never re-dispatched"
    );
    assert!(sup.is_dead("node0.1 data socket"));
}

#[test]
fn replica_kill_recovers_local_blocking() {
    kill_mid_run(false, true);
}

#[test]
fn replica_kill_recovers_local_reactor() {
    kill_mid_run(false, false);
}

#[test]
fn replica_kill_recovers_tcp_blocking() {
    kill_mid_run(true, true);
}

#[test]
fn replica_kill_recovers_tcp_reactor() {
    kill_mid_run(true, false);
}

#[test]
fn replica_kill_recovers_with_batching() {
    // Batched messages re-dispatch as whole (first_frame, batch) units.
    let sup = run_with_faults(
        &[2],
        false,
        true,
        16,
        4,
        &["kill:node0.1@frame=6"],
        CodecRuntime::serial(),
    );
    assert_eq!(sup.replicas_lost(), 1);
    // The kill lands on a 4-frame message; its re-dispatch counts all 4.
    assert!(sup.frames_redispatched() >= 4);
}

#[test]
fn interior_replica_kill_degrades_downstream_merge() {
    // [2, 1]: the *worker-side* merge (node1's ingress) detects the
    // death and switches to arrival order; re-dispatched frames detour
    // through the surviving replica and dedup downstream.
    let sup = run_with_faults(
        &[2, 1],
        false,
        true,
        16,
        1,
        &["kill:node0.1@frame=5"],
        CodecRuntime::serial(),
    );
    assert!(sup.replicas_lost() >= 1);
    assert!(sup.frames_redispatched() >= 1);
}

// ---------------------------------------------------------------------
// Chunk corruption: NACK + in-place patch inside the retry budget.
// ---------------------------------------------------------------------

/// Corrupt roughly half of all DFCK containers at the worker's ingress
/// (deterministic seed). Every one must be patched from the producer's
/// retention ring — zero frame loss, zero re-dispatch required.
fn corrupt_chunks(blocking: bool) {
    let rt = CodecRuntime::chunked(16, None).unwrap(); // 64 elems -> 4 chunks
    let sup = run_with_faults(
        &[1],
        false,
        blocking,
        24,
        1,
        &["corrupt-chunk:p=0.5,seed=7"],
        rt,
    );
    assert!(
        sup.chunks_retried() >= 1,
        "no chunk retry despite p=0.5 corruption"
    );
    assert_eq!(sup.replicas_lost(), 0);
}

#[test]
fn corrupt_chunks_retry_in_place_blocking() {
    corrupt_chunks(true);
}

#[test]
fn corrupt_chunks_retry_in_place_reactor() {
    corrupt_chunks(false);
}

// ---------------------------------------------------------------------
// Egress truncation: half a message, then death — a mid-message EOF.
// ---------------------------------------------------------------------

#[test]
fn truncated_egress_recovers_like_a_kill() {
    let sup = run_with_faults(
        &[2],
        false,
        true,
        16,
        1,
        &["truncate:node0.0@frame=5"],
        CodecRuntime::serial(),
    );
    assert_eq!(sup.replicas_lost(), 1, "mid-message EOF not detected");
    assert!(sup.frames_redispatched() >= 1);
    assert!(sup.is_dead("node0.0 data socket"));
}

// ---------------------------------------------------------------------
// Inertness: recovery enabled, no faults scheduled.
// ---------------------------------------------------------------------

fn fault_free(blocking: bool) {
    let sup = run_with_faults(
        &[2, 1],
        false,
        blocking,
        20,
        2,
        &[],
        CodecRuntime::serial(),
    );
    assert_eq!(sup.replicas_lost(), 0);
    assert_eq!(sup.frames_redispatched(), 0);
    assert_eq!(sup.chunks_retried(), 0);
}

#[test]
fn fault_free_recovery_run_counts_nothing_blocking() {
    fault_free(true);
}

#[test]
fn fault_free_recovery_run_counts_nothing_reactor() {
    fault_free(false);
}
