//! Runtime integration: AOT artifacts -> PJRT -> numerics vs the Python
//! reference. Requires `make artifacts` (tiny profile).

use std::path::PathBuf;

use defer::model::{PartitionPlan, ReferenceVectors};
use defer::runtime::{Engine, Executable};
use defer::tensor::Tensor;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

#[test]
fn single_partition_matches_python_reference() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let plan = PartitionPlan::load(&artifacts(), "tiny", "resnet50", 1).unwrap();
    let exe = Executable::load(&engine, &plan.parts[0]).unwrap();
    let rv = ReferenceVectors::load(&artifacts(), "tiny", "resnet50").unwrap();
    let out = exe.run(&rv.input).unwrap();
    let err = out.max_abs_diff(&rv.output).unwrap();
    let rel = out.rel_l2_error(&rv.output).unwrap();
    assert!(
        rel < 1e-3,
        "rust PJRT output deviates from python: max {err}, rel l2 {rel}"
    );
}

#[test]
fn partition_chain_composes_to_reference() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    for n in [2usize, 4] {
        let plan = PartitionPlan::load(&artifacts(), "tiny", "resnet50", n).unwrap();
        let exes: Vec<Executable> = plan
            .parts
            .iter()
            .map(|p| Executable::load(&engine, p).unwrap())
            .collect();
        let rv = ReferenceVectors::load(&artifacts(), "tiny", "resnet50").unwrap();
        let mut act = rv.input.clone();
        for exe in &exes {
            act = exe.run(&act).unwrap();
        }
        let rel = act.rel_l2_error(&rv.output).unwrap();
        assert!(rel < 1e-3, "{n}-way chain rel l2 {rel}");
    }
}

#[test]
fn vgg16_reference_holds_too() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let plan = PartitionPlan::load(&artifacts(), "tiny", "vgg16", 2).unwrap();
    let rv = ReferenceVectors::load(&artifacts(), "tiny", "vgg16").unwrap();
    let mut act = rv.input.clone();
    for p in &plan.parts {
        let exe = Executable::load(&engine, p).unwrap();
        act = exe.run(&act).unwrap();
    }
    assert!(act.rel_l2_error(&rv.output).unwrap() < 1e-3);
}

#[test]
fn executable_rejects_wrong_input_shape() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let plan = PartitionPlan::load(&artifacts(), "tiny", "resnet50", 1).unwrap();
    let exe = Executable::load(&engine, &plan.parts[0]).unwrap();
    let bad = Tensor::zeros(vec![1, 16, 16, 3]);
    assert!(exe.run(&bad).is_err());
}

#[test]
fn executable_rejects_wrong_weight_payload() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let plan = PartitionPlan::load(&artifacts(), "tiny", "resnet50", 2).unwrap();
    let spec = &plan.parts[0];
    let hlo = spec.read_hlo().unwrap();
    let mut weights = spec.read_weights().unwrap();
    weights.pop(); // drop one array
    assert!(Executable::from_parts(&engine, &hlo, spec, weights).is_err());
}

#[test]
fn run_is_deterministic() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let plan = PartitionPlan::load(&artifacts(), "tiny", "vgg16", 1).unwrap();
    let exe = Executable::load(&engine, &plan.parts[0]).unwrap();
    let x = Tensor::random(exe.input_shape().to_vec(), 99);
    let a = exe.run(&x).unwrap();
    let b = exe.run(&x).unwrap();
    assert_eq!(a, b, "same input must give bitwise-same output");
}
