//! StageSpec fusion-accounting goldens: synthetic partition specs in,
//! exact fused accounting out. No artifacts, no RNG, no clocks.
//!
//! The numbers here are the contract the repartition planner and the
//! coordinator both rely on: summed FLOPs, elided inner boundary bytes
//! (only the fused run's outer boundaries touch the network), and the
//! concatenated weight-manifest order (partition order, then each
//! partition's own manifest order — the exact layout of the fused
//! weights payload).

use defer::model::{PartitionPlan, PartitionSpec, StageSpec, WeightSpec};

fn spec(
    part_index: usize,
    input_shape: Vec<usize>,
    output_shape: Vec<usize>,
    flops: u64,
    weights: Vec<WeightSpec>,
) -> PartitionSpec {
    let weights_bytes = weights.iter().map(|w| w.elements * 4).sum();
    PartitionSpec {
        model: "m".into(),
        profile: "tiny".into(),
        part_index,
        part_count: 3,
        input_shape,
        output_shape,
        flops,
        layers: vec![format!("layer{part_index}")],
        weights,
        weights_bytes,
        hlo_path: std::path::PathBuf::new(),
        weights_path: std::path::PathBuf::new(),
    }
}

fn w(node: &str, param: &str, shape: Vec<usize>) -> WeightSpec {
    let elements = shape.iter().product();
    WeightSpec {
        node: node.into(),
        param: param.into(),
        shape,
        elements,
    }
}

fn three_part_plan() -> PartitionPlan {
    PartitionPlan {
        parts: vec![
            spec(0, vec![1, 4], vec![1, 8], 100, vec![w("a", "w", vec![4, 8])]),
            spec(
                1,
                vec![1, 8],
                vec![1, 2],
                250,
                vec![w("b", "w", vec![8, 2]), w("b", "b", vec![2])],
            ),
            spec(2, vec![1, 2], vec![1, 2], 50, vec![w("c", "w", vec![2, 2])]),
        ],
    }
}

#[test]
fn fusion_accounting_golden() {
    let plan = three_part_plan();
    let stages = plan.fuse(&[0, 2, 3]).unwrap();
    assert_eq!(stages.len(), 2);

    let fused = &stages[0];
    assert_eq!(fused.num_parts(), 2);
    assert_eq!(fused.label(), "p0..p1of3");
    // FLOPs sum.
    assert_eq!(fused.flops(), 350);
    // Outer boundaries only: the stage's network-visible input is p0's
    // input, its output p1's output.
    assert_eq!(fused.input_shape(), &[1, 4]);
    assert_eq!(fused.output_shape(), &[1, 2]);
    assert_eq!(fused.input_bytes(), 16);
    assert_eq!(fused.output_bytes(), 8);
    // The p0 -> p1 boundary ([1, 8] = 32 B) is elided from the network.
    assert_eq!(fused.elided_boundary_bytes(), 32);
    // Weights concatenate: bytes and element counts sum...
    assert_eq!(fused.weights_bytes(), 128 + 72);
    assert_eq!(fused.weight_elements(), 32 + 16 + 2);
    // ...and the manifest order is partition order, then each
    // partition's own manifest order.
    let manifest: Vec<(String, String)> = fused
        .weight_manifest()
        .iter()
        .map(|m| (m.node.clone(), m.param.clone()))
        .collect();
    assert_eq!(
        manifest,
        vec![
            ("a".to_string(), "w".to_string()),
            ("b".to_string(), "w".to_string()),
            ("b".to_string(), "b".to_string()),
        ]
    );

    let single = &stages[1];
    assert_eq!(single.num_parts(), 1);
    assert_eq!(single.label(), "p2of3");
    assert_eq!(single.flops(), 50);
    assert_eq!(single.elided_boundary_bytes(), 0);
    assert_eq!(single.weights_bytes(), 16);

    // The degenerate cuts reproduce the unfused chain exactly.
    let singletons = plan.fuse(&[0, 1, 2, 3]).unwrap();
    assert_eq!(singletons.len(), 3);
    for (st, p) in singletons.iter().zip(&plan.parts) {
        assert_eq!(st.num_parts(), 1);
        assert_eq!(st.flops(), p.flops);
        assert_eq!(st.input_shape(), p.input_shape.as_slice());
    }
}

#[test]
fn fuse_rejects_bad_cuts() {
    let plan = three_part_plan();
    // Must start at 0, end at parts.len(), strictly increase.
    assert!(plan.fuse(&[0, 2]).is_err());
    assert!(plan.fuse(&[1, 3]).is_err());
    assert!(plan.fuse(&[0, 0, 3]).is_err());
    assert!(plan.fuse(&[0, 2, 2, 3]).is_err());
    assert!(plan.fuse(&[0]).is_err());
}

#[test]
fn fuse_rejects_broken_runs() {
    let plan = three_part_plan();
    // Non-contiguous run (p0 then p2).
    let err = StageSpec::fuse(vec![plan.parts[0].clone(), plan.parts[2].clone()])
        .unwrap_err();
    assert!(format!("{err}").contains("not contiguous"), "{err}");
    // Empty run.
    assert!(StageSpec::fuse(vec![]).is_err());
    // Mixed artifact sets (different part_count).
    let mut alien = plan.parts[1].clone();
    alien.part_count = 8;
    let err = StageSpec::fuse(vec![plan.parts[0].clone(), alien]).unwrap_err();
    assert!(format!("{err}").contains("artifact sets"), "{err}");
    // Boundary-shape mismatch inside the run.
    let mut bent = plan.parts[1].clone();
    bent.input_shape = vec![1, 6];
    let err = StageSpec::fuse(vec![plan.parts[0].clone(), bent]).unwrap_err();
    assert!(format!("{err}").contains("boundary mismatch"), "{err}");
}

#[test]
fn partition_plan_validate_names_boundary_mismatch() {
    // PartitionPlan::validate must reject a plan whose adjacent
    // partitions do not chain, naming both sides.
    let mut plan = three_part_plan();
    plan.parts[1].input_shape = vec![1, 6];
    let err = plan.validate().unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("boundary mismatch"), "{msg}");
    assert!(msg.contains("p0") && msg.contains("p1"), "{msg}");
    // The intact plan validates.
    assert!(three_part_plan().validate().is_ok());
}
