//! Placement-planner golden tests: synthetic stage costs in, exact
//! `Topology` out. No artifacts, no RNG, no clocks — the planner is a
//! pure function, so these assert its output byte-for-byte.

use defer::netem::LinkSpec;
use defer::placement::{
    plan, BatchCost, Bottleneck, CodecCost, DeviceProfile, PlacementProblem, StageCost,
};

fn homogeneous(n: usize, mflops: f64) -> Vec<DeviceProfile> {
    (0..n)
        .map(|i| DeviceProfile {
            name: format!("edge{i}"),
            mflops,
        })
        .collect()
}

fn stage(flops: u64, input_bytes: u64, output_bytes: u64) -> StageCost {
    StageCost {
        flops,
        input_bytes,
        output_bytes,
    }
}

/// The acceptance scenario: wifi uplink into the cluster, gigabit
/// candidates inside, one stage 4x heavier than the rest, budget for
/// two extra workers. The planner must pour the whole surplus into the
/// bottleneck stage and route every interior hop over gigabit.
#[test]
fn bottleneck_stage_soaks_up_the_worker_budget() {
    let p = PlacementProblem {
        stages: vec![
            stage(100_000_000, 12_288, 65_536),
            stage(400_000_000, 65_536, 65_536),
            stage(100_000_000, 65_536, 4_096),
        ],
        devices: homogeneous(5, 100.0),
        worker_budget: 5,
        uplink: LinkSpec::wifi(),
        interconnect: vec![LinkSpec::gigabit_lan()],
        codec: CodecCost::default(),
        batch: BatchCost::ZERO,
        relay_junctions: false,
    };
    let placed = plan(&p).unwrap();
    assert_eq!(placed.replica_counts(), vec![1, 3, 1]);
    assert_eq!(placed.num_workers(), 5);
    // Stage 1 at 4 s/frame over 3 replicas still gates the pipeline
    // (4/3 s > 1 s for its neighbours).
    assert_eq!(placed.bottleneck, Bottleneck::Stage(1));
    let hops: Vec<LinkSpec> = placed.hop_links.clone();
    assert_eq!(hops[0], LinkSpec::wifi());
    for h in &hops[1..] {
        assert_eq!(*h, LinkSpec::gigabit_lan());
    }
    // And it materializes as a real Topology, chain-runner ready.
    let topo = placed.topology().unwrap();
    assert_eq!(topo.num_stages(), 3);
    assert_eq!(topo.num_workers(), 5);
    assert_eq!(topo.replicas(1), 3);
    assert_eq!(topo.hop_link(0), LinkSpec::wifi());
    assert_eq!(topo.hop_link(2), LinkSpec::gigabit_lan());
}

/// Byte-identical output across repeated runs and across device input
/// orderings: the planner sorts everything it touches.
#[test]
fn planner_is_deterministic() {
    let mk = |device_order_rev: bool| {
        let mut devices = vec![
            DeviceProfile {
                name: "a".into(),
                mflops: 100.0,
            },
            DeviceProfile {
                name: "b".into(),
                mflops: 200.0,
            },
            DeviceProfile {
                name: "c".into(),
                mflops: 100.0,
            },
            DeviceProfile {
                name: "d".into(),
                mflops: 50.0,
            },
        ];
        if device_order_rev {
            devices.reverse();
        }
        PlacementProblem {
            stages: vec![
                stage(150_000_000, 8_192, 32_768),
                stage(300_000_000, 32_768, 2_048),
            ],
            devices,
            worker_budget: 4,
            uplink: LinkSpec::wifi(),
            interconnect: vec![LinkSpec::gigabit_lan(), LinkSpec::fast_edge()],
            codec: CodecCost::default(),
            batch: BatchCost::ZERO,
            relay_junctions: false,
        }
    };
    let first = plan(&mk(false)).unwrap();
    for _ in 0..3 {
        let again = plan(&mk(false)).unwrap();
        assert_eq!(first.render(), again.render());
        assert_eq!(first.replica_counts(), again.replica_counts());
    }
    // The device *pool* is a set; its listing order must not matter.
    let reordered = plan(&mk(true)).unwrap();
    assert_eq!(first.render(), reordered.render());
}

/// The heaviest stage claims the fastest device, deterministically.
#[test]
fn heaviest_stage_gets_fastest_device() {
    let p = PlacementProblem {
        stages: vec![stage(100_000_000, 1_000, 1_000), stage(400_000_000, 1_000, 1_000)],
        devices: vec![
            DeviceProfile {
                name: "slow".into(),
                mflops: 50.0,
            },
            DeviceProfile {
                name: "fast".into(),
                mflops: 400.0,
            },
        ],
        worker_budget: 2,
        uplink: LinkSpec::ideal(),
        interconnect: vec![],
        codec: CodecCost::default(),
        batch: BatchCost::ZERO,
        relay_junctions: false,
    };
    let placed = plan(&p).unwrap();
    assert_eq!(placed.stages[1].devices, vec!["fast".to_string()]);
    assert_eq!(placed.stages[0].devices, vec!["slow".to_string()]);
    // 400 MFLOPs / 400 MFLOP/s = 1 s; 100 MFLOPs / 50 MFLOP/s = 2 s:
    // after the swap the light stage on the slow device is the gate.
    assert_eq!(placed.bottleneck, Bottleneck::Stage(0));
}

/// An uplink-bound pipeline must not burn budget on useless replicas:
/// hop 0 is one shared physical link however many workers exist.
#[test]
fn uplink_bound_pipeline_is_left_unreplicated() {
    let p = PlacementProblem {
        stages: vec![
            stage(1_000_000, 60_000_000, 10_000),
            stage(1_000_000, 10_000, 10_000),
        ],
        devices: homogeneous(8, 500.0),
        worker_budget: 8,
        uplink: LinkSpec::wifi(),
        interconnect: vec![LinkSpec::gigabit_lan()],
        codec: CodecCost::default(),
        batch: BatchCost::ZERO,
        relay_junctions: false,
    };
    let placed = plan(&p).unwrap();
    assert_eq!(placed.replica_counts(), vec![1, 1]);
    assert_eq!(placed.bottleneck, Bottleneck::Uplink);
    // Predicted throughput = 1 / uplink occupancy.
    let uplink_secs = placed.uplink_time.as_secs_f64();
    assert!((placed.predicted_throughput - 1.0 / uplink_secs).abs() < 1e-9);
}

/// Interior hops pick the candidate with the least modeled transfer
/// time for that hop's bytes; first candidate wins ties.
#[test]
fn interior_hops_pick_fastest_candidate() {
    let p = PlacementProblem {
        stages: vec![stage(10_000_000, 4_096, 1_048_576), stage(10_000_000, 1_048_576, 512)],
        devices: homogeneous(2, 100.0),
        worker_budget: 2,
        uplink: LinkSpec::wifi(),
        interconnect: vec![LinkSpec::wifi(), LinkSpec::gigabit_lan()],
        codec: CodecCost::default(),
        batch: BatchCost::ZERO,
        relay_junctions: false,
    };
    let placed = plan(&p).unwrap();
    // 1 MiB over gigabit (~8 ms + 0.2 ms) beats wifi (~168 ms + 3.5 ms).
    assert_eq!(placed.hop_links[1], LinkSpec::gigabit_lan());
    assert_eq!(placed.hop_links[2], LinkSpec::gigabit_lan());
    assert_eq!(placed.hop_links[0], LinkSpec::wifi());
}

/// Replication stops when the next replica stops paying: with two equal
/// stages and budget 6, [3, 3] and [2, 2] both beat lopsided splits,
/// and the greedy lands on the balanced exhaustion of the budget.
#[test]
fn budget_spreads_across_equal_bottlenecks() {
    let p = PlacementProblem {
        stages: vec![stage(200_000_000, 4_096, 4_096), stage(200_000_000, 4_096, 4_096)],
        devices: homogeneous(6, 100.0),
        worker_budget: 6,
        uplink: LinkSpec::gigabit_lan(),
        interconnect: vec![LinkSpec::gigabit_lan()],
        codec: CodecCost::default(),
        batch: BatchCost::ZERO,
        relay_junctions: false,
    };
    let placed = plan(&p).unwrap();
    assert_eq!(placed.replica_counts(), vec![3, 3]);
    assert_eq!(placed.num_workers(), 6);
}

/// Micro-batch pricing golden: a fixed per-message overhead is
/// amortized across the replicas of a stage but charged whole to the
/// shared uplink, so pricing it can move the reported bottleneck.
/// Unpriced, the replicated stage gates the pipeline; at the planned
/// B=8 the uplink does. Both renders are asserted byte-for-byte, and
/// the unpriced render carries no batch line at all.
#[test]
fn batch_term_moves_reported_bottleneck_golden() {
    let mk = |batch: BatchCost| PlacementProblem {
        stages: vec![stage(2_000_000, 40_000, 20_000)],
        devices: homogeneous(2, 100.0),
        worker_budget: 2,
        uplink: LinkSpec::wifi(),
        interconnect: vec![LinkSpec::gigabit_lan()],
        codec: CodecCost::default(),
        batch,
        relay_junctions: false,
    };
    // 2 MFLOPs / 100 MFLOP/s = 20 ms compute, x2 -> 10.18 ms service;
    // wifi uplink 9.9 ms: the stage gates.
    let unpriced = plan(&mk(BatchCost::ZERO)).unwrap();
    assert_eq!(unpriced.batch, 1);
    assert_eq!(unpriced.bottleneck, Bottleneck::Stage(0));
    let expected = "placement plan: 1 stage(s), 2 worker(s), predicted 98.232 cycles/s\n\
                    \x20 hop 0 uplink wifi (9.900 ms/frame)\n\
                    \x20 stage 0: x2 on [edge0, edge1] via gigabit, compute 20.000 ms + \
                    egress 0.360 ms -> service 10.180 ms/frame, bottleneck\n";
    assert_eq!(unpriced.render(), expected);
    // 8 ms per message amortizes to 1 ms at B=8: the stage pays
    // (20.36 + 1)/2 = 10.68 ms but the shared uplink pays the whole
    // charge, 9.9 + 1 = 10.9 ms, and becomes the gate.
    let priced = plan(&mk(BatchCost {
        fixed_secs: 8e-3,
        max_batch: 8,
        latency_budget_secs: 0.0,
    }))
    .unwrap();
    assert_eq!(priced.batch, 8);
    assert_eq!(priced.bottleneck, Bottleneck::Uplink);
    let expected = "placement plan: 1 stage(s), 2 worker(s), predicted 91.743 cycles/s\n\
                    \x20 hop 0 uplink wifi (10.900 ms/frame, bottleneck)\n\
                    \x20 batch: B=8 per-frame overhead 8.000 ms amortized to 1.000 ms\n\
                    \x20 stage 0: x2 on [edge0, edge1] via gigabit, compute 20.000 ms + \
                    egress 0.360 ms + batch 1.000 ms -> service 10.680 ms/frame\n";
    assert_eq!(priced.render(), expected);
}

/// Render is the goldens surface: assert the exact bytes for a small
/// plan so any cost-model or formatting drift is caught loudly.
#[test]
fn render_golden() {
    let p = PlacementProblem {
        stages: vec![stage(100_000_000, 40_000, 20_000), stage(50_000_000, 20_000, 4_000)],
        devices: homogeneous(3, 100.0),
        worker_budget: 3,
        uplink: LinkSpec::wifi(),
        interconnect: vec![LinkSpec::gigabit_lan()],
        codec: CodecCost::default(),
        batch: BatchCost::ZERO,
        relay_junctions: false,
    };
    let placed = plan(&p).unwrap();
    // wifi uplink: 40 kB * 8 / 50 Mbps = 6.4 ms + 3 ms lat + 0.5 ms E[jitter].
    // stage 0: 1 s compute + (20 kB*8/1 Gbps + 0.2 ms) egress, x2 -> 500.180 ms.
    // stage 1: 0.5 s compute + (4 kB*8/1 Gbps + 0.2 ms) egress, x1 -> 500.232 ms,
    //          which now gates the pipeline: 1/0.500232 s = 1.999 cycles/s.
    let expected = "placement plan: 2 stage(s), 3 worker(s), predicted 1.999 cycles/s\n\
                    \x20 hop 0 uplink wifi (9.900 ms/frame)\n\
                    \x20 stage 0: x2 on [edge0, edge1] via gigabit, compute 1000.000 ms + \
                    egress 0.360 ms -> service 500.180 ms/frame\n\
                    \x20 stage 1: x1 on [edge2] via gigabit, compute 500.000 ms + \
                    egress 0.232 ms -> service 500.232 ms/frame, bottleneck\n";
    assert_eq!(placed.render(), expected);
}
