//! Failure injection: the chain must fail loudly and helpfully, never
//! silently. Exercises the coordinator's error paths against real tiny
//! artifacts (`make artifacts`).

use std::path::PathBuf;
use std::sync::Arc;

use defer::config::{CodecConfig, DeferConfig};
use defer::coordinator::compute_node::{
    encode_architecture, run_compute_node, ComputeOptions, NodeStats,
};
use defer::coordinator::transport::Conn;
use defer::energy::EnergyModel;
use defer::metrics::ByteCounter;
use defer::model::PartitionPlan;
use defer::netem::Link;
use defer::runtime::Engine;
use defer::topology::wiring::{DealSender, MergeReceiver, WorkerConns};
use defer::topology::StageView;
use defer::wire::{Message, MessageType};

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

/// Spawn a compute node wired to local pairs; returns (its result handle,
/// dispatcher-side conns).
struct Harness {
    node: std::thread::JoinHandle<defer::Result<()>>,
    cfg_conn: Conn,
    w_conn: Conn,
    data_in: Conn,
    #[allow(dead_code)]
    result_out: Conn,
}

fn spawn_node(engine: Engine) -> Harness {
    let (cfg_d, cfg_n) = Conn::local_pair(2);
    let (w_d, w_n) = Conn::local_pair(2);
    let (din_d, din_n) = Conn::local_pair(2);
    let (dout_n, dout_d) = Conn::local_pair(2);
    let stats = Arc::new(NodeStats::new(EnergyModel::default()));
    let link = Arc::new(Link::ideal());
    let node = std::thread::spawn(move || {
        run_compute_node(
            engine,
            WorkerConns {
                view: StageView::standalone(0),
                config: cfg_n,
                weights: w_n,
                data_in: MergeReceiver::single(din_n, "dispatcher"),
                data_out: DealSender::single(dout_n, "dispatcher return socket"),
            },
            CodecConfig::default(),
            link,
            stats,
            ComputeOptions {
                pipe_depth: 2,
                ..ComputeOptions::default()
            },
        )
    });
    Harness {
        node,
        cfg_conn: cfg_d,
        w_conn: w_d,
        data_in: din_d,
        result_out: dout_d,
    }
}

fn send(conn: &mut Conn, msg: &Message) {
    conn.send(msg, &Link::ideal(), &ByteCounter::new()).unwrap();
}

#[test]
fn node_rejects_data_before_config() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let mut h = spawn_node(engine);
    // Wrong phase: Data on the config socket.
    send(
        &mut h.cfg_conn,
        &Message {
            msg_type: MessageType::Data,
            frame: 0,
            serialized_len: 0,
            count: 0,
            batch: 1,
            payload: vec![],
        },
    );
    let err = h.node.join().unwrap().unwrap_err();
    assert!(format!("{err}").contains("expected ModelConfig"), "{err}");
}

#[test]
fn node_rejects_truncated_weights() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let plan = PartitionPlan::load(&artifacts(), "tiny", "resnet50", 2).unwrap();
    let spec = &plan.parts[0];
    let hlo = spec.read_hlo().unwrap();
    let mut h = spawn_node(engine);

    let arch = encode_architecture(spec, "dispatcher", &hlo);
    let arch_len = arch.len();
    send(
        &mut h.cfg_conn,
        &Message {
            msg_type: MessageType::ModelConfig,
            frame: 0,
            serialized_len: arch_len as u64,
            count: 0,
            batch: 1,
            payload: arch,
        },
    );
    // Weights with half the elements, binary codec mismatch vs manifest.
    let n_good: usize = spec.weights.iter().map(|w| w.elements).sum();
    let flat = vec![0.0f32; n_good / 2];
    let codec = CodecConfig::default().weights;
    let (payload, mid) = codec.encode_f32s(&flat, None);
    send(
        &mut h.w_conn,
        &Message {
            msg_type: MessageType::Weights,
            frame: 0,
            serialized_len: mid as u64,
            count: flat.len() as u64,
            batch: 1,
            payload,
        },
    );
    let err = h.node.join().unwrap().unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("manifest wants"), "unhelpful error: {msg}");
}

#[test]
fn node_rejects_corrupt_architecture_payload() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let mut h = spawn_node(engine);
    // Valid frame, garbage payload.
    send(
        &mut h.cfg_conn,
        &Message {
            msg_type: MessageType::ModelConfig,
            frame: 0,
            serialized_len: 8,
            count: 0,
            batch: 1,
            payload: vec![0xFF; 8],
        },
    );
    assert!(h.node.join().unwrap().is_err());
}

#[test]
fn node_inference_phase_rejects_config_replay() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let plan = PartitionPlan::load(&artifacts(), "tiny", "resnet50", 2).unwrap();
    let spec = &plan.parts[0];
    let hlo = spec.read_hlo().unwrap();
    let mut h = spawn_node(engine);
    let arch = encode_architecture(spec, "dispatcher", &hlo);
    let arch_len = arch.len();
    send(
        &mut h.cfg_conn,
        &Message {
            msg_type: MessageType::ModelConfig,
            frame: 0,
            serialized_len: arch_len as u64,
            count: 0,
            batch: 1,
            payload: arch,
        },
    );
    let flat: Vec<f32> = plan.parts[0]
        .read_weights()
        .unwrap()
        .into_iter()
        .flatten()
        .collect();
    let codec = CodecConfig::default().weights;
    let (payload, mid) = codec.encode_f32s(&flat, None);
    send(
        &mut h.w_conn,
        &Message {
            msg_type: MessageType::Weights,
            frame: 0,
            serialized_len: mid as u64,
            count: flat.len() as u64,
            batch: 1,
            payload,
        },
    );
    // Wait for Ready.
    let ready = h.cfg_conn.recv(&ByteCounter::new()).unwrap();
    assert_eq!(ready.msg_type, MessageType::Ready);
    // Now replay a Weights message on the DATA path: must be rejected.
    send(
        &mut h.data_in,
        &Message {
            msg_type: MessageType::Weights,
            frame: 1,
            serialized_len: 0,
            count: 0,
            batch: 1,
            payload: vec![],
        },
    );
    let err = h.node.join().unwrap().unwrap_err();
    assert!(format!("{err}").contains("unexpected"), "{err}");
}

#[test]
fn chain_missing_artifacts_is_helpful() {
    let mut cfg = DeferConfig::default();
    cfg.artifacts_dir = PathBuf::from("/nonexistent");
    cfg.profile = "tiny".into();
    let err = defer::coordinator::chain::ChainRunner::new(cfg).err().unwrap();
    assert!(format!("{err}").contains("make artifacts"));
}

#[test]
fn chain_rejects_unbuildable_node_count() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = DeferConfig::default();
    cfg.artifacts_dir = artifacts();
    cfg.profile = "tiny".into();
    cfg.model = "resnet50".into();
    cfg.nodes = 7; // tiny profile ships 1/2/4 only
    assert!(defer::coordinator::chain::ChainRunner::new(cfg).is_err());
}

#[test]
fn lossy_codec_on_architecture_socket_is_rejected_by_decode() {
    // The architecture payload is bytes, not floats — feeding it through a
    // float codec would corrupt it; the node's strict parse catches this.
    let payload = b"definitely not an architecture".to_vec();
    assert!(defer::coordinator::compute_node::decode_architecture(&payload).is_err());
}
