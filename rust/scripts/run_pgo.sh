#!/usr/bin/env bash
# Profile-guided build of the defer binary and benches.
#
# PGO helps exactly where this repo is hot: the codec kernels are tight
# loops whose branch mix (plane population, LZ4 match density) the
# compiler cannot guess. The recipe is the standard three-step:
#
#   1. build instrumented          (RUSTFLAGS=-Cprofile-generate)
#   2. run the codec + chain benches to collect .profraw samples
#   3. merge with llvm-profdata and rebuild with -Cprofile-use
#
# Usage:  rust/scripts/run_pgo.sh [profile-data-dir]
#
# Requires llvm-profdata (rustup component llvm-tools-preview, or any
# system LLVM matching the rustc major). Wire bytes are unaffected —
# PGO changes code layout, never codec output (the kernel-equivalence
# suite still applies to the optimized binary).

set -euo pipefail

cd "$(dirname "$0")/.."

PGO_DIR="${1:-$PWD/target/pgo-data}"
rm -rf "$PGO_DIR"
mkdir -p "$PGO_DIR"

# Prefer the rustup-shipped llvm-profdata so versions always match rustc.
LLVM_PROFDATA="llvm-profdata"
if ! command -v "$LLVM_PROFDATA" >/dev/null 2>&1; then
    TOOLS=$(dirname "$(rustc --print target-libdir)")/bin
    if [ -x "$TOOLS/llvm-profdata" ]; then
        LLVM_PROFDATA="$TOOLS/llvm-profdata"
    else
        echo "error: llvm-profdata not found (rustup component add llvm-tools-preview)" >&2
        exit 1
    fi
fi

echo "== step 1/3: instrumented build"
RUSTFLAGS="-Cprofile-generate=$PGO_DIR" cargo build --release

echo "== step 2/3: profiling run (codec benches; chain bench if artifacts exist)"
# Small payloads/frame counts: PGO needs representative branches, not
# statistically significant timings.
RUSTFLAGS="-Cprofile-generate=$PGO_DIR" DEFER_PAYLOAD_MB=2 DEFER_FRAMES=4 \
    cargo bench --bench codec_parallel
if [ -f artifacts/manifest.json ]; then
    RUSTFLAGS="-Cprofile-generate=$PGO_DIR" DEFER_FRAMES=30 \
        cargo bench --bench table2_codec_throughput
    RUSTFLAGS="-Cprofile-generate=$PGO_DIR" DEFER_FRAMES=100 \
        cargo bench --bench batch_throughput
else
    echo "   (artifacts absent: chain benches skipped, codec profile only)"
fi

echo "== step 3/3: merge + optimized rebuild"
"$LLVM_PROFDATA" merge -o "$PGO_DIR/merged.profdata" "$PGO_DIR"
RUSTFLAGS="-Cprofile-use=$PGO_DIR/merged.profdata" cargo build --release

echo "done: PGO-optimized binary at target/release/defer"
echo "      rerun benches under the same RUSTFLAGS to measure the delta"
