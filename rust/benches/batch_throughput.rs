//! Micro-batching throughput bench (artifact-free).
//!
//! The regime batching targets: small activation frames, where the
//! per-message fixed costs (wire header, CRC, send/recv syscalls,
//! codec setup) rival the payload itself. Synthetic pipeline workers
//! (elementwise compute, no PJRT) run over real TCP sockets so every
//! per-message cost is the genuine article; the dispatcher's batcher
//! coalesces 1..=16 frames per message and the bench reports cycles/s
//! per batch size, plus an adaptive-mode row.
//!
//! Emits `BENCH_batch.json` (machine-readable) into the working
//! directory so the perf trajectory is tracked across PRs.
//!
//! Env: DEFER_FRAMES (default 2000), DEFER_FRAME_ELEMS (default 64).

use std::io::Write as _;
use std::sync::Arc;
use std::time::Instant;

use defer::bench::Table;
use defer::compress::Compression;
use defer::coordinator::dispatcher::{run_inference, DispatcherStats, InferenceOptions};
use defer::coordinator::pipeline::{run_codec_pipeline, PipelineCtx};
use defer::energy::EnergyModel;
use defer::metrics::ByteCounter;
use defer::netem::{Link, LinkSpec};
use defer::serial::{Codec, CodecRuntime, Serialization};
use defer::tensor::Tensor;
use defer::threadpool::pipe;
use defer::topology::wiring::{build, TransportOptions, WorkerConns};
use defer::topology::Topology;
use defer::util::timer::SharedTimer;
use defer::wire::{Message, MessageType};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Synthetic worker: boundary reader feeding the real codec pipeline,
/// elementwise `v -> 2v + 1` in place of the fused executables.
fn spawn_worker(
    wc: WorkerConns,
    codec: Codec,
    rt: CodecRuntime,
) -> std::thread::JoinHandle<defer::Result<()>> {
    std::thread::spawn(move || {
        let WorkerConns {
            view,
            config: _config,
            weights: _weights,
            data_in,
            data_out,
        } = wc;
        let (tx, rx) = pipe::<Message>(8);
        let mut in_conn = data_in;
        let reader = std::thread::spawn(move || loop {
            match in_conn.recv(&ByteCounter::new()) {
                Ok(msg) => {
                    let stop = msg.msg_type == MessageType::Shutdown;
                    if tx.send(msg).is_err() || stop {
                        return;
                    }
                }
                Err(_) => return,
            }
        });
        let ctx = PipelineCtx {
            name: view.name.clone(),
            codec,
            rt,
            overhead: SharedTimer::new(),
            data_tx: ByteCounter::new(),
            frames: ByteCounter::new(),
            out_link: Arc::new(Link::ideal()),
            pipelined: true,
            pipe_depth: 8,
            payload_pool: None,
        };
        let result = run_codec_pipeline(rx, data_out, ctx, |values, _batch| {
            Ok(values.iter().map(|v| v * 2.0 + 1.0).collect())
        });
        reader.join().expect("reader thread");
        result
    })
}

/// One timed run: `frames` small frames through a 2-stage TCP chain at
/// the given batch size. Returns measured cycles/s.
fn run_once(frames: u64, elems: usize, batch: usize, adaptive: bool) -> f64 {
    let replicas = [1usize, 1];
    let hop_links = vec![LinkSpec::ideal(); replicas.len() + 1];
    let topo = Topology::new(&replicas, hop_links).unwrap();
    let defer::topology::wiring::Wiring {
        control,
        to_first,
        from_last,
        workers,
        junctions,
    } = build(
        &topo,
        &TransportOptions {
            tcp: true,
            base_port: None,
            pipe_depth: 8,
            relay_junctions: false,
        },
    )
    .unwrap();
    drop(control);
    let codec = Codec::new(Serialization::Binary, Compression::None);
    let workers: Vec<_> = workers
        .into_iter()
        .map(|wc| spawn_worker(wc, codec, CodecRuntime::serial()))
        .collect();

    let input = Tensor::new(vec![elems], vec![1.0; elems]).unwrap();
    let stats = Arc::new(DispatcherStats::new(EnergyModel::default()));
    let opts = InferenceOptions {
        pipelined: true,
        pipe_depth: 8,
        batch,
        batch_adaptive: adaptive,
        ..InferenceOptions::default()
    };
    let t0 = Instant::now();
    run_inference(
        input,
        frames,
        to_first,
        from_last,
        opts,
        Arc::new(Link::ideal()),
        Arc::clone(&stats),
        None,
        vec![elems],
    )
    .unwrap();
    let secs = t0.elapsed().as_secs_f64();
    for w in workers {
        w.join().unwrap().unwrap();
    }
    junctions.join().unwrap();
    assert_eq!(stats.clock.cycles(), frames, "dropped frames at batch {batch}");
    frames as f64 / secs
}

fn main() {
    let frames = env_usize("DEFER_FRAMES", 2000) as u64;
    let elems = env_usize("DEFER_FRAME_ELEMS", 64).max(1);
    println!(
        "# Micro-batching: {frames} frames of {elems} f32 over TCP, 2-stage synthetic chain"
    );
    // Warm up sockets/allocator so batch=1 is not penalized by order.
    let _ = run_once(frames.min(200), elems, 1, false);

    let mut table = Table::new(&["batch", "cycles/s", "vs batch=1"]);
    let mut rows_json = Vec::new();
    let mut base = 0.0f64;
    for batch in [1usize, 2, 4, 8, 16] {
        let cps = run_once(frames, elems, batch, false);
        if batch == 1 {
            base = cps;
        }
        let speedup = cps / base;
        table.row(&[
            batch.to_string(),
            format!("{cps:.1}"),
            format!("{speedup:.2}x"),
        ]);
        rows_json.push(format!(
            r#"    {{"batch": {batch}, "cycles_per_sec": {cps:.2}, "speedup_vs_unbatched": {speedup:.3}}}"#
        ));
    }
    let adaptive_cps = run_once(frames, elems, 8, true);
    table.row(&[
        "adaptive(<=8)".into(),
        format!("{adaptive_cps:.1}"),
        format!("{:.2}x", adaptive_cps / base),
    ]);
    print!("{}", table.render());

    let json = format!(
        "{{\n  \"frames\": {frames},\n  \"frame_elems\": {elems},\n  \"transport\": \"tcp\",\n  \"stages\": 2,\n  \"rows\": [\n{}\n  ],\n  \"adaptive\": {{\"cap\": 8, \"cycles_per_sec\": {adaptive_cps:.2}, \"speedup_vs_unbatched\": {:.3}}}\n}}\n",
        rows_json.join(",\n"),
        adaptive_cps / base
    );
    match std::fs::File::create("BENCH_batch.json").and_then(|mut f| f.write_all(json.as_bytes()))
    {
        Ok(()) => println!("\nwrote BENCH_batch.json"),
        Err(e) => println!("\ncould not write BENCH_batch.json: {e}"),
    }
}
