//! Micro-batching throughput bench (artifact-free).
//!
//! The regime batching targets: small activation frames, where the
//! per-message fixed costs (wire header, CRC, send/recv syscalls,
//! codec setup) rival the payload itself. Synthetic pipeline workers
//! (elementwise compute, no PJRT) run over real TCP sockets so every
//! per-message cost is the genuine article; the dispatcher's batcher
//! coalesces 1..=16 frames per message and the bench reports cycles/s
//! per batch size, plus an adaptive-mode row.
//!
//! A second section races the two data planes — thread-per-connection
//! blocking I/O vs the sharded reactor — on a replicated u=d=4 mesh,
//! where the blocking plane's thread bill is steepest. A third drives
//! >=4 MiB frames through a 2-stage chain on each plane: the regime
//! where the zero-copy vectored egress path (one writev per frame, no
//! assemble copy) shows up directly in MiB/s.
//!
//! Every row also reports the zero-copy counters for its run —
//! `payload_copies` (serialize-path memcpys; 0 at steady state) and
//! `egress_syscalls` (vectored wire writes; reactor TCP only) — so the
//! copy bill is tracked across PRs alongside throughput.
//!
//! Emits `BENCH_batch.json` and `BENCH_io.json` (machine-readable)
//! into the working directory so the perf trajectory is tracked
//! across PRs.
//!
//! Env: DEFER_FRAMES (default 2000), DEFER_FRAME_ELEMS (default 64),
//! DEFER_LARGE_MB (default 4, min 4), DEFER_LARGE_FRAMES (default
//! scales with DEFER_FRAMES).

use std::io::Write as _;
use std::sync::Arc;
use std::time::Instant;

use defer::bench::Table;
use defer::compress::Compression;
use defer::coordinator::dispatcher::{run_inference, DispatcherStats, InferenceOptions};
use defer::coordinator::pipeline::{run_codec_pipeline, PipelineCtx};
use defer::energy::EnergyModel;
use defer::metrics::{zerocopy, ByteCounter};
use defer::netem::{Link, LinkSpec};
use defer::netio::Reactor;
use defer::serial::{Codec, CodecRuntime, Serialization};
use defer::tensor::Tensor;
use defer::threadpool::pipe;
use defer::topology::wiring::{build, FrameSink, FrameSource, TransportOptions, WorkerConns};
use defer::topology::Topology;
use defer::util::bufpool::BufPool;
use defer::util::timer::SharedTimer;
use defer::wire::{Message, MessageType};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Synthetic worker: elementwise `v -> 2v + 1` in place of the fused
/// executables. Blocking plane parks a boundary-reader thread; the
/// reactor plane registers the boundary with the shared event loop,
/// mirroring `compute_node`'s two branches — including the shared
/// payload pool that closes the recycle loop (ingest draws from it,
/// encode draws from it, `WireFrame` drop returns to it).
fn spawn_worker(
    wc: WorkerConns,
    codec: Codec,
    rt: CodecRuntime,
    reactor: Option<Arc<Reactor>>,
) -> std::thread::JoinHandle<defer::Result<()>> {
    std::thread::spawn(move || {
        let WorkerConns {
            view,
            config: _config,
            weights: _weights,
            data_in,
            data_out,
        } = wc;
        let pool = Arc::new(BufPool::new(8 + 2));
        let (tx, rx) = pipe::<Message>(8);
        let mut reader = None;
        let out: FrameSink = match &reactor {
            Some(r) => {
                r.register_ingress(data_in, tx, Some(Arc::clone(&pool)))?;
                r.register_egress(data_out, 8)?.into()
            }
            None => {
                let mut in_conn = data_in;
                let reader_pool = Arc::clone(&pool);
                reader = Some(std::thread::spawn(move || loop {
                    match in_conn.recv_pooled(&ByteCounter::new(), Some(&reader_pool)) {
                        Ok(msg) => {
                            let stop = msg.msg_type == MessageType::Shutdown;
                            if tx.send(msg).is_err() || stop {
                                return;
                            }
                        }
                        Err(_) => return,
                    }
                }));
                data_out.into()
            }
        };
        let ctx = PipelineCtx {
            name: view.name.clone(),
            codec,
            rt: rt.with_buffers(Arc::clone(&pool)),
            overhead: SharedTimer::new(),
            data_tx: ByteCounter::new(),
            frames: ByteCounter::new(),
            out_link: Arc::new(Link::ideal()),
            pipelined: true,
            pipe_depth: 8,
            payload_pool: Some(pool),
            recovery: None,
        };
        let result = run_codec_pipeline(rx, out, ctx, |values, _batch| {
            Ok(values.iter().map(|v| v * 2.0 + 1.0).collect())
        });
        if let Some(h) = reader {
            h.join().expect("reader thread");
        }
        result
    })
}

/// One timed run: `frames` frames of `elems` f32 through a TCP chain of
/// `replicas` at the given batch size. `io_threads` selects the data
/// plane: `Some(n)` runs everything on an n-shard reactor, `None` is
/// the blocking thread-per-connection plane. Returns measured cycles/s
/// plus the run's zero-copy counter movement.
fn run_chain(
    frames: u64,
    elems: usize,
    batch: usize,
    adaptive: bool,
    replicas: &[usize],
    io_threads: Option<usize>,
) -> (f64, zerocopy::Snapshot) {
    let zc0 = zerocopy::snapshot();
    let reactor = io_threads.map(|n| Reactor::new(n).unwrap());
    let hop_links = vec![LinkSpec::ideal(); replicas.len() + 1];
    let topo = Topology::new(replicas, hop_links).unwrap();
    let defer::topology::wiring::Wiring {
        control,
        to_first,
        from_last,
        workers,
        junctions,
    } = build(
        &topo,
        &TransportOptions {
            tcp: true,
            base_port: None,
            pipe_depth: 8,
            relay_junctions: false,
            recovery: None,
        },
    )
    .unwrap();
    drop(control);
    let codec = Codec::new(Serialization::Binary, Compression::None);
    let workers: Vec<_> = workers
        .into_iter()
        .map(|wc| spawn_worker(wc, codec, CodecRuntime::serial(), reactor.clone()))
        .collect();

    let input = Tensor::new(vec![elems], vec![1.0; elems]).unwrap();
    let stats = Arc::new(DispatcherStats::new(EnergyModel::default()));
    let opts = InferenceOptions {
        pipelined: true,
        pipe_depth: 8,
        batch,
        batch_adaptive: adaptive,
        ..InferenceOptions::default()
    };
    let (sink, source): (FrameSink, FrameSource) = match &reactor {
        Some(r) => {
            let sink = r.register_egress(to_first, 8).unwrap().into();
            let (res_tx, res_rx) = pipe::<Message>(8);
            let err = r.register_ingress(from_last, res_tx, None).unwrap();
            (sink, FrameSource::Queued { rx: res_rx, err })
        }
        None => (to_first.into(), from_last.into()),
    };
    let t0 = Instant::now();
    run_inference(
        input,
        frames,
        sink,
        source,
        opts,
        Arc::new(Link::ideal()),
        Arc::clone(&stats),
        None,
        vec![elems],
    )
    .unwrap();
    let secs = t0.elapsed().as_secs_f64();
    for w in workers {
        w.join().unwrap().unwrap();
    }
    junctions.join().unwrap();
    drop(reactor);
    assert_eq!(stats.clock.cycles(), frames, "dropped frames at batch {batch}");
    (frames as f64 / secs, zerocopy::snapshot().since(&zc0))
}

/// Batching section shape: default 2-stage unreplicated chain, blocking
/// plane (the pre-reactor baseline the trajectory was recorded on).
fn run_once(frames: u64, elems: usize, batch: usize, adaptive: bool) -> (f64, zerocopy::Snapshot) {
    run_chain(frames, elems, batch, adaptive, &[1, 1], None)
}

fn main() {
    let frames = env_usize("DEFER_FRAMES", 2000) as u64;
    let elems = env_usize("DEFER_FRAME_ELEMS", 64).max(1);
    println!(
        "# Micro-batching: {frames} frames of {elems} f32 over TCP, 2-stage synthetic chain"
    );
    // Warm up sockets/allocator so batch=1 is not penalized by order.
    let _ = run_once(frames.min(200), elems, 1, false);

    let mut table = Table::new(&["batch", "cycles/s", "vs batch=1", "copies", "syscalls"]);
    let mut rows_json = Vec::new();
    let mut base = 0.0f64;
    for batch in [1usize, 2, 4, 8, 16] {
        let (cps, zc) = run_once(frames, elems, batch, false);
        if batch == 1 {
            base = cps;
        }
        let speedup = cps / base;
        table.row(&[
            batch.to_string(),
            format!("{cps:.1}"),
            format!("{speedup:.2}x"),
            zc.payload_copies.to_string(),
            zc.egress_syscalls.to_string(),
        ]);
        rows_json.push(format!(
            r#"    {{"batch": {batch}, "cycles_per_sec": {cps:.2}, "speedup_vs_unbatched": {speedup:.3}, "payload_copies": {}, "egress_syscalls": {}}}"#,
            zc.payload_copies, zc.egress_syscalls
        ));
    }
    let (adaptive_cps, adaptive_zc) = run_once(frames, elems, 8, true);
    table.row(&[
        "adaptive(<=8)".into(),
        format!("{adaptive_cps:.1}"),
        format!("{:.2}x", adaptive_cps / base),
        adaptive_zc.payload_copies.to_string(),
        adaptive_zc.egress_syscalls.to_string(),
    ]);
    print!("{}", table.render());

    let json = format!(
        "{{\n  \"frames\": {frames},\n  \"frame_elems\": {elems},\n  \"transport\": \"tcp\",\n  \"stages\": 2,\n  \"rows\": [\n{}\n  ],\n  \"adaptive\": {{\"cap\": 8, \"cycles_per_sec\": {adaptive_cps:.2}, \"speedup_vs_unbatched\": {:.3}, \"payload_copies\": {}, \"egress_syscalls\": {}}}\n}}\n",
        rows_json.join(",\n"),
        adaptive_cps / base,
        adaptive_zc.payload_copies,
        adaptive_zc.egress_syscalls
    );
    match std::fs::File::create("BENCH_batch.json").and_then(|mut f| f.write_all(json.as_bytes()))
    {
        Ok(()) => println!("\nwrote BENCH_batch.json"),
        Err(e) => println!("\ncould not write BENCH_batch.json: {e}"),
    }

    // ---- data-plane I/O: reactor vs thread-per-connection ----
    let io_replicas = [4usize, 4];
    let io_frames = frames.min(1000);
    let io_batch = 4usize;
    // Parked per-connection threads on the blocking plane: one reader
    // per worker plus the dispatcher's result reader (matches the
    // RunReport `data_plane_threads` accounting).
    let blocking_threads = io_replicas.iter().sum::<usize>() + 1;
    let shards = 2usize;
    println!(
        "\n# Data-plane I/O: u=d=4 replicated mesh over TCP, {io_frames} frames, batch {io_batch}"
    );
    let (blocking_cps, blocking_zc) =
        run_chain(io_frames, elems, io_batch, false, &io_replicas, None);
    let (reactor_cps, reactor_zc) =
        run_chain(io_frames, elems, io_batch, false, &io_replicas, Some(shards));
    let ratio = reactor_cps / blocking_cps;
    let mut io_table = Table::new(&[
        "plane",
        "data-plane threads",
        "cycles/s",
        "vs blocking",
        "copies",
        "syscalls",
    ]);
    io_table.row(&[
        "blocking".into(),
        blocking_threads.to_string(),
        format!("{blocking_cps:.1}"),
        "1.00x".into(),
        blocking_zc.payload_copies.to_string(),
        blocking_zc.egress_syscalls.to_string(),
    ]);
    io_table.row(&[
        "reactor".into(),
        shards.to_string(),
        format!("{reactor_cps:.1}"),
        format!("{ratio:.2}x"),
        reactor_zc.payload_copies.to_string(),
        reactor_zc.egress_syscalls.to_string(),
    ]);
    print!("{}", io_table.render());

    // ---- large-frame vectored egress: >=4 MiB payloads per plane ----
    let large_mb = env_usize("DEFER_LARGE_MB", 4).max(4);
    let large_elems = large_mb * 1024 * 1024 / 4;
    let large_frames =
        env_usize("DEFER_LARGE_FRAMES", (frames as usize / 25).clamp(8, 64)) as u64;
    println!(
        "\n# Large-frame egress: {large_frames} frames of {large_mb} MiB over TCP, \
         2-stage chain, batch 1"
    );
    let mut lf_table = Table::new(&["plane", "cycles/s", "MiB/s", "copies", "syscalls"]);
    let mut lf_rows = Vec::new();
    for (plane, io) in [("blocking", None), ("reactor", Some(shards))] {
        let (cps, zc) = run_chain(large_frames, large_elems, 1, false, &[1, 1], io);
        let mibs = cps * large_mb as f64;
        lf_table.row(&[
            plane.into(),
            format!("{cps:.1}"),
            format!("{mibs:.0}"),
            zc.payload_copies.to_string(),
            zc.egress_syscalls.to_string(),
        ]);
        lf_rows.push(format!(
            r#"      {{"plane": "{plane}", "cycles_per_sec": {cps:.2}, "mib_per_sec": {mibs:.1}, "payload_copies": {}, "egress_syscalls": {}}}"#,
            zc.payload_copies, zc.egress_syscalls
        ));
    }
    print!("{}", lf_table.render());

    let io_json = format!(
        "{{\n  \"frames\": {io_frames},\n  \"frame_elems\": {elems},\n  \"transport\": \"tcp\",\n  \"replicas\": [4, 4],\n  \"batch\": {io_batch},\n  \"rows\": [\n    {{\"plane\": \"blocking\", \"data_plane_threads\": {blocking_threads}, \"cycles_per_sec\": {blocking_cps:.2}, \"vs_blocking\": 1.000, \"payload_copies\": {}, \"egress_syscalls\": {}}},\n    {{\"plane\": \"reactor\", \"data_plane_threads\": {shards}, \"cycles_per_sec\": {reactor_cps:.2}, \"vs_blocking\": {ratio:.3}, \"payload_copies\": {}, \"egress_syscalls\": {}}}\n  ],\n  \"large_frame\": {{\n    \"payload_mib\": {large_mb},\n    \"frames\": {large_frames},\n    \"batch\": 1,\n    \"rows\": [\n{}\n    ]\n  }}\n}}\n",
        blocking_zc.payload_copies,
        blocking_zc.egress_syscalls,
        reactor_zc.payload_copies,
        reactor_zc.egress_syscalls,
        lf_rows.join(",\n")
    );
    match std::fs::File::create("BENCH_io.json").and_then(|mut f| f.write_all(io_json.as_bytes()))
    {
        Ok(()) => println!("\nwrote BENCH_io.json"),
        Err(e) => println!("\ncould not write BENCH_io.json: {e}"),
    }
}
