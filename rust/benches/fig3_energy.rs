//! Fig. 3 — Energy consumption per node per inference cycle, ResNet50,
//! DEFER x {4, 6, 8} nodes vs single-device inference.
//!
//! Energy model per the paper: TDP x busy time for compute/serialization,
//! 10 pJ/bit for network transmission. Claims under test:
//!   (1) per-node energy decreases as node count grows (each node runs a
//!       smaller partition per cycle);
//!   (2) DEFER drops below single-device energy at >= 6 nodes
//!       (paper: -63% at 8 nodes).
//!
//! Env: DEFER_FRAMES (default 12), DEFER_PROFILE (default edge),
//!      DEFER_EMULATED_MFLOPS (default 50 — deterministic device-speed
//!      emulation, see DESIGN.md §Substitutions).

use defer::bench::Table;
use defer::config::DeferConfig;
use defer::coordinator::baseline::SingleDevice;
use defer::coordinator::chain::ChainRunner;
use defer::runtime::Engine;

fn main() {
    let frames: u64 = std::env::var("DEFER_FRAMES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let profile = std::env::var("DEFER_PROFILE").unwrap_or_else(|_| "edge".into());
    let mflops: f64 = std::env::var("DEFER_EMULATED_MFLOPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(50.0);
    let engine = Engine::cpu().expect("PJRT cpu client");

    println!(
        "# Fig. 3: per-node energy per cycle (J), ResNet50, profile={profile}, emulated device = {mflops} MFLOPS"
    );
    let mut table = Table::new(&[
        "config",
        "energy/node/cycle (J)",
        "compute (J)",
        "codec (J)",
        "network (J)",
    ]);

    let mut series = Vec::new();
    let mut single = f64::NAN;
    for nodes in [1usize, 4, 6, 8] {
        let mut cfg = DeferConfig::default();
        cfg.profile = profile.clone();
        cfg.model = "resnet50".into();
        cfg.nodes = nodes;
        cfg.emulated_mflops = mflops;
        let report = if nodes == 1 {
            SingleDevice::with_engine(cfg, engine.clone())
                .and_then(|r| r.run_frames(frames))
        } else {
            ChainRunner::with_engine(cfg, engine.clone()).and_then(|r| r.run_frames(frames))
        };
        match report {
            Ok(r) => {
                let per = r.energy_per_node_per_cycle();
                let n = r.node_energy.len() as f64 * r.cycles as f64;
                let compute: f64 = r.node_energy.iter().map(|e| e.compute_j).sum::<f64>() / n;
                let codec: f64 = r.node_energy.iter().map(|e| e.codec_j).sum::<f64>() / n;
                let net: f64 = r.node_energy.iter().map(|e| e.network_j).sum::<f64>() / n;
                table.row(&[
                    if nodes == 1 { "single device".into() } else { format!("DEFER {nodes} nodes") },
                    format!("{per:.6}"),
                    format!("{compute:.6}"),
                    format!("{codec:.6}"),
                    format!("{net:.8}"),
                ]);
                if nodes == 1 {
                    single = per;
                } else {
                    series.push((nodes, per));
                }
            }
            Err(e) => table.row(&[
                format!("DEFER {nodes} nodes"),
                format!("n/a ({e})"),
                String::new(),
                String::new(),
                String::new(),
            ]),
        }
    }
    print!("{}", table.render());
    let decreasing = series.windows(2).all(|w| w[1].1 <= w[0].1 * 1.05);
    println!("claim (1) per-node energy falls with node count: {}", if decreasing { "HOLDS" } else { "FAILS" });
    if let Some((_, at8)) = series.iter().find(|(n, _)| *n == 8) {
        println!(
            "claim (2) DEFER@8 vs single device: {:.2}x (paper: 0.37x)",
            at8 / single
        );
    }
}
