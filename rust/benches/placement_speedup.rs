//! Planned-vs-uniform throughput: what the placement planner buys.
//!
//! Part 1 is artifact-free: a synthetic heterogeneous scenario (wifi
//! uplink, gigabit cluster, one 4x-heavy stage) run through the pure
//! cost model, reporting the planner's predicted throughput against the
//! uniform unreplicated chain at several worker budgets. Deterministic:
//! identical output every run.
//!
//! Part 2 (needs `make artifacts`) measures the same comparison on the
//! real chain: tiny resnet50, deterministic edge-device emulation,
//! uniform vs `--auto-place` topologies.
//!
//! Env: DEFER_FRAMES (default 8), DEFER_EMULATED_MFLOPS (default 20).

use defer::bench::Table;
use defer::config::DeferConfig;
use defer::coordinator::chain::ChainRunner;
use defer::netem::LinkSpec;
use defer::placement::{plan, BatchCost, CodecCost, DeviceProfile, PlacementProblem, StageCost};
use defer::repartition::{self, PartCost, RepartitionProblem};
use defer::runtime::Engine;

fn synthetic_problem(budget: usize) -> PlacementProblem {
    PlacementProblem {
        stages: vec![
            StageCost {
                flops: 100_000_000,
                input_bytes: 12_288,
                output_bytes: 65_536,
            },
            StageCost {
                flops: 400_000_000,
                input_bytes: 65_536,
                output_bytes: 65_536,
            },
            StageCost {
                flops: 100_000_000,
                input_bytes: 65_536,
                output_bytes: 4_096,
            },
        ],
        devices: (0..budget)
            .map(|i| DeviceProfile {
                name: format!("edge{i}"),
                mflops: 100.0,
            })
            .collect(),
        worker_budget: budget,
        uplink: LinkSpec::wifi(),
        interconnect: vec![LinkSpec::gigabit_lan()],
        codec: CodecCost::default(),
        batch: BatchCost::ZERO,
        relay_junctions: false,
    }
}

fn main() {
    println!("# placement planner: planned vs uniform throughput");
    println!();
    println!("## part 1: cost model only (synthetic 3-stage scenario, no artifacts)");
    let uniform = plan(&synthetic_problem(3)).expect("uniform plan");
    let mut table = Table::new(&[
        "worker budget",
        "replicas",
        "predicted cycles/s",
        "vs uniform",
    ]);
    for budget in [3usize, 4, 5, 6, 8] {
        let placed = plan(&synthetic_problem(budget)).expect("plan");
        let reps: Vec<String> = placed
            .replica_counts()
            .iter()
            .map(|r| r.to_string())
            .collect();
        table.row(&[
            budget.to_string(),
            reps.join(","),
            format!("{:.3}", placed.predicted_throughput),
            format!(
                "{:.2}x",
                placed.predicted_throughput / uniform.predicted_throughput
            ),
        ]);
    }
    print!("{}", table.render());
    println!();
    print!("{}", plan(&synthetic_problem(6)).expect("plan").render());

    // ---- part 1b: joint repartitioning over a finer cut set ----
    // The same pipeline split into 6 fine partitions: the repartition
    // planner may now *move* the boundaries (under a per-worker memory
    // cap of half the model) as well as replicate, reporting what the
    // extra freedom buys over the fixed 3-stage cuts at each budget.
    println!();
    println!("## part 1b: joint repartitioning (6 fine partitions, memory-capped, no artifacts)");
    let fine_part = |flops: u64, input_bytes: u64, output_bytes: u64| PartCost {
        flops,
        input_bytes,
        output_bytes,
        weights_bytes: 200_000,
    };
    let fine_parts = || {
        vec![
            fine_part(50_000_000, 12_288, 32_768),
            fine_part(50_000_000, 32_768, 65_536),
            fine_part(200_000_000, 65_536, 65_536),
            fine_part(200_000_000, 65_536, 65_536),
            fine_part(50_000_000, 65_536, 16_384),
            fine_part(50_000_000, 16_384, 4_096),
        ]
    };
    let mut table = Table::new(&[
        "worker budget",
        "cuts",
        "replicas",
        "predicted cycles/s",
        "vs fixed 3-stage",
    ]);
    for budget in [3usize, 4, 5, 6] {
        let fixed = plan(&synthetic_problem(budget)).expect("fixed plan");
        let joint = repartition::plan(&RepartitionProblem {
            parts: fine_parts(),
            devices: (0..budget)
                .map(|i| DeviceProfile {
                    name: format!("edge{i}"),
                    mflops: 100.0,
                })
                .collect(),
            worker_budget: budget,
            device_memory: Some(600_000),
            uplink: LinkSpec::wifi(),
            interconnect: vec![LinkSpec::gigabit_lan()],
            codec: CodecCost::default(),
            batch: BatchCost::ZERO,
            relay_junctions: false,
        })
        .expect("joint plan");
        let reps: Vec<String> = joint
            .replica_counts()
            .iter()
            .map(|r| r.to_string())
            .collect();
        table.row(&[
            budget.to_string(),
            format!("{:?}", joint.cuts),
            reps.join(","),
            format!("{:.3}", joint.predicted_throughput()),
            format!(
                "{:.2}x",
                joint.predicted_throughput() / fixed.predicted_throughput
            ),
        ]);
    }
    print!("{}", table.render());

    // ---- part 2: measured, needs artifacts ----
    let frames: u64 = std::env::var("DEFER_FRAMES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let mflops: f64 = std::env::var("DEFER_EMULATED_MFLOPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20.0);
    let mut base = DeferConfig::default();
    base.profile = "tiny".into();
    base.model = "resnet50".into();
    base.nodes = 2;
    base.emulated_mflops = mflops;
    base.per_hop_links = vec![
        LinkSpec::wifi(),
        LinkSpec::gigabit_lan(),
        LinkSpec::gigabit_lan(),
    ];
    println!();
    println!(
        "## part 2: measured on tiny resnet50 ({frames} frames, {mflops} MFLOP/s devices)"
    );
    let engine = match Engine::cpu() {
        Ok(e) => e,
        Err(e) => {
            println!("skipping: {e}");
            return;
        }
    };
    let uniform_run = ChainRunner::with_engine(base.clone(), engine.clone())
        .and_then(|r| r.run_frames(frames));
    let r_uni = match uniform_run {
        Ok(r) => r,
        Err(e) => {
            println!("skipping (run `make artifacts`): {e}");
            return;
        }
    };
    let mut auto = base;
    auto.auto_place = true;
    auto.workers_budget = 4;
    let r_auto = ChainRunner::with_engine(auto, engine)
        .and_then(|r| r.run_frames(frames))
        .expect("auto-place run");
    println!(
        "uniform chain: {:.3} cycles/s ({} workers)",
        r_uni.throughput, r_uni.workers
    );
    println!(
        "auto-placed  : {:.3} cycles/s ({} workers, {:.2}x)",
        r_auto.throughput,
        r_auto.workers,
        r_auto.throughput / r_uni.throughput
    );
}
