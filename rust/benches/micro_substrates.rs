//! Substrate microbenchmarks: codec throughput (LZ4, ZFP, JSON, binary),
//! wire framing, and netem shaper fidelity. These feed the §Perf iteration
//! log in EXPERIMENTS.md — the paper-table benches sit on top of them.
//!
//! Env: DEFER_MICRO_N (payload elements, default 262144 = 1 MiB of f32).

use defer::bench::{bench, Stats, Table};
use defer::compress::{lz4, Compression};
use defer::metrics::ByteCounter;
use defer::netem::Link;
use defer::serial::{json, zfp, Codec, Serialization};
use defer::util::prng::Rng;
use defer::wire::{read_message, write_message, Message, MessageType};

fn row(table: &mut Table, name: &str, stats: Stats, bytes: usize) {
    table.row(&[
        name.into(),
        format!("{:.3} ms", stats.mean.as_secs_f64() * 1e3),
        format!("{:.1}", stats.mb_per_sec(bytes)),
        format!("{:.1}", stats.stddev.as_secs_f64() * 1e6),
    ]);
}

fn main() {
    let n: usize = std::env::var("DEFER_MICRO_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(262_144);
    let mut rng = Rng::new(77);
    let floats: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    let float_bytes: Vec<u8> = floats.iter().flat_map(|f| f.to_le_bytes()).collect();
    let text_bytes = rng.compressible_bytes(n * 4);
    let raw_mb = n * 4;

    println!("# substrate microbenches, payload = {} f32 ({} bytes)", n, raw_mb);
    let mut table = Table::new(&["op", "mean", "MB/s", "stddev (us)"]);

    // LZ4.
    let c_floats = lz4::compress(&float_bytes);
    let c_text = lz4::compress(&text_bytes);
    row(&mut table, "lz4 compress (f32 noise)", bench(2, 8, || lz4::compress(&float_bytes)), raw_mb);
    row(&mut table, "lz4 compress (motif text)", bench(2, 8, || lz4::compress(&text_bytes)), raw_mb);
    row(&mut table, "lz4 decompress (f32 noise)", bench(2, 8, || lz4::decompress(&c_floats, float_bytes.len()).unwrap()), raw_mb);
    row(&mut table, "lz4 decompress (motif text)", bench(2, 8, || lz4::decompress(&c_text, text_bytes.len()).unwrap()), raw_mb);
    println!(
        "lz4 ratios: f32 noise {:.3}, motif text {:.3}",
        c_floats.len() as f64 / float_bytes.len() as f64,
        c_text.len() as f64 / text_bytes.len() as f64
    );

    // ZFP.
    for rate in [16u8, 24, 32] {
        let enc = zfp::encode(&floats, zfp::ZfpRate(rate)).unwrap();
        row(&mut table, &format!("zfp encode (rate {rate})"), bench(1, 5, || zfp::encode(&floats, zfp::ZfpRate(rate)).unwrap()), raw_mb);
        row(&mut table, &format!("zfp decode (rate {rate})"), bench(1, 5, || zfp::decode(&enc).unwrap()), raw_mb);
    }

    // JSON float arrays.
    let jenc = json::encode_f32s(&floats);
    row(&mut table, "json encode f32s", bench(1, 5, || json::encode_f32s(&floats)), raw_mb);
    row(&mut table, "json decode f32s", bench(1, 5, || json::decode_f32s(&jenc).unwrap()), raw_mb);

    // Full codec stacks (what the chain hot path runs per frame).
    for codec in Codec::paper_sweep().into_iter().chain([Codec::new(Serialization::Binary, Compression::None)]) {
        let (wire, mid) = codec.encode_f32s(&floats, None);
        row(&mut table, &format!("codec encode {}", codec.label()), bench(1, 5, || codec.encode_f32s(&floats, None)), raw_mb);
        row(&mut table, &format!("codec decode {}", codec.label()), bench(1, 5, || codec.decode_f32s(&wire, mid, n, None).unwrap()), raw_mb);
    }

    // Wire framing (512 kB chunks) through an ideal link.
    let msg = Message {
        msg_type: MessageType::Data,
        frame: 1,
        serialized_len: float_bytes.len() as u64,
        count: n as u64,
        batch: 1,
        payload: float_bytes.clone(),
    };
    let link = Link::ideal();
    let counter = ByteCounter::new();
    let mut buf: Vec<u8> = Vec::with_capacity(float_bytes.len() + 64);
    row(&mut table, "wire write_message", bench(2, 8, || {
        buf.clear();
        write_message(&mut buf, &msg, &link, &counter).unwrap();
    }), raw_mb);
    let mut encoded = Vec::new();
    write_message(&mut encoded, &msg, &link, &counter).unwrap();
    row(&mut table, "wire read_message", bench(2, 8, || {
        read_message(&mut encoded.as_slice(), &counter).unwrap()
    }), raw_mb);

    print!("{}", table.render());
}
