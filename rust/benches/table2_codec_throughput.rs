//! Table II — Inference throughput for different serialization and
//! compression configurations (ResNet50, 4 compute nodes).
//!
//! Paper values: JSON+LZ4 0.477, JSON 0.493, ZFP+LZ4 0.673, ZFP 0.5
//! cycles/s. Claim under test: ZFP+LZ4 yields the highest throughput —
//! "communication demands become increasingly important, and using ZFP with
//! LZ4 minimizes the amount of data sent over the network ... despite the
//! additional computational cost". The crossover only appears when links
//! are bandwidth-bound, so this bench runs on an emulated 100 Mbit edge
//! link (env DEFER_LINK to override: ideal|gigabit|edge|wifi).
//!
//! Env: DEFER_FRAMES (default 10), DEFER_PROFILE (default edge),
//!      DEFER_LINK (default wifi — constrained wireless edge),
//!      DEFER_EMULATED_MFLOPS (default 400 — light device emulation so
//!      codec costs stay visible against compute, as in the paper's regime),
//!      DEFER_CODEC_KERNEL (scalar|batched — ZFP kernel A/B, default batched).

use defer::bench::Table;
use defer::config::DeferConfig;
use defer::coordinator::chain::ChainRunner;
use defer::netem::LinkSpec;
use defer::runtime::Engine;
use defer::serial::{Codec, CodecKernel};

fn main() {
    let frames: u64 = std::env::var("DEFER_FRAMES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);
    let profile = std::env::var("DEFER_PROFILE").unwrap_or_else(|_| "edge".into());
    let link = LinkSpec::parse(&std::env::var("DEFER_LINK").unwrap_or_else(|_| "wifi".into()))
        .expect("link spec");
    let kernel = std::env::var("DEFER_CODEC_KERNEL")
        .map(|s| CodecKernel::parse(&s).expect("DEFER_CODEC_KERNEL"))
        .unwrap_or_default();
    let engine = Engine::cpu().expect("PJRT cpu client");

    println!(
        "# Table II: inference throughput per codec (ResNet50, 4 nodes, profile={profile}, link={:?})",
        std::env::var("DEFER_LINK").unwrap_or_else(|_| "wifi".into())
    );
    let mut table = Table::new(&["Serialization", "Compression", "Throughput (cycles/s)", "paper"]);
    let paper = [0.477, 0.493, 0.673, 0.5];
    let mut measured = Vec::new();
    for (codec, paper_val) in Codec::paper_sweep().into_iter().zip(paper) {
        let mut cfg = DeferConfig::default();
        cfg.profile = profile.clone();
        cfg.model = "resnet50".into();
        cfg.nodes = 4;
        cfg.link = link;
        cfg.emulated_mflops = std::env::var("DEFER_EMULATED_MFLOPS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(400.0);
        cfg.codecs.data = codec;
        cfg.codecs.weights = codec;
        cfg.codec_kernel = kernel;
        let report = ChainRunner::with_engine(cfg, engine.clone())
            .expect("artifacts present (make artifacts)")
            .run_frames(frames)
            .expect("chain run");
        table.row(&[
            codec.serialization.name().into(),
            codec.compression.name().into(),
            format!("{:.3}", report.throughput),
            format!("{paper_val}"),
        ]);
        measured.push((codec.label(), report.throughput));
    }
    print!("{}", table.render());
    let best = measured
        .iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    println!(
        "claim: ZFP+LZ4 has the highest throughput -> best here: {} ({})",
        best.0,
        if best.0 == "ZFP+LZ4" { "HOLDS" } else { "differs (see EXPERIMENTS.md discussion)" }
    );
}
