//! Table I — Energy consumption, overhead, and network payload for
//! ResNet50 with 4 compute nodes, per traffic class and codec:
//!
//!   Architecture x JSON x {LZ4, Uncompressed}
//!   Weights      x {JSON, ZFP} x {LZ4, Uncompressed}
//!   Data         x {JSON, ZFP} x {LZ4, Uncompressed}
//!
//! Methodology mirrors the paper exactly, per socket class:
//!   overhead = time spent formatting (serialize+compress and the inverse),
//!   payload  = bytes that cross the socket (all 4 nodes / all hops),
//!   energy   = overhead x TDP + payload x 10 pJ/bit.
//! Architecture and weights are configuration-step traffic measured on the
//! real artifact bytes; data is inference-step traffic measured on the
//! real boundary activations produced by a live 4-node chain run.
//!
//! Claims under test (paper §V):
//!   (a) architecture: JSON uncompressed has lower overhead than JSON+LZ4
//!       and both payloads are tiny;
//!   (b) weights: ZFP+LZ4 minimizes payload;
//!   (c) data: ZFP+LZ4 minimizes payload.
//!
//! Env: DEFER_FRAMES (default 8), DEFER_PROFILE (default edge).

use std::time::Instant;

use defer::bench::Table;
use defer::compress::Compression;
use defer::config::DeferConfig;
use defer::coordinator::chain::ChainRunner;
use defer::coordinator::compute_node::{encode_architecture, encode_stage_architecture};
use defer::energy::EnergyModel;
use defer::model::PartitionPlan;
use defer::runtime::{Engine, Executable};
use defer::serial::Codec;
use defer::wire::HEADER_SIZE;

struct Row {
    class: &'static str,
    ser: String,
    comp: String,
    energy_j: f64,
    overhead_s: f64,
    payload_mb: f64,
}

fn main() {
    let frames: u64 = std::env::var("DEFER_FRAMES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let profile = std::env::var("DEFER_PROFILE").unwrap_or_else(|_| "edge".into());
    let engine = Engine::cpu().expect("PJRT cpu client");
    let energy = EnergyModel::default();
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let plan = PartitionPlan::load(&artifacts, &profile, "resnet50", 4)
        .expect("run `make artifacts` first");
    println!("# Table I: ResNet50, 4 compute nodes, profile={profile}, frames={frames}");

    let mut rows: Vec<Row> = Vec::new();

    // ---- Architecture: meta JSON + HLO text per node, compression swept.
    let arch_payloads: Vec<Vec<u8>> = plan
        .parts
        .iter()
        .map(|p| encode_architecture(p, "next", &p.read_hlo().unwrap()))
        .collect();
    for compression in [Compression::Lz4, Compression::None] {
        let t0 = Instant::now();
        let mut bytes = 0u64;
        for raw in &arch_payloads {
            let wire = compression.compress(raw);
            bytes += wire.len() as u64 + HEADER_SIZE as u64;
            let back = compression.decompress(&wire, raw.len()).unwrap();
            assert_eq!(back.len(), raw.len());
        }
        let overhead = t0.elapsed().as_secs_f64();
        rows.push(Row {
            class: "Architecture",
            ser: "JSON".into(),
            comp: compression.name().into(),
            energy_j: overhead * energy.tdp_watts + energy.network_energy(bytes),
            overhead_s: overhead,
            payload_mb: bytes as f64 / 1e6,
        });
    }

    // ---- Architecture, fused: the same four partitions shipped as one
    // multi-partition stage payload (what a fused `--auto-partition`
    // stage sends) — one exchange, one compression context.
    let hlos: Vec<String> = plan.parts.iter().map(|p| p.read_hlo().unwrap()).collect();
    let hlo_refs: Vec<&str> = hlos.iter().map(String::as_str).collect();
    let fused_raw = encode_stage_architecture(&plan.parts, &hlo_refs, "next");
    for compression in [Compression::Lz4, Compression::None] {
        let t0 = Instant::now();
        let wire = compression.compress(&fused_raw);
        let bytes = wire.len() as u64 + HEADER_SIZE as u64;
        let back = compression.decompress(&wire, fused_raw.len()).unwrap();
        assert_eq!(back.len(), fused_raw.len());
        let overhead = t0.elapsed().as_secs_f64();
        rows.push(Row {
            class: "Arch (fused x4)",
            ser: "JSON".into(),
            comp: compression.name().into(),
            energy_j: overhead * energy.tdp_watts + energy.network_energy(bytes),
            overhead_s: overhead,
            payload_mb: bytes as f64 / 1e6,
        });
    }

    // ---- Weights: the real per-partition weight arrays, 2x2 codec sweep.
    let weight_arrays: Vec<Vec<f32>> = plan
        .parts
        .iter()
        .map(|p| p.read_weights().unwrap().into_iter().flatten().collect())
        .collect();
    for codec in Codec::paper_sweep() {
        let t0 = Instant::now();
        let mut bytes = 0u64;
        for flat in &weight_arrays {
            let (wire, mid) = codec.encode_f32s(flat, None);
            bytes += wire.len() as u64 + HEADER_SIZE as u64;
            let back = codec.decode_f32s(&wire, mid, flat.len(), None).unwrap();
            assert_eq!(back.len(), flat.len());
        }
        let overhead = t0.elapsed().as_secs_f64();
        rows.push(Row {
            class: "Weights",
            ser: codec.serialization.name().into(),
            comp: codec.compression.name().into(),
            energy_j: overhead * energy.tdp_watts + energy.network_energy(bytes),
            overhead_s: overhead,
            payload_mb: bytes as f64 / 1e6,
        });
    }

    // ---- Data: real boundary activations from running the partitions on
    // the reference input, then `frames` frames worth of chain traffic.
    let rv = defer::model::ReferenceVectors::load(&artifacts, &profile, "resnet50").unwrap();
    let mut boundary_tensors = Vec::new(); // activations crossing each hop
    let mut act = rv.input.clone();
    boundary_tensors.push(act.clone()); // dispatcher -> node0
    for spec in &plan.parts {
        let exe = Executable::load(&engine, spec).unwrap();
        act = exe.run(&act).unwrap();
        boundary_tensors.push(act.clone()); // node i -> next hop
    }
    for codec in Codec::paper_sweep() {
        let t0 = Instant::now();
        let mut bytes = 0u64;
        for t in &boundary_tensors {
            let (wire, mid) = codec.encode_f32s(t.data(), None);
            bytes += wire.len() as u64 + HEADER_SIZE as u64;
            let back = codec.decode_f32s(&wire, mid, t.len(), None).unwrap();
            assert_eq!(back.len(), t.len());
        }
        let overhead = t0.elapsed().as_secs_f64() * frames as f64;
        rows.push(Row {
            class: "Data",
            ser: codec.serialization.name().into(),
            comp: codec.compression.name().into(),
            energy_j: overhead * energy.tdp_watts
                + energy.network_energy(bytes * frames),
            overhead_s: overhead,
            payload_mb: (bytes * frames) as f64 / 1e6,
        });
    }

    let mut table = Table::new(&[
        "Type",
        "Serialization",
        "Compression",
        "Energy (J)",
        "Overhead (s)",
        "Network Payload (MB)",
    ]);
    for row in &rows {
        table.row(&[
            row.class.into(),
            row.ser.clone(),
            row.comp.clone(),
            format!("{:.5}", row.energy_j),
            format!("{:.5}", row.overhead_s),
            format!("{:.4}", row.payload_mb),
        ]);
    }
    print!("{}", table.render());

    // ---- Shape checks vs the paper.
    let find = |class: &str, s: &str, c: &str| {
        rows.iter()
            .find(|r| r.class == class && r.ser == s && r.comp == c)
            .unwrap()
    };
    let a_lz = find("Architecture", "JSON", "LZ4");
    let a_un = find("Architecture", "JSON", "Uncompressed");
    println!(
        "claim (a) architecture JSON uncompressed has lower overhead: {}",
        if a_un.overhead_s < a_lz.overhead_s { "HOLDS" } else { "FAILS" }
    );
    for (class, claim) in [("Weights", "(b)"), ("Data", "(c)")] {
        let best = rows
            .iter()
            .filter(|r| r.class == class)
            .min_by(|a, b| a.payload_mb.partial_cmp(&b.payload_mb).unwrap())
            .unwrap();
        println!(
            "claim {claim} {class} ZFP+LZ4 minimizes payload: {}",
            if best.ser == "ZFP" && best.comp == "LZ4" { "HOLDS" } else { "FAILS" }
        );
    }

    // Cross-check payload accounting against a live chain run (data class).
    let mut cfg = DeferConfig::default();
    cfg.artifacts_dir = artifacts;
    cfg.profile = profile;
    cfg.model = "resnet50".into();
    cfg.nodes = 4;
    let live = ChainRunner::with_engine(cfg, engine)
        .unwrap()
        .run_frames(frames)
        .unwrap();
    let table_data = find("Data", "ZFP", "LZ4").payload_mb;
    println!(
        "live-chain data payload (ZFP+LZ4): {:.4} MB vs table row {:.4} MB (should be ~equal, minus the shutdown frames)",
        live.data_bytes as f64 / 1e6,
        table_data
    );
}
