//! Fig. 2 — Inference throughput for different models and numbers of
//! compute nodes (VGG16, VGG19, ResNet50 x {single-device, 4, 6, 8}).
//!
//! Regenerates the paper's figure as a table. Absolute cycles/s differ from
//! the paper's testbed; the claims under test are the *shapes*:
//!   (1) ResNet50 throughput grows with node count; DEFER@8 > single device
//!       (paper: +53%).
//!   (2) "there is a limit to an increase in throughput from utilizing
//!       additional compute nodes" for the VGGs (paper §V): VGG16 stops
//!       gaining by 8 nodes (plateau/decline, its huge early activations
//!       make extra hops expensive) while ResNet50 is still gaining.
//!
//! Env: DEFER_FRAMES (default 16), DEFER_PROFILE (default edge),
//!      DEFER_MODELS (default vgg16,vgg19,resnet50),
//!      DEFER_EMULATED_MFLOPS (default 50 — deterministic device-speed
//!      emulation matching the paper's TF-on-edge-CPU
//!      compute:communication ratio; see DESIGN.md §Substitutions).

use defer::bench::Table;
use defer::config::DeferConfig;
use defer::coordinator::baseline::SingleDevice;
use defer::coordinator::chain::ChainRunner;
use defer::runtime::Engine;

fn main() {
    let frames: u64 = std::env::var("DEFER_FRAMES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let profile = std::env::var("DEFER_PROFILE").unwrap_or_else(|_| "edge".into());
    let models = std::env::var("DEFER_MODELS")
        .unwrap_or_else(|_| "vgg16,vgg19,resnet50".into());
    let mflops: f64 = std::env::var("DEFER_EMULATED_MFLOPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(50.0);
    let engine = Engine::cpu().expect("PJRT cpu client");

    println!(
        "# Fig. 2: inference throughput (cycles/s), profile={profile}, frames={frames}, emulated device = {mflops} MFLOPS"
    );
    let mut table = Table::new(&["model", "single", "4 nodes", "6 nodes", "8 nodes"]);
    let mut resnet_ok = None;
    let mut vgg_decreasing = None;

    for model in models.split(',') {
        let mut row = vec![model.to_string()];
        let mut tputs = Vec::new();
        for nodes in [1usize, 4, 6, 8] {
            let mut cfg = DeferConfig::default();
            cfg.profile = profile.clone();
            cfg.model = model.to_string();
            cfg.nodes = nodes;
            cfg.emulated_mflops = mflops;
            let tput = if nodes == 1 {
                SingleDevice::with_engine(cfg, engine.clone())
                    .and_then(|r| r.run_frames(frames))
                    .map(|r| r.throughput)
            } else {
                ChainRunner::with_engine(cfg, engine.clone())
                    .and_then(|r| r.run_frames(frames))
                    .map(|r| r.throughput)
            };
            match tput {
                Ok(t) => {
                    row.push(format!("{t:.3}"));
                    tputs.push(t);
                }
                Err(e) => {
                    row.push(format!("n/a ({e})"));
                    tputs.push(f64::NAN);
                }
            }
        }
        if model == "resnet50" && tputs.len() == 4 && tputs[3].is_finite() {
            resnet_ok = Some(tputs[3] > tputs[0]);
            println!(
                "resnet50: DEFER@8 / single = {:.2}x (paper: 1.53x)",
                tputs[3] / tputs[0]
            );
        }
        if model == "vgg16" && tputs.len() == 4 && tputs.iter().all(|t| t.is_finite()) {
            // Relative gain from 6 -> 8 nodes must have dried up (<5%).
            vgg_decreasing = Some(tputs[3] <= tputs[2] * 1.05);
        }
        table.row(&row);
    }
    print!("{}", table.render());
    if let Some(ok) = resnet_ok {
        println!("claim (1) ResNet50 DEFER@8 beats single device: {}", if ok { "HOLDS" } else { "FAILS" });
    }
    if let Some(ok) = vgg_decreasing {
        println!(
            "claim (2) VGG16 gains dry up by 8 nodes (ResNet50 still gaining): {}",
            if ok { "HOLDS" } else { "FAILS" }
        );
    }
}
