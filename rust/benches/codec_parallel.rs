//! Chunk-parallel codec + pipelined-chain throughput bench.
//!
//! Part 1 (artifact-free): serial vs chunk-parallel encode/decode GB/s
//! for every `Codec::paper_sweep()` arm × ZFP kernel (scalar reference
//! vs batched lane-parallel) on a MiB-scale activation payload, plus
//! the byte-identity checks the container and the kernel A/B guarantee:
//! parallel == serial AND batched == scalar, to the byte.
//!
//! Part 2 (needs `make artifacts`): chain throughput on a codec-bound
//! configuration (ZFP+LZ4 data path, ideal links) with the inline loop
//! vs the software-pipelined codec path (and the chunk-parallel codec
//! on top). Skipped gracefully when artifacts are absent.
//!
//! Emits `BENCH_codec.json` (machine-readable) next to the working
//! directory so the perf trajectory is tracked across PRs.
//!
//! Env: DEFER_CODEC_THREADS (default 4), DEFER_PAYLOAD_MB (default 4),
//!      DEFER_FRAMES (default 12), DEFER_PROFILE (default edge).

use std::io::Write as _;
use std::sync::Arc;

use defer::bench::{bench, Table};
use defer::config::DeferConfig;
use defer::coordinator::chain::ChainRunner;
use defer::netem::LinkSpec;
use defer::serial::{chunked, Codec, CodecKernel, CodecRuntime};
use defer::threadpool::CodecPool;
use defer::util::prng::Rng;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let threads = env_usize("DEFER_CODEC_THREADS", 4).max(1);
    let payload_mb = env_usize("DEFER_PAYLOAD_MB", 4).max(1);
    let n = payload_mb * 1024 * 1024 / 4; // f32 count
    let raw_bytes = n * 4;
    let data = Rng::new(42).normal_vec(n);
    let pool = Arc::new(CodecPool::new(threads));
    let chunk = chunked::DEFAULT_CHUNK_ELEMS;

    println!(
        "# Chunk-parallel codec: {payload_mb} MiB payload, chunk {chunk} elems, {threads} workers"
    );
    let mut table = Table::new(&[
        "codec",
        "kernel",
        "serial enc GB/s",
        "parallel enc GB/s",
        "serial dec GB/s",
        "parallel dec GB/s",
        "enc speedup",
        "bytes identical",
    ]);
    let mut rows_json = Vec::new();
    let gbs = |secs: f64| raw_bytes as f64 / 1e9 / secs;
    for codec in Codec::paper_sweep() {
        // Scalar-kernel serial bytes are the reference: every kernel ×
        // runtime combination must reproduce them exactly.
        let ref_rt = CodecRuntime::chunked(chunk, None)
            .unwrap()
            .with_kernel(CodecKernel::Scalar);
        let (wire_ref, mid_ref) = codec.encode_frame(&data, &ref_rt, None);
        for kernel in [CodecKernel::Scalar, CodecKernel::Batched] {
            let serial_rt = CodecRuntime::chunked(chunk, None).unwrap().with_kernel(kernel);
            let par_rt = CodecRuntime::chunked(chunk, Some(Arc::clone(&pool)))
                .unwrap()
                .with_kernel(kernel);
            let (wire_s, mid_s) = codec.encode_frame(&data, &serial_rt, None);
            let (wire_p, mid_p) = codec.encode_frame(&data, &par_rt, None);
            let identical =
                wire_s == wire_p && mid_s == mid_p && wire_s == wire_ref && mid_s == mid_ref;

            let enc_serial = bench(1, 5, || codec.encode_frame(&data, &serial_rt, None));
            let enc_par = bench(1, 5, || codec.encode_frame(&data, &par_rt, None));
            let dec_serial = bench(1, 5, || {
                codec
                    .decode_frame(&wire_s, mid_s, n, &serial_rt, None)
                    .unwrap()
            });
            let dec_par = bench(1, 5, || {
                codec.decode_frame(&wire_p, mid_p, n, &par_rt, None).unwrap()
            });

            let se = gbs(enc_serial.mean.as_secs_f64());
            let pe = gbs(enc_par.mean.as_secs_f64());
            let sd = gbs(dec_serial.mean.as_secs_f64());
            let pd = gbs(dec_par.mean.as_secs_f64());
            table.row(&[
                codec.label(),
                kernel.name().into(),
                format!("{se:.3}"),
                format!("{pe:.3}"),
                format!("{sd:.3}"),
                format!("{pd:.3}"),
                format!("{:.2}x", pe / se),
                identical.to_string(),
            ]);
            rows_json.push(format!(
                r#"    {{"codec": "{}", "kernel": "{}", "serial_enc_gbps": {se:.4}, "parallel_enc_gbps": {pe:.4}, "serial_dec_gbps": {sd:.4}, "parallel_dec_gbps": {pd:.4}, "bytes_identical": {identical}}}"#,
                codec.label(),
                kernel.name()
            ));
        }
    }
    print!("{}", table.render());

    // ---- Part 2: pipelined vs inline chain (artifact-gated) ----
    let mut chain_json = String::from("null");
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.json").exists() {
        let frames = env_usize("DEFER_FRAMES", 12) as u64;
        let profile = std::env::var("DEFER_PROFILE").unwrap_or_else(|_| "edge".into());
        let engine = defer::runtime::Engine::cpu().expect("PJRT cpu client");
        let run = |pipelined: bool, codec_threads: usize| -> f64 {
            let mut cfg = DeferConfig::default();
            cfg.artifacts_dir = artifacts.clone();
            cfg.profile = profile.clone();
            cfg.model = "resnet50".into();
            cfg.nodes = 4;
            cfg.link = LinkSpec::ideal(); // codec-bound: fast links
            cfg.codec_pipeline = pipelined;
            cfg.codec_threads = codec_threads;
            ChainRunner::with_engine(cfg, engine.clone())
                .expect("artifacts present")
                .run_frames(frames)
                .expect("chain run")
                .throughput
        };
        println!("\n# Codec-bound chain (ZFP+LZ4 data path, ideal links, {frames} frames)");
        let inline = run(false, 0);
        let pipelined = run(true, 0);
        let pipelined_par = run(true, threads);
        let mut t2 = Table::new(&["configuration", "throughput (cycles/s)", "vs inline"]);
        t2.row(&["inline codec".into(), format!("{inline:.3}"), "1.00x".into()]);
        t2.row(&[
            "pipelined codec".into(),
            format!("{pipelined:.3}"),
            format!("{:.2}x", pipelined / inline),
        ]);
        t2.row(&[
            format!("pipelined + {threads}-way chunk codec"),
            format!("{pipelined_par:.3}"),
            format!("{:.2}x", pipelined_par / inline),
        ]);
        print!("{}", t2.render());
        chain_json = format!(
            r#"{{"frames": {frames}, "inline_cps": {inline:.4}, "pipelined_cps": {pipelined:.4}, "pipelined_parallel_cps": {pipelined_par:.4}}}"#
        );
    } else {
        println!("\n(chain rows skipped: run `make artifacts` for part 2)");
    }

    let json = format!(
        "{{\n  \"payload_bytes\": {raw_bytes},\n  \"chunk_elems\": {chunk},\n  \"codec_threads\": {threads},\n  \"codecs\": [\n{}\n  ],\n  \"chain\": {chain_json}\n}}\n",
        rows_json.join(",\n")
    );
    match std::fs::File::create("BENCH_codec.json").and_then(|mut f| f.write_all(json.as_bytes()))
    {
        Ok(()) => println!("\nwrote BENCH_codec.json"),
        Err(e) => println!("\ncould not write BENCH_codec.json: {e}"),
    }
}
