//! Fuzz `wire::Header::parse` + `Header::into_message`: the first code
//! that touches bytes from a peer. Parse must reject hostile headers
//! before any allocation; into_message must verify the CRC over header
//! and payload without panicking on any split of the input.
#![no_main]

use defer::wire::{Header, HEADER_SIZE};
use libfuzzer_sys::fuzz_target;

/// Headers whose (attacker-controlled) payload length survives parsing
/// can legitimately demand up to 8 GiB; cap what the harness actually
/// materializes so the fuzzer measures crashes, not RSS.
const MAX_FUZZ_PAYLOAD: u64 = 1 << 20;

fuzz_target!(|data: &[u8]| {
    if data.len() < HEADER_SIZE {
        return;
    }
    let raw: [u8; HEADER_SIZE] = data[..HEADER_SIZE].try_into().unwrap();
    if let Ok(h) = Header::parse(&raw) {
        if h.wire_len <= MAX_FUZZ_PAYLOAD {
            let _ = h.into_message(data[HEADER_SIZE..].to_vec());
        }
    }
});
