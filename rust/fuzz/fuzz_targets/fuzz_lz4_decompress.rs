//! Fuzz the from-scratch LZ4 block decoder. The first two input bytes
//! choose the `expected` output size (bounded) so the fuzzer explores
//! both the too-short and too-long rejection paths as well as exact
//! matches.
#![no_main]

use defer::compress::lz4;
use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    if data.len() < 2 {
        return;
    }
    let expected = u16::from_le_bytes([data[0], data[1]]) as usize;
    let src = &data[2..];
    if let Ok(out) = lz4::decompress(src, expected) {
        // Accepted streams must round-trip: recompressing the output
        // and decompressing again yields the same bytes.
        assert_eq!(out.len(), expected);
        let re = lz4::compress(&out);
        assert_eq!(lz4::decompress(&re, expected).unwrap(), out);
    }
});
