//! Fuzz the reactor's `FrameAssembler` state machine: the fuzz input is
//! treated as a hostile byte stream delivered in small reads with
//! interleaved WouldBlock events, exactly like a slow or malicious peer
//! on a nonblocking socket.
#![no_main]

use std::cell::Cell;

use defer::wire::{FrameAssembler, Header, HEADER_SIZE};
use libfuzzer_sys::fuzz_target;

const MAX_FUZZ_PAYLOAD: u64 = 1 << 20;

fuzz_target!(|data: &[u8]| {
    // Skip inputs whose valid header demands a huge payload allocation:
    // that path is exercised (and capped) in fuzz_wire_header.
    if data.len() >= HEADER_SIZE {
        let raw: [u8; HEADER_SIZE] = data[..HEADER_SIZE].try_into().unwrap();
        if let Ok(h) = Header::parse(&raw) {
            if h.wire_len > MAX_FUZZ_PAYLOAD {
                return;
            }
        }
    }
    let mut asm = FrameAssembler::new();
    let cursor = Cell::new(0usize);
    let block_next = Cell::new(false);
    let mut read = |buf: &mut [u8]| -> std::io::Result<usize> {
        if block_next.replace(false) {
            return Err(std::io::ErrorKind::WouldBlock.into());
        }
        let at = cursor.get();
        if at >= data.len() {
            return Ok(0);
        }
        let n = buf.len().min(data.len() - at).min(7);
        buf[..n].copy_from_slice(&data[at..at + n]);
        cursor.set(at + n);
        block_next.set(true);
        Ok(n)
    };
    for _ in 0..data.len() * 2 + 8 {
        match asm.poll(&mut read, None) {
            Ok(_) => {}
            Err(_) => break,
        }
        if cursor.get() >= data.len() && asm.at_boundary() {
            break;
        }
    }
});
