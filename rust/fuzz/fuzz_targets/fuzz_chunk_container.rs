//! Fuzz the DFCK chunk-container decoder end to end: container header,
//! per-chunk table, CRCs, and the inner codec decode. The first input
//! byte picks the codec so coverage spans every paper configuration.
#![no_main]

use defer::serial::chunked::{self, CodecRuntime};
use defer::serial::Codec;
use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let Some((&sel, wire)) = data.split_first() else {
        return;
    };
    let codecs = Codec::paper_sweep();
    let codec = codecs[sel as usize % codecs.len()];
    let rt = CodecRuntime::chunked(1024, None).expect("static runtime config");
    // Modest truthful-looking cross-check values plus lying ones; the
    // decoder must reject or decode, never panic or over-allocate.
    let _ = chunked::decode_frame(&codec, wire, wire.len(), 4096, &rt, None);
    let _ = chunked::decode_frame(&codec, wire, 1, 7, &rt, None);
});
