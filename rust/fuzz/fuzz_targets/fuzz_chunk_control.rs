//! Fuzz the recovery control-frame path introduced with chunk-level
//! retry: `wire::parse_chunk_control` on arbitrary NACK/retry messages,
//! then `chunked::chunk_payload_span` with the parsed (hostile) chunk
//! index and bytes — the exact surface a misbehaving peer reaches by
//! sending traffic on the retry control mesh.
#![no_main]

use defer::serial::chunked::chunk_payload_span;
use defer::wire::{parse_chunk_control, Header, Message, MessageType, HEADER_SIZE};
use libfuzzer_sys::fuzz_target;

/// Same RSS guard as the other wire-facing targets: lengths that parse
/// but would demand gigabytes are not materialized.
const MAX_FUZZ_PAYLOAD: u64 = 1 << 20;

fuzz_target!(|data: &[u8]| {
    // Path 1: full wire decode (CRC-gated), as a TCP control peer.
    if data.len() >= HEADER_SIZE {
        let raw: [u8; HEADER_SIZE] = data[..HEADER_SIZE].try_into().unwrap();
        if let Ok(h) = Header::parse(&raw) {
            if h.wire_len <= MAX_FUZZ_PAYLOAD {
                if let Ok(msg) = h.into_message(data[HEADER_SIZE..].to_vec()) {
                    if let Ok((idx, span)) = parse_chunk_control(&msg) {
                        let _ = chunk_payload_span(span, idx as usize);
                    }
                }
            }
        }
    }
    // Path 2: the in-process control mesh hands `Message` structs over
    // without re-framing (no CRC gate); drive the parser and the span
    // cutter directly so every mutation reaches them.
    if data.len() >= 13 {
        let msg_type = if data[0] & 1 == 0 {
            MessageType::ChunkNack
        } else {
            MessageType::ChunkRetry
        };
        let msg = Message {
            msg_type,
            frame: u64::from_le_bytes(data[1..9].try_into().unwrap()),
            serialized_len: 0,
            count: 0,
            batch: 1,
            payload: data[9..].to_vec(),
        };
        if let Ok((idx, span)) = parse_chunk_control(&msg) {
            let _ = chunk_payload_span(span, idx as usize);
        }
    }
});
