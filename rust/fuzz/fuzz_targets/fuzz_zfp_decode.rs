//! Fuzz `zfp::decode` with both kernels. Beyond crash-freedom, this
//! target asserts the PR 8 equivalence invariant on every input the
//! decoder accepts: the scalar and batched kernels must produce
//! bit-identical values even for streams no encoder ever emitted.
#![no_main]

use defer::serial::zfp;
use defer::serial::CodecKernel;
use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let scalar = zfp::decode_kernel(data, CodecKernel::Scalar);
    let batched = zfp::decode_kernel(data, CodecKernel::Batched);
    match (scalar, batched) {
        (Ok(a), Ok(b)) => {
            let a: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, b, "kernels diverged on a decodable stream");
        }
        (Err(_), Err(_)) => {}
        (a, b) => panic!(
            "kernels disagree on decodability: scalar={:?} batched={:?}",
            a.is_ok(),
            b.is_ok()
        ),
    }
});
