//! Dense f32 tensor: the unit of data moving through the DEFER chain.
//!
//! Activations and weights are always f32 row-major (matching the
//! `<f4`-LE `weights.bin` artifacts and the NHWC layout of the L2 models).

use crate::error::{DeferError, Result};

/// A shape-checked, row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Build from shape + data; the element count must match.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(DeferError::Tensor(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Tensor { shape, data })
    }

    /// All-zeros tensor.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    /// Deterministic synthetic tensor (for workload generation).
    pub fn random(shape: Vec<usize>, seed: u64) -> Self {
        let n: usize = shape.iter().product();
        let mut rng = crate::util::prng::Rng::new(seed);
        Tensor {
            shape,
            data: rng.normal_vec(n),
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Size of the raw payload in bytes.
    pub fn byte_len(&self) -> usize {
        self.data.len() * 4
    }

    pub fn into_parts(self) -> (Vec<usize>, Vec<f32>) {
        (self.shape, self.data)
    }

    /// Serialize data to little-endian bytes (shape travels in metadata).
    pub fn to_le_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.data.len() * 4);
        for v in &self.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Parse from little-endian bytes with a known shape.
    pub fn from_le_bytes(shape: Vec<usize>, bytes: &[u8]) -> Result<Self> {
        if bytes.len() % 4 != 0 {
            return Err(DeferError::Tensor(format!(
                "byte length {} not a multiple of 4",
                bytes.len()
            )));
        }
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Tensor::new(shape, data)
    }

    /// Max absolute difference against another tensor (same shape required).
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        if self.shape != other.shape {
            return Err(DeferError::Tensor(format!(
                "shape mismatch {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max))
    }

    /// Relative L2 error vs a reference (0 when identical).
    pub fn rel_l2_error(&self, reference: &Tensor) -> Result<f32> {
        if self.shape != reference.shape {
            return Err(DeferError::Tensor("shape mismatch".into()));
        }
        let num: f32 = self
            .data
            .iter()
            .zip(&reference.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        let den: f32 = reference.data.iter().map(|b| b * b).sum();
        Ok((num / den.max(1e-30)).sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_element_count() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn le_bytes_round_trip() {
        let t = Tensor::random(vec![3, 4, 5], 42);
        let bytes = t.to_le_bytes();
        assert_eq!(bytes.len(), t.byte_len());
        let back = Tensor::from_le_bytes(vec![3, 4, 5], &bytes).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn from_le_bytes_rejects_ragged() {
        assert!(Tensor::from_le_bytes(vec![1], &[0u8; 3]).is_err());
    }

    #[test]
    fn error_metrics() {
        let a = Tensor::new(vec![2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(vec![2], vec![1.5, 2.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b).unwrap(), 0.5);
        assert_eq!(a.max_abs_diff(&a).unwrap(), 0.0);
        assert!(a.rel_l2_error(&a).unwrap() < 1e-12);
        let c = Tensor::new(vec![3], vec![0.0; 3]).unwrap();
        assert!(a.max_abs_diff(&c).is_err());
    }

    #[test]
    fn random_is_deterministic() {
        assert_eq!(Tensor::random(vec![16], 9), Tensor::random(vec![16], 9));
    }
}
