//! Run configuration: everything a DEFER deployment needs, loadable from a
//! JSON config file with CLI overrides — the launcher's config system.

use std::path::{Path, PathBuf};

use crate::cli::Args;
use crate::compress::Compression;
use crate::energy::EnergyModel;
use crate::error::{DeferError, Result};
use crate::netem::LinkSpec;
use crate::serial::{json::Json, Codec, Serialization};

/// Per-socket codec configuration (architecture / weights / data), exactly
/// the three rows of the paper's Table I sweep.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CodecConfig {
    pub architecture: Codec,
    pub weights: Codec,
    pub data: Codec,
}

impl Default for CodecConfig {
    /// The paper's recommended mix: JSON/uncompressed architecture,
    /// ZFP+LZ4 weights, ZFP+LZ4 data.
    fn default() -> Self {
        CodecConfig {
            architecture: Codec::new(Serialization::Json, Compression::None),
            weights: Codec::default(),
            data: Codec::default(),
        }
    }
}

/// Complete run configuration.
#[derive(Clone, Debug)]
pub struct DeferConfig {
    /// Artifact root (from `make artifacts`).
    pub artifacts_dir: PathBuf,
    /// Scale profile: tiny | edge | full.
    pub profile: String,
    /// Model name: resnet50 | vgg16 | vgg19.
    pub model: String,
    /// Number of compute nodes (1 = single-device baseline).
    pub nodes: usize,
    pub codecs: CodecConfig,
    pub link: LinkSpec,
    pub energy: EnergyModel,
    /// Bounded pipe depth between chain stages (backpressure window).
    pub pipe_depth: usize,
    /// Device-speed emulation: model compute is slowed by this factor
    /// (sleep after each execute), emulating the paper's edge-class devices
    /// running the full-scale model. 1.0 = native speed. The energy model
    /// accounts the slowed busy time. Codec/serialization stays native —
    /// its absolute cost already matches the paper's CPU class.
    ///
    /// Prefer [`DeferConfig::emulated_mflops`] for benchmarking: the
    /// multiplicative form amplifies host CPU noise by the factor.
    pub compute_slowdown: f64,
    /// Deterministic device-speed emulation: each stage's compute time is
    /// floored to `stage_flops / (emulated_mflops * 1e6)` seconds,
    /// emulating an edge device with that effective FLOP rate. 0 = off.
    /// Unlike `compute_slowdown`, host CPU contention cannot perturb the
    /// emulated stage time (the sleep target is a constant of the plan).
    pub emulated_mflops: f64,
    /// Run the chain over real TCP loopback sockets instead of in-process.
    pub tcp: bool,
    /// Base TCP port for chain sockets.
    pub base_port: u16,
}

impl Default for DeferConfig {
    fn default() -> Self {
        DeferConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            profile: "edge".into(),
            model: "resnet50".into(),
            nodes: 4,
            codecs: CodecConfig::default(),
            link: LinkSpec::ideal(),
            energy: EnergyModel::default(),
            pipe_depth: 4,
            compute_slowdown: 1.0,
            emulated_mflops: 0.0,
            tcp: false,
            base_port: 47_000,
        }
    }
}

fn parse_codec(obj: &Json, key: &str, default: Codec) -> Result<Codec> {
    match obj.as_obj()?.get(key) {
        None => Ok(default),
        Some(c) => {
            let ser = match c.as_obj()?.get("serialization") {
                Some(s) => Serialization::parse(s.as_str()?)?,
                None => default.serialization,
            };
            let comp = match c.as_obj()?.get("compression") {
                Some(s) => Compression::parse(s.as_str()?)?,
                None => default.compression,
            };
            Ok(Codec::new(ser, comp))
        }
    }
}

impl DeferConfig {
    /// Load from a JSON file; missing fields keep defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json_str(&text)
    }

    pub fn from_json_str(text: &str) -> Result<Self> {
        let v = crate::serial::json::parse(text)?;
        let mut cfg = DeferConfig::default();
        let obj = v.as_obj()?;
        if let Some(x) = obj.get("artifacts_dir") {
            cfg.artifacts_dir = PathBuf::from(x.as_str()?);
        }
        if let Some(x) = obj.get("profile") {
            cfg.profile = x.as_str()?.to_string();
        }
        if let Some(x) = obj.get("model") {
            cfg.model = x.as_str()?.to_string();
        }
        if let Some(x) = obj.get("nodes") {
            cfg.nodes = x.as_usize()?;
        }
        if let Some(x) = obj.get("link") {
            cfg.link = LinkSpec::parse(x.as_str()?)?;
        }
        if let Some(x) = obj.get("pipe_depth") {
            cfg.pipe_depth = x.as_usize()?;
        }
        if let Some(x) = obj.get("compute_slowdown") {
            cfg.compute_slowdown = x.as_f64()?;
        }
        if let Some(x) = obj.get("emulated_mflops") {
            cfg.emulated_mflops = x.as_f64()?;
        }
        if let Some(x) = obj.get("tcp") {
            cfg.tcp = matches!(x, Json::Bool(true));
        }
        if let Some(x) = obj.get("base_port") {
            cfg.base_port = x.as_usize()? as u16;
        }
        if let Some(x) = obj.get("tdp_watts") {
            cfg.energy.tdp_watts = x.as_f64()?;
        }
        if let Some(x) = obj.get("joules_per_bit") {
            cfg.energy.joules_per_bit = x.as_f64()?;
        }
        if obj.contains_key("codecs") {
            let c = v.get("codecs")?;
            let d = CodecConfig::default();
            cfg.codecs = CodecConfig {
                architecture: parse_codec(c, "architecture", d.architecture)?,
                weights: parse_codec(c, "weights", d.weights)?,
                data: parse_codec(c, "data", d.data)?,
            };
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply CLI overrides on top of this config.
    pub fn apply_args(mut self, args: &Args) -> Result<Self> {
        if let Some(m) = args.get("model") {
            self.model = m.to_string();
        }
        if let Some(p) = args.get("profile") {
            self.profile = p.to_string();
        }
        if let Some(d) = args.get("artifacts") {
            self.artifacts_dir = PathBuf::from(d);
        }
        self.nodes = args.get_usize("nodes", self.nodes)?;
        self.pipe_depth = args.get_usize("pipe-depth", self.pipe_depth)?;
        self.compute_slowdown = args.get_f64("slowdown", self.compute_slowdown)?;
        self.emulated_mflops = args.get_f64("emulated-mflops", self.emulated_mflops)?;
        if let Some(l) = args.get("link") {
            self.link = LinkSpec::parse(l)?;
        }
        if args.has("tcp") {
            self.tcp = true;
        }
        self.base_port = args.get_usize("base-port", self.base_port as usize)? as u16;
        self.energy.tdp_watts = args.get_f64("tdp", self.energy.tdp_watts)?;
        if let Some(s) = args.get("data-serialization") {
            self.codecs.data.serialization = Serialization::parse(s)?;
        }
        if let Some(c) = args.get("data-compression") {
            self.codecs.data.compression = Compression::parse(c)?;
        }
        if let Some(s) = args.get("weights-serialization") {
            self.codecs.weights.serialization = Serialization::parse(s)?;
        }
        if let Some(c) = args.get("weights-compression") {
            self.codecs.weights.compression = Compression::parse(c)?;
        }
        self.validate()?;
        Ok(self)
    }

    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 {
            return Err(DeferError::Config("nodes must be >= 1".into()));
        }
        if self.pipe_depth == 0 {
            return Err(DeferError::Config("pipe_depth must be >= 1".into()));
        }
        if !matches!(self.model.as_str(), "resnet50" | "vgg16" | "vgg19") {
            return Err(DeferError::Config(format!("unknown model {:?}", self.model)));
        }
        if !matches!(self.profile.as_str(), "tiny" | "edge" | "full") {
            return Err(DeferError::Config(format!(
                "unknown profile {:?}",
                self.profile
            )));
        }
        if !(self.compute_slowdown >= 1.0) {
            return Err(DeferError::Config(format!(
                "compute_slowdown must be >= 1.0, got {}",
                self.compute_slowdown
            )));
        }
        if !(self.emulated_mflops >= 0.0) {
            return Err(DeferError::Config(format!(
                "emulated_mflops must be >= 0, got {}",
                self.emulated_mflops
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_recommended() {
        let cfg = DeferConfig::default();
        assert_eq!(cfg.codecs.architecture.label(), "JSON+Uncompressed");
        assert_eq!(cfg.codecs.weights.label(), "ZFP+LZ4");
        assert_eq!(cfg.codecs.data.label(), "ZFP+LZ4");
        cfg.validate().unwrap();
    }

    #[test]
    fn json_file_round_trip() {
        let text = r#"{
            "model": "vgg19",
            "profile": "tiny",
            "nodes": 6,
            "link": "gigabit",
            "tcp": true,
            "tdp_watts": 7.5,
            "codecs": {
                "data": {"serialization": "json", "compression": "lz4"},
                "weights": {"serialization": "zfp:16"}
            }
        }"#;
        let cfg = DeferConfig::from_json_str(text).unwrap();
        assert_eq!(cfg.model, "vgg19");
        assert_eq!(cfg.nodes, 6);
        assert!(cfg.tcp);
        assert_eq!(cfg.energy.tdp_watts, 7.5);
        assert_eq!(cfg.codecs.data.label(), "JSON+LZ4");
        assert_eq!(
            cfg.codecs.weights.serialization,
            Serialization::Zfp(crate::serial::zfp::ZfpRate(16))
        );
        // Unspecified weight compression keeps the default (LZ4).
        assert_eq!(cfg.codecs.weights.compression, Compression::Lz4);
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(DeferConfig::from_json_str(r#"{"nodes": 0}"#).is_err());
        assert!(DeferConfig::from_json_str(r#"{"model": "alexnet"}"#).is_err());
        assert!(DeferConfig::from_json_str(r#"{"profile": "huge"}"#).is_err());
        assert!(DeferConfig::from_json_str("not json").is_err());
    }

    #[test]
    fn cli_overrides() {
        let raw: Vec<String> = ["--model", "vgg16", "--nodes", "8", "--tcp", "--data-serialization", "json"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&raw, &["tcp"]).unwrap();
        let cfg = DeferConfig::default().apply_args(&args).unwrap();
        assert_eq!(cfg.model, "vgg16");
        assert_eq!(cfg.nodes, 8);
        assert!(cfg.tcp);
        assert_eq!(cfg.codecs.data.serialization, Serialization::Json);
    }
}
