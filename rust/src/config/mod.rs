//! Run configuration: everything a DEFER deployment needs, loadable from a
//! JSON config file with CLI overrides — the launcher's config system.

use std::path::{Path, PathBuf};

use crate::cli::Args;
use crate::compress::Compression;
use crate::energy::EnergyModel;
use crate::error::{DeferError, Result};
use crate::netem::LinkSpec;
use crate::serial::{json::Json, Codec, CodecKernel, Serialization};

/// Per-socket codec configuration (architecture / weights / data), exactly
/// the three rows of the paper's Table I sweep.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CodecConfig {
    pub architecture: Codec,
    pub weights: Codec,
    pub data: Codec,
}

impl Default for CodecConfig {
    /// The paper's recommended mix: JSON/uncompressed architecture,
    /// ZFP+LZ4 weights, ZFP+LZ4 data.
    fn default() -> Self {
        CodecConfig {
            architecture: Codec::new(Serialization::Json, Compression::None),
            weights: Codec::default(),
            data: Codec::default(),
        }
    }
}

/// Complete run configuration.
#[derive(Clone, Debug)]
pub struct DeferConfig {
    /// Artifact root (from `make artifacts`).
    pub artifacts_dir: PathBuf,
    /// Scale profile: tiny | edge | full.
    pub profile: String,
    /// Model name: resnet50 | vgg16 | vgg19.
    pub model: String,
    /// Number of chain stages (1 = single-device baseline).
    pub nodes: usize,
    /// Worker replicas per stage, fed round-robin with FIFO merge
    /// (empty = 1 per stage, the paper's chain). Length must equal
    /// `nodes` when set.
    pub replicas: Vec<usize>,
    pub codecs: CodecConfig,
    /// Uniform link spec, used for every hop when `per_hop_links` is
    /// empty.
    pub link: LinkSpec,
    /// Heterogeneous per-hop links: `nodes + 1` entries (dispatcher
    /// uplink, inter-stage hops, return link) or a single entry applied
    /// to all hops. Empty = uniform `link`.
    pub per_hop_links: Vec<LinkSpec>,
    pub energy: EnergyModel,
    /// Bounded pipe depth between chain stages (backpressure window).
    pub pipe_depth: usize,
    /// Device-speed emulation: model compute is slowed by this factor
    /// (sleep after each execute), emulating the paper's edge-class devices
    /// running the full-scale model. 1.0 = native speed. The energy model
    /// accounts the slowed busy time. Codec/serialization stays native —
    /// its absolute cost already matches the paper's CPU class.
    ///
    /// Prefer [`DeferConfig::emulated_mflops`] for benchmarking: the
    /// multiplicative form amplifies host CPU noise by the factor.
    pub compute_slowdown: f64,
    /// Deterministic device-speed emulation: each stage's compute time is
    /// floored to `stage_flops / (emulated_mflops * 1e6)` seconds,
    /// emulating an edge device with that effective FLOP rate. 0 = off.
    /// Unlike `compute_slowdown`, host CPU contention cannot perturb the
    /// emulated stage time (the sleep target is a constant of the plan).
    pub emulated_mflops: f64,
    /// Run the chain over real TCP loopback sockets instead of in-process.
    pub tcp: bool,
    /// Optional fixed base TCP port for chain sockets (CORE-style
    /// deployments with predictable ports). `None` = ephemeral binds,
    /// immune to port collisions across parallel runs.
    pub base_port: Option<u16>,
    /// Let the placement planner (`placement::plan`) derive replica
    /// counts and per-hop links from stage costs instead of taking
    /// `replicas`/`per_hop_links` verbatim. Needs a device model:
    /// `device_profile` or `emulated_mflops`.
    pub auto_place: bool,
    /// Let the repartition planner (`repartition::plan`) choose the
    /// stage boundaries too: it fuses the *finest-granularity* partition
    /// set into stages jointly with replica placement, so `nodes` stops
    /// mattering and `per_hop_links` is read as uplink + interconnect
    /// candidates (the hop count is a planning output). Needs a device
    /// model like `auto_place`.
    pub auto_partition: bool,
    /// Total worker replicas the planner may place (0 = auto: the
    /// device-profile size, or `nodes` without a profile).
    pub workers_budget: usize,
    /// Max resident weight bytes one worker may host (bounds how much of
    /// the model `auto_partition` may fuse into one stage). 0 =
    /// unlimited — the cost model then favors few, wide stages; see
    /// `repartition` module docs.
    pub device_memory: u64,
    /// Path to a device-profile JSON (`{"devices": [{"name", "mflops"}]}`)
    /// describing the worker pool for auto-placement. `None` = a
    /// homogeneous pool of `emulated_mflops`-speed devices.
    pub device_profile: Option<PathBuf>,
    /// Chunk-parallel codec workers shared by the whole deployment
    /// (`serial::chunked`). 0 = legacy single-buffer codec payloads;
    /// >= 1 = data payloads travel as chunk containers encoded/decoded
    /// on a pool of this many threads (1 is useful for byte-identity
    /// testing: same container, sequential work).
    pub codec_threads: usize,
    /// Elements per codec chunk when `codec_threads > 0`; must be a
    /// positive multiple of 4 (ZFP block alignment). Default 128 Ki
    /// values = 512 KiB raw, the paper's transfer-chunk granularity.
    pub codec_chunk_elems: usize,
    /// ZFP kernel implementation (`--codec-kernel scalar|batched`).
    /// Both produce byte-identical wire streams; `scalar` is the
    /// reference block-at-a-time coder kept as the A/B fallback,
    /// `batched` (default) is the lane-parallel SIMD-friendly kernel.
    pub codec_kernel: CodecKernel,
    /// Software-pipeline decode | compute | encode inside every compute
    /// node (and encode/send + read/decode in the dispatcher). `false`
    /// restores the paper's inline loop (`--inline-codec`) for A/B runs.
    pub codec_pipeline: bool,
    /// Codec rate for the planner's service-time model, in GB/s of raw
    /// activation bytes. `None` = use the built-in per-codec calibration
    /// table; `Some(0.0)` = charge no codec time (the pre-calibration
    /// model); `Some(g > 0)` = charge `1/g` secs/byte for both encode
    /// and decode.
    pub codec_gbps: Option<f64>,
    /// Measure the codec rate live (micro-benchmark on synthetic data)
    /// instead of the calibration table. Plans stop being byte-stable
    /// across machines — off by default.
    pub codec_measure: bool,
    /// Restore the legacy coordinator-side junction relay threads for
    /// replicated stage boundaries (and the relay-hop planner cost
    /// model) instead of the worker-owned deal/merge data plane. A/B
    /// escape hatch — off by default.
    pub relay_junctions: bool,
    /// Max input frames coalesced into one batched wire message
    /// (micro-batching). 1 = unbatched — byte-identical to the legacy
    /// data plane. The planner also prices batch sizes up to this cap
    /// when `batch_overhead_us > 0`.
    pub batch: usize,
    /// Latency budget for filling a batch, in milliseconds (0 =
    /// unbounded). The planner only accepts a batch size B when the
    /// extra wait a frame can see — (B-1) gate periods — fits the
    /// budget.
    pub batch_latency_ms: f64,
    /// Adaptive batching: size each batch to the dispatcher's live send
    /// queue depth (up to `batch`) instead of always filling to the
    /// cap, so a drained queue ships single frames.
    pub batch_adaptive: bool,
    /// Per-message fixed overhead for the planner's batch pricing, in
    /// microseconds per frame at B=1 (amortized as `overhead / B`).
    /// 0 = batching is not priced and the planner keeps B=1.
    pub batch_overhead_us: f64,
    /// Reactor I/O shard threads for the data plane. 0 = auto
    /// (`min(2, cores)`). Ignored under `blocking_io`.
    pub io_threads: usize,
    /// Keep the legacy blocking thread-per-connection data plane instead
    /// of the sharded reactor. A/B escape hatch — off by default.
    pub blocking_io: bool,
    /// Self-healing data plane: replica death degrades the mesh and the
    /// dispatcher re-dispatches lost frames; corrupt chunks are patched
    /// in place via NACK/retry. Off by default (fail-fast, byte-identical
    /// wire traffic). Implied by a non-empty `faults` list.
    pub recovery: bool,
    /// Bounded in-flight window for the recovery dispatcher: how many
    /// dispatched messages may be unacknowledged at once.
    pub recovery_window: usize,
    /// Deterministic fault schedule (`netem::FaultPlan` grammar), e.g.
    /// `kill:node1.1@frame=40`, `truncate:node2.1@frame=10`,
    /// `corrupt-chunk:p=0.01[,seed=7]`. Non-empty implies `recovery`.
    /// On the CLI, `--fault` takes specs separated by `;`.
    pub faults: Vec<String>,
}

impl Default for DeferConfig {
    fn default() -> Self {
        DeferConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            profile: "edge".into(),
            model: "resnet50".into(),
            nodes: 4,
            replicas: Vec::new(),
            codecs: CodecConfig::default(),
            link: LinkSpec::ideal(),
            per_hop_links: Vec::new(),
            energy: EnergyModel::default(),
            pipe_depth: 4,
            compute_slowdown: 1.0,
            emulated_mflops: 0.0,
            tcp: false,
            base_port: None,
            auto_place: false,
            auto_partition: false,
            workers_budget: 0,
            device_memory: 0,
            device_profile: None,
            codec_threads: 0,
            codec_chunk_elems: crate::serial::chunked::DEFAULT_CHUNK_ELEMS,
            codec_kernel: CodecKernel::default(),
            codec_pipeline: true,
            codec_gbps: None,
            codec_measure: false,
            relay_junctions: false,
            batch: 1,
            batch_latency_ms: 0.0,
            batch_adaptive: false,
            batch_overhead_us: 0.0,
            io_threads: 0,
            blocking_io: false,
            recovery: false,
            recovery_window: crate::runtime::recovery::DEFAULT_WINDOW,
            faults: Vec::new(),
        }
    }
}

fn parse_codec(obj: &Json, key: &str, default: Codec) -> Result<Codec> {
    match obj.as_obj()?.get(key) {
        None => Ok(default),
        Some(c) => {
            let ser = match c.as_obj()?.get("serialization") {
                Some(s) => Serialization::parse(s.as_str()?)?,
                None => default.serialization,
            };
            let comp = match c.as_obj()?.get("compression") {
                Some(s) => Compression::parse(s.as_str()?)?,
                None => default.compression,
            };
            Ok(Codec::new(ser, comp))
        }
    }
}

impl DeferConfig {
    /// Load from a JSON file; missing fields keep defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json_str(&text)
    }

    pub fn from_json_str(text: &str) -> Result<Self> {
        let v = crate::serial::json::parse(text)?;
        let mut cfg = DeferConfig::default();
        let obj = v.as_obj()?;
        if let Some(x) = obj.get("artifacts_dir") {
            cfg.artifacts_dir = PathBuf::from(x.as_str()?);
        }
        if let Some(x) = obj.get("profile") {
            cfg.profile = x.as_str()?.to_string();
        }
        if let Some(x) = obj.get("model") {
            cfg.model = x.as_str()?.to_string();
        }
        if let Some(x) = obj.get("nodes") {
            cfg.nodes = x.as_usize()?;
        }
        if let Some(x) = obj.get("replicas") {
            cfg.replicas = x
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(x) = obj.get("link") {
            cfg.link = LinkSpec::parse(x.as_str()?)?;
        }
        if let Some(x) = obj.get("per_hop_links") {
            cfg.per_hop_links = x
                .as_arr()?
                .iter()
                .map(|v| LinkSpec::parse(v.as_str()?))
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(x) = obj.get("pipe_depth") {
            cfg.pipe_depth = x.as_usize()?;
        }
        if let Some(x) = obj.get("compute_slowdown") {
            cfg.compute_slowdown = x.as_f64()?;
        }
        if let Some(x) = obj.get("emulated_mflops") {
            cfg.emulated_mflops = x.as_f64()?;
        }
        if let Some(x) = obj.get("tcp") {
            cfg.tcp = matches!(x, Json::Bool(true));
        }
        if let Some(x) = obj.get("auto_place") {
            cfg.auto_place = matches!(x, Json::Bool(true));
        }
        if let Some(x) = obj.get("auto_partition") {
            cfg.auto_partition = matches!(x, Json::Bool(true));
        }
        if let Some(x) = obj.get("workers_budget") {
            cfg.workers_budget = x.as_usize()?;
        }
        if let Some(x) = obj.get("device_memory") {
            cfg.device_memory = x.as_usize()? as u64;
        }
        if let Some(x) = obj.get("device_profile") {
            cfg.device_profile = Some(PathBuf::from(x.as_str()?));
        }
        if let Some(x) = obj.get("codec_threads") {
            cfg.codec_threads = x.as_usize()?;
        }
        if let Some(x) = obj.get("codec_chunk_elems") {
            cfg.codec_chunk_elems = x.as_usize()?;
        }
        if let Some(x) = obj.get("codec_kernel") {
            cfg.codec_kernel = CodecKernel::parse(x.as_str()?)?;
        }
        if let Some(x) = obj.get("codec_pipeline") {
            cfg.codec_pipeline = matches!(x, Json::Bool(true));
        }
        if let Some(x) = obj.get("codec_gbps") {
            cfg.codec_gbps = Some(x.as_f64()?);
        }
        if let Some(x) = obj.get("codec_measure") {
            cfg.codec_measure = matches!(x, Json::Bool(true));
        }
        if let Some(x) = obj.get("relay_junctions") {
            cfg.relay_junctions = matches!(x, Json::Bool(true));
        }
        if let Some(x) = obj.get("batch") {
            cfg.batch = x.as_usize()?;
        }
        if let Some(x) = obj.get("batch_latency_ms") {
            cfg.batch_latency_ms = x.as_f64()?;
        }
        if let Some(x) = obj.get("batch_adaptive") {
            cfg.batch_adaptive = matches!(x, Json::Bool(true));
        }
        if let Some(x) = obj.get("batch_overhead_us") {
            cfg.batch_overhead_us = x.as_f64()?;
        }
        if let Some(x) = obj.get("io_threads") {
            cfg.io_threads = x.as_usize()?;
        }
        if let Some(x) = obj.get("blocking_io") {
            cfg.blocking_io = matches!(x, Json::Bool(true));
        }
        if let Some(x) = obj.get("recovery") {
            cfg.recovery = matches!(x, Json::Bool(true));
        }
        if let Some(x) = obj.get("recovery_window") {
            cfg.recovery_window = x.as_usize()?;
        }
        if let Some(x) = obj.get("faults") {
            cfg.faults = x
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(x) = obj.get("base_port") {
            let p = x.as_usize()?;
            if p > u16::MAX as usize {
                return Err(DeferError::Config(format!(
                    "base_port {p} out of range (max {})",
                    u16::MAX
                )));
            }
            cfg.base_port = Some(p as u16);
        }
        if let Some(x) = obj.get("tdp_watts") {
            cfg.energy.tdp_watts = x.as_f64()?;
        }
        if let Some(x) = obj.get("joules_per_bit") {
            cfg.energy.joules_per_bit = x.as_f64()?;
        }
        if obj.contains_key("codecs") {
            let c = v.get("codecs")?;
            let d = CodecConfig::default();
            cfg.codecs = CodecConfig {
                architecture: parse_codec(c, "architecture", d.architecture)?,
                weights: parse_codec(c, "weights", d.weights)?,
                data: parse_codec(c, "data", d.data)?,
            };
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply CLI overrides on top of this config.
    pub fn apply_args(mut self, args: &Args) -> Result<Self> {
        if let Some(m) = args.get("model") {
            self.model = m.to_string();
        }
        if let Some(p) = args.get("profile") {
            self.profile = p.to_string();
        }
        if let Some(d) = args.get("artifacts") {
            self.artifacts_dir = PathBuf::from(d);
        }
        self.nodes = args.get_usize("nodes", self.nodes)?;
        if args.get("replicas").is_some() {
            self.replicas = args.get_usize_list("replicas", &[])?;
        }
        self.pipe_depth = args.get_usize("pipe-depth", self.pipe_depth)?;
        self.compute_slowdown = args.get_f64("slowdown", self.compute_slowdown)?;
        self.emulated_mflops = args.get_f64("emulated-mflops", self.emulated_mflops)?;
        if let Some(l) = args.get("link") {
            self.link = LinkSpec::parse(l)?;
        }
        if let Some(items) = args.get_list("links") {
            self.per_hop_links = items
                .iter()
                .map(|s| LinkSpec::parse(s))
                .collect::<Result<Vec<_>>>()?;
        }
        if args.has("tcp") {
            self.tcp = true;
        }
        if args.has("auto-place") {
            self.auto_place = true;
        }
        if args.has("auto-partition") {
            self.auto_partition = true;
        }
        self.workers_budget = args.get_usize("workers-budget", self.workers_budget)?;
        self.device_memory = args.get_usize("device-memory", self.device_memory as usize)? as u64;
        if let Some(p) = args.get("device-profile") {
            self.device_profile = Some(PathBuf::from(p));
        }
        self.codec_threads = args.get_usize("codec-threads", self.codec_threads)?;
        self.codec_chunk_elems =
            args.get_usize("codec-chunk-elems", self.codec_chunk_elems)?;
        if let Some(k) = args.get("codec-kernel") {
            self.codec_kernel = CodecKernel::parse(k)?;
        }
        if args.has("inline-codec") {
            self.codec_pipeline = false;
        }
        if let Some(g) = args.get("codec-gbps") {
            self.codec_gbps = Some(g.parse().map_err(|_| {
                DeferError::Cli(format!("--codec-gbps wants a number, got {g:?}"))
            })?);
        }
        if args.has("codec-measure") {
            self.codec_measure = true;
        }
        if args.has("relay-junctions") {
            self.relay_junctions = true;
        }
        self.batch = args.get_usize("batch", self.batch)?;
        self.batch_latency_ms = args.get_f64("batch-latency-ms", self.batch_latency_ms)?;
        if args.has("batch-adaptive") {
            self.batch_adaptive = true;
        }
        self.batch_overhead_us = args.get_f64("batch-overhead-us", self.batch_overhead_us)?;
        self.io_threads = args.get_usize("io-threads", self.io_threads)?;
        if args.has("blocking-io") {
            self.blocking_io = true;
        }
        if args.has("recovery") {
            self.recovery = true;
        }
        self.recovery_window = args.get_usize("recovery-window", self.recovery_window)?;
        if let Some(v) = args.get("fault") {
            // Semicolon-separated: the spec grammar itself uses commas
            // (`corrupt-chunk:p=0.01,seed=7`).
            self.faults = v
                .split(';')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
        }
        if let Some(p) = args.get("base-port") {
            self.base_port = Some(p.parse().map_err(|_| {
                DeferError::Cli(format!("--base-port wants a port number, got {p:?}"))
            })?);
        }
        self.energy.tdp_watts = args.get_f64("tdp", self.energy.tdp_watts)?;
        if let Some(s) = args.get("data-serialization") {
            self.codecs.data.serialization = Serialization::parse(s)?;
        }
        if let Some(c) = args.get("data-compression") {
            self.codecs.data.compression = Compression::parse(c)?;
        }
        if let Some(s) = args.get("weights-serialization") {
            self.codecs.weights.serialization = Serialization::parse(s)?;
        }
        if let Some(c) = args.get("weights-compression") {
            self.codecs.weights.compression = Compression::parse(c)?;
        }
        self.validate()?;
        Ok(self)
    }

    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 {
            return Err(DeferError::Config("nodes must be >= 1".into()));
        }
        if !self.replicas.is_empty() {
            if self.replicas.len() != self.nodes {
                return Err(DeferError::Config(format!(
                    "replicas lists {} stages for {} nodes",
                    self.replicas.len(),
                    self.nodes
                )));
            }
            if let Some(i) = self.replicas.iter().position(|&r| r == 0) {
                return Err(DeferError::Config(format!(
                    "stage {i}: replicas must be >= 1"
                )));
            }
        }
        // With auto_partition the hop count is a planning output, so
        // per_hop_links is read as uplink + interconnect candidates and
        // any non-empty length is legal.
        if !self.auto_partition
            && !self.per_hop_links.is_empty()
            && self.per_hop_links.len() != 1
            && self.per_hop_links.len() != self.nodes + 1
        {
            return Err(DeferError::Config(format!(
                "per_hop_links wants 1 or {} entries ({} stages + dispatcher \
                 uplink and return), got {}",
                self.nodes + 1,
                self.nodes,
                self.per_hop_links.len()
            )));
        }
        if self.pipe_depth == 0 {
            return Err(DeferError::Config("pipe_depth must be >= 1".into()));
        }
        if self.auto_place
            && !self.auto_partition
            && self.workers_budget > 0
            && self.workers_budget < self.nodes
        {
            return Err(DeferError::Config(format!(
                "workers_budget {} cannot cover {} stages (one replica each)",
                self.workers_budget, self.nodes
            )));
        }
        if !matches!(self.model.as_str(), "resnet50" | "vgg16" | "vgg19") {
            return Err(DeferError::Config(format!("unknown model {:?}", self.model)));
        }
        if !matches!(self.profile.as_str(), "tiny" | "edge" | "full") {
            return Err(DeferError::Config(format!(
                "unknown profile {:?}",
                self.profile
            )));
        }
        if !(self.compute_slowdown >= 1.0) {
            return Err(DeferError::Config(format!(
                "compute_slowdown must be >= 1.0, got {}",
                self.compute_slowdown
            )));
        }
        if !(self.emulated_mflops >= 0.0) {
            return Err(DeferError::Config(format!(
                "emulated_mflops must be >= 0, got {}",
                self.emulated_mflops
            )));
        }
        if self.io_threads > 256 {
            return Err(DeferError::Config(format!(
                "io_threads {} is past any plausible core count (max 256)",
                self.io_threads
            )));
        }
        if self.codec_threads > 256 {
            return Err(DeferError::Config(format!(
                "codec_threads {} is past any plausible core count (max 256)",
                self.codec_threads
            )));
        }
        if self.codec_threads > 0 {
            // Fail at config time with the chunk-size rules, not at the
            // first frame.
            crate::serial::CodecRuntime::chunked(self.codec_chunk_elems, None)?;
        }
        if let Some(g) = self.codec_gbps {
            if !(g >= 0.0 && g.is_finite()) {
                return Err(DeferError::Config(format!(
                    "codec_gbps must be a finite rate >= 0 (0 = charge no codec \
                     time), got {g}"
                )));
            }
        }
        if self.batch == 0 || self.batch > crate::wire::MAX_BATCH as usize {
            return Err(DeferError::Config(format!(
                "batch must be in 1..={}, got {}",
                crate::wire::MAX_BATCH,
                self.batch
            )));
        }
        if !(self.batch_latency_ms >= 0.0 && self.batch_latency_ms.is_finite()) {
            return Err(DeferError::Config(format!(
                "batch_latency_ms must be a finite budget >= 0 (0 = unbounded), \
                 got {}",
                self.batch_latency_ms
            )));
        }
        if !(self.batch_overhead_us >= 0.0 && self.batch_overhead_us.is_finite()) {
            return Err(DeferError::Config(format!(
                "batch_overhead_us must be finite and >= 0 (0 = batching not \
                 priced), got {}",
                self.batch_overhead_us
            )));
        }
        if self.recovery_window == 0 {
            return Err(DeferError::Config("recovery_window must be >= 1".into()));
        }
        // Fail at config time with the fault grammar, not mid-run.
        crate::netem::FaultPlan::parse(&self.faults)?;
        if (self.recovery || !self.faults.is_empty()) && self.relay_junctions {
            return Err(DeferError::Config(
                "recovery/faults are incompatible with relay_junctions (the \
                 legacy relay threads have no self-healing path)"
                    .into(),
            ));
        }
        Ok(())
    }

    /// Self-healing mode is on when asked for explicitly or implied by a
    /// fault schedule (an injected fault without recovery would just be a
    /// guaranteed run failure).
    pub fn recovery_enabled(&self) -> bool {
        self.recovery || !self.faults.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_recommended() {
        let cfg = DeferConfig::default();
        assert_eq!(cfg.codecs.architecture.label(), "JSON+Uncompressed");
        assert_eq!(cfg.codecs.weights.label(), "ZFP+LZ4");
        assert_eq!(cfg.codecs.data.label(), "ZFP+LZ4");
        cfg.validate().unwrap();
    }

    #[test]
    fn json_file_round_trip() {
        let text = r#"{
            "model": "vgg19",
            "profile": "tiny",
            "nodes": 6,
            "link": "gigabit",
            "tcp": true,
            "tdp_watts": 7.5,
            "codecs": {
                "data": {"serialization": "json", "compression": "lz4"},
                "weights": {"serialization": "zfp:16"}
            }
        }"#;
        let cfg = DeferConfig::from_json_str(text).unwrap();
        assert_eq!(cfg.model, "vgg19");
        assert_eq!(cfg.nodes, 6);
        assert!(cfg.tcp);
        assert_eq!(cfg.energy.tdp_watts, 7.5);
        assert_eq!(cfg.codecs.data.label(), "JSON+LZ4");
        assert_eq!(
            cfg.codecs.weights.serialization,
            Serialization::Zfp(crate::serial::zfp::ZfpRate(16))
        );
        // Unspecified weight compression keeps the default (LZ4).
        assert_eq!(cfg.codecs.weights.compression, Compression::Lz4);
    }

    #[test]
    fn topology_surface_round_trip() {
        let text = r#"{
            "profile": "tiny",
            "nodes": 4,
            "replicas": [1, 2, 1, 1],
            "per_hop_links": ["wifi", "gigabit", "gigabit", "gigabit", "gigabit"],
            "base_port": 48000
        }"#;
        let cfg = DeferConfig::from_json_str(text).unwrap();
        assert_eq!(cfg.replicas, vec![1, 2, 1, 1]);
        assert_eq!(cfg.per_hop_links.len(), 5);
        assert_eq!(cfg.per_hop_links[0], LinkSpec::wifi());
        assert_eq!(cfg.per_hop_links[1], LinkSpec::gigabit_lan());
        assert_eq!(cfg.base_port, Some(48_000));
        // Defaults stay replication-free with ephemeral ports.
        let d = DeferConfig::default();
        assert!(d.replicas.is_empty());
        assert!(d.per_hop_links.is_empty());
        assert_eq!(d.base_port, None);
    }

    #[test]
    fn auto_place_surface_round_trip() {
        let text = r#"{
            "nodes": 2,
            "auto_place": true,
            "workers_budget": 4,
            "device_profile": "devices.json"
        }"#;
        let cfg = DeferConfig::from_json_str(text).unwrap();
        assert!(cfg.auto_place);
        assert_eq!(cfg.workers_budget, 4);
        assert_eq!(cfg.device_profile, Some(PathBuf::from("devices.json")));
        // CLI spelling.
        let raw: Vec<String> = [
            "run",
            "--nodes",
            "2",
            "--auto-place",
            "--workers-budget",
            "5",
            "--device-profile",
            "pool.json",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(&raw, &["tcp", "auto-place"]).unwrap();
        let cfg = DeferConfig::default().apply_args(&args).unwrap();
        assert!(cfg.auto_place);
        assert_eq!(cfg.workers_budget, 5);
        assert_eq!(cfg.device_profile, Some(PathBuf::from("pool.json")));
        // A budget below one-replica-per-stage is rejected up front
        // (only when planning is actually on — otherwise the key is
        // inert and must not block unrelated subcommands).
        assert!(DeferConfig::from_json_str(
            r#"{"nodes": 4, "auto_place": true, "workers_budget": 2}"#
        )
        .is_err());
        assert!(DeferConfig::from_json_str(r#"{"nodes": 4, "workers_budget": 2}"#).is_ok());
        // Defaults keep planning off.
        assert!(!DeferConfig::default().auto_place);
    }

    #[test]
    fn auto_partition_surface_round_trip() {
        let text = r#"{
            "auto_partition": true,
            "workers_budget": 4,
            "device_memory": 250000,
            "per_hop_links": ["wifi", "gigabit"]
        }"#;
        let cfg = DeferConfig::from_json_str(text).unwrap();
        assert!(cfg.auto_partition);
        assert_eq!(cfg.workers_budget, 4);
        assert_eq!(cfg.device_memory, 250_000);
        // Two per-hop entries are rejected for a fixed chain, but under
        // auto_partition they are uplink + interconnect candidates (the
        // hop count is a planning output).
        assert_eq!(cfg.per_hop_links.len(), 2);
        assert!(DeferConfig::from_json_str(
            r#"{"nodes": 4, "per_hop_links": ["wifi", "gigabit"]}"#
        )
        .is_err());
        // A budget below `nodes` is fine too: the stage count is planned.
        assert!(DeferConfig::from_json_str(
            r#"{"nodes": 4, "auto_place": true, "auto_partition": true,
                "workers_budget": 2}"#
        )
        .is_ok());
        // CLI spelling.
        let raw: Vec<String> = [
            "run",
            "--auto-partition",
            "--device-memory",
            "1000000",
            "--workers-budget",
            "3",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(&raw, &["tcp", "auto-place", "auto-partition"]).unwrap();
        let cfg = DeferConfig::default().apply_args(&args).unwrap();
        assert!(cfg.auto_partition);
        assert_eq!(cfg.device_memory, 1_000_000);
        assert_eq!(cfg.workers_budget, 3);
        // Defaults keep repartitioning off.
        assert!(!DeferConfig::default().auto_partition);
        assert_eq!(DeferConfig::default().device_memory, 0);
    }

    #[test]
    fn codec_pipeline_surface_round_trip() {
        let text = r#"{
            "codec_threads": 4,
            "codec_chunk_elems": 65536,
            "codec_pipeline": false,
            "codec_gbps": 0.4
        }"#;
        let cfg = DeferConfig::from_json_str(text).unwrap();
        assert_eq!(cfg.codec_threads, 4);
        assert_eq!(cfg.codec_chunk_elems, 65_536);
        assert!(!cfg.codec_pipeline);
        assert_eq!(cfg.codec_gbps, Some(0.4));
        // Defaults: legacy payloads, pipelining on, calibrated planning.
        let d = DeferConfig::default();
        assert_eq!(d.codec_threads, 0);
        assert!(d.codec_pipeline);
        assert_eq!(d.codec_gbps, None);
        assert!(!d.codec_measure);
        // Chunk-size rules enforced at config time (only when chunking on).
        assert!(DeferConfig::from_json_str(
            r#"{"codec_threads": 2, "codec_chunk_elems": 6}"#
        )
        .is_err());
        assert!(DeferConfig::from_json_str(r#"{"codec_chunk_elems": 6}"#).is_ok());
        assert!(DeferConfig::from_json_str(r#"{"codec_threads": 9999}"#).is_err());
        assert!(DeferConfig::from_json_str(r#"{"codec_gbps": -1}"#).is_err());
        // CLI spelling.
        let raw: Vec<String> = [
            "run",
            "--codec-threads",
            "8",
            "--inline-codec",
            "--codec-gbps",
            "0",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(&raw, &["tcp", "inline-codec", "codec-measure"]).unwrap();
        let cfg = DeferConfig::default().apply_args(&args).unwrap();
        assert_eq!(cfg.codec_threads, 8);
        assert!(!cfg.codec_pipeline);
        assert_eq!(cfg.codec_gbps, Some(0.0));
    }

    #[test]
    fn codec_kernel_surface_round_trip() {
        let cfg = DeferConfig::from_json_str(r#"{"codec_kernel": "scalar"}"#).unwrap();
        assert_eq!(cfg.codec_kernel, CodecKernel::Scalar);
        let cfg = DeferConfig::from_json_str(r#"{"codec_kernel": "Batched"}"#).unwrap();
        assert_eq!(cfg.codec_kernel, CodecKernel::Batched);
        assert!(DeferConfig::from_json_str(r#"{"codec_kernel": "avx9000"}"#).is_err());
        // The batched kernel is the default; scalar is the A/B fallback.
        assert_eq!(DeferConfig::default().codec_kernel, CodecKernel::Batched);
        // CLI spelling.
        let raw: Vec<String> = ["run", "--codec-kernel", "scalar"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&raw, &["tcp"]).unwrap();
        let cfg = DeferConfig::default().apply_args(&args).unwrap();
        assert_eq!(cfg.codec_kernel, CodecKernel::Scalar);
    }

    #[test]
    fn relay_junctions_surface_round_trip() {
        let cfg = DeferConfig::from_json_str(r#"{"relay_junctions": true}"#).unwrap();
        assert!(cfg.relay_junctions);
        // CLI spelling.
        let raw: Vec<String> = ["run", "--relay-junctions"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&raw, &["tcp", "relay-junctions"]).unwrap();
        let cfg = DeferConfig::default().apply_args(&args).unwrap();
        assert!(cfg.relay_junctions);
        // The default data plane is worker-owned.
        assert!(!DeferConfig::default().relay_junctions);
    }

    #[test]
    fn io_surface_round_trip() {
        let text = r#"{
            "io_threads": 3,
            "blocking_io": true
        }"#;
        let cfg = DeferConfig::from_json_str(text).unwrap();
        assert_eq!(cfg.io_threads, 3);
        assert!(cfg.blocking_io);
        // Defaults: auto-sized reactor plane.
        let d = DeferConfig::default();
        assert_eq!(d.io_threads, 0);
        assert!(!d.blocking_io);
        // Implausible shard counts rejected at config time.
        assert!(DeferConfig::from_json_str(r#"{"io_threads": 9999}"#).is_err());
        // CLI spelling.
        let raw: Vec<String> = ["run", "--io-threads", "2", "--blocking-io"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&raw, &["tcp", "blocking-io"]).unwrap();
        let cfg = DeferConfig::default().apply_args(&args).unwrap();
        assert_eq!(cfg.io_threads, 2);
        assert!(cfg.blocking_io);
    }

    #[test]
    fn batching_surface_round_trip() {
        let text = r#"{
            "batch": 8,
            "batch_latency_ms": 2.5,
            "batch_adaptive": true,
            "batch_overhead_us": 120
        }"#;
        let cfg = DeferConfig::from_json_str(text).unwrap();
        assert_eq!(cfg.batch, 8);
        assert_eq!(cfg.batch_latency_ms, 2.5);
        assert!(cfg.batch_adaptive);
        assert_eq!(cfg.batch_overhead_us, 120.0);
        // Defaults stay unbatched and unpriced.
        let d = DeferConfig::default();
        assert_eq!(d.batch, 1);
        assert_eq!(d.batch_latency_ms, 0.0);
        assert!(!d.batch_adaptive);
        assert_eq!(d.batch_overhead_us, 0.0);
        // Out-of-range values rejected at config time.
        assert!(DeferConfig::from_json_str(r#"{"batch": 0}"#).is_err());
        assert!(DeferConfig::from_json_str(r#"{"batch": 99999999}"#).is_err());
        assert!(DeferConfig::from_json_str(r#"{"batch_latency_ms": -1}"#).is_err());
        assert!(DeferConfig::from_json_str(r#"{"batch_overhead_us": -0.5}"#).is_err());
        // CLI spelling.
        let raw: Vec<String> = [
            "run",
            "--batch",
            "4",
            "--batch-latency-ms",
            "1.5",
            "--batch-adaptive",
            "--batch-overhead-us",
            "80",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(&raw, &["tcp", "batch-adaptive"]).unwrap();
        let cfg = DeferConfig::default().apply_args(&args).unwrap();
        assert_eq!(cfg.batch, 4);
        assert_eq!(cfg.batch_latency_ms, 1.5);
        assert!(cfg.batch_adaptive);
        assert_eq!(cfg.batch_overhead_us, 80.0);
    }

    #[test]
    fn recovery_surface_round_trip() {
        let text = r#"{
            "recovery": true,
            "recovery_window": 16,
            "faults": ["kill:node1.1@frame=40", "corrupt-chunk:p=0.01"]
        }"#;
        let cfg = DeferConfig::from_json_str(text).unwrap();
        assert!(cfg.recovery);
        assert!(cfg.recovery_enabled());
        assert_eq!(cfg.recovery_window, 16);
        assert_eq!(cfg.faults.len(), 2);
        // Defaults: fail-fast data plane, default window, no faults.
        let d = DeferConfig::default();
        assert!(!d.recovery);
        assert!(!d.recovery_enabled());
        assert_eq!(d.recovery_window, crate::runtime::recovery::DEFAULT_WINDOW);
        assert!(d.faults.is_empty());
        // A fault schedule implies recovery without the explicit flag.
        let cfg =
            DeferConfig::from_json_str(r#"{"faults": ["corrupt-chunk:p=0.5"]}"#).unwrap();
        assert!(!cfg.recovery);
        assert!(cfg.recovery_enabled());
        // Bad grammar, zero window, and the relay conflict fail early.
        assert!(DeferConfig::from_json_str(r#"{"faults": ["explode:everything"]}"#).is_err());
        assert!(DeferConfig::from_json_str(r#"{"recovery_window": 0}"#).is_err());
        assert!(DeferConfig::from_json_str(
            r#"{"recovery": true, "relay_junctions": true}"#
        )
        .is_err());
        // CLI spelling (semicolon-separated --fault list, since the spec
        // grammar itself uses commas; --recovery switch).
        let raw: Vec<String> = [
            "run",
            "--recovery",
            "--recovery-window",
            "4",
            "--fault",
            "kill:node1.1@frame=40; corrupt-chunk:p=0.01,seed=7",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(&raw, &["tcp", "recovery"]).unwrap();
        let cfg = DeferConfig::default().apply_args(&args).unwrap();
        assert!(cfg.recovery);
        assert_eq!(cfg.recovery_window, 4);
        assert_eq!(cfg.faults.len(), 2);
    }

    #[test]
    fn invalid_topology_rejected() {
        assert!(DeferConfig::from_json_str(r#"{"base_port": 70000}"#).is_err());
        assert!(DeferConfig::from_json_str(r#"{"nodes": 2, "replicas": [1, 0]}"#).is_err());
        assert!(DeferConfig::from_json_str(r#"{"nodes": 2, "replicas": [1, 1, 1]}"#).is_err());
        // 2 stages need 1 or 3 per-hop entries, not 2.
        assert!(DeferConfig::from_json_str(
            r#"{"nodes": 2, "per_hop_links": ["wifi", "gigabit"]}"#
        )
        .is_err());
    }

    #[test]
    fn cli_topology_overrides() {
        let raw: Vec<String> = [
            "run",
            "--nodes",
            "4",
            "--replicas",
            "1,2,1,1",
            "--links",
            "wifi,gigabit,gigabit,gigabit,gigabit",
            "--base-port",
            "48100",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(&raw, &["tcp"]).unwrap();
        let cfg = DeferConfig::default().apply_args(&args).unwrap();
        assert_eq!(cfg.replicas, vec![1, 2, 1, 1]);
        assert_eq!(cfg.per_hop_links.len(), 5);
        assert_eq!(cfg.per_hop_links[0], LinkSpec::wifi());
        assert_eq!(cfg.base_port, Some(48_100));
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(DeferConfig::from_json_str(r#"{"nodes": 0}"#).is_err());
        assert!(DeferConfig::from_json_str(r#"{"model": "alexnet"}"#).is_err());
        assert!(DeferConfig::from_json_str(r#"{"profile": "huge"}"#).is_err());
        assert!(DeferConfig::from_json_str("not json").is_err());
    }

    #[test]
    fn cli_overrides() {
        let raw: Vec<String> = ["--model", "vgg16", "--nodes", "8", "--tcp", "--data-serialization", "json"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&raw, &["tcp"]).unwrap();
        let cfg = DeferConfig::default().apply_args(&args).unwrap();
        assert_eq!(cfg.model, "vgg16");
        assert_eq!(cfg.nodes, 8);
        assert!(cfg.tcp);
        assert_eq!(cfg.codecs.data.serialization, Serialization::Json);
    }
}
