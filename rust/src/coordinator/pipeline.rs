//! Software-pipelined codec stages for one worker replica.
//!
//! The paper's Algorithm 2 runs decode → compute → encode → send inline
//! on one thread, so codec time adds 1:1 to every stage's service time.
//! This module decouples the three phases onto their own threads joined
//! by bounded [`pipe`]s, so frame `k+1` decodes while frame `k` computes
//! and frame `k-1` encodes/transmits — per-stage occupancy drops from
//! `decode + compute + encode + egress` to
//! `max(decode, compute, encode + egress)` at steady state. FIFO order
//! is preserved end to end: each phase is a single thread consuming a
//! FIFO pipe, so frames cannot overtake inside a replica, and the
//! worker-owned deal/merge schedules (see [`crate::topology::wiring`])
//! preserve order across replicas.
//!
//! The encode stage writes through a [`FrameSink`] — the replica's own
//! round-robin fan-out over its successor set (a single connection for
//! unreplicated successors), blocking or reactor-backed. There is no
//! relay thread between stages: the pipeline's last phase *is* the
//! boundary deal.
//!
//! [`run_codec_pipeline`] is generic over the compute step (a closure),
//! which keeps it independent of PJRT — the order-preservation and
//! error-path tests drive it with synthetic compute, no artifacts
//! needed. `compute_node` passes the fused-executable run; the inline
//! (non-pipelined) mode reproduces the legacy loop exactly for A/B
//! benchmarking via `--inline-codec`.

use std::sync::{Arc, Mutex};

use crate::error::{DeferError, Result};
use crate::metrics::ByteCounter;
use crate::netem::Link;
use crate::runtime::recovery::{decode_with_retry, ChunkRetryClient, RecoverySupervisor};
use crate::serial::chunked::chunk_payload_span;
use crate::serial::{Codec, CodecRuntime};
use crate::threadpool::{pipe, WorkerPool};
use crate::topology::wiring::FrameSink;
use crate::util::bufpool::BufPool;
use crate::util::timer::SharedTimer;
use crate::wire::{Message, MessageType, SharedPayload, WireFrame};

/// Self-healing hooks for one replica's codec pipeline: the run-wide
/// supervisor (fault schedule, escalation) plus this replica's
/// chunk-retry client (NACKs corrupt chunks to the producing upstream).
#[derive(Clone)]
pub struct PipelineRecovery {
    pub supervisor: Arc<RecoverySupervisor>,
    pub client: Option<Arc<ChunkRetryClient>>,
}

/// Everything the pipeline needs besides the connections and compute.
pub struct PipelineCtx {
    /// Stage name for thread labels and error messages. In recovery mode
    /// this is also the fault-schedule key (the node name, e.g.
    /// `node1.1`).
    pub name: String,
    /// The data-socket codec.
    pub codec: Codec,
    /// Chunking/pool/buffer runtime shared with the peer.
    pub rt: CodecRuntime,
    /// Codec-time accumulator (the paper's "Overhead" metric).
    pub overhead: SharedTimer,
    /// Egress byte counter (this node's data-socket tx).
    pub data_tx: ByteCounter,
    /// Completed-frame counter.
    pub frames: ByteCounter,
    /// Shaped egress link.
    pub out_link: Arc<Link>,
    /// `false` = legacy inline loop (decode+compute+encode on one thread).
    pub pipelined: bool,
    /// Bounded depth of the inter-phase pipes (backpressure window).
    pub pipe_depth: usize,
    /// Recycles inbound payload buffers after decode (pair with the
    /// reader's `recv_pooled`).
    pub payload_pool: Option<Arc<BufPool>>,
    /// Self-healing mode (fault injection, chunk retry, escalation).
    /// `None` = fail-fast, byte-identical to the pre-recovery pipeline.
    pub recovery: Option<PipelineRecovery>,
}

/// Flip one byte inside chunk 0's *body* (past the 12-byte per-chunk
/// header) so the chunk CRC — not the container parser — detects the
/// damage. Non-chunked payloads are left alone: `corrupt-chunk` models
/// DFCK wire damage, which plain containers cannot carry per-chunk.
fn corrupt_one_byte(payload: &mut [u8], entropy: u64) {
    const CHUNK_HEADER: usize = 12;
    if let Ok(span) = chunk_payload_span(payload, 0) {
        if span.len() > CHUNK_HEADER {
            let body = span.len() - CHUNK_HEADER;
            let off = span.start + CHUNK_HEADER + (entropy as usize % body);
            payload[off] ^= 0x40;
        }
    }
}

/// Decode one inbound data message under the recovery policy: injected
/// faults first (kill aborts the replica, corruption flips a chunk
/// byte — both deterministic per node + frame), then decode with
/// chunk-level NACK/retry. A frame whose retry budget is exhausted is
/// escalated for whole-frame re-dispatch and skipped (`Ok(None)`).
fn decode_step(
    codec: &Codec,
    rt: &CodecRuntime,
    overhead: &SharedTimer,
    recovery: Option<&PipelineRecovery>,
    name: &str,
    msg: Message,
    payload_pool: Option<&BufPool>,
) -> Result<Option<(u64, u32, Vec<f32>)>> {
    let Message {
        frame,
        batch,
        serialized_len,
        count,
        mut payload,
        ..
    } = msg;
    if let Some(rec) = recovery {
        let faults = rec.supervisor.faults();
        if let Some(k) = faults.kill_frame(name) {
            if frame + u64::from(batch) > k {
                return Err(DeferError::FaultInjected(format!(
                    "{name} killed at frame {k}"
                )));
            }
        }
        if let Some(entropy) = faults.corrupt_roll(name, frame) {
            corrupt_one_byte(&mut payload, entropy);
        }
    }
    let client = recovery.and_then(|r| r.client.as_deref());
    let res = decode_with_retry(client, frame, &mut payload, |bytes| {
        codec.decode_frame(
            bytes,
            serialized_len as usize,
            count as usize,
            rt,
            Some(overhead),
        )
    });
    let values = match res {
        Ok(v) => v,
        Err(e @ DeferError::CorruptChunk { .. }) => match recovery {
            Some(rec) => {
                // Unrecoverable in place: the dispatcher re-encodes and
                // re-deals this message; this replica skips it.
                rec.supervisor.escalate_frame(frame, batch);
                if let Some(p) = payload_pool {
                    p.put(payload);
                }
                return Ok(None);
            }
            None => return Err(e),
        },
        Err(e) => return Err(e),
    };
    if let Some(p) = payload_pool {
        p.put(payload);
    }
    Ok(Some((frame, batch, values)))
}

/// Injected-truncation check before an egress send: when the schedule
/// says this node truncates at `frame`, write a half message and die.
/// The (counted) message materialization only happens when the fault
/// actually fires — the steady-state path stays zero-copy.
fn truncate_check(
    out: &mut FrameSink,
    recovery: Option<&PipelineRecovery>,
    name: &str,
    wf: &WireFrame,
) -> Result<()> {
    let Some(rec) = recovery else { return Ok(()) };
    let Some(t) = rec.supervisor.faults().truncate_frame(name) else {
        return Ok(());
    };
    if wf.frame() + u64::from(wf.batch()) > t {
        let msg = wf.to_message();
        out.send_truncated(&msg, msg.wire_size() as usize / 2)?;
        return Err(DeferError::FaultInjected(format!(
            "{name} truncated egress at frame {t} and died"
        )));
    }
    Ok(())
}

/// A frame (or batch of frames) moving between pipeline phases, or the
/// end-of-stream marker.
enum Step<T> {
    Frame { frame: u64, batch: u32, data: T },
    /// Clean shutdown received from upstream; relay downstream.
    Shutdown,
}

/// Clone an error's message for cross-thread reporting (the underlying
/// enum is not `Clone`; the text is what matters at the boundary).
/// Injected faults keep their variant so the node driver can tell a
/// scheduled death from a real failure.
fn describe(stage: &str, e: &DeferError) -> DeferError {
    match e {
        DeferError::FaultInjected(m) => DeferError::FaultInjected(format!("{stage}: {m}")),
        _ => DeferError::Coordinator(format!("{stage}: {e}")),
    }
}

/// Run one worker's inference phase: pull framed activations off `rx`
/// (fed by the socket-reader thread), decode, run `compute`, encode, and
/// send downstream — inline or software-pipelined per
/// [`PipelineCtx::pipelined`]. Returns after relaying `Shutdown`, or
/// when `rx` closes without one (upstream teardown — the reader's error
/// is surfaced by the caller joining its pool), or with the first error.
///
/// Batches stay whole: a message carrying `batch` stacked frames is
/// decoded once, handed to `compute` as one stacked vector (with the
/// batch count as the second argument), encoded once, and forwarded as
/// one message with the batch field preserved — so the per-message fixed
/// costs are paid once per batch, not once per frame.
pub fn run_codec_pipeline<F>(
    rx: crate::threadpool::PipeReceiver<Message>,
    out: impl Into<FrameSink>,
    ctx: PipelineCtx,
    mut compute: F,
) -> Result<()>
where
    F: FnMut(Vec<f32>, usize) -> Result<Vec<f32>>,
{
    let mut out = out.into();
    if !ctx.pipelined {
        // Legacy inline loop: one thread does everything per frame.
        while let Some(msg) = rx.recv() {
            match msg.msg_type {
                MessageType::Shutdown => {
                    out.broadcast_shutdown(&ctx.out_link, &ctx.data_tx)?;
                    return Ok(());
                }
                MessageType::Data => {
                    let Some((frame, batch, values)) = decode_step(
                        &ctx.codec,
                        &ctx.rt,
                        &ctx.overhead,
                        ctx.recovery.as_ref(),
                        &ctx.name,
                        msg,
                        ctx.payload_pool.as_deref(),
                    )?
                    else {
                        continue; // escalated for re-dispatch
                    };
                    let output = compute(values, batch as usize)?;
                    let (wire, mid) =
                        ctx.codec
                            .encode_frame(&output, &ctx.rt, Some(&ctx.overhead));
                    // One wire form, produced here, shared by every
                    // consumer; the pooled buffer returns to the codec
                    // pool when the last reference drops.
                    let wf = WireFrame::new(
                        MessageType::Data,
                        frame,
                        batch,
                        mid as u64,
                        output.len() as u64,
                        SharedPayload::from_vec(wire, ctx.rt.buffers_arc()),
                    )?;
                    truncate_check(&mut out, ctx.recovery.as_ref(), &ctx.name, &wf)?;
                    out.send_frame(wf, &ctx.out_link, &ctx.data_tx)?;
                    ctx.frames.add(batch as u64);
                }
                other => {
                    return Err(DeferError::Coordinator(format!(
                        "{}: unexpected {other:?} in inference phase",
                        ctx.name
                    )))
                }
            }
        }
        return Ok(());
    }

    // ---- pipelined: decode | compute (this thread) | encode+send ----
    let (dec_tx, dec_rx) = pipe::<Step<Vec<f32>>>(ctx.pipe_depth);
    let (enc_tx, enc_rx) = pipe::<Step<Vec<f32>>>(ctx.pipe_depth);
    // Stage errors are stashed here (as text) so the compute thread can
    // surface the *root cause* when it cannot join a detached stage.
    let err_slot: Arc<Mutex<Option<DeferError>>> = Arc::new(Mutex::new(None));
    let mut pool = WorkerPool::new();

    {
        let codec = ctx.codec;
        let rt = ctx.rt.clone();
        let overhead = ctx.overhead.clone();
        let payload_pool = ctx.payload_pool.clone();
        let recovery = ctx.recovery.clone();
        let name = ctx.name.clone();
        let slot = Arc::clone(&err_slot);
        pool.spawn(&format!("{}-decode", ctx.name), move || {
            let body = || -> Result<()> {
                while let Some(msg) = rx.recv() {
                    match msg.msg_type {
                        MessageType::Shutdown => {
                            dec_tx
                                .send(Step::Shutdown)
                                .map_err(|_| DeferError::ChannelClosed("decode pipe"))?;
                            return Ok(());
                        }
                        MessageType::Data => {
                            let Some((frame, batch, values)) = decode_step(
                                &codec,
                                &rt,
                                &overhead,
                                recovery.as_ref(),
                                &name,
                                msg,
                                payload_pool.as_deref(),
                            )?
                            else {
                                continue; // escalated for re-dispatch
                            };
                            dec_tx
                                .send(Step::Frame {
                                    frame,
                                    batch,
                                    data: values,
                                })
                                .map_err(|_| DeferError::ChannelClosed("decode pipe"))?;
                        }
                        other => {
                            return Err(DeferError::Coordinator(format!(
                                "{name}: unexpected {other:?} in inference phase"
                            )))
                        }
                    }
                }
                // Upstream reader ended without Shutdown (teardown); end
                // quietly — the reader's own error names the cause.
                Ok(())
            };
            body().inspect_err(|e| err_slot_store(&slot, describe("decode stage", e)))
        });
    }

    {
        let codec = ctx.codec;
        let rt = ctx.rt.clone();
        let overhead = ctx.overhead.clone();
        let out_link = Arc::clone(&ctx.out_link);
        let data_tx = ctx.data_tx.clone();
        let frames = ctx.frames.clone();
        let recovery = ctx.recovery.clone();
        let name = ctx.name.clone();
        let slot = Arc::clone(&err_slot);
        pool.spawn(&format!("{}-encode", ctx.name), move || {
            let mut body = || -> Result<()> {
                while let Some(step) = enc_rx.recv() {
                    match step {
                        Step::Shutdown => {
                            out.broadcast_shutdown(&out_link, &data_tx)?;
                            return Ok(());
                        }
                        Step::Frame { frame, batch, data } => {
                            let (wire, mid) =
                                codec.encode_frame(&data, &rt, Some(&overhead));
                            let wf = WireFrame::new(
                                MessageType::Data,
                                frame,
                                batch,
                                mid as u64,
                                data.len() as u64,
                                SharedPayload::from_vec(wire, rt.buffers_arc()),
                            )?;
                            truncate_check(&mut out, recovery.as_ref(), &name, &wf)?;
                            out.send_frame(wf, &out_link, &data_tx)?;
                            frames.add(batch as u64);
                        }
                    }
                }
                Ok(())
            };
            body().inspect_err(|e| err_slot_store(&slot, describe("encode stage", e)))
        });
    }

    // Compute phase on this thread, between the two pipes.
    let result: Result<()> = (|| {
        while let Some(step) = dec_rx.recv() {
            match step {
                Step::Shutdown => {
                    enc_tx
                        .send(Step::Shutdown)
                        .map_err(|_| DeferError::ChannelClosed("encode pipe"))?;
                    return Ok(());
                }
                Step::Frame { frame, batch, data } => {
                    let output = compute(data, batch as usize)?;
                    enc_tx
                        .send(Step::Frame {
                            frame,
                            batch,
                            data: output,
                        })
                        .map_err(|_| DeferError::ChannelClosed("encode pipe"))?;
                }
            }
        }
        Ok(())
    })();
    // Close our sender so the encoder drains and exits even when the
    // decode stage died mid-stream.
    drop(enc_tx);
    drop(dec_rx);

    match result {
        Ok(()) => {
            // Clean end (or upstream teardown): joining surfaces any
            // stage error with its original message.
            pool.join()?;
            Ok(())
        }
        Err(e) => {
            // A stage is possibly blocked on I/O that only unblocks at
            // teardown; do not wait for it. Prefer the stashed root
            // cause over our own pipe-closed symptom.
            pool.detach();
            let root = err_slot.lock().unwrap().take();
            Err(root.unwrap_or(e))
        }
    }
}

fn err_slot_store(slot: &Mutex<Option<DeferError>>, e: DeferError) {
    let mut s = slot.lock().unwrap();
    if s.is_none() {
        *s = Some(e);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Compression;
    use crate::coordinator::transport::Conn;
    use crate::serial::Serialization;
    use crate::threadpool::PipeSender;
    use crate::topology::wiring::DealSender;

    fn sink(conn: Conn) -> DealSender {
        DealSender::single(conn, "test sink")
    }

    fn ctx(name: &str, pipelined: bool) -> PipelineCtx {
        PipelineCtx {
            name: name.into(),
            codec: Codec::new(Serialization::Binary, Compression::None),
            rt: CodecRuntime::serial(),
            overhead: SharedTimer::new(),
            data_tx: ByteCounter::new(),
            frames: ByteCounter::new(),
            out_link: Arc::new(Link::ideal()),
            pipelined,
            pipe_depth: 4,
            payload_pool: None,
            recovery: None,
        }
    }

    fn feed_frames(tx: &PipeSender<Message>, codec: Codec, n: u64) {
        for frame in 0..n {
            let data = vec![frame as f32; 8];
            let (payload, mid) = codec.encode_f32s(&data, None);
            tx.send(Message {
                msg_type: MessageType::Data,
                frame,
                serialized_len: mid as u64,
                count: 8,
                batch: 1,
                payload,
            })
            .unwrap();
        }
        tx.send(Message::control(MessageType::Shutdown)).unwrap();
    }

    #[test]
    fn pipelined_preserves_fifo_order_and_values() {
        for pipelined in [false, true] {
            let (tx, rx) = pipe::<Message>(32);
            let (out_a, mut out_b) = Conn::local_pair(32);
            let c = ctx("t", pipelined);
            let codec = c.codec;
            let frames_counter = c.frames.clone();
            feed_frames(&tx, codec, 10);
            drop(tx);
            run_codec_pipeline(rx, sink(out_a), c, |v, _| {
                Ok(v.iter().map(|x| x * 2.0).collect())
            })
            .unwrap();
            let counter = ByteCounter::new();
            for f in 0..10u64 {
                let m = out_b.recv(&counter).unwrap();
                assert_eq!(m.frame, f, "pipelined={pipelined}");
                let vals = codec
                    .decode_f32s(&m.payload, m.serialized_len as usize, 8, None)
                    .unwrap();
                assert_eq!(vals, vec![f as f32 * 2.0; 8]);
            }
            assert_eq!(
                out_b.recv(&counter).unwrap().msg_type,
                MessageType::Shutdown
            );
            assert_eq!(frames_counter.total(), 10);
        }
    }

    #[test]
    fn compute_error_propagates() {
        for pipelined in [false, true] {
            let (tx, rx) = pipe::<Message>(32);
            let (out_a, _out_b) = Conn::local_pair(32);
            let c = ctx("t", pipelined);
            feed_frames(&tx, c.codec, 3);
            drop(tx);
            let err = run_codec_pipeline(rx, sink(out_a), c, |_, _| {
                Err(DeferError::Runtime("synthetic compute failure".into()))
            })
            .unwrap_err();
            assert!(
                format!("{err}").contains("synthetic compute failure"),
                "pipelined={pipelined}: {err}"
            );
        }
    }

    #[test]
    fn decode_error_names_root_cause() {
        for pipelined in [false, true] {
            let (tx, rx) = pipe::<Message>(8);
            let (out_a, _out_b) = Conn::local_pair(8);
            let c = ctx("t", pipelined);
            // A Data frame whose payload is not a valid Binary payload.
            tx.send(Message {
                msg_type: MessageType::Data,
                frame: 0,
                serialized_len: 3,
                count: 1,
                batch: 1,
                payload: vec![1, 2, 3],
            })
            .unwrap();
            drop(tx);
            let err = run_codec_pipeline(rx, sink(out_a), c, |v, _| Ok(v)).unwrap_err();
            assert!(
                format!("{err}").contains("ragged"),
                "pipelined={pipelined}: {err}"
            );
        }
    }

    #[test]
    fn unexpected_message_type_rejected() {
        let (tx, rx) = pipe::<Message>(8);
        let (out_a, _out_b) = Conn::local_pair(8);
        let c = ctx("stage7", true);
        tx.send(Message::control(MessageType::Ready)).unwrap();
        drop(tx);
        let err = run_codec_pipeline(rx, sink(out_a), c, |v, _| Ok(v)).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("stage7") && msg.contains("Ready"), "{msg}");
    }

    #[test]
    fn upstream_teardown_without_shutdown_ends_quietly() {
        for pipelined in [false, true] {
            let (tx, rx) = pipe::<Message>(8);
            let (out_a, _out_b) = Conn::local_pair(8);
            let c = ctx("t", pipelined);
            drop(tx); // reader died without sending anything
            run_codec_pipeline(rx, sink(out_a), c, |v, _| Ok(v)).unwrap();
        }
    }

    #[test]
    fn batched_frames_flow_whole_and_count_per_frame() {
        // A batch of 4 stacked frames must decode/compute/encode once,
        // leave as one message with the batch field intact, and advance
        // the completed-frame counter by the batch size.
        for pipelined in [false, true] {
            let (tx, rx) = pipe::<Message>(8);
            let (out_a, mut out_b) = Conn::local_pair(8);
            let c = ctx("t", pipelined);
            let codec = c.codec;
            let frames_counter = c.frames.clone();
            let data: Vec<f32> = (0..32).map(|i| i as f32).collect(); // 4 x 8
            let (payload, mid) = codec.encode_f32s(&data, None);
            tx.send(Message {
                msg_type: MessageType::Data,
                frame: 10,
                serialized_len: mid as u64,
                count: 32,
                batch: 4,
                payload,
            })
            .unwrap();
            tx.send(Message::control(MessageType::Shutdown)).unwrap();
            drop(tx);
            let mut seen_batch = 0usize;
            run_codec_pipeline(rx, sink(out_a), c, |v, b| {
                seen_batch = b;
                Ok(v.iter().map(|x| x + 1.0).collect())
            })
            .unwrap();
            assert_eq!(seen_batch, 4, "pipelined={pipelined}");
            assert_eq!(frames_counter.total(), 4);
            let counter = ByteCounter::new();
            let m = out_b.recv(&counter).unwrap();
            assert_eq!(m.frame, 10);
            assert_eq!(m.batch, 4);
            let vals = codec
                .decode_f32s(&m.payload, m.serialized_len as usize, 32, None)
                .unwrap();
            let expect: Vec<f32> = (0..32).map(|i| i as f32 + 1.0).collect();
            assert_eq!(vals, expect);
            assert_eq!(
                out_b.recv(&counter).unwrap().msg_type,
                MessageType::Shutdown
            );
        }
    }
}
