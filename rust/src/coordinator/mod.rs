//! L3 coordinator: DEFER's dispatcher + compute-node pipeline over a
//! declarative [`crate::topology::Topology`].
//!
//! Implements the paper's three phases:
//!
//! 1. **Model partitioning** happened at build time (Python `partitioner`);
//!    the artifacts are the *finest* partitioned model. Stage boundaries,
//!    however, are no longer pinned to the artifacts: the repartition
//!    planner ([`crate::repartition`]) may fuse contiguous runs of
//!    partitions into stages ([`crate::model::StageSpec`]) at plan time.
//! 2. **Configuration step** ([`dispatcher`]): the dispatcher opens two
//!    connections per worker replica — one for the serialized stage
//!    architecture (every fused partition's meta JSON + HLO text, one
//!    exchange) and one for the stage's concatenated weights array —
//!    and tells each worker its successor set in the topology.
//! 3. **Distributed inference step** ([`compute_node`]): workers relay
//!    intermediate activations in FIFO order, each running its stage's
//!    partition, so the deployment acts as a pipeline and throughput
//!    exceeds one device running the whole model. Replicated stages are
//!    fed round-robin with an order-preserving merge (see
//!    [`crate::topology::wiring`]), so results still arrive FIFO.
//!
//! [`chain::ChainRunner`] is a thin plan → wire → spawn → report driver:
//! it derives the topology from config (stage replication, per-hop
//! links), lets [`crate::topology::wiring`] establish every connection
//! (in-process pipes or real TCP loopback sockets with ephemeral ports,
//! both through the [`crate::netem`] link shaper), spawns one thread per
//! worker, and assembles the [`RunReport`]. [`baseline`] is the paper's
//! single-device comparison.

pub mod baseline;
pub mod chain;
pub mod compute_node;
pub mod dispatcher;
pub mod pipeline;
pub mod transport;

pub use transport::Conn;

use crate::energy::EnergyReport;
use std::time::Duration;

/// Everything a run produces — the inputs to every paper table/figure.
pub struct RunReport {
    pub model: String,
    pub profile: String,
    /// Pipeline stages (= partitions).
    pub nodes: usize,
    /// Worker replicas that served the run (== `nodes` unless stages are
    /// replicated; `node_energy` has one entry per worker, stage-major).
    pub workers: usize,
    /// Inference cycles completed.
    pub cycles: u64,
    /// Wall-clock duration of the inference phase.
    pub elapsed: Duration,
    /// Cycles per second (paper Fig. 2 / Table II).
    pub throughput: f64,
    /// End-to-end per-frame latency stats.
    pub latency_mean: Duration,
    pub latency_p50: Duration,
    pub latency_p99: Duration,
    /// Per-node energy for the inference phase (paper Fig. 3).
    pub node_energy: Vec<EnergyReport>,
    /// Dispatcher-side energy (serialization + tx).
    pub dispatcher_energy: EnergyReport,
    /// Bytes on the wire by traffic class (paper Table I "Network Payload").
    pub architecture_bytes: u64,
    pub weights_bytes: u64,
    pub data_bytes: u64,
    /// Time spent formatting data for the network (paper Table I "Overhead").
    pub config_overhead: Duration,
    pub data_overhead: Duration,
    /// Configuration-step wall time (model + weights distribution).
    pub config_time: Duration,
    /// Max |err| of the final frame vs the Python reference (None if the
    /// run never checked).
    pub reference_error: Option<f32>,
    /// High-water depth of the dispatcher's bounded encode→send queue —
    /// the observable backpressure signal (0 when the wire kept up, or
    /// for the single-device baseline which has no queue).
    pub queue_high_water: u64,
    /// Dedicated data-plane I/O threads the run spawned: the parked
    /// per-connection readers (workers + dispatcher) on the blocking
    /// plane, the reactor's shard threads otherwise. Legacy
    /// `--relay-junctions` threads are not included. 0 for the baseline.
    pub data_plane_threads: u64,
    /// Final `(wakeups, dispatches)` counters per reactor shard; empty
    /// on the blocking plane and for the baseline.
    pub io_shards: Vec<(u64, u64)>,
    /// Self-healing counters (all 0 when recovery is off or the run saw
    /// no faults): logical frames replayed after a replica death or an
    /// exhausted chunk-retry budget, corrupt chunks patched in place via
    /// NACK/retry, and replicas declared dead mid-run.
    pub frames_redispatched: u64,
    pub chunks_retried: u64,
    pub replicas_lost: u64,
    /// Zero-copy data-plane counters scoped to the inference phase:
    /// payload memcpys on the serialize/egress path (0 at steady state),
    /// wire-write syscalls retired, and buffer-pool hit/miss movement.
    /// All 0 for the single-device baseline (no data plane).
    pub zerocopy: crate::metrics::zerocopy::Snapshot,
}

impl RunReport {
    /// Mean per-node energy per inference cycle — the paper's Fig. 3 metric.
    pub fn energy_per_node_per_cycle(&self) -> f64 {
        if self.node_energy.is_empty() || self.cycles == 0 {
            return 0.0;
        }
        let total: f64 = self.node_energy.iter().map(EnergyReport::total).sum();
        total / self.node_energy.len() as f64 / self.cycles as f64
    }

    pub fn total_payload_bytes(&self) -> u64 {
        self.architecture_bytes + self.weights_bytes + self.data_bytes
    }
}
