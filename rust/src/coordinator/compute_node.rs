//! Compute node runtime — the paper's Algorithm 2, generalized to fused
//! stages.
//!
//! A node is one worker replica of a topology stage (its
//! [`StageView`](crate::topology::StageView) says which); sole replicas
//! of single-partition stages behave exactly like the paper's chain
//! nodes. It first serves the configuration step: one connection carries
//! the serialized stage architecture — *every* partition of the fused
//! run, metas + HLO texts, in one exchange — and another the stage's
//! concatenated weights array. The node instantiates one executable per
//! fused partition, then acknowledges `Ready`.
//!
//! The inference loop then runs as two threads connected by a bounded pipe
//! (the paper's THREAD-1 / THREAD-2 "to avoid inference bottleneck"):
//! the reader thread pulls framed activations off the incoming
//! connection set and pipes them to the compute thread, which
//! deserializes + decompresses, runs the fused partitions back to back
//! in process memory (inner boundaries never touch a codec or the
//! network), re-serializes + compresses the final output, and deals to
//! the next hop. FIFO order is preserved end to end.
//!
//! The node **owns its boundary fan**: `data_in` is a
//! [`MergeReceiver`](crate::topology::wiring::MergeReceiver) holding one
//! FIFO connection per predecessor replica (restoring global frame
//! order by schedule, no relay thread), and the pipeline's egress is a
//! [`DealSender`](crate::topology::wiring::DealSender) rotating over the
//! successor replicas. Unreplicated neighbours degrade both to plain
//! single connections — the paper's chain node exactly.
//!
//! Under the reactor data plane ([`ComputeOptions::reactor`]) the reader
//! thread is subsumed by a readiness-driven ingress machine on a shared
//! I/O shard, and the egress deal retires through a queued sink on the
//! same reactor — the pipe, the schedules, and the byte accounting are
//! unchanged, so both planes emit identical wire traffic.

use std::sync::Arc;

use crate::config::CodecConfig;
use crate::coordinator::pipeline::{run_codec_pipeline, PipelineCtx, PipelineRecovery};
use crate::energy::{EnergyMeter, EnergyModel};
use crate::error::{DeferError, Result};
use crate::metrics::ByteCounter;
use crate::model::{PartitionSpec, StageSpec};
use crate::netem::Link;
use crate::netio::Reactor;
use crate::runtime::{Engine, Executable};
use crate::serial::{json, CodecRuntime};
use crate::tensor::Tensor;
use crate::threadpool::{pipe, WorkerPool};
use crate::topology::wiring::{FrameSink, WorkerConns};
use crate::util::bufpool::BufPool;
use crate::wire::{Message, MessageType};

/// Encode a fused stage's architecture payload:
/// `[count u32le]` then, per partition,
/// `[meta_len u32le][meta json][hlo_len u32le][hlo text]`.
/// `specs` and `hlos` pair up index-wise; every meta carries the same
/// `next_hop` (the stage's successor set).
pub fn encode_stage_architecture(
    specs: &[PartitionSpec],
    hlos: &[&str],
    next_hop: &str,
) -> Vec<u8> {
    assert_eq!(specs.len(), hlos.len(), "one HLO text per partition");
    let mut out = Vec::new();
    out.extend_from_slice(&(specs.len() as u32).to_le_bytes());
    for (spec, hlo) in specs.iter().zip(hlos) {
        let meta = json::to_string(&spec.to_config_json(next_hop));
        out.extend_from_slice(&(meta.len() as u32).to_le_bytes());
        out.extend_from_slice(meta.as_bytes());
        out.extend_from_slice(&(hlo.len() as u32).to_le_bytes());
        out.extend_from_slice(hlo.as_bytes());
    }
    out
}

/// Single-partition convenience over [`encode_stage_architecture`] (the
/// unfused chain case, and the substrate benches/tests).
pub fn encode_architecture(spec: &PartitionSpec, next_hop: &str, hlo: &str) -> Vec<u8> {
    encode_stage_architecture(std::slice::from_ref(spec), &[hlo], next_hop)
}

fn read_u32(payload: &[u8], off: &mut usize, what: &str) -> Result<usize> {
    if payload.len() < *off + 4 {
        return Err(DeferError::Coordinator(format!(
            "architecture payload truncated in {what}"
        )));
    }
    let v = u32::from_le_bytes(payload[*off..*off + 4].try_into().unwrap()) as usize;
    *off += 4;
    Ok(v)
}

fn read_str<'a>(payload: &'a [u8], off: &mut usize, len: usize, what: &str) -> Result<&'a str> {
    if payload.len() < *off + len {
        return Err(DeferError::Coordinator(format!(
            "architecture payload truncated in {what}"
        )));
    }
    let s = std::str::from_utf8(&payload[*off..*off + len])
        .map_err(|e| DeferError::Coordinator(format!("{what} not utf8: {e}")))?;
    *off += len;
    Ok(s)
}

/// Decode a fused stage's architecture payload into per-partition
/// `(spec, hlo_text)` pairs (fusion order) and the stage's next hop.
pub fn decode_stage_architecture(payload: &[u8]) -> Result<(Vec<(PartitionSpec, String)>, String)> {
    let mut off = 0usize;
    let count = read_u32(payload, &mut off, "partition count")?;
    // Each partition needs at least its two length prefixes; this bounds
    // `count` before any allocation so garbage input fails cleanly.
    if count == 0 || count > payload.len() / 8 {
        return Err(DeferError::Coordinator(format!(
            "architecture payload corrupt: {count} partition(s) in {} bytes",
            payload.len()
        )));
    }
    let mut parts = Vec::with_capacity(count);
    let mut next_hop = String::new();
    for i in 0..count {
        let meta_len = read_u32(payload, &mut off, "meta length")?;
        let meta_text = read_str(payload, &mut off, meta_len, "meta")?;
        let (spec, next) = PartitionSpec::from_config_json(&json::parse(meta_text)?)?;
        let hlo_len = read_u32(payload, &mut off, "hlo length")?;
        let hlo = read_str(payload, &mut off, hlo_len, "hlo")?.to_string();
        if i == 0 {
            next_hop = next;
        }
        parts.push((spec, hlo));
    }
    if off != payload.len() {
        return Err(DeferError::Coordinator(format!(
            "architecture payload has {} trailing bytes",
            payload.len() - off
        )));
    }
    Ok((parts, next_hop))
}

/// Decode a payload that must hold exactly one partition (the unfused
/// case). Returns (spec, next_hop, hlo_text).
pub fn decode_architecture(payload: &[u8]) -> Result<(PartitionSpec, String, String)> {
    let (mut parts, next) = decode_stage_architecture(payload)?;
    if parts.len() != 1 {
        return Err(DeferError::Coordinator(format!(
            "expected a single-partition architecture payload, got {} partitions",
            parts.len()
        )));
    }
    let (spec, hlo) = parts.remove(0);
    Ok((spec, next, hlo))
}

/// Split a flat weights vector into per-manifest arrays.
pub fn split_weights(spec: &PartitionSpec, flat: Vec<f32>) -> Result<Vec<Vec<f32>>> {
    let expected: usize = spec.weights.iter().map(|w| w.elements).sum();
    if flat.len() != expected {
        return Err(DeferError::Coordinator(format!(
            "weights vector has {} elements, manifest wants {expected}",
            flat.len()
        )));
    }
    let mut out = Vec::with_capacity(spec.weights.len());
    let mut off = 0;
    for w in &spec.weights {
        out.push(flat[off..off + w.elements].to_vec());
        off += w.elements;
    }
    Ok(out)
}

/// Per-node instrumentation shared with the chain runner.
pub struct NodeStats {
    pub meter: EnergyMeter,
    /// Bytes this node pushed onto its outgoing data socket.
    pub data_tx: ByteCounter,
    pub frames: ByteCounter,
}

impl NodeStats {
    pub fn new(model: EnergyModel) -> Self {
        NodeStats {
            meter: EnergyMeter::new(model),
            data_tx: ByteCounter::new(),
            frames: ByteCounter::new(),
        }
    }
}

/// Runtime knobs for one compute node (shared by every replica).
#[derive(Clone)]
pub struct ComputeOptions {
    /// Reader → compute pipe depth (backpressure window).
    pub pipe_depth: usize,
    /// Legacy multiplicative device-speed emulation (>= 1.0).
    pub compute_slowdown: f64,
    /// Deterministic device-speed emulation in MFLOPS (0 = off).
    pub emulated_mflops: f64,
    /// Shared codec runtime (chunking + worker pool) — used by the data
    /// path and the config-phase weights exchange alike.
    pub codec_rt: CodecRuntime,
    /// Software-pipeline the codec phases (decode | compute | encode on
    /// separate threads); `false` = the paper's inline loop.
    pub pipelined: bool,
    /// Shared reactor data plane. When set, the node's boundary I/O runs
    /// as readiness-driven state machines on the reactor's shards
    /// instead of a parked reader thread plus blocking deal writes.
    /// `None` = the blocking plane (`--blocking-io`).
    pub reactor: Option<Arc<Reactor>>,
}

impl Default for ComputeOptions {
    fn default() -> Self {
        ComputeOptions {
            pipe_depth: 4,
            compute_slowdown: 1.0,
            emulated_mflops: 0.0,
            codec_rt: CodecRuntime::serial(),
            pipelined: true,
            reactor: None,
        }
    }
}

/// Run one compute node to completion (configuration + inference phases).
///
/// `conns` bundles the worker's topology view with its four established
/// connections: config (receives `ModelConfig`, replies `Ready`),
/// weights (receives `Weights`), and the data in/out path. The
/// architecture payload may fuse several partitions; the node builds one
/// executable per partition and runs them back to back per frame, so a
/// fused stage costs one configuration exchange and zero network traffic
/// at its inner boundaries.
pub fn run_compute_node(
    engine: Engine,
    conns: WorkerConns,
    codecs: CodecConfig,
    out_link: Arc<Link>,
    stats: Arc<NodeStats>,
    opts: ComputeOptions,
) -> Result<()> {
    let WorkerConns {
        view,
        config: mut config_conn,
        weights: mut weights_conn,
        data_in: in_conn,
        data_out: out_conn,
    } = conns;
    // ---------------- configuration step ----------------
    let rx_counter = ByteCounter::new(); // inbound bytes are counted by the sender side
    let cfg_msg = config_conn.recv(&rx_counter)?;
    if cfg_msg.msg_type != MessageType::ModelConfig {
        return Err(DeferError::Coordinator(format!(
            "{}: expected ModelConfig, got {:?}",
            view.name, cfg_msg.msg_type
        )));
    }
    let raw = codecs.architecture.compression.decompress(
        &cfg_msg.payload,
        cfg_msg.serialized_len as usize,
    )?;
    let (fused, _next) = decode_stage_architecture(&raw)?;
    let (specs, hlos): (Vec<PartitionSpec>, Vec<String>) = fused.into_iter().unzip();
    // Re-validate the fused run on the receiving side: contiguous
    // indices, chained boundary shapes, one artifact set.
    let stage = StageSpec::fuse(specs)?;

    let w_msg = weights_conn.recv(&rx_counter)?;
    if w_msg.msg_type != MessageType::Weights {
        return Err(DeferError::Coordinator(format!(
            "{}: expected Weights, got {:?}",
            view.name, w_msg.msg_type
        )));
    }
    // The weights exchange rides the same chunk-parallel codec runtime
    // as the data path (the dispatcher encodes with the identical
    // runtime), so large fused-stage weight blobs no longer serialize
    // on the legacy inline path.
    let flat = codecs.weights.decode_frame(
        &w_msg.payload,
        w_msg.serialized_len as usize,
        w_msg.count as usize,
        &opts.codec_rt,
        Some(&stats.meter.codec),
    )?;
    // The stage's weights arrive as one concatenated array, partition
    // order then manifest order — exactly `StageSpec::weight_manifest`.
    if flat.len() != stage.weight_elements() {
        return Err(DeferError::Coordinator(format!(
            "weights vector has {} elements, stage manifest wants {}",
            flat.len(),
            stage.weight_elements()
        )));
    }
    let mut exes = Vec::with_capacity(stage.num_parts());
    let mut off = 0usize;
    for (spec, hlo) in stage.parts.iter().zip(&hlos) {
        let elems: usize = spec.weights.iter().map(|w| w.elements).sum();
        let weight_arrays = split_weights(spec, flat[off..off + elems].to_vec())?;
        off += elems;
        exes.push(Executable::from_parts(&engine, hlo, spec, weight_arrays)?);
    }
    // The executables' timers *are* the node's compute-energy clock.
    let compute_timers: Vec<_> = exes.iter().map(|e| e.exec_timer.clone()).collect();
    let stats_for_energy = Arc::clone(&stats);

    config_conn.send(
        &Message::control(MessageType::Ready),
        &Link::ideal(),
        &ByteCounter::new(),
    )?;
    drop(config_conn);
    drop(weights_conn);

    // ---------------- distributed inference step ----------------
    // THREAD-1: boundary reader -> pipe. The merge receiver restores
    // global FIFO order across the predecessor replicas by schedule;
    // the codec pipeline (`run_codec_pipeline`) then runs
    // decode | compute | encode either inline on this thread (the
    // paper's loop) or software-pipelined on three threads so frame k+1
    // decodes while frame k computes and frame k-1 encodes/transmits,
    // with the encode phase dealing to the successor replicas.
    let (tx, rx) = pipe::<Message>(opts.pipe_depth);
    let payload_pool = Arc::new(BufPool::new(opts.pipe_depth + 2));
    let mut pool = WorkerPool::new();
    let reader_pool = Arc::clone(&payload_pool);
    // Self-healing hooks travel with the wiring: the merge receiver
    // carries the run supervisor and this replica's chunk-retry client
    // when recovery is enabled (see `topology::wiring::enable_recovery`).
    let recovery = in_conn.recovery_handle().map(|supervisor| PipelineRecovery {
        supervisor,
        client: in_conn.chunk_client(),
    });
    let mut ingress_err = None;
    let out: FrameSink = if let Some(reactor) = &opts.reactor {
        // Reactor plane: the shard-owned ingress machine replaces the
        // parked reader thread (same merge schedule, same pipe, same
        // buffer pool), and the egress deal becomes a queued sink whose
        // writes retire on readiness. Serialization, link shaping and
        // byte accounting stay on the compute thread inside the sink.
        ingress_err = Some(reactor.register_ingress(in_conn, tx, Some(reader_pool))?);
        reactor.register_egress(out_conn, opts.pipe_depth)?.into()
    } else {
        let mut in_conn = in_conn;
        let reader_recovery = recovery.as_ref().map(|r| Arc::clone(&r.supervisor));
        let reader_name = view.name.clone();
        pool.spawn(&format!("{}-reader", view.name), move || loop {
            let msg = in_conn.recv_pooled(&ByteCounter::new(), Some(&reader_pool))?;
            // Injected kill: the node dies the moment it *observes* the
            // scheduled frame — the reader returns, dropping the ingress
            // conns and the pipe, so peers see EOF exactly as they would
            // for a crashed process.
            if let Some(sup) = &reader_recovery {
                if let Some(k) = sup.faults().kill_frame(&reader_name) {
                    if msg.msg_type == MessageType::Data && msg.frame + u64::from(msg.batch) > k
                    {
                        return Err(DeferError::FaultInjected(format!(
                            "{reader_name} killed at frame {k}"
                        )));
                    }
                }
            }
            let stop = msg.msg_type == MessageType::Shutdown;
            tx.send(msg)
                .map_err(|_| DeferError::ChannelClosed("node reader pipe"))?;
            if stop {
                return Ok(());
            }
        });
        out_conn.into()
    };

    let in_shape = stage.input_shape().to_vec();
    // Deterministic device emulation: floor each frame's compute to the
    // emulated device's FLOP time for the *whole fused run* (constant of
    // the plan, immune to host contention). Tracks total emulated busy
    // time for the energy model.
    let flops_floor = if opts.emulated_mflops > 0.0 {
        Some(std::time::Duration::from_secs_f64(
            stage.flops() as f64 / (opts.emulated_mflops * 1e6),
        ))
    } else {
        None
    };
    let mut emulated_busy = std::time::Duration::ZERO;
    let ctx = PipelineCtx {
        name: view.name.clone(),
        codec: codecs.data,
        rt: opts.codec_rt.clone().with_buffers(Arc::clone(&payload_pool)),
        overhead: stats.meter.codec.clone(),
        data_tx: stats.data_tx.clone(),
        frames: stats.frames.clone(),
        out_link: Arc::clone(&out_link),
        pipelined: opts.pipelined,
        pipe_depth: opts.pipe_depth,
        payload_pool: Some(Arc::clone(&payload_pool)),
        recovery,
    };
    let per_frame_elems: usize = in_shape.iter().product();
    let node_name = view.name.clone();
    let result: Result<()> = run_codec_pipeline(rx, out, ctx, |values, batch| {
        let t_run = std::time::Instant::now();
        let b = batch.max(1);
        if values.len() != per_frame_elems * b {
            return Err(DeferError::Coordinator(format!(
                "{node_name}: batch of {b} frame(s) carries {} values, \
                 expected {} ({} per frame)",
                values.len(),
                per_frame_elems * b,
                per_frame_elems
            )));
        }
        // Fused partitions run back to back; inner activations stay in
        // process memory, no codec, no link. A batched message splits
        // into its member frames here — the executables' shapes are
        // per-frame — and the outputs re-stack in order.
        let output = if b == 1 {
            let mut cur = Tensor::new(in_shape.clone(), values)?;
            for exe in &exes {
                cur = exe.run(&cur)?;
            }
            cur.into_parts().1
        } else {
            let mut out = Vec::with_capacity(values.len());
            for sub in values.chunks(per_frame_elems) {
                let mut cur = Tensor::new(in_shape.clone(), sub.to_vec())?;
                for exe in &exes {
                    cur = exe.run(&cur)?;
                }
                out.extend_from_slice(&cur.into_parts().1);
            }
            out
        };
        if let Some(floor) = flops_floor {
            // The emulated device runs every member frame: the floor
            // scales with the batch.
            let floor = floor.mul_f64(b as f64);
            let elapsed = t_run.elapsed();
            if elapsed < floor {
                std::thread::sleep(floor - elapsed);
            }
            emulated_busy += elapsed.max(floor);
        } else if opts.compute_slowdown > 1.0 {
            // Legacy multiplicative emulation (noise-amplifying;
            // prefer emulated_mflops).
            std::thread::sleep(t_run.elapsed().mul_f64(opts.compute_slowdown - 1.0));
        }
        Ok(output)
    });

    // Fold the on-device time into the node energy meter, under whichever
    // device-speed emulation is active (the emulated device is busy for
    // the stretched duration).
    if flops_floor.is_some() {
        stats_for_energy.meter.compute.add(emulated_busy);
    } else {
        let measured: std::time::Duration =
            compute_timers.iter().map(|t| t.total()).sum();
        stats_for_energy
            .meter
            .compute
            .add(measured.mul_f64(opts.compute_slowdown));
    }
    // Outgoing bytes drive network energy.
    stats_for_energy.meter.tx_bytes.add(stats.data_tx.total());

    // On the reactor plane, ingress failures land in the error slot (the
    // machine closes the pipe, which the pipeline sees as a generic
    // closed-channel error); prefer the labelled root cause.
    let take_ingress_err = |slot: &Option<crate::netio::ErrSlot>| {
        slot.as_ref().and_then(|s| s.lock().unwrap().take())
    };
    if let Err(e) = &result {
        // Do not wait for the reader: it may be blocked on the incoming
        // socket, which only closes when the peer tears down. Detach it —
        // it exits when its connection drops — and surface the real error.
        pool.detach();
        // A *scheduled* death is not a failure of the run: the replica
        // simply disappears (its conns drop on return) and the supervisor
        // re-dispatches whatever it still owed to the survivors.
        if e.is_fault_injection() {
            return Ok(());
        }
        if let Some(e) = take_ingress_err(&ingress_err) {
            return Err(e);
        }
        return result;
    }
    match pool.join() {
        Ok(()) => {}
        // Blocking-plane injected kill surfaces from the reader thread.
        Err(e) if e.is_fault_injection() => return Ok(()),
        Err(e) => return Err(e),
    }
    if let Some(e) = take_ingress_err(&ingress_err) {
        return Err(e);
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_spec() -> PartitionSpec {
        PartitionSpec {
            model: "m".into(),
            profile: "tiny".into(),
            part_index: 1,
            part_count: 4,
            input_shape: vec![1, 8],
            output_shape: vec![1, 4],
            flops: 64,
            layers: vec!["dense1".into()],
            weights: vec![
                crate::model::WeightSpec {
                    node: "dense1".into(),
                    param: "w".into(),
                    shape: vec![8, 4],
                    elements: 32,
                },
                crate::model::WeightSpec {
                    node: "dense1".into(),
                    param: "b".into(),
                    shape: vec![4],
                    elements: 4,
                },
            ],
            weights_bytes: 36 * 4,
            hlo_path: std::path::PathBuf::new(),
            weights_path: std::path::PathBuf::new(),
        }
    }

    /// The partition downstream of `fake_spec` (boundary-chained).
    fn fake_spec_next() -> PartitionSpec {
        PartitionSpec {
            model: "m".into(),
            profile: "tiny".into(),
            part_index: 2,
            part_count: 4,
            input_shape: vec![1, 4],
            output_shape: vec![1, 2],
            flops: 16,
            layers: vec!["dense2".into()],
            weights: vec![crate::model::WeightSpec {
                node: "dense2".into(),
                param: "w".into(),
                shape: vec![4, 2],
                elements: 8,
            }],
            weights_bytes: 8 * 4,
            hlo_path: std::path::PathBuf::new(),
            weights_path: std::path::PathBuf::new(),
        }
    }

    #[test]
    fn architecture_payload_round_trip() {
        let spec = fake_spec();
        let hlo = "HloModule fake\nENTRY main { ... }";
        let payload = encode_architecture(&spec, "127.0.0.1:9999", hlo);
        let (spec2, next, hlo2) = decode_architecture(&payload).unwrap();
        assert_eq!(spec2.model, spec.model);
        assert_eq!(spec2.part_index, 1);
        assert_eq!(spec2.weights.len(), 2);
        assert_eq!(spec2.input_shape, vec![1, 8]);
        assert_eq!(next, "127.0.0.1:9999");
        assert_eq!(hlo2, hlo);
    }

    #[test]
    fn fused_architecture_payload_round_trip() {
        let a = fake_spec();
        let b = fake_spec_next();
        let payload = encode_stage_architecture(
            &[a.clone(), b.clone()],
            &["HLO A", "HLO B"],
            "node2",
        );
        let (parts, next) = decode_stage_architecture(&payload).unwrap();
        assert_eq!(next, "node2");
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].0.part_index, 1);
        assert_eq!(parts[0].1, "HLO A");
        assert_eq!(parts[1].0.part_index, 2);
        assert_eq!(parts[1].1, "HLO B");
        // The decoded run fuses: chained shapes, contiguous indices.
        let stage =
            StageSpec::fuse(parts.into_iter().map(|(s, _)| s).collect()).unwrap();
        assert_eq!(stage.flops(), a.flops + b.flops);
        assert_eq!(stage.input_shape(), &[1, 8]);
        assert_eq!(stage.output_shape(), &[1, 2]);
        // A fused payload is not a legal single-partition payload.
        assert!(decode_architecture(&payload).is_err());
    }

    #[test]
    fn architecture_payload_corrupt_rejected() {
        assert!(decode_architecture(&[1, 2]).is_err());
        let spec = fake_spec();
        let payload = encode_architecture(&spec, "next", "HLO");
        // Truncate inside the JSON.
        assert!(decode_architecture(&payload[..10]).is_err());
        // Trailing garbage is rejected too.
        let mut noisy = payload.clone();
        noisy.push(0);
        assert!(decode_architecture(&noisy).is_err());
    }

    #[test]
    fn split_weights_checks_totals() {
        let spec = fake_spec();
        let flat: Vec<f32> = (0..36).map(|i| i as f32).collect();
        let parts = split_weights(&spec, flat).unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].len(), 32);
        assert_eq!(parts[1], vec![32.0, 33.0, 34.0, 35.0]);
        assert!(split_weights(&spec, vec![0.0; 35]).is_err());
    }
}
