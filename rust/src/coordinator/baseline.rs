//! Single-device inference baseline — the paper's comparison point.
//!
//! The whole model (the 1-partition artifact) runs on one node; no sockets,
//! no serialization, no network energy. Fig. 2 plots its throughput as the
//! dashed line, Fig. 3 its per-cycle energy.

use std::time::Instant;

use crate::config::DeferConfig;
use crate::coordinator::RunReport;
use crate::energy::{EnergyMeter, EnergyReport};
use crate::error::Result;
use crate::model::{PartitionPlan, ReferenceVectors};
use crate::runtime::{Engine, Executable};
use crate::tensor::Tensor;

/// Single-device runner.
pub struct SingleDevice {
    cfg: DeferConfig,
    exe: Executable,
    reference: Option<ReferenceVectors>,
    /// Whole-model FLOPs (drives the emulated-device compute floor).
    flops: u64,
}

impl SingleDevice {
    pub fn new(cfg: DeferConfig) -> Result<Self> {
        let engine = Engine::cpu()?;
        Self::with_engine(cfg, engine)
    }

    pub fn with_engine(cfg: DeferConfig, engine: Engine) -> Result<Self> {
        let mut cfg = cfg;
        cfg.nodes = 1;
        cfg.validate()?;
        let plan = PartitionPlan::load(&cfg.artifacts_dir, &cfg.profile, &cfg.model, 1)?;
        let exe = Executable::load(&engine, &plan.parts[0])?;
        let reference =
            ReferenceVectors::load(&cfg.artifacts_dir, &cfg.profile, &cfg.model).ok();
        let flops = plan.total_flops();
        Ok(SingleDevice {
            cfg,
            exe,
            reference,
            flops,
        })
    }

    /// Run `frames` sequential inference cycles.
    pub fn run_frames(&self, frames: u64) -> Result<RunReport> {
        let meter = EnergyMeter::new(self.cfg.energy);
        let input = match &self.reference {
            Some(r) => r.input.clone(),
            None => Tensor::random(self.exe.input_shape().to_vec(), 7),
        };
        let latency = crate::metrics::Histogram::new();
        self.exe.exec_timer.reset();
        // Same device-speed emulation as the chain nodes (see compute_node).
        let flops_floor = if self.cfg.emulated_mflops > 0.0 {
            Some(std::time::Duration::from_secs_f64(
                self.flops as f64 / (self.cfg.emulated_mflops * 1e6),
            ))
        } else {
            None
        };
        let mut emulated_busy = std::time::Duration::ZERO;
        let t0 = Instant::now();
        let mut reference_error: Option<f32> = None;
        for _ in 0..frames {
            let f0 = Instant::now();
            let out = self.exe.run(&input)?;
            if let Some(floor) = flops_floor {
                let elapsed = f0.elapsed();
                if elapsed < floor {
                    std::thread::sleep(floor - elapsed);
                }
                emulated_busy += elapsed.max(floor);
            } else if self.cfg.compute_slowdown > 1.0 {
                std::thread::sleep(f0.elapsed().mul_f64(self.cfg.compute_slowdown - 1.0));
            }
            latency.record(f0.elapsed());
            if let Some(r) = &self.reference {
                let err = out.max_abs_diff(&r.output)?;
                reference_error = Some(reference_error.unwrap_or(0.0).max(err));
            }
        }
        let elapsed = t0.elapsed();
        if flops_floor.is_some() {
            meter.compute.add(emulated_busy);
        } else {
            meter
                .compute
                .add(self.exe.exec_timer.total().mul_f64(self.cfg.compute_slowdown));
        }
        Ok(RunReport {
            model: self.cfg.model.clone(),
            profile: self.cfg.profile.clone(),
            nodes: 1,
            workers: 1,
            cycles: frames,
            elapsed,
            throughput: frames as f64 / elapsed.as_secs_f64(),
            latency_mean: latency.mean(),
            latency_p50: latency.quantile(0.5),
            latency_p99: latency.quantile(0.99),
            node_energy: vec![meter.report()],
            dispatcher_energy: EnergyReport::default(),
            architecture_bytes: 0,
            weights_bytes: 0,
            data_bytes: 0,
            config_overhead: std::time::Duration::ZERO,
            data_overhead: std::time::Duration::ZERO,
            config_time: self.exe.compile_time(),
            reference_error,
            queue_high_water: 0,
            data_plane_threads: 0,
            io_shards: Vec::new(),
            frames_redispatched: 0,
            chunks_retried: 0,
            replicas_lost: 0,
            zerocopy: crate::metrics::zerocopy::Snapshot::default(),
        })
    }
}
