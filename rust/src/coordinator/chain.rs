//! Chain orchestration: build the dispatcher + N compute nodes topology,
//! run the configuration step, pump frames, and collect a [`RunReport`].
//!
//! Two transports, selected by `DeferConfig::tcp`:
//! * **in-process** — every hop is a bounded byte pipe (default; fastest to
//!   stand up, identical wire accounting);
//! * **TCP loopback** — every hop is a real kernel socket, one listener per
//!   node, matching the paper's CORE deployment on a single host.
//!
//! Either way each compute node runs on its own thread (its own "device"),
//! links run through the [`crate::netem`] shaper, and all traffic passes
//! the same framing/codec stack.

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

use crate::config::DeferConfig;
use crate::coordinator::compute_node::{run_compute_node, NodeStats};
use crate::coordinator::dispatcher::{configure_nodes, run_inference, DispatcherStats};
use crate::coordinator::transport::Conn;
use crate::coordinator::RunReport;
use crate::error::{DeferError, Result};
use crate::metrics::ByteCounter;
use crate::model::{PartitionPlan, ReferenceVectors};
use crate::netem::Link;
use crate::runtime::Engine;
use crate::tensor::Tensor;
use crate::threadpool::WorkerPool;

/// A ready-to-run DEFER deployment.
pub struct ChainRunner {
    pub cfg: DeferConfig,
    engine: Engine,
    plan: PartitionPlan,
    reference: Option<ReferenceVectors>,
}

impl ChainRunner {
    /// Load artifacts and prepare the runner. Fails early with a helpful
    /// message if `make artifacts` has not produced this configuration.
    pub fn new(cfg: DeferConfig) -> Result<Self> {
        cfg.validate()?;
        let engine = Engine::cpu()?;
        let plan = PartitionPlan::load(&cfg.artifacts_dir, &cfg.profile, &cfg.model, cfg.nodes)?;
        let reference =
            ReferenceVectors::load(&cfg.artifacts_dir, &cfg.profile, &cfg.model).ok();
        Ok(ChainRunner {
            cfg,
            engine,
            plan,
            reference,
        })
    }

    /// Reuse an existing engine (avoids re-initializing PJRT across sweeps).
    pub fn with_engine(cfg: DeferConfig, engine: Engine) -> Result<Self> {
        cfg.validate()?;
        let plan = PartitionPlan::load(&cfg.artifacts_dir, &cfg.profile, &cfg.model, cfg.nodes)?;
        let reference =
            ReferenceVectors::load(&cfg.artifacts_dir, &cfg.profile, &cfg.model).ok();
        Ok(ChainRunner {
            cfg,
            engine,
            plan,
            reference,
        })
    }

    pub fn plan(&self) -> &PartitionPlan {
        &self.plan
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Run `frames` inference cycles through the chain; returns the report.
    pub fn run_frames(&self, frames: u64) -> Result<RunReport> {
        let n = self.cfg.nodes;
        let link = Arc::new(Link::new(self.cfg.link));
        let dstats = Arc::new(DispatcherStats::new(self.cfg.energy));
        let node_stats: Vec<Arc<NodeStats>> = (0..n)
            .map(|_| Arc::new(NodeStats::new(self.cfg.energy)))
            .collect();

        // ---- build topology ----
        let mut node_conns: Vec<(Conn, Conn, Conn, Conn)> = Vec::with_capacity(n);
        let mut dispatcher_side: Vec<(Conn, Conn)> = Vec::with_capacity(n);
        let (to_first, from_last);

        if self.cfg.tcp {
            // One listener per node for (config, weights, data-in) plus a
            // dispatcher listener for the chain's return link.
            let base = self.cfg.base_port;
            let mut listeners = Vec::with_capacity(n * 3);
            for i in 0..n {
                for k in 0..3u16 {
                    let port = base + (i as u16) * 3 + k;
                    listeners.push(
                        TcpListener::bind(("127.0.0.1", port)).map_err(|e| {
                            DeferError::Coordinator(format!("bind 127.0.0.1:{port}: {e}"))
                        })?,
                    );
                }
            }
            let ret_port = base + (n as u16) * 3;
            let ret_listener = TcpListener::bind(("127.0.0.1", ret_port))
                .map_err(|e| DeferError::Coordinator(format!("bind :{ret_port}: {e}")))?;

            // Dispatcher connects out; each node accepts its three inbound
            // connections on its own thread later. To avoid accept/connect
            // deadlock we spawn acceptor threads per node now.
            let mut acceptors = Vec::with_capacity(n);
            for i in 0..n {
                let cfg_l = listeners[i * 3].try_clone()?;
                let w_l = listeners[i * 3 + 1].try_clone()?;
                let d_l = listeners[i * 3 + 2].try_clone()?;
                acceptors.push(std::thread::spawn(move || -> Result<(Conn, Conn, Conn)> {
                    Ok((
                        Conn::tcp_accept(&cfg_l)?,
                        Conn::tcp_accept(&w_l)?,
                        Conn::tcp_accept(&d_l)?,
                    ))
                }));
            }
            // Dispatcher-side connections.
            for i in 0..n {
                let cfg_c = Conn::tcp_connect(&format!("127.0.0.1:{}", base + (i as u16) * 3))?;
                let w_c = Conn::tcp_connect(&format!("127.0.0.1:{}", base + (i as u16) * 3 + 1))?;
                dispatcher_side.push((cfg_c, w_c));
            }
            to_first = Conn::tcp_connect(&format!("127.0.0.1:{}", base + 2))?;
            // Walk the chain in order: node i's acceptor can only finish
            // once its data-in peer (dispatcher or node i-1) has connected,
            // so join acceptor i, THEN dial node i's outbound link — which
            // unblocks acceptor i+1.
            for (i, a) in acceptors.into_iter().enumerate() {
                let (cfg_c, w_c, d_in) = a.join().map_err(|_| {
                    DeferError::Coordinator("acceptor thread panicked".into())
                })??;
                let out = if i + 1 < n {
                    Conn::tcp_connect(&format!("127.0.0.1:{}", base + ((i + 1) as u16) * 3 + 2))?
                } else {
                    Conn::tcp_connect(&format!("127.0.0.1:{ret_port}"))?
                };
                node_conns.push((cfg_c, w_c, d_in, out));
            }
            from_last = Conn::tcp_accept(&ret_listener)?;
        } else {
            let depth = self.cfg.pipe_depth;
            let mut data_in: Vec<Conn> = Vec::with_capacity(n);
            let (tf, first_in) = Conn::local_pair(depth);
            to_first = tf;
            data_in.push(first_in);
            let mut outs: Vec<Option<Conn>> = (0..n).map(|_| None).collect();
            for i in 0..n - 1 {
                let (out, inn) = Conn::local_pair(depth);
                outs[i] = Some(out);
                data_in.push(inn);
            }
            let (last_out, fl) = Conn::local_pair(depth);
            outs[n - 1] = Some(last_out);
            from_last = fl;
            for (i, d_in) in data_in.into_iter().enumerate() {
                let (cfg_d, cfg_n) = Conn::local_pair(2);
                let (w_d, w_n) = Conn::local_pair(2);
                dispatcher_side.push((cfg_d, w_d));
                node_conns.push((cfg_n, w_n, d_in, outs[i].take().unwrap()));
            }
        }

        // ---- spawn compute nodes ----
        let mut pool = WorkerPool::new();
        for (i, (cfg_c, w_c, d_in, d_out)) in node_conns.into_iter().enumerate() {
            let engine = self.engine.clone();
            let codecs = self.cfg.codecs;
            let link = Arc::clone(&link);
            let stats = Arc::clone(&node_stats[i]);
            let depth = self.cfg.pipe_depth;
            let slowdown = self.cfg.compute_slowdown;
            let mflops = self.cfg.emulated_mflops;
            pool.spawn(&format!("compute-node-{i}"), move || {
                run_compute_node(
                    i, engine, cfg_c, w_c, d_in, d_out, codecs, link, stats, depth, slowdown,
                    mflops,
                )
            });
        }

        // ---- configuration step ----
        let next_hops: Vec<String> = (0..n)
            .map(|i| {
                if i + 1 < n {
                    format!("node{}", i + 1)
                } else {
                    "dispatcher".to_string()
                }
            })
            .collect();
        configure_nodes(
            &self.plan,
            &mut dispatcher_side,
            &next_hops,
            &self.cfg.codecs,
            &link,
            &dstats,
        )?;
        drop(dispatcher_side);

        // ---- distributed inference step ----
        let input = match &self.reference {
            Some(r) => r.input.clone(),
            None => Tensor::random(self.plan.input_shape().to_vec(), 7),
        };
        let expected = self.reference.as_ref().map(|r| r.output.clone());
        let t0 = std::time::Instant::now();
        run_inference(
            input,
            frames,
            to_first,
            from_last,
            self.cfg.codecs,
            Arc::clone(&link),
            Arc::clone(&dstats),
            expected,
            self.plan.output_shape().to_vec(),
        )?;
        let elapsed = t0.elapsed();
        pool.join()?;

        // ---- assemble report ----
        let cycles = dstats.clock.cycles();
        if cycles != frames {
            return Err(DeferError::Coordinator(format!(
                "completed {cycles}/{frames} cycles"
            )));
        }
        let config_time = *dstats.config_time.lock().unwrap();
        let reference_error = *dstats.reference_error.lock().unwrap();
        Ok(RunReport {
            model: self.cfg.model.clone(),
            profile: self.cfg.profile.clone(),
            nodes: n,
            cycles,
            elapsed,
            throughput: cycles as f64 / elapsed.as_secs_f64(),
            latency_mean: dstats.latency.mean(),
            latency_p50: dstats.latency.quantile(0.5),
            latency_p99: dstats.latency.quantile(0.99),
            node_energy: node_stats.iter().map(|s| s.meter.report()).collect(),
            dispatcher_energy: dstats.meter.report(),
            architecture_bytes: dstats.architecture_tx.total(),
            weights_bytes: dstats.weights_tx.total(),
            data_bytes: dstats.data_tx.total()
                + node_stats.iter().map(|s| s.data_tx.total()).sum::<u64>(),
            config_overhead: dstats.meter.codec.total(),
            data_overhead: node_stats
                .iter()
                .map(|s| s.meter.codec.total())
                .sum::<Duration>(),
            config_time,
            reference_error,
        })
    }
}

/// Count a ByteCounter total as u64 (helper for reports).
pub fn total(c: &ByteCounter) -> u64 {
    c.total()
}
