//! Chain orchestration, reduced to **plan → wire → spawn → report**.
//!
//! * **plan** — derive the fused stages and the declarative [`Topology`]
//!   from the config. By default each stage is one partition of the
//!   `(model, nodes)` artifact and the topology is hand-written
//!   (`replicas`/`per_hop_links`). With `auto_place` the
//!   [`crate::placement`] planner derives replica counts and hop links
//!   from the partition plan's stage costs and the configured device
//!   budgets. With `auto_partition` the [`crate::repartition`] planner
//!   goes further: it loads the *finest-granularity* partition set and
//!   jointly chooses cut points and replica counts, so stages become
//!   fused runs of partitions ([`crate::model::StageSpec`]) and the
//!   stage count itself is a planning output. Either way the rest of
//!   the pipeline consumes the same stages + `Topology` and cannot tell
//!   who wrote them.
//! * **wire** — hand the topology to [`crate::topology::wiring`], which
//!   establishes every connection for either transport (in-process byte
//!   pipes, or TCP loopback with ephemeral ports — the paper's CORE
//!   deployment on one host). Replicated stage boundaries are
//!   worker-owned: each replica merges from its predecessor set and
//!   deals to its successor set directly, so no relay thread (and on
//!   real multi-host deployments, no extra network crossing) sits
//!   between stages. `--relay-junctions` restores the legacy
//!   coordinator-side relay threads for A/B comparison.
//! * **spawn** — one thread per worker replica (its own "device"), each
//!   owning an independent instance of its uplink's [`Link`] shaper
//!   (replication adds physical links, not shared capacity).
//! * **report** — run the configuration + inference phases and assemble
//!   the [`RunReport`].
//!
//! With default config (replicas = 1 per stage, uniform links) the wiring
//! degenerates to the paper's chain: no junctions, identical wire bytes,
//! identical `RunReport` byte accounting. One deliberate semantic change
//! for *shaped* links: every hop now owns an independent token bucket
//! (each hop is its own physical link, as under CORE), where the old
//! builder funneled all hops through a single shared bucket. Ideal-link
//! runs are unaffected; shaped-run timing is now per-hop rather than
//! shared-medium.

use std::sync::Arc;
use std::time::Duration;

use crate::config::DeferConfig;
use crate::coordinator::compute_node::{run_compute_node, ComputeOptions, NodeStats};
use crate::coordinator::dispatcher::{
    configure_nodes, run_inference, DispatcherStats, InferenceOptions, WorkerAssignment,
};
use crate::coordinator::pipeline::PipelineRecovery;
use crate::coordinator::RunReport;
use crate::netem::FaultPlan;
use crate::runtime::recovery::RecoverySupervisor;
use crate::error::{DeferError, Result};
use crate::model::{PartitionPlan, ReferenceVectors, StageSpec};
use crate::netem::Link;
use crate::netio::Reactor;
use crate::runtime::Engine;
use crate::serial::CodecRuntime;
use crate::tensor::Tensor;
use crate::threadpool::{pipe, CodecPool, WorkerPool};
use crate::topology::wiring::{FrameSink, FrameSource};
use crate::topology::{wiring, Topology};
use crate::wire::Message;

/// A ready-to-run DEFER deployment.
pub struct ChainRunner {
    pub cfg: DeferConfig,
    engine: Engine,
    plan: PartitionPlan,
    /// Fused pipeline stages (single-partition unless `auto_partition`
    /// re-cut the plan); `stages.len() == topo.num_stages()`.
    stages: Vec<StageSpec>,
    topo: Topology,
    /// Rendered planner output when a planner chose the topology
    /// (`auto_place` / `auto_partition`); the CLI surfaces it.
    plan_render: Option<String>,
    reference: Option<ReferenceVectors>,
}

impl ChainRunner {
    /// Load artifacts and prepare the runner. Fails early with a helpful
    /// message if `make artifacts` has not produced this configuration.
    pub fn new(cfg: DeferConfig) -> Result<Self> {
        // Validate before paying for PJRT initialization, so a bad
        // config surfaces its own error immediately.
        cfg.validate()?;
        let engine = Engine::cpu()?;
        Self::with_engine(cfg, engine)
    }

    /// Reuse an existing engine (avoids re-initializing PJRT across sweeps).
    pub fn with_engine(cfg: DeferConfig, engine: Engine) -> Result<Self> {
        cfg.validate()?;
        // Resolve stages + topology once, at construction: planning is
        // pure, so the deployed topology always matches what the CLI
        // reports, even if a device profile on disk changes afterwards.
        let (plan, stages, topo, plan_render) = if cfg.auto_partition {
            // Stage boundaries are a planning output: fuse the finest
            // partition set the artifacts provide.
            let finest = crate::model::finest_part_count(
                &cfg.artifacts_dir,
                &cfg.profile,
                &cfg.model,
            )?;
            let plan =
                PartitionPlan::load(&cfg.artifacts_dir, &cfg.profile, &cfg.model, finest)?;
            let rp = crate::repartition::plan_from_config(&cfg, &plan)?;
            let stages = plan.fuse(&rp.cuts)?;
            let topo = rp.topology()?;
            let render = rp.render();
            (plan, stages, topo, Some(render))
        } else {
            let plan =
                PartitionPlan::load(&cfg.artifacts_dir, &cfg.profile, &cfg.model, cfg.nodes)?;
            let stages = plan.singleton_stages();
            let (topo, render) = if cfg.auto_place {
                let problem =
                    crate::placement::PlacementProblem::from_config(&cfg, &plan)?;
                let placed = crate::placement::plan(&problem)?;
                let render = placed.render();
                (placed.topology()?, Some(render))
            } else {
                (Topology::from_config(&cfg)?, None)
            };
            (plan, stages, topo, render)
        };
        let reference =
            ReferenceVectors::load(&cfg.artifacts_dir, &cfg.profile, &cfg.model).ok();
        Ok(ChainRunner {
            cfg,
            engine,
            plan,
            stages,
            topo,
            plan_render,
            reference,
        })
    }

    pub fn plan(&self) -> &PartitionPlan {
        &self.plan
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// The fused pipeline stages this deployment serves.
    pub fn stages(&self) -> &[StageSpec] {
        &self.stages
    }

    /// The topology this deployment runs: hand-written
    /// (`replicas`/`per_hop_links`) by default, emitted by the placement
    /// planner under `auto_place`, or jointly re-cut by the repartition
    /// planner under `auto_partition`.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// The planner's rendered report when one chose the topology
    /// (byte-stable; `None` for hand-written deployments).
    pub fn plan_render(&self) -> Option<&str> {
        self.plan_render.as_deref()
    }

    /// Run `frames` inference cycles through the chain; returns the report.
    pub fn run_frames(&self, frames: u64) -> Result<RunReport> {
        // ---- plan: fused stages + topology, resolved at construction ----
        let topo = &self.topo;
        if topo.num_stages() != self.stages.len() {
            return Err(DeferError::Coordinator(format!(
                "topology has {} stages for {} fused stages",
                topo.num_stages(),
                self.stages.len()
            )));
        }
        let views = topo.worker_views();
        let dstats = Arc::new(DispatcherStats::new(self.cfg.energy));
        let node_stats: Vec<Arc<NodeStats>> = views
            .iter()
            .map(|_| Arc::new(NodeStats::new(self.cfg.energy)))
            .collect();

        // ---- self-healing supervisor (recovery mode) ----
        // One supervisor per run: every endpoint reports deaths to it,
        // the dispatcher re-dispatches from it, and the fault schedule
        // (if any) rides along so both I/O planes inject identically.
        let supervisor: Option<std::sync::Arc<RecoverySupervisor>> =
            if self.cfg.recovery_enabled() {
                let plan = FaultPlan::parse(&self.cfg.faults)?;
                Some(RecoverySupervisor::new(self.cfg.recovery_window, plan))
            } else {
                None
            };

        // ---- wire: connection bundles for either transport ----
        let wiring::Wiring {
            mut control,
            to_first,
            from_last,
            workers,
            junctions,
        } = wiring::build(
            topo,
            &wiring::TransportOptions {
                tcp: self.cfg.tcp,
                base_port: self.cfg.base_port,
                pipe_depth: self.cfg.pipe_depth,
                relay_junctions: self.cfg.relay_junctions,
                recovery: supervisor.clone(),
            },
        )?;

        // ---- data-plane runtime ----
        // Default: a sharded reactor owns every mesh connection's
        // readiness, so the data plane costs `io_threads` shard threads
        // total instead of one parked thread per connection.
        // `--blocking-io` keeps the thread-per-connection plane for A/B.
        let reactor = if self.cfg.blocking_io {
            None
        } else {
            let shards = if self.cfg.io_threads > 0 {
                self.cfg.io_threads
            } else {
                Reactor::default_io_threads()
            };
            Some(Arc::new(Reactor::new(shards)?))
        };

        // ---- spawn one thread per worker replica ----
        // One codec worker pool is shared by every replica (and the
        // dispatcher), so `--codec-threads` bounds total chunk-codec
        // parallelism for the whole deployment.
        let codec_pool = if self.cfg.codec_threads > 0 {
            Some(Arc::new(CodecPool::new(self.cfg.codec_threads)))
        } else {
            None
        };
        let codec_rt = if self.cfg.codec_threads > 0 {
            CodecRuntime::chunked(self.cfg.codec_chunk_elems, codec_pool)?
                .with_kernel(self.cfg.codec_kernel)
        } else {
            CodecRuntime::serial().with_kernel(self.cfg.codec_kernel)
        };
        let mut pool = WorkerPool::new();
        for (wc, stats) in workers.into_iter().zip(&node_stats) {
            let engine = self.engine.clone();
            let codecs = self.cfg.codecs;
            // Each replica owns an independent instance of its uplink.
            let out_link = Arc::new(Link::new(topo.hop_link(wc.view.stage + 1)));
            let stats = Arc::clone(stats);
            let opts = ComputeOptions {
                pipe_depth: self.cfg.pipe_depth,
                compute_slowdown: self.cfg.compute_slowdown,
                emulated_mflops: self.cfg.emulated_mflops,
                codec_rt: codec_rt.clone(),
                pipelined: self.cfg.codec_pipeline,
                reactor: reactor.clone(),
            };
            pool.spawn(&format!("compute-{}", wc.view.name), move || {
                run_compute_node(engine, wc, codecs, out_link, stats, opts)
            });
        }

        // ---- configuration step ----
        // Every replica of stage i receives fused stage i (all of its
        // partitions in one exchange); control-plane sends to a stage
        // are shaped like its ingress hop.
        let assignments: Vec<WorkerAssignment> = views
            .iter()
            .map(|v| WorkerAssignment {
                stage_index: v.stage,
                next_hop: v.successors.join(","),
                link: Arc::new(Link::new(topo.hop_link(v.stage))),
            })
            .collect();
        configure_nodes(
            &self.stages,
            &mut control,
            &assignments,
            &self.cfg.codecs,
            &codec_rt,
            &dstats,
        )?;
        drop(control);

        // ---- distributed inference step ----
        let input = match &self.reference {
            Some(r) => r.input.clone(),
            None => Tensor::random(self.plan.input_shape().to_vec(), 7),
        };
        let expected = self.reference.as_ref().map(|r| r.output.clone());
        let uplink = Arc::new(Link::new(topo.hop_link(0)));
        // The dispatcher's endpoints join whichever plane is active. On
        // the reactor plane the egress deal becomes a queued sink and
        // the return merge feeds a pipe via a shard-owned ingress
        // machine; serialization/shaping/accounting still happen on the
        // dispatcher's own threads, so wire traffic is byte-identical.
        // The dispatcher's own chunk-retry client (result boundary) must
        // be extracted before the endpoints are converted/registered.
        let dispatcher_client = from_last.chunk_client();
        let (to_first, from_last): (FrameSink, FrameSource) = match &reactor {
            Some(r) => {
                let sink = r.register_egress(to_first, self.cfg.pipe_depth)?.into();
                let (res_tx, res_rx) = pipe::<Message>(self.cfg.pipe_depth);
                let err = r.register_ingress(from_last, res_tx, None)?;
                (sink, FrameSource::Queued { rx: res_rx, err })
            }
            None => (to_first.into(), from_last.into()),
        };
        // Threads whose whole job is moving frames on/off connections:
        // per-worker parked readers plus the dispatcher's connection
        // owners on the blocking plane; the shard threads otherwise.
        let data_plane_threads = match &reactor {
            Some(r) => r.io_threads() as u64,
            None => views.len() as u64 + if self.cfg.codec_pipeline { 2 } else { 1 },
        };
        // Scope the process-global zero-copy counters to this run's
        // inference phase (config traffic rides the legacy copy path by
        // design — it is one exchange per worker).
        let zc0 = crate::metrics::zerocopy::snapshot();
        let t0 = std::time::Instant::now();
        run_inference(
            input,
            frames,
            to_first,
            from_last,
            InferenceOptions {
                codecs: self.cfg.codecs,
                rt: codec_rt,
                pipelined: self.cfg.codec_pipeline,
                pipe_depth: self.cfg.pipe_depth,
                batch: self.cfg.batch,
                batch_latency_ms: self.cfg.batch_latency_ms,
                batch_adaptive: self.cfg.batch_adaptive,
                recovery: supervisor.as_ref().map(|s| PipelineRecovery {
                    supervisor: Arc::clone(s),
                    client: dispatcher_client.clone(),
                }),
            },
            uplink,
            Arc::clone(&dstats),
            expected,
            self.plan.output_shape().to_vec(),
        )?;
        let elapsed = t0.elapsed();
        let zerocopy = crate::metrics::zerocopy::snapshot().since(&zc0);
        pool.join()?;
        junctions.join()?;
        // Snapshot the shard counters, then retire the reactor (workers
        // have joined, so this is the last handle; every machine drained
        // with the final merged shutdown).
        let io_shards: Vec<(u64, u64)> = reactor
            .as_ref()
            .map(|r| r.shard_stats())
            .unwrap_or_default();
        drop(reactor);

        // ---- assemble report ----
        let cycles = dstats.clock.cycles();
        if cycles != frames {
            return Err(DeferError::Coordinator(format!(
                "completed {cycles}/{frames} cycles"
            )));
        }
        let config_time = *dstats.config_time.lock().unwrap();
        let reference_error = *dstats.reference_error.lock().unwrap();
        Ok(RunReport {
            model: self.cfg.model.clone(),
            profile: self.cfg.profile.clone(),
            nodes: topo.num_stages(),
            workers: views.len(),
            cycles,
            elapsed,
            throughput: cycles as f64 / elapsed.as_secs_f64(),
            latency_mean: dstats.latency.mean(),
            latency_p50: dstats.latency.quantile(0.5),
            latency_p99: dstats.latency.quantile(0.99),
            node_energy: node_stats.iter().map(|s| s.meter.report()).collect(),
            dispatcher_energy: dstats.meter.report(),
            architecture_bytes: dstats.architecture_tx.total(),
            weights_bytes: dstats.weights_tx.total(),
            data_bytes: dstats.data_tx.total()
                + node_stats.iter().map(|s| s.data_tx.total()).sum::<u64>(),
            config_overhead: dstats.meter.codec.total(),
            data_overhead: node_stats
                .iter()
                .map(|s| s.meter.codec.total())
                .sum::<Duration>(),
            config_time,
            reference_error,
            queue_high_water: dstats.queue_depth.high_water() as u64,
            data_plane_threads,
            io_shards,
            frames_redispatched: supervisor
                .as_ref()
                .map_or(0, |s| s.frames_redispatched()),
            chunks_retried: supervisor.as_ref().map_or(0, |s| s.chunks_retried()),
            replicas_lost: supervisor.as_ref().map_or(0, |s| s.replicas_lost()),
            zerocopy,
        })
    }
}
