//! Chain transport: one abstraction over real TCP loopback sockets and
//! in-process byte pipes.
//!
//! Both paths move the *same wire bytes* through the *same framing, CRC,
//! 512 kB chunking, link shaping and byte counting* — the only difference
//! is whether the kernel socket layer sits underneath. That keeps every
//! payload/overhead measurement identical across modes (and matches the
//! paper, which ran "distributed" nodes as CORE containers on one host).

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use crate::error::{DeferError, Result};
use crate::metrics::{zerocopy, ByteCounter};
use crate::netem::Link;
use crate::threadpool::{pipe, PipeReceiver, PipeSender};
use crate::util::bufpool::BufPool;
use crate::wire::{write_message, Message, WireBuf, WireFrame};

/// One directed connection endpoint.
pub enum Conn {
    Tcp {
        writer: BufWriter<TcpStream>,
        reader: BufReader<TcpStream>,
    },
    Local {
        /// Local pipes carry [`WireBuf`]s: structured frames hand the
        /// shared payload across with no serialize copy; raw buffers
        /// carry legacy control traffic and injected fault bytes.
        tx: PipeSender<WireBuf>,
        rx: PipeReceiver<WireBuf>,
        /// Partially consumed inbound raw buffer (multiple messages per
        /// buffer are not produced today, but keep reads robust).
        pending: Vec<u8>,
        /// Frame-buffer pool shared by both endpoints of the pair: the
        /// sender draws its outbound wire buffer here, the receiver puts
        /// the fully consumed inbound buffer back. Closes the last
        /// allocation loop in the deal/merge hot path (each local send
        /// used to pay a fresh `Vec` per message).
        frames: Arc<BufPool>,
    },
}

impl Conn {
    /// Default total deadline for [`Conn::tcp_connect`] retries.
    pub const CONNECT_DEADLINE: std::time::Duration = std::time::Duration::from_secs(10);

    /// Connect to a TCP endpoint, retrying with jittered exponential
    /// backoff (1 ms doubling to 100 ms, plus up to +50% deterministic
    /// jitter so a fleet of dialers retrying the same listener
    /// de-synchronizes) capped by the total `deadline`. `peer` names the
    /// remote role/stage (e.g. `node1 data socket`) for the error
    /// message, which also reports how many attempts were made.
    pub fn tcp_connect_with_deadline(
        addr: &str,
        peer: &str,
        deadline: std::time::Duration,
    ) -> Result<Conn> {
        let t_end = std::time::Instant::now() + deadline;
        let mut backoff = std::time::Duration::from_millis(1);
        let max_backoff = std::time::Duration::from_millis(100);
        // Jitter stream seeded per (addr, peer): deterministic for a
        // given dialer, distinct across dialers — no shared RNG state.
        let mut jitter = addr
            .bytes()
            .chain(peer.bytes())
            .fold(0x9E37_79B9_7F4A_7C15u64, |h, b| {
                (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3)
            })
            | 1;
        let mut attempts = 0u64;
        let mut last_err;
        loop {
            attempts += 1;
            match TcpStream::connect(addr) {
                Ok(s) => {
                    s.set_nodelay(true).ok();
                    let reader = BufReader::new(s.try_clone()?);
                    return Ok(Conn::Tcp {
                        writer: BufWriter::new(s),
                        reader,
                    });
                }
                Err(e) => last_err = e,
            }
            let now = std::time::Instant::now();
            if now >= t_end {
                return Err(DeferError::Coordinator(format!(
                    "cannot connect to {peer} at {addr} within {deadline:?} \
                     ({attempts} attempts): {last_err}"
                )));
            }
            jitter ^= jitter << 13;
            jitter ^= jitter >> 7;
            jitter ^= jitter << 17;
            let jitter_us = jitter % (backoff.as_micros() as u64 / 2 + 1);
            let sleep = backoff + std::time::Duration::from_micros(jitter_us);
            std::thread::sleep(sleep.min(t_end - now));
            backoff = (backoff * 2).min(max_backoff);
        }
    }

    /// Connect to a TCP endpoint with the default deadline; `peer` names
    /// the remote role/stage for error reporting.
    pub fn tcp_connect(addr: &str, peer: &str) -> Result<Conn> {
        Self::tcp_connect_with_deadline(addr, peer, Self::CONNECT_DEADLINE)
    }

    /// Accept one connection from a bound listener.
    pub fn tcp_accept(listener: &TcpListener) -> Result<Conn> {
        let (s, _) = listener.accept()?;
        s.set_nodelay(true).ok();
        let reader = BufReader::new(s.try_clone()?);
        Ok(Conn::Tcp {
            writer: BufWriter::new(s),
            reader,
        })
    }

    /// [`Conn::tcp_accept`] with a deadline, mirroring
    /// [`Conn::tcp_connect_with_deadline`]: a peer that never dials must
    /// not park the wiring forever. `peer` names the *expected* dialer
    /// (e.g. `node1.0 data socket`) for the error message.
    pub fn tcp_accept_with_deadline(
        listener: &TcpListener,
        peer: &str,
        deadline: std::time::Duration,
    ) -> Result<Conn> {
        let t_end = std::time::Instant::now() + deadline;
        let mut backoff = std::time::Duration::from_millis(1);
        let max_backoff = std::time::Duration::from_millis(100);
        listener.set_nonblocking(true)?;
        let result = loop {
            match listener.accept() {
                Ok((s, _)) => {
                    // Accepted sockets are blocking by default on Linux,
                    // but make it explicit: the nonblocking flag belongs
                    // to the listener, not the connection.
                    s.set_nonblocking(false)?;
                    s.set_nodelay(true).ok();
                    let reader = BufReader::new(s.try_clone()?);
                    break Ok(Conn::Tcp {
                        writer: BufWriter::new(s),
                        reader,
                    });
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::Interrupted =>
                {
                    let now = std::time::Instant::now();
                    if now >= t_end {
                        let addr = listener
                            .local_addr()
                            .map(|a| a.to_string())
                            .unwrap_or_else(|_| "?".into());
                        break Err(DeferError::Coordinator(format!(
                            "no connection from {peer} on {addr} within {deadline:?}"
                        )));
                    }
                    std::thread::sleep(backoff.min(t_end - now));
                    backoff = (backoff * 2).min(max_backoff);
                }
                Err(e) => break Err(e.into()),
            }
        };
        // Leave the listener as we found it for any further accepts.
        listener.set_nonblocking(false)?;
        result
    }

    /// Consume this connection into its nonblocking read side for
    /// reactor registration. Any bytes the buffered reader already held
    /// are preserved as `residue` so no wire data is lost at the split.
    pub fn into_read_half(self) -> Result<ReadHalf> {
        self.into_read_half_pooled(None)
    }

    /// [`Conn::into_read_half`] drawing the residue buffer from `pool`
    /// when the pre-split reader actually held bytes. The common case —
    /// a clean split at a message boundary — keeps the residue as the
    /// empty `Vec` (no allocation, no copy at all).
    pub fn into_read_half_pooled(self, pool: Option<&BufPool>) -> Result<ReadHalf> {
        match self {
            Conn::Tcp { reader, writer } => {
                drop(writer); // the reader's clone keeps the socket open
                let residue = if reader.buffer().is_empty() {
                    Vec::new()
                } else {
                    let mut buf = pool.map(|p| p.take()).unwrap_or_default();
                    buf.extend_from_slice(reader.buffer());
                    buf
                };
                let stream = reader.into_inner();
                stream.set_nonblocking(true)?;
                Ok(ReadHalf::Tcp { stream, residue })
            }
            Conn::Local {
                rx,
                pending,
                frames,
                tx,
            } => {
                drop(tx);
                Ok(ReadHalf::Local {
                    rx,
                    pending,
                    frames,
                })
            }
        }
    }

    /// Consume this connection into its nonblocking write side for
    /// reactor registration (flushes any buffered output first).
    pub fn into_write_half(self) -> Result<WriteHalf> {
        match self {
            Conn::Tcp { reader, writer } => {
                drop(reader);
                let stream = writer
                    .into_inner()
                    .map_err(|e| DeferError::Io(e.into_error()))?;
                stream.set_nonblocking(true)?;
                Ok(WriteHalf::Tcp { stream })
            }
            Conn::Local { tx, frames, .. } => Ok(WriteHalf::Local { tx, frames }),
        }
    }

    /// An in-process bidirectional pair (a <-> b) with bounded depth.
    pub fn local_pair(depth: usize) -> (Conn, Conn) {
        let (atx, brx) = pipe::<WireBuf>(depth);
        let (btx, arx) = pipe::<WireBuf>(depth);
        // Bound the shared frame pool by what can be in flight across
        // both directions at once (pipe depth each way, plus slack for
        // the buffers the two endpoints hold while reading/writing).
        let frames = Arc::new(BufPool::new(2 * depth.max(1) + 2));
        (
            Conn::Local {
                tx: atx,
                rx: arx,
                pending: Vec::new(),
                frames: Arc::clone(&frames),
            },
            Conn::Local {
                tx: btx,
                rx: brx,
                pending: Vec::new(),
                frames,
            },
        )
    }

    /// Send one framed message through the link shaper, counting bytes.
    /// This is the legacy owned-payload path (control/config traffic);
    /// per-frame data goes through [`Conn::send_frame`], which never
    /// copies the payload.
    pub fn send(&mut self, msg: &Message, link: &Link, counter: &ByteCounter) -> Result<()> {
        match self {
            Conn::Tcp { writer, .. } => write_message(writer, msg, link, counter),
            Conn::Local { tx, frames, .. } => {
                if !msg.payload.is_empty() {
                    zerocopy::count_payload_copy();
                }
                let mut buf = frames.take();
                buf.reserve(msg.wire_size() as usize);
                write_message(&mut buf, msg, link, counter)?;
                tx.send(WireBuf::Raw(buf))
                    .map_err(|_| DeferError::ChannelClosed("local conn send"))
            }
        }
    }

    /// Send one [`WireFrame`] — the zero-copy data path. TCP leaves via
    /// vectored writes (header + payload gathered, no assemble copy);
    /// local pipes move the frame itself, payload shared by reference.
    /// Shaper and counter observe exactly [`Conn::send`]'s sequence.
    pub fn send_frame(&mut self, wf: WireFrame, link: &Link, counter: &ByteCounter) -> Result<()> {
        wf.charge(link, counter);
        match self {
            Conn::Tcp { writer, .. } => {
                wf.write_to(writer)?;
                writer.flush()?;
                Ok(())
            }
            Conn::Local { tx, .. } => tx
                .send(WireBuf::Frame(wf))
                .map_err(|_| DeferError::ChannelClosed("local conn send")),
        }
    }

    /// Receive one framed message, counting bytes.
    pub fn recv(&mut self, counter: &ByteCounter) -> Result<Message> {
        self.recv_pooled(counter, None)
    }

    /// Wait up to `timeout` for this conn to become readable, without
    /// consuming anything: true when a `recv` now would not block (data
    /// buffered, bytes in the pipe, the peer closed, or the socket is in
    /// an error state a recv would surface). The recovery layer uses this
    /// to poll idle connections for peer death instead of parking
    /// indefinitely in `recv`.
    pub fn wait_readable(&mut self, timeout: std::time::Duration) -> bool {
        match self {
            Conn::Local { rx, pending, .. } => {
                !pending.is_empty() || rx.wait_readable(timeout)
            }
            Conn::Tcp { reader, .. } => {
                if !reader.buffer().is_empty() {
                    return true;
                }
                let stream = reader.get_ref();
                let prev = stream.read_timeout().ok().flatten();
                if stream.set_read_timeout(Some(timeout)).is_err() {
                    return true;
                }
                let mut byte = [0u8; 1];
                // peek never consumes, so a timed-out probe leaves the
                // stream exactly as it found it; Ok(0) is EOF, which a
                // recv would surface as an error — readable.
                let ready = match stream.peek(&mut byte) {
                    Ok(_) => true,
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock
                            || e.kind() == std::io::ErrorKind::TimedOut =>
                    {
                        false
                    }
                    Err(_) => true,
                };
                stream.set_read_timeout(prev).ok();
                ready
            }
        }
    }

    /// Fault injection: write exactly the first `n` bytes of `msg`'s wire
    /// encoding (at least 1, at most all-but-one), then stop — the caller
    /// is about to die and the peer must observe a mid-message EOF.
    pub fn send_truncated(&mut self, msg: &Message, n: usize) -> Result<()> {
        let mut wire = Vec::new();
        write_message(&mut wire, msg, &Link::ideal(), &ByteCounter::new())?;
        wire.truncate(n.clamp(1, wire.len().saturating_sub(1)));
        match self {
            Conn::Tcp { writer, .. } => {
                use std::io::Write as _;
                writer.write_all(&wire)?;
                writer.flush()?;
            }
            Conn::Local { tx, .. } => {
                tx.send(WireBuf::Raw(wire))
                    .map_err(|_| DeferError::ChannelClosed("local conn send"))?;
            }
        }
        Ok(())
    }

    /// [`Conn::recv`] with the payload buffer drawn from `pool` — the
    /// per-connection allocation-hygiene variant (see
    /// [`crate::wire::read_message_pooled`]).
    pub fn recv_pooled(
        &mut self,
        counter: &ByteCounter,
        pool: Option<&crate::util::bufpool::BufPool>,
    ) -> Result<Message> {
        match self {
            Conn::Tcp { reader, .. } => crate::wire::read_message_pooled(reader, counter, pool),
            Conn::Local { rx, pending, frames, .. } => {
                let raw = loop {
                    if !pending.is_empty() {
                        break None;
                    }
                    match rx
                        .recv()
                        .ok_or(DeferError::ChannelClosed("local conn recv"))?
                    {
                        // Structured frame: the payload buffer moves
                        // straight out of the shared cell — no parse, no
                        // CRC re-sweep (the bytes never left memory), no
                        // copy when this is the last reference.
                        WireBuf::Frame(wf) => {
                            counter.add(wf.wire_size());
                            return Ok(wf.into_message());
                        }
                        WireBuf::Raw(buf) => break Some(buf),
                    }
                };
                if let Some(buf) = raw {
                    *pending = buf;
                }
                let mut cursor = std::io::Cursor::new(pending.as_slice());
                let msg = crate::wire::read_message_pooled(&mut cursor, counter, pool)?;
                let consumed = cursor.position() as usize;
                pending.drain(..consumed);
                if pending.is_empty() {
                    // Hand the drained wire buffer back for the next send
                    // on either endpoint.
                    frames.put(std::mem::take(pending));
                }
                Ok(msg)
            }
        }
    }
}

/// The read side of a split [`Conn`], ready for readiness-driven I/O:
/// the TCP arm is a nonblocking stream (registered with epoll), the
/// local arm keeps the pipe receiver (a virtual readiness source via its
/// data waker).
pub enum ReadHalf {
    Tcp {
        stream: TcpStream,
        /// Bytes the pre-split buffered reader had already pulled off
        /// the socket; must be consumed before fresh socket reads.
        residue: Vec<u8>,
    },
    Local {
        rx: PipeReceiver<WireBuf>,
        /// Partially consumed inbound raw buffer (same role as
        /// [`Conn::Local`]'s field).
        pending: Vec<u8>,
        frames: Arc<BufPool>,
    },
}

/// The write side of a split [`Conn`]: nonblocking TCP stream or the
/// local pipe sender (readiness via its space waker).
pub enum WriteHalf {
    Tcp { stream: TcpStream },
    Local {
        tx: PipeSender<WireBuf>,
        frames: Arc<BufPool>,
    },
}

/// A shared, cloneable link handle (chain stages share one shaper per hop).
pub type SharedLink = Arc<Link>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::MessageType;

    fn data_msg(frame: u64, n: usize) -> Message {
        Message {
            msg_type: MessageType::Data,
            frame,
            serialized_len: n as u64,
            count: 0,
            batch: 1,
            payload: vec![frame as u8; n],
        }
    }

    #[test]
    fn local_pair_recycles_wire_buffers() {
        // After a send/recv cycle the consumed wire buffer must return
        // to the pair's shared pool and feed the next send.
        let (mut a, mut b) = Conn::local_pair(2);
        let link = Link::ideal();
        let c = ByteCounter::new();
        for f in 0..6u64 {
            a.send(&data_msg(f, 256), &link, &c).unwrap();
            b.recv(&c).unwrap();
        }
        let pooled = match &a {
            Conn::Local { frames, .. } => frames.pooled(),
            _ => unreachable!(),
        };
        assert!(pooled >= 1, "no buffer returned to the pool");
    }

    #[test]
    fn local_pair_round_trip() {
        // Depth must cover the 5 messages sent before any recv (bounded
        // pipes block the sender at capacity — that's the backpressure).
        let (mut a, mut b) = Conn::local_pair(8);
        let link = Link::ideal();
        let c = ByteCounter::new();
        for f in 0..5u64 {
            a.send(&data_msg(f, 100), &link, &c).unwrap();
        }
        for f in 0..5u64 {
            let m = b.recv(&c).unwrap();
            assert_eq!(m.frame, f);
            assert_eq!(m.payload, vec![f as u8; 100]);
        }
    }

    #[test]
    fn local_pair_bidirectional() {
        let (mut a, mut b) = Conn::local_pair(2);
        let link = Link::ideal();
        let c = ByteCounter::new();
        a.send(&data_msg(1, 10), &link, &c).unwrap();
        b.send(&data_msg(2, 20), &link, &c).unwrap();
        assert_eq!(b.recv(&c).unwrap().frame, 1);
        assert_eq!(a.recv(&c).unwrap().frame, 2);
    }

    #[test]
    fn tcp_round_trip_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || {
            let mut server = Conn::tcp_accept(&listener).unwrap();
            let c = ByteCounter::new();
            let m = server.recv(&c).unwrap();
            server.send(&m, &Link::ideal(), &c).unwrap();
        });
        let mut client = Conn::tcp_connect(&addr, "echo server").unwrap();
        let c = ByteCounter::new();
        let sent = data_msg(42, 1000);
        client.send(&sent, &Link::ideal(), &c).unwrap();
        let echoed = client.recv(&c).unwrap();
        assert_eq!(echoed, sent);
        h.join().unwrap();
    }

    #[test]
    fn connect_failure_names_peer_and_respects_deadline() {
        // Nothing listens on a just-closed ephemeral port; the connect
        // must back off, hit the deadline, and name the peer role.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let t0 = std::time::Instant::now();
        let err = Conn::tcp_connect_with_deadline(
            &addr,
            "node3 weights socket",
            std::time::Duration::from_millis(120),
        )
        .unwrap_err();
        assert!(t0.elapsed() < std::time::Duration::from_secs(5));
        let msg = format!("{err}");
        assert!(msg.contains("node3 weights socket"), "{msg}");
        assert!(msg.contains(&addr), "{msg}");
    }

    #[test]
    fn accept_deadline_names_expected_peer() {
        // No one ever dials: the accept must give up at the deadline and
        // say who it was waiting for.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let t0 = std::time::Instant::now();
        let err = Conn::tcp_accept_with_deadline(
            &listener,
            "node1.0 data socket",
            std::time::Duration::from_millis(120),
        )
        .unwrap_err();
        assert!(t0.elapsed() < std::time::Duration::from_secs(5));
        let msg = format!("{err}");
        assert!(msg.contains("node1.0 data socket"), "{msg}");

        // A dialer that does show up is accepted, and the listener is
        // back in blocking mode for the next accept.
        let addr = listener.local_addr().unwrap().to_string();
        let dial = std::thread::spawn(move || {
            let mut c = Conn::tcp_connect(&addr, "acceptor").unwrap();
            c.send(&data_msg(9, 64), &Link::ideal(), &ByteCounter::new())
                .unwrap();
        });
        let mut server = Conn::tcp_accept_with_deadline(
            &listener,
            "late dialer",
            std::time::Duration::from_secs(5),
        )
        .unwrap();
        assert_eq!(server.recv(&ByteCounter::new()).unwrap().frame, 9);
        dial.join().unwrap();
    }

    #[test]
    fn split_halves_carry_the_stream_intact() {
        // TCP: a message sent through a WriteHalf's raw stream must be
        // readable through the peer's ReadHalf via the frame assembler.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || {
            Conn::tcp_accept(&listener).unwrap().into_read_half().unwrap()
        });
        let client = Conn::tcp_connect(&addr, "split peer").unwrap();
        let wh = client.into_write_half().unwrap();
        let read_half = h.join().unwrap();

        let msg = data_msg(5, 300);
        let mut wire = Vec::new();
        crate::wire::write_message(&mut wire, &msg, &Link::ideal(), &ByteCounter::new())
            .unwrap();
        let WriteHalf::Tcp { stream } = &wh else {
            unreachable!()
        };
        // A one-shot blocking write is fine here: the payload fits the
        // socket buffer.
        stream.set_nonblocking(false).unwrap();
        use std::io::Write as _;
        let mut w: &TcpStream = stream;
        w.write_all(&wire).unwrap();

        let ReadHalf::Tcp { stream, residue } = read_half else {
            unreachable!()
        };
        assert!(residue.is_empty(), "unread bytes at split");
        let mut asm = crate::wire::FrameAssembler::new();
        use std::io::Read as _;
        loop {
            match asm
                .poll(&mut |buf: &mut [u8]| (&stream).read(buf), None)
                .unwrap()
            {
                Some(m) => {
                    assert_eq!(m, msg);
                    break;
                }
                None => std::thread::sleep(std::time::Duration::from_millis(1)),
            }
        }

        // Local: the halves keep the pipe ends; a buffer pushed by the
        // write half arrives on the read half's receiver.
        let (a, b) = Conn::local_pair(4);
        let wh = a.into_write_half().unwrap();
        let rh = b.into_read_half().unwrap();
        let WriteHalf::Local { tx, .. } = &wh else {
            unreachable!()
        };
        tx.send(WireBuf::Raw(vec![1, 2, 3])).unwrap();
        let ReadHalf::Local { rx, .. } = &rh else {
            unreachable!()
        };
        match rx.recv() {
            Some(WireBuf::Raw(b)) => assert_eq!(b, vec![1, 2, 3]),
            other => panic!("expected raw buffer, got {other:?}"),
        }
    }

    #[test]
    fn send_frame_matches_send_on_both_transports() {
        // The zero-copy frame path must deliver the same message and
        // count the same bytes as the legacy Message path.
        let msg = data_msg(11, 2048);
        let wf = |m: &Message| {
            WireFrame::new(
                m.msg_type,
                m.frame,
                m.batch,
                m.serialized_len,
                m.count,
                crate::wire::SharedPayload::from_vec(m.payload.clone(), None),
            )
            .unwrap()
        };

        let (mut a, mut b) = Conn::local_pair(2);
        let c_local = ByteCounter::new();
        a.send_frame(wf(&msg), &Link::ideal(), &c_local).unwrap();
        let got = b.recv(&ByteCounter::new()).unwrap();
        assert_eq!(got, msg);
        assert_eq!(c_local.total(), msg.wire_size());

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || {
            let mut server = Conn::tcp_accept(&listener).unwrap();
            server.recv(&ByteCounter::new()).unwrap()
        });
        let mut client = Conn::tcp_connect(&addr, "frame peer").unwrap();
        let c_tcp = ByteCounter::new();
        client.send_frame(wf(&msg), &Link::ideal(), &c_tcp).unwrap();
        assert_eq!(h.join().unwrap(), msg);
        assert_eq!(c_tcp.total(), msg.wire_size());
    }

    #[test]
    fn closed_local_conn_errors() {
        let (a, mut b) = Conn::local_pair(1);
        drop(a);
        assert!(b.recv(&ByteCounter::new()).is_err());
    }

    #[test]
    fn byte_counters_match_both_transports() {
        // The same message must count the same bytes over local and TCP.
        let msg = data_msg(7, 12_345);
        let (mut a, mut b) = Conn::local_pair(1);
        let c_local = ByteCounter::new();
        a.send(&msg, &Link::ideal(), &c_local).unwrap();
        b.recv(&ByteCounter::new()).unwrap();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let msg2 = msg.clone();
        let h = std::thread::spawn(move || {
            let mut server = Conn::tcp_accept(&listener).unwrap();
            server.recv(&ByteCounter::new()).unwrap()
        });
        let mut client = Conn::tcp_connect(&addr, "byte-count peer").unwrap();
        let c_tcp = ByteCounter::new();
        client.send(&msg2, &Link::ideal(), &c_tcp).unwrap();
        h.join().unwrap();
        assert_eq!(c_local.total(), c_tcp.total());
        assert_eq!(c_local.total(), msg.wire_size());
    }
}
