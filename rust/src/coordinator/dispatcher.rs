//! Dispatcher node — the paper's Algorithm 1, generalized to a
//! per-worker view of the topology over fused stages.
//!
//! Configuration step: for each worker replica, open two connections and
//! send (a) the serialized stage architecture — every fused partition's
//! meta JSON + HLO text in *one* exchange — together with the worker's
//! successor set, and (b) the stage's weights arrays concatenated into
//! one serialized + compressed payload (partition order, then each
//! partition's manifest order). Wait for every worker's `Ready`. Which
//! fused stage a worker receives and how its control-plane link is
//! shaped come from its [`WorkerAssignment`] — replicated stages simply
//! list the same stage index more than once.
//!
//! Distributed inference step: pump serialized input frames into the
//! stage-0 replica set and collect results from the last stage's
//! replica set, FIFO. The dispatcher owns its boundary fan like any
//! other node: it **deals** frames round-robin straight to the stage-0
//! replicas through a [`DealSender`] and **merges** results from the
//! last-stage replicas through a [`MergeReceiver`] — no junction relay
//! in either direction. Sender and receiver run on separate threads so
//! the pipeline stays full (the chain applies backpressure through its
//! bounded links).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::CodecConfig;
use crate::energy::{EnergyMeter, EnergyModel};
use crate::error::{DeferError, Result};
use crate::metrics::{ByteCounter, Histogram, QueueDepthGauge, ThroughputClock};
use crate::model::StageSpec;
use crate::netem::Link;
use crate::runtime::recovery::decode_with_retry;
use crate::serial::CodecRuntime;
use crate::tensor::Tensor;
use crate::threadpool::{pipe, WorkerPool};
use crate::topology::wiring::{FrameSink, FrameSource};
use crate::util::bufpool::BufPool;
use crate::wire::{Message, MessageType, SharedPayload, WireFrame};

use super::compute_node::encode_stage_architecture;
use super::pipeline::PipelineRecovery;
use super::transport::Conn;

/// How long the re-dispatch loop tolerates zero progress (no completion,
/// death, or escalation) before declaring the recovery run wedged.
const REDISPATCH_STALL: Duration = Duration::from_secs(30);

/// Dispatcher-side instrumentation.
pub struct DispatcherStats {
    pub meter: EnergyMeter,
    pub architecture_tx: ByteCounter,
    pub weights_tx: ByteCounter,
    pub data_tx: ByteCounter,
    pub latency: Arc<Histogram>,
    pub clock: ThroughputClock,
    pub config_time: Mutex<Duration>,
    /// Max |err| vs expected output, when an expectation is provided.
    pub reference_error: Mutex<Option<f32>>,
    /// Depth of the dispatcher's bounded encode→send queue (last seen +
    /// high water). The batcher reads `last()` in adaptive mode; the
    /// run report surfaces `high_water()` as the backpressure signal.
    pub queue_depth: QueueDepthGauge,
}

impl DispatcherStats {
    pub fn new(model: EnergyModel) -> Self {
        DispatcherStats {
            meter: EnergyMeter::new(model),
            architecture_tx: ByteCounter::new(),
            weights_tx: ByteCounter::new(),
            data_tx: ByteCounter::new(),
            latency: Arc::new(Histogram::new()),
            clock: ThroughputClock::new(),
            config_time: Mutex::new(Duration::ZERO),
            reference_error: Mutex::new(None),
            queue_depth: QueueDepthGauge::new(),
        }
    }
}

/// One worker's configuration-step assignment: which fused stage it
/// serves, the successor label(s) shipped in its architecture payload,
/// and the link shaping its control-plane traffic.
pub struct WorkerAssignment {
    pub stage_index: usize,
    pub next_hop: String,
    pub link: Arc<Link>,
}

/// Send the configuration step to every worker: architecture + weights.
///
/// `stages` are the pipeline's fused stages (single-partition in the
/// paper's chain); `conns[i]` is the (config, weights) connection pair
/// for the worker described by `assignments[i]` (stage-major order).
/// `rt` is the deployment's shared codec runtime: the weights payloads
/// travel the same chunk-parallel path as data frames, so large
/// fused-stage weight blobs encode concurrently instead of on the
/// legacy inline path.
pub fn configure_nodes(
    stages: &[StageSpec],
    conns: &mut [(Conn, Conn)],
    assignments: &[WorkerAssignment],
    codecs: &CodecConfig,
    rt: &CodecRuntime,
    stats: &DispatcherStats,
) -> Result<()> {
    let t0 = Instant::now();
    if conns.len() != assignments.len() {
        return Err(DeferError::Coordinator(format!(
            "{} connection pairs for {} worker assignments",
            conns.len(),
            assignments.len()
        )));
    }
    for ((config_conn, weights_conn), a) in conns.iter_mut().zip(assignments) {
        let stage = stages.get(a.stage_index).ok_or_else(|| {
            DeferError::Coordinator(format!(
                "assignment wants stage {} of {}",
                a.stage_index,
                stages.len()
            ))
        })?;
        send_architecture(stage, &a.next_hop, config_conn, codecs, &a.link, stats)?;
        send_weights(stage, weights_conn, codecs, rt, &a.link, stats)?;
    }
    // Wait for every node to instantiate its model (paper: the model socket
    // waits for weights, then builds the TensorFlow model).
    for (config_conn, _) in conns.iter_mut() {
        let ready = config_conn.recv(&ByteCounter::new())?;
        if ready.msg_type != MessageType::Ready {
            return Err(DeferError::Coordinator(format!(
                "expected Ready, got {:?}",
                ready.msg_type
            )));
        }
    }
    *stats.config_time.lock().unwrap() = t0.elapsed();
    Ok(())
}

fn send_architecture(
    stage: &StageSpec,
    next_hop: &str,
    conn: &mut Conn,
    codecs: &CodecConfig,
    link: &Link,
    stats: &DispatcherStats,
) -> Result<()> {
    let hlos = stage
        .parts
        .iter()
        .map(|p| p.read_hlo())
        .collect::<Result<Vec<_>>>()?;
    let hlo_refs: Vec<&str> = hlos.iter().map(String::as_str).collect();
    let (payload, mid) = stats.meter.codec.time(|| {
        let raw = encode_stage_architecture(&stage.parts, &hlo_refs, next_hop);
        let mid = raw.len();
        // Zero-copy on the default Uncompressed architecture socket.
        let (payload, _) = codecs.architecture.compression.compress_vec(raw, None);
        (payload, mid)
    });
    let msg = Message {
        msg_type: MessageType::ModelConfig,
        frame: 0,
        serialized_len: mid as u64,
        count: 0,
        batch: 1,
        payload,
    };
    conn.send(&msg, link, &stats.architecture_tx)?;
    stats.meter.tx_bytes.add(msg.wire_size());
    Ok(())
}

fn send_weights(
    stage: &StageSpec,
    conn: &mut Conn,
    codecs: &CodecConfig,
    rt: &CodecRuntime,
    link: &Link,
    stats: &DispatcherStats,
) -> Result<()> {
    // Concatenate every fused partition's flat weights in partition
    // order — the layout `StageSpec::weight_manifest` documents and the
    // compute node's split relies on.
    let mut flat: Vec<f32> = Vec::with_capacity(stage.weight_elements());
    for spec in &stage.parts {
        for arr in spec.read_weights()? {
            flat.extend(arr);
        }
    }
    // Chunk-parallel when the deployment runs chunked (byte-identical
    // legacy payload otherwise) — the receiving node decodes with the
    // same shared runtime.
    let (payload, mid) = codecs.weights.encode_frame(&flat, rt, Some(&stats.meter.codec));
    let msg = Message {
        msg_type: MessageType::Weights,
        frame: 0,
        serialized_len: mid as u64,
        count: flat.len() as u64,
        batch: 1,
        payload,
    };
    conn.send(&msg, link, &stats.weights_tx)?;
    stats.meter.tx_bytes.add(msg.wire_size());
    Ok(())
}

/// Dispatcher-side runtime options for the inference phase.
#[derive(Clone)]
pub struct InferenceOptions {
    pub codecs: CodecConfig,
    /// Data-path codec runtime (chunking + shared worker pool).
    pub rt: CodecRuntime,
    /// Software-pipeline encode|send and read|decode on separate
    /// threads, so frame k+1 encodes while frame k is on the wire (and
    /// results decode while the next one is being read).
    pub pipelined: bool,
    /// Bounded depth of the intra-dispatcher pipes.
    pub pipe_depth: usize,
    /// Max logical frames coalesced into one batched wire message
    /// (>= 1; 1 = unbatched, byte-identical to the legacy data plane).
    pub batch: usize,
    /// Latency budget for filling a batch, in milliseconds (0 =
    /// unbounded). In the closed-loop dispatcher every input frame is
    /// available immediately, so the budget never forces a short batch
    /// here; it is carried for parity with the planner's feasibility
    /// rule and for open-loop front-ends.
    pub batch_latency_ms: f64,
    /// Adaptive batching (pipelined mode): size each batch to what is
    /// already waiting — `min(batch, queue_depth + 1)` — so a drained
    /// queue degrades to single frames and a backed-up wire coalesces
    /// up to the cap. The inline path has no queue and uses the fixed
    /// batch size.
    pub batch_adaptive: bool,
    /// Self-healing mode: bounded in-flight window, per-frame completion
    /// tracking, and re-dispatch of frames lost to replica death or an
    /// exhausted chunk-retry budget. `None` keeps the legacy fail-fast
    /// data plane (byte-identical wire traffic).
    pub recovery: Option<PipelineRecovery>,
}

impl Default for InferenceOptions {
    fn default() -> Self {
        InferenceOptions {
            codecs: CodecConfig::default(),
            rt: CodecRuntime::serial(),
            pipelined: true,
            pipe_depth: 4,
            batch: 1,
            batch_latency_ms: 0.0,
            batch_adaptive: false,
            recovery: None,
        }
    }
}

/// Send one encoded data message carrying `batch` coalesced frames
/// (first id `frame`): stamp every member frame's send time, deal the
/// whole batch to the stage-0 replica the round-robin schedule owns
/// (through the shaped uplink with byte/energy accounting). The payload
/// moves into a pooled [`WireFrame`] — its buffer returns to the
/// dispatcher's pool when the last reference drops, with no serialize
/// copy on the way out. Shared by the pipelined and inline sender paths
/// so the accounting cannot diverge between them.
#[allow(clippy::too_many_arguments)]
fn send_data_frame(
    to_first: &mut FrameSink,
    frame: u64,
    batch: u32,
    payload: Vec<u8>,
    serialized_len: usize,
    count: u64,
    link: &Link,
    stats: &DispatcherStats,
    send_times: &Mutex<HashMap<u64, Instant>>,
    rt: &CodecRuntime,
) -> Result<()> {
    let wf = WireFrame::new(
        MessageType::Data,
        frame,
        batch,
        serialized_len as u64,
        count,
        SharedPayload::from_vec(payload, rt.buffers_arc()),
    )?;
    let now = Instant::now();
    {
        let mut st = send_times.lock().unwrap();
        for f in frame..frame + batch as u64 {
            st.insert(f, now);
        }
    }
    let wire_size = wf.wire_size();
    to_first.send_frame(wf, link, &stats.data_tx)?;
    stats.meter.tx_bytes.add(wire_size);
    Ok(())
}

/// Stack `b` copies of the per-frame input values into `scratch` (the
/// dispatcher replays one input tensor per frame, so a batch is the
/// input repeated). Rebuilds only when the batch size changes.
fn stack_input<'a>(input: &'a [f32], b: usize, scratch: &'a mut Vec<f32>) -> &'a [f32] {
    if b == 1 {
        return input;
    }
    if scratch.len() != input.len() * b {
        scratch.clear();
        for _ in 0..b {
            scratch.extend_from_slice(input);
        }
    }
    scratch
}

/// Pump `frames` input tensors into the chain and collect all results.
///
/// Returns when every frame's result has come back. If `expected` is given,
/// each result is compared against it and the max abs error recorded.
#[allow(clippy::too_many_arguments)]
pub fn run_inference(
    input: Tensor,
    frames: u64,
    to_first: impl Into<FrameSink>,
    from_last: impl Into<FrameSource>,
    opts: InferenceOptions,
    link: Arc<Link>,
    stats: Arc<DispatcherStats>,
    expected: Option<Tensor>,
    output_shape: Vec<usize>,
) -> Result<()> {
    let mut to_first = to_first.into();
    let mut from_last = from_last.into();
    let send_times: Arc<Mutex<HashMap<u64, Instant>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let codecs = opts.codecs;
    let recovery = opts.recovery;
    // Encode scratch + payload recycling for the dispatcher's side.
    let rt = opts
        .rt
        .clone()
        .with_buffers(Arc::new(BufPool::new(opts.pipe_depth + 2)));

    let mut pool = WorkerPool::new();
    if opts.pipelined {
        // ---- encode | send on separate threads ----
        // The sender is spawned first: `WorkerPool::join` surfaces the
        // first error in spawn order, and when the chain dies the
        // sender holds the root cause (the peer-labelled socket error)
        // while the encoder only sees its pipe close.
        // The pipe carries (first frame id, batch, payload, mid).
        let (enc_tx, enc_rx) = pipe::<(u64, u32, Vec<u8>, usize)>(opts.pipe_depth);
        let count = input.len() as u64;
        {
            let stats = Arc::clone(&stats);
            let send_times = Arc::clone(&send_times);
            let link = Arc::clone(&link);
            let rt = rt.clone();
            pool.spawn("dispatcher-sender", move || {
                while let Some((frame, batch, payload, mid)) = enc_rx.recv() {
                    // Depth of the encode→send queue *behind* this
                    // message, plus whatever the sink has serialized but
                    // not yet put on the wire (0 on the blocking plane,
                    // whose sends complete inline): the adaptive
                    // batcher's feedback signal and the run report's
                    // backpressure high-water.
                    stats.queue_depth.observe(enc_rx.len() + to_first.queue_len());
                    send_data_frame(
                        &mut to_first,
                        frame,
                        batch,
                        payload,
                        mid,
                        count * batch as u64,
                        &link,
                        &stats,
                        &send_times,
                        &rt,
                    )?;
                }
                // FIFO: shutdown travels behind the last frame,
                // broadcast to every stage-0 replica.
                to_first.broadcast_shutdown(&link, &stats.data_tx)?;
                Ok(())
            });
        }
        {
            let stats = Arc::clone(&stats);
            let rt = rt.clone();
            let b_max = opts.batch.max(1);
            let adaptive = opts.batch_adaptive;
            let recovery = recovery.clone();
            pool.spawn("dispatcher-encoder", move || {
                let mut scratch: Vec<f32> = Vec::new();
                let mut sent = 0u64;
                while sent < frames {
                    // Adaptive mode batches what is already waiting:
                    // a drained send queue means the wire keeps up, so
                    // ship single frames for latency; a backed-up queue
                    // means per-message overhead is the bottleneck, so
                    // coalesce up to the cap. The tail flushes short.
                    let want = if adaptive {
                        (stats.queue_depth.last() + 1).min(b_max)
                    } else {
                        b_max
                    };
                    let b = (want as u64).min(frames - sent).max(1) as usize;
                    if let Some(rec) = &recovery {
                        // Bounded in-flight window: a new message takes a
                        // slot; re-dispatches below reuse the one their
                        // frame already holds.
                        rec.supervisor.acquire_slot()?;
                        rec.supervisor.note_sent(sent, b as u32);
                    }
                    let values = stack_input(input.data(), b, &mut scratch);
                    let (payload, mid) = codecs
                        .data
                        .encode_frame(values, &rt, Some(&stats.meter.codec));
                    enc_tx
                        .send((sent, b as u32, payload, mid))
                        .map_err(|_| DeferError::ChannelClosed("dispatcher encode pipe"))?;
                    sent += b as u64;
                }
                if let Some(rec) = &recovery {
                    // Re-dispatch loop: replay any message lost to a
                    // replica death or an exhausted chunk-retry budget.
                    // The dispatcher replays one input tensor per frame,
                    // so re-encoding from the input is exact. Closing the
                    // pipe (on return) releases the sender to broadcast
                    // shutdown — only after everything completed.
                    let sup = &rec.supervisor;
                    let mut last_probe = sup.progress_probe();
                    let mut last_change = Instant::now();
                    while !sup.all_complete() {
                        if let Some((frame, batch)) = sup.take_redispatch() {
                            let b = batch.max(1) as usize;
                            let values = stack_input(input.data(), b, &mut scratch);
                            let (payload, mid) = codecs
                                .data
                                .encode_frame(values, &rt, Some(&stats.meter.codec));
                            sup.count_frame_redispatched(b as u64);
                            enc_tx
                                .send((frame, b as u32, payload, mid))
                                .map_err(|_| {
                                    DeferError::ChannelClosed("dispatcher encode pipe")
                                })?;
                            last_change = Instant::now();
                            continue;
                        }
                        sup.wait_progress(Duration::from_millis(100));
                        let probe = sup.progress_probe();
                        if probe != last_probe {
                            last_probe = probe;
                            last_change = Instant::now();
                        } else if last_change.elapsed() > REDISPATCH_STALL {
                            return Err(DeferError::Coordinator(format!(
                                "dispatcher: recovery stalled — no frame completed, \
                                 re-dispatched, or escalated for {REDISPATCH_STALL:?}"
                            )));
                        }
                    }
                }
                Ok(())
            });
        }
    } else {
        let stats = Arc::clone(&stats);
        let send_times = Arc::clone(&send_times);
        let link = Arc::clone(&link);
        let rt = rt.clone();
        let b_max = opts.batch.max(1);
        let recovery = recovery.clone();
        pool.spawn("dispatcher-sender", move || {
            let count = input.len() as u64;
            let mut scratch: Vec<f32> = Vec::new();
            let mut sent = 0u64;
            while sent < frames {
                // Inline mode has no send queue to adapt to; it uses
                // the fixed batch size (tail flushes short).
                let b = (b_max as u64).min(frames - sent).max(1) as usize;
                if let Some(rec) = &recovery {
                    rec.supervisor.acquire_slot()?;
                    rec.supervisor.note_sent(sent, b as u32);
                }
                let values = stack_input(input.data(), b, &mut scratch);
                let (payload, mid) = codecs
                    .data
                    .encode_frame(values, &rt, Some(&stats.meter.codec));
                send_data_frame(
                    &mut to_first,
                    sent,
                    b as u32,
                    payload,
                    mid,
                    count * b as u64,
                    &link,
                    &stats,
                    &send_times,
                    &rt,
                )?;
                sent += b as u64;
            }
            if let Some(rec) = &recovery {
                // Re-dispatch loop (inline flavour): same contract as the
                // pipelined encoder's — replay lost messages until every
                // sent frame completed, then let shutdown travel.
                let sup = &rec.supervisor;
                let mut last_probe = sup.progress_probe();
                let mut last_change = Instant::now();
                while !sup.all_complete() {
                    if let Some((frame, batch)) = sup.take_redispatch() {
                        let b = batch.max(1) as usize;
                        let values = stack_input(input.data(), b, &mut scratch);
                        let (payload, mid) = codecs
                            .data
                            .encode_frame(values, &rt, Some(&stats.meter.codec));
                        sup.count_frame_redispatched(b as u64);
                        send_data_frame(
                            &mut to_first,
                            frame,
                            b as u32,
                            payload,
                            mid,
                            count * b as u64,
                            &link,
                            &stats,
                            &send_times,
                            &rt,
                        )?;
                        last_change = Instant::now();
                        continue;
                    }
                    sup.wait_progress(Duration::from_millis(100));
                    let probe = sup.progress_probe();
                    if probe != last_probe {
                        last_probe = probe;
                        last_change = Instant::now();
                    } else if last_change.elapsed() > REDISPATCH_STALL {
                        return Err(DeferError::Coordinator(format!(
                            "dispatcher: recovery stalled — no frame completed, \
                             re-dispatched, or escalated for {REDISPATCH_STALL:?}"
                        )));
                    }
                }
            }
            // FIFO: shutdown travels behind the last frame, broadcast
            // to every stage-0 replica.
            to_first.broadcast_shutdown(&link, &stats.data_tx)?;
            Ok(())
        });
    }

    // ---- result path: read (and, when pipelined, decode elsewhere) ----
    // A batched result decodes once, then splits into its member frames
    // FIFO: each gets its own latency sample, throughput cycle, and
    // reference check, so per-frame metrics stay batch-size-invariant.
    let out_elems: usize = output_shape.iter().product();
    // Returns how many logical frames this message newly completed (0 for
    // a duplicate delivery of a re-dispatched frame, or a corrupt result
    // escalated back to the re-dispatch queue).
    let decode_one = {
        let stats = Arc::clone(&stats);
        let send_times = Arc::clone(&send_times);
        let rt = rt.clone();
        let recovery = recovery.clone();
        move |msg: Message| -> Result<u64> {
            let Message {
                frame: first,
                batch,
                serialized_len,
                count,
                mut payload,
                ..
            } = msg;
            let b = batch.max(1) as usize;
            if let Some(rec) = &recovery {
                if rec.supervisor.is_frame_done(first) {
                    // Duplicate delivery: the original arrived after its
                    // frame was already re-dispatched. Drop it.
                    if let Some(p) = rt.buffers() {
                        p.put(payload);
                    }
                    return Ok(0);
                }
            }
            let client = recovery.as_ref().and_then(|r| r.client.as_deref());
            let decoded = decode_with_retry(client, first, &mut payload, |bytes| {
                codecs.data.decode_frame(
                    bytes,
                    serialized_len as usize,
                    count as usize,
                    &rt,
                    Some(&stats.meter.codec),
                )
            });
            let values = match decoded {
                Ok(v) => v,
                Err(DeferError::CorruptChunk { .. }) if recovery.is_some() => {
                    // Retry budget exhausted at the result boundary:
                    // escalate to a whole-message re-dispatch.
                    let rec = recovery.as_ref().unwrap();
                    rec.supervisor.escalate_frame(first, batch.max(1));
                    if let Some(p) = rt.buffers() {
                        p.put(payload);
                    }
                    return Ok(0);
                }
                Err(e) => return Err(e),
            };
            if let Some(p) = rt.buffers() {
                p.put(payload);
            }
            if values.len() != out_elems * b {
                return Err(DeferError::Coordinator(format!(
                    "dispatcher: result batch of {b} frame(s) carries {} values, \
                     expected {}",
                    values.len(),
                    out_elems * b
                )));
            }
            let finish = |frame: u64, result: Tensor| -> Result<()> {
                let t_sent = send_times.lock().unwrap().remove(&frame);
                if let Some(exp) = &expected {
                    let err = result.max_abs_diff(exp)?;
                    let mut slot = stats.reference_error.lock().unwrap();
                    *slot = Some(slot.unwrap_or(0.0).max(err));
                }
                if let Some(t) = t_sent {
                    stats.latency.record(t.elapsed());
                }
                stats.clock.record_cycle();
                Ok(())
            };
            if b == 1 {
                finish(first, Tensor::new(output_shape.clone(), values)?)?;
            } else {
                for (i, sub) in values.chunks(out_elems).enumerate() {
                    let result = Tensor::new(output_shape.clone(), sub.to_vec())?;
                    finish(first + i as u64, result)?;
                }
            }
            if let Some(rec) = &recovery {
                rec.supervisor.mark_frame_done(first);
            }
            Ok(b as u64)
        }
    };

    let direct = matches!(from_last, FrameSource::Direct(_));
    // Recovery runs cannot terminate on a frame count: re-dispatched
    // messages may arrive more than once, so both the reader and the
    // receiver run until the chain relays shutdown (which the sender
    // broadcasts only once every frame completed), deduping by frame id.
    let recovering = recovery.is_some();
    if opts.pipelined && direct {
        // Blocking plane: a dedicated reader thread pulls framed bytes
        // off the merge set so socket waits overlap with decode.
        let (res_tx, res_rx) = pipe::<Message>(opts.pipe_depth);
        let reader_rt = rt.clone();
        pool.spawn("dispatcher-reader", move || {
            let mut data_seen = 0u64;
            while recovering || data_seen < frames {
                // Payload buffers come from the dispatcher's pool (the
                // decode side puts them back once decoded).
                let msg = from_last.recv_pooled(&ByteCounter::new(), reader_rt.buffers())?;
                let stop = msg.msg_type == MessageType::Shutdown;
                if matches!(
                    msg.msg_type,
                    MessageType::Data | MessageType::ResultMsg
                ) {
                    data_seen += msg.batch.max(1) as u64;
                }
                res_tx
                    .send(msg)
                    .map_err(|_| DeferError::ChannelClosed("dispatcher result pipe"))?;
                if stop {
                    return Ok(());
                }
            }
            // Drain the trailing shutdown if the chain relays it.
            let _ = from_last.recv(&ByteCounter::new());
            Ok(())
        });
        pool.spawn("dispatcher-receiver", move || {
            let mut received = 0u64;
            while recovering || received < frames {
                let Some(msg) = res_rx.recv() else {
                    return Err(DeferError::ChannelClosed("dispatcher result pipe"));
                };
                match msg.msg_type {
                    MessageType::Data | MessageType::ResultMsg => {
                        received += decode_one(msg)?;
                    }
                    MessageType::Shutdown => break,
                    other => {
                        return Err(DeferError::Coordinator(format!(
                            "dispatcher: unexpected {other:?}"
                        )))
                    }
                }
            }
            if recovering && received != frames {
                return Err(DeferError::Coordinator(format!(
                    "dispatcher: recovery run completed {received} of {frames} frames"
                )));
            }
            Ok(())
        });
    } else {
        // Inline mode, or a reactor-fed source: the ingress machine (or
        // the inline contract) already decouples the wire from decode,
        // so the receiver consumes the source directly — no reader
        // thread.
        pool.spawn("dispatcher-receiver", move || {
            let mut received = 0u64;
            while recovering || received < frames {
                let msg = from_last.recv_pooled(&ByteCounter::new(), rt.buffers())?;
                match msg.msg_type {
                    MessageType::Data | MessageType::ResultMsg => {
                        received += decode_one(msg)?;
                    }
                    MessageType::Shutdown => break,
                    other => {
                        return Err(DeferError::Coordinator(format!(
                            "dispatcher: unexpected {other:?}"
                        )))
                    }
                }
            }
            if recovering && received != frames {
                return Err(DeferError::Coordinator(format!(
                    "dispatcher: recovery run completed {received} of {frames} frames"
                )));
            }
            // Drain the trailing shutdown if the chain relays it (the
            // reactor ingress machine drains its own mesh, so only the
            // blocking source holds one; a recovery run already consumed
            // it as its loop terminator).
            if direct && !recovering && received == frames {
                let _ = from_last.recv(&ByteCounter::new());
            }
            Ok(())
        });
    }

    pool.join()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_initialize_clean() {
        let s = DispatcherStats::new(EnergyModel::default());
        assert_eq!(s.architecture_tx.total(), 0);
        assert_eq!(s.clock.cycles(), 0);
        assert!(s.reference_error.lock().unwrap().is_none());
    }
}
