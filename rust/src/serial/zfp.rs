//! Fixed-rate ZFP-style floating-point codec (Lindstrom 2014), from scratch.
//!
//! The paper serializes weights and activations with ZFP; no codec crates
//! exist in the offline environment, so this implements the algorithm
//! family directly, specialized to 1-D blocks of 4 f32 values:
//!
//! 1. **Block floating point**: each 4-value block shares the max exponent;
//!    values become signed fixed-point integers with `INT_PREC` fraction
//!    bits below that exponent.
//! 2. **Decorrelating lift**: a 2-level exactly-invertible integer
//!    S-transform (Haar-style lifting) concentrating energy in the low
//!    coefficients, playing the role of zfp's orthogonal block transform.
//! 3. **Negabinary mapping**: signed -> unsigned so magnitude ordering
//!    matches bit-plane ordering.
//! 4. **Bit-plane coding, MSB first**, truncated to the fixed per-block bit
//!    budget — this is where fixed-rate compression (and its bounded loss)
//!    happens. Planes that are entirely zero cost 1 bit (a group-test flag),
//!    which lets low-entropy blocks carry more significant planes within the
//!    same budget.
//!
//! `rate` is bits-per-value (1..=32). Rate 32 is near-lossless for
//! activations/weights (max rel. error ~1e-6 measured); rate 16 halves the
//! payload of raw f32. Every block costs exactly `4 * rate` bits, so
//! payload size is `ceil(n/4) * rate * 4 / 8` bytes + a 12-byte header —
//! the deterministic-size property the dispatcher relies on.

use crate::error::{DeferError, Result};
use crate::serial::bits::{BitReader, BitWriter};

/// Fixed-point fraction bits under the block exponent. Two lifting levels
/// grow magnitudes by <= 2 bits, so 28 + 2 = 30 bits stays inside i32.
const INT_PREC: i32 = 28;
/// Exponent bias for the 8-bit stored exponent (f32 exponent range).
const EXP_BIAS: i32 = 127;
const MAGIC: u32 = 0x5A46_5031; // "ZFP1"

/// Encode parameters: bits per value.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ZfpRate(pub u8);

impl ZfpRate {
    pub fn validate(self) -> Result<Self> {
        // Rate 3 is the floor: a nonzero block spends 9 header bits
        // (flag + 8-bit exponent) and the budget is 4*rate bits.
        if (3..=32).contains(&self.0) {
            Ok(self)
        } else {
            Err(DeferError::Codec(format!("zfp rate {} out of 3..=32", self.0)))
        }
    }

    pub fn block_bits(self) -> usize {
        self.0 as usize * 4
    }
}

#[inline]
fn fwd_lift(v: &mut [i32; 4]) {
    // Level 1: pairwise S-transform (exactly invertible).
    let d0 = v[0].wrapping_sub(v[1]);
    let s0 = v[1].wrapping_add(d0 >> 1);
    let d1 = v[2].wrapping_sub(v[3]);
    let s1 = v[3].wrapping_add(d1 >> 1);
    // Level 2 over the sums.
    let dd = s0.wrapping_sub(s1);
    let ss = s1.wrapping_add(dd >> 1);
    *v = [ss, dd, d0, d1];
}

#[inline]
fn inv_lift(v: &mut [i32; 4]) {
    let [ss, dd, d0, d1] = *v;
    let s1 = ss.wrapping_sub(dd >> 1);
    let s0 = s1.wrapping_add(dd);
    let v1 = s0.wrapping_sub(d0 >> 1);
    let v0 = v1.wrapping_add(d0);
    let v3 = s1.wrapping_sub(d1 >> 1);
    let v2 = v3.wrapping_add(d1);
    *v = [v0, v1, v2, v3];
}

/// Signed -> negabinary-ish unsigned (zfp's int2uint): order by magnitude
/// so MSB-first bit planes are an embedded code.
#[inline]
fn int2uint(x: i32) -> u32 {
    ((x as u32).wrapping_add(0xAAAA_AAAA)) ^ 0xAAAA_AAAA
}

#[inline]
fn uint2int(u: u32) -> i32 {
    (u ^ 0xAAAA_AAAA).wrapping_sub(0xAAAA_AAAA) as i32
}

fn encode_block(w: &mut BitWriter, block: &[f32; 4], rate: ZfpRate) {
    let start = w.bit_len();
    let budget = rate.block_bits();

    // Sanitize first (non-finite values encode as zero), THEN take the
    // block exponent from the max finite magnitude.
    let mut vals = [0.0f32; 4];
    for (i, x) in block.iter().enumerate() {
        vals[i] = if x.is_finite() { *x } else { 0.0 };
    }
    let max_abs = vals.iter().fold(0.0f32, |m, x| m.max(x.abs()));
    if max_abs == 0.0 {
        // All-zero block: single 0 flag.
        w.write_bit(false);
        w.pad_to(start + budget);
        return;
    }
    w.write_bit(true);
    // frexp-style exponent: max_abs = m * 2^e, m in [0.5, 1).
    let e = max_abs.log2().floor() as i32 + 1;
    let e_biased = (e + EXP_BIAS).clamp(0, 255) as u64;
    w.write(e_biased, 8);

    // Fixed-point conversion under the shared exponent.
    let scale = (INT_PREC - e) as f32;
    let factor = scale.exp2();
    let mut v = [0i32; 4];
    for (i, val) in vals.iter().enumerate() {
        v[i] = (val * factor).round().clamp(-(1i64 << 30) as f32, ((1i64 << 30) - 1) as f32)
            as i32;
    }
    fwd_lift(&mut v);
    let u: [u32; 4] = [int2uint(v[0]), int2uint(v[1]), int2uint(v[2]), int2uint(v[3])];

    // Bit planes, MSB (plane 31) first. Group-test bit per plane: 0 = plane
    // all zero, 1 = 4 raw bits follow. Planes are accumulated into a local
    // 64-bit buffer and flushed in bulk (§Perf: one BitWriter call per ~12
    // planes instead of two per plane).
    let mut acc: u64 = 0;
    let mut acc_bits: u8 = 0;
    let mut used = w.bit_len() - start; // 9 header bits
    for plane in (0..32).rev() {
        let bits = (((u[0] >> plane) & 1) << 3)
            | (((u[1] >> plane) & 1) << 2)
            | (((u[2] >> plane) & 1) << 1)
            | ((u[3] >> plane) & 1);
        let cost: usize = if bits == 0 { 1 } else { 5 };
        if used + cost > budget {
            break;
        }
        if bits == 0 {
            acc <<= 1;
            acc_bits += 1;
        } else {
            acc = (acc << 5) | 0x10 | bits as u64;
            acc_bits += 5;
        }
        used += cost;
        if acc_bits > 59 {
            w.write(acc, acc_bits);
            acc = 0;
            acc_bits = 0;
        }
    }
    if acc_bits > 0 {
        w.write(acc, acc_bits);
    }
    w.pad_to(start + budget);
}

fn decode_block(r: &mut BitReader, rate: ZfpRate) -> [f32; 4] {
    let start = r.bit_pos();
    let budget = rate.block_bits();
    let mut out = [0.0f32; 4];
    if !r.read_bit() {
        r.seek(start + budget);
        return out;
    }
    let e = r.read(8) as i32 - EXP_BIAS;
    let mut u = [0u32; 4];
    for plane in (0..32).rev() {
        let used = r.bit_pos() - start;
        if used + 1 > budget {
            break;
        }
        let present = r.read_bit();
        if present {
            if r.bit_pos() - start + 4 > budget {
                break;
            }
            let bits = r.read(4) as u32;
            for i in 0..4 {
                u[i] |= ((bits >> (3 - i)) & 1) << plane;
            }
        }
    }
    let mut v = [uint2int(u[0]), uint2int(u[1]), uint2int(u[2]), uint2int(u[3])];
    inv_lift(&mut v);
    let factor = ((e - INT_PREC) as f32).exp2();
    for i in 0..4 {
        out[i] = v[i] as f32 * factor;
    }
    r.seek(start + budget);
    out
}

/// Encode an f32 slice at the given fixed rate.
///
/// Layout: `MAGIC u32le | count u32le | rate u8 | pad[3] | blocks...`
pub fn encode(data: &[f32], rate: ZfpRate) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(encoded_size(data.len(), rate));
    encode_into(data, rate, &mut out)?;
    Ok(out)
}

/// [`encode`] into a reused buffer (cleared first) — the pooled-buffer
/// variant for the per-frame hot path. Output bytes are identical to
/// [`encode`].
pub fn encode_into(data: &[f32], rate: ZfpRate, out: &mut Vec<u8>) -> Result<()> {
    let rate = rate.validate()?;
    let n = data.len();
    if n as u64 > u32::MAX as u64 {
        return Err(DeferError::Codec("zfp: >u32::MAX elements".into()));
    }
    out.clear();
    out.reserve(encoded_size(n, rate));
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&(n as u32).to_le_bytes());
    out.push(rate.0);
    out.extend_from_slice(&[0u8; 3]);
    // Emit block bits straight after the header in the (reused) output
    // buffer — no separate body allocation, no copy. Block accounting in
    // encode_block is relative to the writer's running bit_len, so the
    // 96 header bits underneath do not disturb the fixed-rate budgets.
    let mut w = BitWriter::over(std::mem::take(out));
    for chunk in data.chunks(4) {
        let mut block = [0.0f32; 4];
        block[..chunk.len()].copy_from_slice(chunk);
        encode_block(&mut w, &block, rate);
    }
    *out = w.into_bytes();
    Ok(())
}

/// Decode a buffer produced by [`encode`].
pub fn decode(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() < 12 {
        return Err(DeferError::Codec("zfp: truncated header".into()));
    }
    let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(DeferError::Codec("zfp: bad magic".into()));
    }
    let n = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
    let rate = ZfpRate(bytes[8]).validate()?;
    let n_blocks = n.div_ceil(4);
    let need = 12 + (n_blocks * rate.block_bits()).div_ceil(8);
    if bytes.len() < need {
        return Err(DeferError::Codec(format!(
            "zfp: body too short ({} < {need})",
            bytes.len()
        )));
    }
    let mut r = BitReader::new(&bytes[12..]);
    let mut out = Vec::with_capacity(n_blocks * 4);
    for _ in 0..n_blocks {
        out.extend_from_slice(&decode_block(&mut r, rate));
    }
    out.truncate(n);
    Ok(out)
}

/// Exact encoded size for `n` values at `rate` — used by the dispatcher to
/// pre-size buffers and by the payload accounting.
pub fn encoded_size(n: usize, rate: ZfpRate) -> usize {
    12 + (n.div_ceil(4) * rate.block_bits()).div_ceil(8)
}

/// Worst-case absolute error for a block with max exponent `e_max` at
/// `rate`: dominated by dropped planes (see module docs). Exposed for the
/// accuracy tests and for choosing per-socket rates.
pub fn error_bound(max_abs: f32, rate: ZfpRate) -> f32 {
    if max_abs == 0.0 {
        return 0.0;
    }
    let e = max_abs.log2().floor() as i32 + 1;
    // Bits available for planes after flag+exponent; each coded plane costs
    // <= 5 bits, so at least this many significant planes survive:
    let planes = ((rate.block_bits() - 9) / 5) as i32;
    let dropped_weight = (e - INT_PREC + (32 - planes).max(0)) as f32;
    // One lifting level can double an error; two levels -> factor 4 margin.
    4.0 * dropped_weight.exp2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn lift_is_exactly_invertible() {
        let mut rng = Rng::new(31);
        for _ in 0..10_000 {
            let orig = [
                (rng.next_u64() as i32) >> 4,
                (rng.next_u64() as i32) >> 4,
                (rng.next_u64() as i32) >> 4,
                (rng.next_u64() as i32) >> 4,
            ];
            let mut v = orig;
            fwd_lift(&mut v);
            inv_lift(&mut v);
            assert_eq!(v, orig);
        }
    }

    #[test]
    fn int_uint_bijection() {
        for x in [0i32, 1, -1, 1234567, -7654321, i32::MAX / 2, i32::MIN / 2] {
            assert_eq!(uint2int(int2uint(x)), x);
        }
    }

    #[test]
    fn zeros_are_exact() {
        let data = vec![0.0f32; 37];
        let enc = encode(&data, ZfpRate(8)).unwrap();
        assert_eq!(decode(&enc).unwrap(), data);
    }

    #[test]
    fn rate32_near_lossless() {
        // Block floating point: precision is relative to the *block max*
        // (small values sharing a block with a large one keep absolute, not
        // relative, accuracy — inherent to zfp's design).
        let mut rng = Rng::new(32);
        let data: Vec<f32> = (0..4096).map(|_| rng.normal_f32()).collect();
        let dec = decode(&encode(&data, ZfpRate(32)).unwrap()).unwrap();
        let mut max_rel = 0.0f32;
        for (cin, cout) in data.chunks(4).zip(dec.chunks(4)) {
            let bmax = cin.iter().fold(1e-6f32, |m, x| m.max(x.abs()));
            for (a, b) in cin.iter().zip(cout) {
                max_rel = max_rel.max((a - b).abs() / bmax);
            }
        }
        assert!(max_rel < 1e-5, "rate-32 max block-rel err {max_rel}");
    }

    #[test]
    fn error_decreases_with_rate() {
        let mut rng = Rng::new(33);
        let data: Vec<f32> = (0..4096).map(|_| rng.normal_f32() * 10.0).collect();
        let mut last = f32::INFINITY;
        for rate in [4u8, 8, 16, 24, 32] {
            let dec = decode(&encode(&data, ZfpRate(rate)).unwrap()).unwrap();
            let err = data
                .iter()
                .zip(&dec)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                err <= last * 1.5 + 1e-6,
                "error not decreasing: rate {rate} err {err} last {last}"
            );
            last = err;
        }
        assert!(last < 1e-4, "rate-32 abs err {last}");
    }

    #[test]
    fn error_within_published_bound() {
        let mut rng = Rng::new(34);
        for rate in [8u8, 16, 32] {
            for _ in 0..50 {
                let scale = (rng.f32() * 20.0 - 10.0).exp2();
                let data: Vec<f32> = (0..64).map(|_| rng.normal_f32() * scale).collect();
                let dec = decode(&encode(&data, ZfpRate(rate)).unwrap()).unwrap();
                for chunk in data.chunks(4).zip(dec.chunks(4)) {
                    let max_abs = chunk.0.iter().fold(0.0f32, |m, x| m.max(x.abs()));
                    let bound = error_bound(max_abs, ZfpRate(rate));
                    for (a, b) in chunk.0.iter().zip(chunk.1) {
                        assert!(
                            (a - b).abs() <= bound,
                            "rate {rate}: |{a} - {b}| > bound {bound}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn encoded_size_is_deterministic() {
        let mut rng = Rng::new(35);
        for n in [0usize, 1, 3, 4, 5, 100, 4097] {
            let data: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            for rate in [3u8, 7, 16, 32] {
                let enc = encode(&data, ZfpRate(rate)).unwrap();
                assert_eq!(enc.len(), encoded_size(n, ZfpRate(rate)), "n={n} rate={rate}");
            }
        }
    }

    #[test]
    fn rate16_halves_payload() {
        let n = 10_000;
        let size = encoded_size(n, ZfpRate(16));
        assert!((size as f64) < 0.51 * (n * 4) as f64);
    }

    #[test]
    fn truncated_and_corrupt_inputs_rejected() {
        let data = vec![1.0f32; 16];
        let enc = encode(&data, ZfpRate(16)).unwrap();
        assert!(decode(&enc[..8]).is_err());
        assert!(decode(&enc[..enc.len() - 1]).is_err());
        let mut bad_magic = enc.clone();
        bad_magic[0] ^= 0xFF;
        assert!(decode(&bad_magic).is_err());
        let mut bad_rate = enc;
        bad_rate[8] = 99;
        assert!(decode(&bad_rate).is_err());
    }

    #[test]
    fn non_finite_values_become_zero() {
        let data = [f32::NAN, f32::INFINITY, -f32::INFINITY, 1.0];
        let dec = decode(&encode(&data, ZfpRate(32)).unwrap()).unwrap();
        assert!(dec[..3].iter().all(|x| x.is_finite()));
        assert!((dec[3] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn property_random_shapes_and_scales() {
        let mut rng = Rng::new(36);
        for _ in 0..100 {
            let n = rng.range(1, 500);
            let scale = (rng.f32() * 30.0 - 15.0).exp2();
            let data: Vec<f32> = (0..n).map(|_| rng.normal_f32() * scale).collect();
            let dec = decode(&encode(&data, ZfpRate(32)).unwrap()).unwrap();
            assert_eq!(dec.len(), n);
            for (a, b) in data.iter().zip(&dec) {
                let tol = a.abs().max(scale) * 1e-5 + 1e-30;
                assert!((a - b).abs() <= tol, "{a} vs {b} (scale {scale})");
            }
        }
    }
}
