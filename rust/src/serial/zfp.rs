//! Fixed-rate ZFP-style floating-point codec (Lindstrom 2014), from scratch.
//!
//! The paper serializes weights and activations with ZFP; no codec crates
//! exist in the offline environment, so this implements the algorithm
//! family directly, specialized to 1-D blocks of 4 f32 values:
//!
//! 1. **Block floating point**: each 4-value block shares the max exponent;
//!    values become signed fixed-point integers with `INT_PREC` fraction
//!    bits below that exponent.
//! 2. **Decorrelating lift**: a 2-level exactly-invertible integer
//!    S-transform (Haar-style lifting) concentrating energy in the low
//!    coefficients, playing the role of zfp's orthogonal block transform.
//! 3. **Negabinary mapping**: signed -> unsigned so magnitude ordering
//!    matches bit-plane ordering.
//! 4. **Bit-plane coding, MSB first**, truncated to the fixed per-block bit
//!    budget — this is where fixed-rate compression (and its bounded loss)
//!    happens. Planes that are entirely zero cost 1 bit (a group-test flag),
//!    which lets low-entropy blocks carry more significant planes within the
//!    same budget.
//!
//! `rate` is bits-per-value (1..=32). Rate 32 is near-lossless for
//! activations/weights (max rel. error ~1e-6 measured); rate 16 halves the
//! payload of raw f32. Every block costs exactly `4 * rate` bits, so
//! payload size is `ceil(n/4) * rate * 4 / 8` bytes + a 12-byte header —
//! the deterministic-size property the dispatcher relies on.
//!
//! # Kernels
//!
//! Two implementations produce the byte stream: the reference scalar
//! block-at-a-time coder ([`CodecKernel::Scalar`]) and a lane-batched
//! kernel ([`CodecKernel::Batched`], the default) that transforms
//! [`GROUP_BLOCKS`] blocks at once in structure-of-arrays form —
//! sanitize (SSE2 on x86_64), quantize, lift and the negabinary map as
//! straight-line loops the compiler autovectorizes, with bit-plane
//! emission reading nibbles out of a bit-transposed u128 instead of 32
//! shift-and-test iterations per block. The two are **byte-identical**
//! by construction (shared exponent/scale helpers, verbatim quantize
//! expression, same bit sequence); `tests/codec_kernels.rs` proves it
//! across adversarial exponent edges.

use crate::error::{DeferError, Result};
use crate::serial::bits::{BitReader, BitWriter};
use crate::serial::CodecKernel;

/// Fixed-point fraction bits under the block exponent. Two lifting levels
/// grow magnitudes by <= 2 bits, so 28 + 2 = 30 bits stays inside i32.
const INT_PREC: i32 = 28;
/// Exponent bias for the 8-bit stored exponent (f32 exponent range).
const EXP_BIAS: i32 = 127;
const MAGIC: u32 = 0x5A46_5031; // "ZFP1"

/// Blocks transformed together by the batched kernel (64 f32 lanes).
const GROUP_BLOCKS: usize = 16;
const GROUP_VALS: usize = GROUP_BLOCKS * 4;

/// Encode parameters: bits per value.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ZfpRate(pub u8);

impl ZfpRate {
    pub fn validate(self) -> Result<Self> {
        // Rate 3 is the floor: a nonzero block spends 9 header bits
        // (flag + 8-bit exponent) and the budget is 4*rate bits.
        if (3..=32).contains(&self.0) {
            Ok(self)
        } else {
            Err(DeferError::Codec(format!("zfp rate {} out of 3..=32", self.0)))
        }
    }

    pub fn block_bits(self) -> usize {
        self.0 as usize * 4
    }
}

#[inline]
fn fwd_lift(v: &mut [i32; 4]) {
    // Level 1: pairwise S-transform (exactly invertible).
    let d0 = v[0].wrapping_sub(v[1]);
    let s0 = v[1].wrapping_add(d0 >> 1);
    let d1 = v[2].wrapping_sub(v[3]);
    let s1 = v[3].wrapping_add(d1 >> 1);
    // Level 2 over the sums.
    let dd = s0.wrapping_sub(s1);
    let ss = s1.wrapping_add(dd >> 1);
    *v = [ss, dd, d0, d1];
}

#[inline]
fn inv_lift(v: &mut [i32; 4]) {
    let [ss, dd, d0, d1] = *v;
    let s1 = ss.wrapping_sub(dd >> 1);
    let s0 = s1.wrapping_add(dd);
    let v1 = s0.wrapping_sub(d0 >> 1);
    let v0 = v1.wrapping_add(d0);
    let v3 = s1.wrapping_sub(d1 >> 1);
    let v2 = v3.wrapping_add(d1);
    *v = [v0, v1, v2, v3];
}

/// Signed -> negabinary-ish unsigned (zfp's int2uint): order by magnitude
/// so MSB-first bit planes are an embedded code.
#[inline]
fn int2uint(x: i32) -> u32 {
    ((x as u32).wrapping_add(0xAAAA_AAAA)) ^ 0xAAAA_AAAA
}

#[inline]
fn uint2int(u: u32) -> i32 {
    (u ^ 0xAAAA_AAAA).wrapping_sub(0xAAAA_AAAA) as i32
}

/// Exact frexp-style binary exponent of a positive finite f32: the unique
/// `e` with `x` in `[2^(e-1), 2^e)`, read straight from the bit pattern.
/// Exponent extraction used to go through `log2().floor() + 1`, whose
/// libm rounding pushes values just below a power of two into the wrong
/// bucket; both kernels now share this exact form (the stored exponent
/// still travels in the stream, so decode never depends on the choice).
#[inline]
fn block_exponent(x: f32) -> i32 {
    debug_assert!(x > 0.0 && x.is_finite());
    let bits = x.to_bits();
    let biased = (bits >> 23) & 0xFF;
    if biased != 0 {
        biased as i32 - EXP_BIAS + 1
    } else {
        // Subnormal: x = mantissa * 2^-149, so the top set mantissa bit
        // k puts x in [2^(k-149), 2^(k-148)).
        (31 - (bits & 0x007F_FFFF).leading_zeros() as i32) - 148
    }
}

/// 2^n as f32, exact — bit-assembled instead of libm `exp2f` so encode
/// and decode (and both kernels) scale with literally the same factor.
/// Saturates to `inf` above the f32 range and flushes to 0 below the
/// smallest subnormal, matching correctly-rounded `exp2f` on integers.
#[inline]
fn exp2i(n: i32) -> f32 {
    if n >= 128 {
        f32::INFINITY
    } else if n >= -126 {
        f32::from_bits(((n + EXP_BIAS) as u32) << 23)
    } else if n >= -149 {
        f32::from_bits(1u32 << (n + 149))
    } else {
        0.0
    }
}

/// Copy `src` into `dst` replacing non-finite lanes with zero; bit-exact
/// passthrough for every finite input (-0.0 and subnormals included).
fn sanitize_into(src: &[f32], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    #[cfg(target_arch = "x86_64")]
    sanitize_sse2(src, dst);
    #[cfg(not(target_arch = "x86_64"))]
    for (d, x) in dst.iter_mut().zip(src.iter()) {
        *d = if x.is_finite() { *x } else { 0.0 };
    }
}

/// SSE2 sanitize (baseline on x86_64, no runtime dispatch needed):
/// `(v & 0x7FFFFFFF) < inf` selects exactly the finite lanes — NaN and
/// ±inf compare false, subnormals compare true (Rust never enables
/// DAZ/FTZ) — and the mask either passes a lane through bit-exactly or
/// zeroes it, so this equals the portable `is_finite` branch.
#[cfg(target_arch = "x86_64")]
fn sanitize_sse2(src: &[f32], dst: &mut [f32]) {
    use core::arch::x86_64::*;
    let n = src.len();
    let mut i = 0;
    // SAFETY: SSE2 is part of the x86_64 baseline; every load/store
    // stays inside the `i + 4 <= n` bound, which holds for both slices.
    unsafe {
        let abs_mask = _mm_castsi128_ps(_mm_set1_epi32(0x7FFF_FFFF));
        let inf = _mm_castsi128_ps(_mm_set1_epi32(0x7F80_0000));
        while i + 4 <= n {
            let v = _mm_loadu_ps(src.as_ptr().add(i));
            let finite = _mm_cmplt_ps(_mm_and_ps(v, abs_mask), inf);
            _mm_storeu_ps(dst.as_mut_ptr().add(i), _mm_and_ps(v, finite));
            i += 4;
        }
    }
    for (d, x) in dst[i..].iter_mut().zip(&src[i..]) {
        *d = if x.is_finite() { *x } else { 0.0 };
    }
}

fn encode_block(w: &mut BitWriter, block: &[f32; 4], rate: ZfpRate) {
    let start = w.bit_len();
    let budget = rate.block_bits();

    // Sanitize first (non-finite values encode as zero), THEN take the
    // block exponent from the max finite magnitude.
    let mut vals = [0.0f32; 4];
    sanitize_into(block, &mut vals);
    let max_abs = vals.iter().fold(0.0f32, |m, x| m.max(x.abs()));
    if max_abs == 0.0 {
        // All-zero block: single 0 flag.
        w.write_bit(false);
        w.pad_to(start + budget);
        return;
    }
    w.write_bit(true);
    let e = block_exponent(max_abs);
    let e_biased = (e + EXP_BIAS).clamp(0, 255) as u64;
    w.write(e_biased, 8);

    // Fixed-point conversion under the shared exponent.
    let factor = exp2i(INT_PREC - e);
    let mut v = [0i32; 4];
    for (q, val) in v.iter_mut().zip(&vals) {
        *q = (val * factor).round().clamp(-(1i64 << 30) as f32, ((1i64 << 30) - 1) as f32)
            as i32;
    }
    fwd_lift(&mut v);
    let u: [u32; 4] = [int2uint(v[0]), int2uint(v[1]), int2uint(v[2]), int2uint(v[3])];

    // Bit planes, MSB (plane 31) first. Group-test bit per plane: 0 = plane
    // all zero, 1 = 4 raw bits follow. Planes are accumulated into a local
    // 64-bit buffer and flushed in bulk (§Perf: one BitWriter call per ~12
    // planes instead of two per plane).
    let mut acc: u64 = 0;
    let mut acc_bits: u8 = 0;
    let mut used = w.bit_len() - start; // 9 header bits
    for plane in (0..32).rev() {
        let bits = (((u[0] >> plane) & 1) << 3)
            | (((u[1] >> plane) & 1) << 2)
            | (((u[2] >> plane) & 1) << 1)
            | ((u[3] >> plane) & 1);
        let cost: usize = if bits == 0 { 1 } else { 5 };
        if used + cost > budget {
            break;
        }
        if bits == 0 {
            acc <<= 1;
            acc_bits += 1;
        } else {
            acc = (acc << 5) | 0x10 | bits as u64;
            acc_bits += 5;
        }
        used += cost;
        if acc_bits > 59 {
            w.write(acc, acc_bits);
            acc = 0;
            acc_bits = 0;
        }
    }
    if acc_bits > 0 {
        w.write(acc, acc_bits);
    }
    w.pad_to(start + budget);
}

fn decode_block(r: &mut BitReader, rate: ZfpRate) -> [f32; 4] {
    let start = r.bit_pos();
    let budget = rate.block_bits();
    let mut out = [0.0f32; 4];
    if !r.read_bit() {
        r.seek(start + budget);
        return out;
    }
    let e = r.read(8) as i32 - EXP_BIAS;
    let mut u = [0u32; 4];
    for plane in (0..32).rev() {
        let used = r.bit_pos() - start;
        if used + 1 > budget {
            break;
        }
        let present = r.read_bit();
        if present {
            if r.bit_pos() - start + 4 > budget {
                break;
            }
            let bits = r.read(4) as u32;
            for (i, slot) in u.iter_mut().enumerate() {
                *slot |= ((bits >> (3 - i)) & 1) << plane;
            }
        }
    }
    let mut v = [uint2int(u[0]), uint2int(u[1]), uint2int(u[2]), uint2int(u[3])];
    inv_lift(&mut v);
    let factor = exp2i(e - INT_PREC);
    for (o, x) in out.iter_mut().zip(&v) {
        *o = *x as f32 * factor;
    }
    r.seek(start + budget);
    out
}

/// Spread each bit of a 32-bit lane to every 4th bit of a u128
/// (bit `p` -> bit `4p`): two interleave-by-two steps of the standard
/// Morton spread.
#[inline]
fn spread4(x: u32) -> u128 {
    let mut x = x as u64;
    x = (x | (x << 16)) & 0x0000_FFFF_0000_FFFF;
    x = (x | (x << 8)) & 0x00FF_00FF_00FF_00FF;
    x = (x | (x << 4)) & 0x0F0F_0F0F_0F0F_0F0F;
    x = (x | (x << 2)) & 0x3333_3333_3333_3333;
    x = (x | (x << 1)) & 0x5555_5555_5555_5555;
    let mut y = x as u128;
    y = (y | (y << 32)) & 0x0000_0000_FFFF_FFFF_0000_0000_FFFF_FFFF;
    y = (y | (y << 16)) & 0x0000_FFFF_0000_FFFF_0000_FFFF_0000_FFFF;
    y = (y | (y << 8)) & 0x00FF_00FF_00FF_00FF_00FF_00FF_00FF_00FF;
    y = (y | (y << 4)) & 0x0F0F_0F0F_0F0F_0F0F_0F0F_0F0F_0F0F_0F0F;
    y = (y | (y << 2)) & 0x3333_3333_3333_3333_3333_3333_3333_3333;
    y = (y | (y << 1)) & 0x5555_5555_5555_5555_5555_5555_5555_5555;
    y
}

/// Emit one nonzero block's bit planes from a bit-transposed u128: bit
/// `4p + (3 - lane)` of `planes` is bit `p` of lane `lane`, so plane
/// `p`'s group-test nibble is `(planes >> 4p) & 0xF` — the scalar
/// coder's shift-and-or expression, computed once per block. The leading
/// all-zero planes (1 flag bit each) go out as a single masked write.
fn emit_planes(w: &mut BitWriter, u: &[u32], budget: usize, start: usize) {
    let or = u[0] | u[1] | u[2] | u[3];
    let planes =
        spread4(u[3]) | (spread4(u[2]) << 1) | (spread4(u[1]) << 2) | (spread4(u[0]) << 3);
    let mut used = 9usize; // flag + exponent already written
    // A nonzero block always keeps or != 0 (the quantized max is at
    // least 2^27), but clamp to the budget defensively.
    let zeros = (or.leading_zeros() as usize).min(budget - used);
    if zeros > 0 {
        w.write(0, zeros as u8);
        used += zeros;
    }
    let top = 32 - or.leading_zeros() as usize;
    let mut acc: u64 = 0;
    let mut acc_bits: u8 = 0;
    for plane in (0..top).rev() {
        let bits = ((planes >> (4 * plane)) & 0xF) as u64;
        let cost: usize = if bits == 0 { 1 } else { 5 };
        if used + cost > budget {
            break;
        }
        if bits == 0 {
            acc <<= 1;
            acc_bits += 1;
        } else {
            acc = (acc << 5) | 0x10 | bits;
            acc_bits += 5;
        }
        used += cost;
        if acc_bits > 59 {
            w.write(acc, acc_bits);
            acc = 0;
            acc_bits = 0;
        }
    }
    if acc_bits > 0 {
        w.write(acc, acc_bits);
    }
    w.pad_to(start + budget);
}

/// Lane-batched encoder: transform up to [`GROUP_BLOCKS`] blocks in
/// structure-of-arrays form (straight-line lane loops), then emit each
/// block's planes from the transposed nibble word. Byte-identical to
/// [`encode_block`]: shared `block_exponent`/`exp2i`, the quantize
/// expression verbatim (kept portable — `_mm_cvtps_epi32` rounds ties
/// to even where `f32::round` rounds away from zero), same bit order.
fn encode_group(w: &mut BitWriter, group: &[f32], rate: ZfpRate) {
    let nb = group.len().div_ceil(4);
    let budget = rate.block_bits();

    // Tail lanes beyond the group stay zero, matching the scalar coder's
    // zero-padded final block.
    let mut vals = [0.0f32; GROUP_VALS];
    sanitize_into(group, &mut vals[..group.len()]);

    // Per-block max magnitude.
    let mut max_abs = [0.0f32; GROUP_BLOCKS];
    for (b, m) in max_abs.iter_mut().enumerate().take(nb) {
        *m = vals[b * 4..b * 4 + 4]
            .iter()
            .fold(0.0f32, |acc, x| acc.max(x.abs()));
    }

    // Exponent, fixed-point quantize, lift, negabinary map — lane loops.
    let mut e = [0i32; GROUP_BLOCKS];
    let mut u = [0u32; GROUP_VALS];
    for b in 0..nb {
        if max_abs[b] == 0.0 {
            continue;
        }
        e[b] = block_exponent(max_abs[b]);
        let factor = exp2i(INT_PREC - e[b]);
        let mut v = [0i32; 4];
        for (q, val) in v.iter_mut().zip(&vals[b * 4..b * 4 + 4]) {
            *q = (val * factor)
                .round()
                .clamp(-(1i64 << 30) as f32, ((1i64 << 30) - 1) as f32) as i32;
        }
        fwd_lift(&mut v);
        for (slot, x) in u[b * 4..b * 4 + 4].iter_mut().zip(v) {
            *slot = int2uint(x);
        }
    }

    // Wire order is per block: emit headers + planes serially.
    for b in 0..nb {
        let start = w.bit_len();
        if max_abs[b] == 0.0 {
            w.write_bit(false);
            w.pad_to(start + budget);
            continue;
        }
        let e_biased = (e[b] + EXP_BIAS).clamp(0, 255) as u64;
        // Flag bit + 8 exponent bits in one call (same 9-bit prefix).
        w.write(0x100 | e_biased, 9);
        emit_planes(w, &u[b * 4..b * 4 + 4], budget, start);
    }
}

/// Lane-batched decoder mirror: parse each block's header and planes out
/// of a left-aligned u128 window (two bulk word reads instead of up to
/// 64 flag/nibble reads), scatter into lanes, then run uint2int /
/// inv_lift / dequantize as straight-line loops over the group.
fn decode_group(r: &mut BitReader, nb: usize, rate: ZfpRate, out: &mut Vec<f32>) {
    let budget = rate.block_bits();
    let mut u = [0u32; GROUP_VALS];
    let mut e = [0i32; GROUP_BLOCKS];
    let mut coded = [false; GROUP_BLOCKS];
    for b in 0..nb {
        let start = r.bit_pos();
        if !r.read_bit() {
            r.seek(start + budget);
            continue;
        }
        coded[b] = true;
        e[b] = r.read(8) as i32 - EXP_BIAS;
        // Pull the remaining block bits (<= 119) into the window; the
        // reader zero-fills past the buffer end exactly like the
        // incremental reads would.
        let rem = budget - 9;
        let n1 = rem.min(64);
        let mut win = (r.read(n1 as u8) as u128) << (128 - n1);
        if rem > 64 {
            let n2 = rem - 64;
            win |= (r.read(n2 as u8) as u128) << (64 - n2);
        }
        let lanes = &mut u[b * 4..b * 4 + 4];
        let mut used = 9usize;
        for plane in (0..32).rev() {
            if used + 1 > budget {
                break;
            }
            let present = (win >> 127) != 0;
            win <<= 1;
            used += 1;
            if present {
                if used + 4 > budget {
                    break;
                }
                let bits = (win >> 124) as u32;
                win <<= 4;
                used += 4;
                lanes[0] |= ((bits >> 3) & 1) << plane;
                lanes[1] |= ((bits >> 2) & 1) << plane;
                lanes[2] |= ((bits >> 1) & 1) << plane;
                lanes[3] |= (bits & 1) << plane;
            }
        }
        r.seek(start + budget);
    }
    for b in 0..nb {
        if !coded[b] {
            out.extend_from_slice(&[0.0; 4]);
            continue;
        }
        let mut v = [0i32; 4];
        for (slot, x) in v.iter_mut().zip(&u[b * 4..b * 4 + 4]) {
            *slot = uint2int(*x);
        }
        inv_lift(&mut v);
        let factor = exp2i(e[b] - INT_PREC);
        let lanes = [
            v[0] as f32 * factor,
            v[1] as f32 * factor,
            v[2] as f32 * factor,
            v[3] as f32 * factor,
        ];
        out.extend_from_slice(&lanes);
    }
}

/// Encode an f32 slice at the given fixed rate.
///
/// Layout: `MAGIC u32le | count u32le | rate u8 | pad[3] | blocks...`
pub fn encode(data: &[f32], rate: ZfpRate) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(encoded_size(data.len(), rate));
    encode_into(data, rate, &mut out)?;
    Ok(out)
}

/// [`encode`] into a reused buffer (cleared first) — the pooled-buffer
/// variant for the per-frame hot path. Output bytes are identical to
/// [`encode`].
pub fn encode_into(data: &[f32], rate: ZfpRate, out: &mut Vec<u8>) -> Result<()> {
    encode_into_kernel(data, rate, out, CodecKernel::default())
}

/// [`encode_into`] with an explicit kernel selection (`--codec-kernel`);
/// both kernels produce the same bytes, the choice only changes speed.
pub fn encode_into_kernel(
    data: &[f32],
    rate: ZfpRate,
    out: &mut Vec<u8>,
    kernel: CodecKernel,
) -> Result<()> {
    let rate = rate.validate()?;
    let n = data.len();
    if n as u64 > u32::MAX as u64 {
        return Err(DeferError::Codec("zfp: >u32::MAX elements".into()));
    }
    out.clear();
    out.reserve(encoded_size(n, rate));
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&(n as u32).to_le_bytes());
    out.push(rate.0);
    out.extend_from_slice(&[0u8; 3]);
    // Emit block bits straight after the header in the (reused) output
    // buffer — no separate body allocation, no copy. Block accounting is
    // relative to the writer's running bit_len, so the 96 header bits
    // underneath do not disturb the fixed-rate budgets.
    let mut w = BitWriter::over(std::mem::take(out));
    match kernel {
        CodecKernel::Scalar => {
            for chunk in data.chunks(4) {
                let mut block = [0.0f32; 4];
                block[..chunk.len()].copy_from_slice(chunk);
                encode_block(&mut w, &block, rate);
            }
        }
        CodecKernel::Batched => {
            for group in data.chunks(GROUP_VALS) {
                encode_group(&mut w, group, rate);
            }
        }
    }
    *out = w.into_bytes();
    Ok(())
}

/// Decode a buffer produced by [`encode`].
pub fn decode(bytes: &[u8]) -> Result<Vec<f32>> {
    decode_kernel(bytes, CodecKernel::default())
}

/// [`decode`] with an explicit kernel selection; identical output.
pub fn decode_kernel(bytes: &[u8], kernel: CodecKernel) -> Result<Vec<f32>> {
    if bytes.len() < 12 {
        return Err(DeferError::Codec("zfp: truncated header".into()));
    }
    let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(DeferError::Codec("zfp: bad magic".into()));
    }
    let n = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
    let rate = ZfpRate(bytes[8]).validate()?;
    let n_blocks = n.div_ceil(4);
    let need = 12 + (n_blocks * rate.block_bits()).div_ceil(8);
    if bytes.len() < need {
        return Err(DeferError::Codec(format!(
            "zfp: body too short ({} < {need})",
            bytes.len()
        )));
    }
    let mut r = BitReader::new(&bytes[12..]);
    let mut out = Vec::with_capacity(n_blocks * 4);
    match kernel {
        CodecKernel::Scalar => {
            for _ in 0..n_blocks {
                out.extend_from_slice(&decode_block(&mut r, rate));
            }
        }
        CodecKernel::Batched => {
            let mut remaining = n_blocks;
            while remaining > 0 {
                let nb = remaining.min(GROUP_BLOCKS);
                decode_group(&mut r, nb, rate, &mut out);
                remaining -= nb;
            }
        }
    }
    out.truncate(n);
    Ok(out)
}

/// Exact encoded size for `n` values at `rate` — used by the dispatcher to
/// pre-size buffers and by the payload accounting.
pub fn encoded_size(n: usize, rate: ZfpRate) -> usize {
    12 + (n.div_ceil(4) * rate.block_bits()).div_ceil(8)
}

/// Worst-case absolute error for a block with max exponent `e_max` at
/// `rate`: dominated by dropped planes (see module docs). Exposed for the
/// accuracy tests and for choosing per-socket rates.
pub fn error_bound(max_abs: f32, rate: ZfpRate) -> f32 {
    if max_abs == 0.0 {
        return 0.0;
    }
    let e = block_exponent(max_abs);
    // Bits available for planes after flag+exponent; each coded plane costs
    // <= 5 bits, so at least this many significant planes survive:
    let planes = ((rate.block_bits() - 9) / 5) as i32;
    let dropped_weight = (e - INT_PREC + (32 - planes).max(0)) as f32;
    // One lifting level can double an error; two levels -> factor 4 margin.
    4.0 * dropped_weight.exp2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn lift_is_exactly_invertible() {
        let mut rng = Rng::new(31);
        for _ in 0..10_000 {
            let orig = [
                (rng.next_u64() as i32) >> 4,
                (rng.next_u64() as i32) >> 4,
                (rng.next_u64() as i32) >> 4,
                (rng.next_u64() as i32) >> 4,
            ];
            let mut v = orig;
            fwd_lift(&mut v);
            inv_lift(&mut v);
            assert_eq!(v, orig);
        }
    }

    #[test]
    fn int_uint_bijection() {
        for x in [0i32, 1, -1, 1234567, -7654321, i32::MAX / 2, i32::MIN / 2] {
            assert_eq!(uint2int(int2uint(x)), x);
        }
    }

    #[test]
    fn block_exponent_is_exact_frexp() {
        // The defining property: x in [2^(e-1), 2^e), checked at every
        // adversarial edge the old log2-based form got wrong or nearly
        // wrong: exact powers of two, the largest value below each power,
        // subnormals, and the extremes of the f32 range.
        let mut cases: Vec<f32> = vec![
            f32::MIN_POSITIVE,                  // 2^-126
            f32::from_bits(1),                  // smallest subnormal, 2^-149
            f32::from_bits(0x007F_FFFF),        // largest subnormal
            f32::from_bits(0x0000_0100),        // mid subnormal
            f32::MAX,
            1.0,
            1.5,
            2.0,
        ];
        for k in -140i32..=120 {
            let p = exp2i(k);
            cases.push(p);
            cases.push(f32::from_bits(p.to_bits() - 1)); // just below 2^k
            cases.push(f32::from_bits(p.to_bits() + 1)); // just above 2^k
        }
        let mut rng = Rng::new(37);
        for _ in 0..1000 {
            cases.push(rng.normal_f32().abs().max(f32::MIN_POSITIVE));
        }
        for x in cases {
            if x <= 0.0 || !x.is_finite() {
                continue;
            }
            let e = block_exponent(x);
            assert!(exp2i(e - 1) <= x, "2^{} > {x:e}", e - 1);
            assert!(x < exp2i(e), "{x:e} >= 2^{e}");
        }
    }

    #[test]
    fn exp2i_matches_libm() {
        for n in -148i32..=127 {
            assert_eq!(
                exp2i(n).to_bits(),
                (n as f32).exp2().to_bits(),
                "exp2i({n})"
            );
        }
        assert_eq!(exp2i(128), f32::INFINITY);
        assert_eq!(exp2i(1000), f32::INFINITY);
        assert_eq!(exp2i(-149), f32::from_bits(1));
        assert_eq!(exp2i(-150), 0.0);
        assert_eq!(exp2i(i32::MIN + 200), 0.0);
    }

    #[test]
    fn spread4_transposes_planes() {
        let mut rng = Rng::new(38);
        for _ in 0..200 {
            let u: [u32; 4] = [
                rng.next_u64() as u32,
                rng.next_u64() as u32,
                rng.next_u64() as u32,
                rng.next_u64() as u32,
            ];
            let planes = spread4(u[3])
                | (spread4(u[2]) << 1)
                | (spread4(u[1]) << 2)
                | (spread4(u[0]) << 3);
            for plane in 0..32 {
                let expect = (((u[0] >> plane) & 1) << 3)
                    | (((u[1] >> plane) & 1) << 2)
                    | (((u[2] >> plane) & 1) << 1)
                    | ((u[3] >> plane) & 1);
                assert_eq!(
                    ((planes >> (4 * plane)) & 0xF) as u32,
                    expect,
                    "plane {plane}"
                );
            }
        }
    }

    #[test]
    fn kernels_bitstream_identical_smoke() {
        // Quick in-module check; the adversarial-edge property suite
        // lives in tests/codec_kernels.rs.
        let mut rng = Rng::new(39);
        for rate in [3u8, 8, 16, 24, 32] {
            for n in [0usize, 1, 4, 63, 64, 65, 1000] {
                let data: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
                let mut scalar = Vec::new();
                let mut batched = Vec::new();
                encode_into_kernel(&data, ZfpRate(rate), &mut scalar, CodecKernel::Scalar)
                    .unwrap();
                encode_into_kernel(&data, ZfpRate(rate), &mut batched, CodecKernel::Batched)
                    .unwrap();
                assert_eq!(scalar, batched, "rate {rate} n {n}");
                let ds = decode_kernel(&scalar, CodecKernel::Scalar).unwrap();
                let db = decode_kernel(&scalar, CodecKernel::Batched).unwrap();
                let sb: Vec<u32> = ds.iter().map(|x| x.to_bits()).collect();
                let bb: Vec<u32> = db.iter().map(|x| x.to_bits()).collect();
                assert_eq!(sb, bb, "decode rate {rate} n {n}");
            }
        }
    }

    #[test]
    fn zeros_are_exact() {
        let data = vec![0.0f32; 37];
        let enc = encode(&data, ZfpRate(8)).unwrap();
        assert_eq!(decode(&enc).unwrap(), data);
    }

    #[test]
    fn rate32_near_lossless() {
        // Block floating point: precision is relative to the *block max*
        // (small values sharing a block with a large one keep absolute, not
        // relative, accuracy — inherent to zfp's design).
        let mut rng = Rng::new(32);
        let data: Vec<f32> = (0..4096).map(|_| rng.normal_f32()).collect();
        let dec = decode(&encode(&data, ZfpRate(32)).unwrap()).unwrap();
        let mut max_rel = 0.0f32;
        for (cin, cout) in data.chunks(4).zip(dec.chunks(4)) {
            let bmax = cin.iter().fold(1e-6f32, |m, x| m.max(x.abs()));
            for (a, b) in cin.iter().zip(cout) {
                max_rel = max_rel.max((a - b).abs() / bmax);
            }
        }
        assert!(max_rel < 1e-5, "rate-32 max block-rel err {max_rel}");
    }

    #[test]
    fn error_decreases_with_rate() {
        let mut rng = Rng::new(33);
        let data: Vec<f32> = (0..4096).map(|_| rng.normal_f32() * 10.0).collect();
        let mut last = f32::INFINITY;
        for rate in [4u8, 8, 16, 24, 32] {
            let dec = decode(&encode(&data, ZfpRate(rate)).unwrap()).unwrap();
            let err = data
                .iter()
                .zip(&dec)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                err <= last * 1.5 + 1e-6,
                "error not decreasing: rate {rate} err {err} last {last}"
            );
            last = err;
        }
        assert!(last < 1e-4, "rate-32 abs err {last}");
    }

    #[test]
    fn error_within_published_bound() {
        let mut rng = Rng::new(34);
        for rate in [8u8, 16, 32] {
            for _ in 0..50 {
                let scale = (rng.f32() * 20.0 - 10.0).exp2();
                let data: Vec<f32> = (0..64).map(|_| rng.normal_f32() * scale).collect();
                let dec = decode(&encode(&data, ZfpRate(rate)).unwrap()).unwrap();
                for chunk in data.chunks(4).zip(dec.chunks(4)) {
                    let max_abs = chunk.0.iter().fold(0.0f32, |m, x| m.max(x.abs()));
                    let bound = error_bound(max_abs, ZfpRate(rate));
                    for (a, b) in chunk.0.iter().zip(chunk.1) {
                        assert!(
                            (a - b).abs() <= bound,
                            "rate {rate}: |{a} - {b}| > bound {bound}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn encoded_size_is_deterministic() {
        let mut rng = Rng::new(35);
        for n in [0usize, 1, 3, 4, 5, 100, 4097] {
            let data: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            for rate in [3u8, 7, 16, 32] {
                let enc = encode(&data, ZfpRate(rate)).unwrap();
                assert_eq!(enc.len(), encoded_size(n, ZfpRate(rate)), "n={n} rate={rate}");
            }
        }
    }

    #[test]
    fn rate16_halves_payload() {
        let n = 10_000;
        let size = encoded_size(n, ZfpRate(16));
        assert!((size as f64) < 0.51 * (n * 4) as f64);
    }

    #[test]
    fn truncated_and_corrupt_inputs_rejected() {
        let data = vec![1.0f32; 16];
        let enc = encode(&data, ZfpRate(16)).unwrap();
        assert!(decode(&enc[..8]).is_err());
        assert!(decode(&enc[..enc.len() - 1]).is_err());
        let mut bad_magic = enc.clone();
        bad_magic[0] ^= 0xFF;
        assert!(decode(&bad_magic).is_err());
        let mut bad_rate = enc;
        bad_rate[8] = 99;
        assert!(decode(&bad_rate).is_err());
    }

    #[test]
    fn non_finite_values_become_zero() {
        let data = [f32::NAN, f32::INFINITY, -f32::INFINITY, 1.0];
        let dec = decode(&encode(&data, ZfpRate(32)).unwrap()).unwrap();
        assert!(dec[..3].iter().all(|x| x.is_finite()));
        assert!((dec[3] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn property_random_shapes_and_scales() {
        let mut rng = Rng::new(36);
        for _ in 0..100 {
            let n = rng.range(1, 500);
            let scale = (rng.f32() * 30.0 - 15.0).exp2();
            let data: Vec<f32> = (0..n).map(|_| rng.normal_f32() * scale).collect();
            let dec = decode(&encode(&data, ZfpRate(32)).unwrap()).unwrap();
            assert_eq!(dec.len(), n);
            for (a, b) in data.iter().zip(&dec) {
                let tol = a.abs().max(scale) * 1e-5 + 1e-30;
                assert!((a - b).abs() <= tol, "{a} vs {b} (scale {scale})");
            }
        }
    }
}
