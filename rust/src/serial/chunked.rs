//! Chunk-parallel codec container: data-parallel ZFP/LZ4 over one frame.
//!
//! The paper's codecs are embarrassingly parallel below the frame level:
//! ZFP codes independent 4-value blocks and LZ4 blocks are
//! self-contained, so a frame can be split into fixed-size runs of
//! elements ("chunks") that encode and decode concurrently on a shared
//! [`CodecPool`]. This module defines the wire container that carries
//! the per-chunk results and the [`CodecRuntime`] knob bundle the
//! coordinator threads share.
//!
//! # Container layout (all integers u32 little-endian)
//!
//! ```text
//! magic        0x4446434B ("DFCK")
//! chunk_count  n
//! chunk_elems  elements per chunk (last chunk may be short)
//! n x { wire_len | serialized_len | crc32 }   per-chunk header
//! n x chunk payload bytes               each exactly a Codec::encode_f32s output
//! ```
//!
//! Each chunk header carries a CRC-32 ([`crate::wire::crc32`]) of its
//! payload bytes, so a corrupted chunk is detected and reported **by
//! chunk index** before any decode work runs, instead of surfacing as
//! an opaque whole-frame codec failure (the outer wire CRC says *that*
//! the frame is bad; the per-chunk CRC says *where*).
//!
//! With `chunk_elems >= count` the container holds exactly one chunk
//! whose payload bytes are byte-identical to today's single-buffer
//! [`Codec::encode_f32s`] output — the chunked path *degrades to* the
//! legacy layout plus a 24-byte container header. The outer wire header
//! ([`crate::wire`]) still carries the summed `serialized_len`, so
//! payload accounting is unchanged.
//!
//! # Determinism guarantee
//!
//! Chunk boundaries depend only on `chunk_elems` (validated to be a
//! multiple of ZFP's 4-value block), and chunk results are reassembled
//! in index order. Therefore the container bytes are a pure function of
//! `(codec, data, chunk_elems)` — **independent of the worker count**,
//! including the fully sequential no-pool path. The planner goldens and
//! the `codec_parallel` equivalence suite rely on this.

use std::sync::{Arc, Mutex};

use crate::compress::lz4;
use crate::error::{DeferError, Result};
use crate::serial::{Codec, CodecKernel};
use crate::threadpool::CodecPool;
use crate::util::bufpool::BufPool;
use crate::util::timer::SharedTimer;

/// Container magic: "DFCK".
pub const CHUNK_MAGIC: u32 = 0x4446_434B;
/// Fixed container header: magic + chunk_count + chunk_elems.
pub const CONTAINER_HEADER: usize = 12;
/// Per-chunk header: wire_len + serialized_len + payload crc32.
pub const PER_CHUNK_HEADER: usize = 12;
/// Default chunk size: 128 Ki f32 values = 512 KiB raw — the paper's
/// 512 kB transfer-chunk granularity applied to the codec.
pub const DEFAULT_CHUNK_ELEMS: usize = 128 * 1024;
/// Upper bound keeping every per-chunk length representable in u32 even
/// for the most inflating arm (JSON, <= 12 bytes + comma per value).
pub const MAX_CHUNK_ELEMS: usize = 1 << 26;

/// Runtime codec configuration shared by the coordinator's hot-path
/// threads: chunking granularity, the shared worker pool, and an
/// optional scratch-buffer pool (allocation hygiene).
///
/// `Default`/[`CodecRuntime::serial`] is the legacy single-buffer path —
/// byte-identical to pre-chunking deployments.
#[derive(Clone, Default)]
pub struct CodecRuntime {
    /// Elements per chunk; 0 = legacy single-buffer codec (no container).
    chunk_elems: usize,
    /// Shared chunk-work pool; `None` = encode/decode chunks inline.
    pool: Option<Arc<CodecPool>>,
    /// Scratch buffers for serialize/compress outputs.
    buffers: Option<Arc<BufPool>>,
    /// ZFP kernel implementation (`--codec-kernel`); byte-invisible A/B.
    kernel: CodecKernel,
    /// Warm LZ4 hash tables shared by every thread using this runtime
    /// (coordinator + codec workers), so the steady-state frame path
    /// never zeroes a fresh 256 KiB table.
    lz4: Arc<lz4::ScratchPool>,
}

impl CodecRuntime {
    /// The legacy single-buffer codec path (no container, no pool).
    pub fn serial() -> Self {
        Self::default()
    }

    /// A chunked runtime: payloads travel as containers of
    /// `chunk_elems`-value chunks, encoded/decoded on `pool` when given.
    pub fn chunked(chunk_elems: usize, pool: Option<Arc<CodecPool>>) -> Result<Self> {
        if chunk_elems == 0 || chunk_elems % 4 != 0 || chunk_elems > MAX_CHUNK_ELEMS {
            return Err(DeferError::Config(format!(
                "codec chunk size {chunk_elems} must be a positive multiple of 4 \
                 (ZFP block alignment) and at most {MAX_CHUNK_ELEMS}"
            )));
        }
        Ok(CodecRuntime {
            chunk_elems,
            pool,
            ..CodecRuntime::default()
        })
    }

    /// Attach a scratch-buffer pool (typically one per worker/connection).
    pub fn with_buffers(mut self, buffers: Arc<BufPool>) -> Self {
        self.buffers = Some(buffers);
        self
    }

    /// Select the ZFP kernel implementation (default [`CodecKernel::Batched`];
    /// the bytes are identical either way, only throughput changes).
    pub fn with_kernel(mut self, kernel: CodecKernel) -> Self {
        self.kernel = kernel;
        self
    }

    /// Whether payloads use the chunk container.
    pub fn is_chunked(&self) -> bool {
        self.chunk_elems > 0
    }

    pub fn chunk_elems(&self) -> usize {
        self.chunk_elems
    }

    pub fn pool(&self) -> Option<&CodecPool> {
        self.pool.as_deref()
    }

    pub fn buffers(&self) -> Option<&BufPool> {
        self.buffers.as_deref()
    }

    /// The scratch pool as an owned handle — what a
    /// [`crate::wire::WireFrame`] payload cell holds so the buffer
    /// returns here when the last reference drops.
    pub fn buffers_arc(&self) -> Option<Arc<BufPool>> {
        self.buffers.clone()
    }

    pub fn kernel(&self) -> CodecKernel {
        self.kernel
    }

    /// The shared LZ4 hash-table pool (always present; cloning the
    /// runtime shares it, so chunk workers and coordinator threads all
    /// draw from one warm set).
    pub fn lz4_scratch(&self) -> &lz4::ScratchPool {
        &self.lz4
    }
}

/// Order-preserving parallel map over `items` (sequential when `pool` is
/// absent or there are fewer than two items). Results are reassembled in
/// index order, so output — and therefore every downstream byte — is
/// independent of the worker count; parallelism only changes wall-clock.
fn par_map<T, R, F>(pool: Option<&CodecPool>, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    match pool {
        Some(pool) if items.len() > 1 => {
            let n = items.len();
            let results: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(n));
            let f_ref = &f;
            let results_ref = &results;
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = items
                .into_iter()
                .enumerate()
                .map(|(i, item)| {
                    Box::new(move || {
                        let r = f_ref(i, item);
                        results_ref.lock().unwrap().push((i, r));
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(jobs);
            let mut results = results.into_inner().unwrap();
            results.sort_by_key(|&(i, _)| i);
            results.into_iter().map(|(_, r)| r).collect()
        }
        _ => items
            .into_iter()
            .enumerate()
            .map(|(i, t)| f(i, t))
            .collect(),
    }
}

/// Encode one frame as a chunk container (see module docs for layout and
/// the determinism guarantee). Returns the container bytes and the
/// summed pre-compression serialized length for payload accounting.
pub fn encode_frame(
    codec: &Codec,
    data: &[f32],
    rt: &CodecRuntime,
    overhead: Option<&SharedTimer>,
) -> (Vec<u8>, usize) {
    debug_assert!(rt.is_chunked());
    let work = || {
        let chunks: Vec<&[f32]> = data.chunks(rt.chunk_elems.max(1)).collect();
        // The per-chunk CRC rides the same parallel pass as the encode
        // itself — a serial CRC sweep afterwards would floor large-frame
        // encode throughput at single-thread CRC speed.
        let encoded: Vec<(Vec<u8>, usize, u32)> = par_map(rt.pool(), chunks, |_, chunk| {
            let (wire, mid) = codec.encode_f32s_rt(chunk, rt, None);
            let crc = crate::wire::crc32::crc32(&wire);
            (wire, mid, crc)
        });
        let body: usize = encoded.iter().map(|(w, _, _)| w.len()).sum();
        let mut out = rt.buffers().map(|p| p.take()).unwrap_or_default();
        out.clear();
        out.reserve(CONTAINER_HEADER + encoded.len() * PER_CHUNK_HEADER + body);
        out.extend_from_slice(&CHUNK_MAGIC.to_le_bytes());
        out.extend_from_slice(&(encoded.len() as u32).to_le_bytes());
        out.extend_from_slice(&(rt.chunk_elems as u32).to_le_bytes());
        let mut mid_total = 0usize;
        for (chunk_wire, mid, crc) in &encoded {
            out.extend_from_slice(&(chunk_wire.len() as u32).to_le_bytes());
            out.extend_from_slice(&(*mid as u32).to_le_bytes());
            out.extend_from_slice(&crc.to_le_bytes());
            mid_total += *mid;
        }
        for (chunk_wire, _, _) in encoded {
            out.extend_from_slice(&chunk_wire);
            if let Some(p) = rt.buffers() {
                p.put(chunk_wire);
            }
        }
        (out, mid_total)
    };
    match overhead {
        Some(t) => t.time(work),
        None => work(),
    }
}

fn read_u32(wire: &[u8], off: usize) -> usize {
    u32::from_le_bytes(wire[off..off + 4].try_into().unwrap()) as usize
}

/// Decode a chunk container back into the frame's f32 values.
/// `serialized_len` (from the outer wire header) cross-checks the summed
/// per-chunk lengths; `count` is the total element count.
pub fn decode_frame(
    codec: &Codec,
    wire: &[u8],
    serialized_len: usize,
    count: usize,
    rt: &CodecRuntime,
    overhead: Option<&SharedTimer>,
) -> Result<Vec<f32>> {
    let work = || -> Result<Vec<f32>> {
        let err = |m: String| DeferError::Codec(format!("chunk container: {m}"));
        if wire.len() < CONTAINER_HEADER {
            return Err(err("truncated header".into()));
        }
        if read_u32(wire, 0) != CHUNK_MAGIC as usize {
            return Err(err(
                "bad magic (peer not running the chunked codec path?)".into()
            ));
        }
        let n_chunks = read_u32(wire, 4);
        let chunk_elems = read_u32(wire, 8);
        if n_chunks > (wire.len() - CONTAINER_HEADER) / PER_CHUNK_HEADER {
            return Err(err(format!(
                "{n_chunks} chunk(s) cannot fit in {} bytes",
                wire.len()
            )));
        }
        let expected_chunks = if count == 0 || chunk_elems == 0 {
            0
        } else {
            count.div_ceil(chunk_elems)
        };
        if n_chunks != expected_chunks {
            return Err(err(format!(
                "{n_chunks} chunk(s) for {count} values at {chunk_elems}/chunk \
                 (expected {expected_chunks})"
            )));
        }
        let mut off = CONTAINER_HEADER + n_chunks * PER_CHUNK_HEADER;
        let mut parts = Vec::with_capacity(n_chunks);
        let mut sum_serialized = 0usize;
        for i in 0..n_chunks {
            let hdr = CONTAINER_HEADER + i * PER_CHUNK_HEADER;
            let wire_len = read_u32(wire, hdr);
            let chunk_serialized = read_u32(wire, hdr + 4);
            let chunk_crc = read_u32(wire, hdr + 8) as u32;
            if wire.len() < off + wire_len {
                return Err(err(format!("chunk {i} truncated")));
            }
            let chunk_count = if i + 1 == n_chunks {
                count - chunk_elems * i
            } else {
                chunk_elems
            };
            parts.push((
                &wire[off..off + wire_len],
                chunk_serialized,
                chunk_count,
                chunk_crc,
            ));
            off += wire_len;
            sum_serialized += chunk_serialized;
        }
        if off != wire.len() {
            return Err(err(format!("{} trailing bytes", wire.len() - off)));
        }
        if sum_serialized != serialized_len {
            return Err(err(format!(
                "chunk serialized lengths sum to {sum_serialized}, \
                 wire header says {serialized_len}"
            )));
        }
        // Per-chunk integrity first, decode second — a corrupted chunk
        // is reported by index (the outer wire CRC only says the frame
        // is bad somewhere), and the codec never chews on garbage.
        let decoded: Vec<Result<Vec<f32>>> =
            par_map(rt.pool(), parts, |i, (bytes, mid, chunk_count, expect)| {
                let actual = crate::wire::crc32::crc32(bytes);
                if actual != expect {
                    // Structured (not a rendered `Codec` string) so the
                    // recovery layer can NACK this chunk by index.
                    return Err(DeferError::CorruptChunk {
                        chunk: i,
                        of: n_chunks,
                        detail: format!("crc {actual:#010x} != {expect:#010x}"),
                    });
                }
                codec.decode_f32s_rt(bytes, mid, chunk_count, rt, None)
            });
        let mut out = Vec::with_capacity(count);
        for part in decoded {
            out.extend_from_slice(&part?);
        }
        if out.len() != count {
            return Err(err(format!(
                "decoded {} values, expected {count}",
                out.len()
            )));
        }
        Ok(out)
    };
    match overhead {
        Some(t) => t.time(work),
        None => work(),
    }
}

/// A structurally valid container layout, as probed by
/// [`container_layout`]: the metadata prefix (container header + the
/// per-chunk header block) and the chunk count.
#[derive(Clone, Copy, Debug)]
pub struct ContainerLayout {
    /// Bytes before the first chunk body — the region *not* covered by
    /// the stored per-chunk CRCs.
    pub prefix_len: usize,
    pub n_chunks: usize,
}

/// Probe `payload` for a structurally valid chunk container: magic, a
/// chunk count that fits, and per-chunk wire lengths that exactly tile
/// the rest of the buffer. `None` means "not a container" — the caller
/// falls back to whole-buffer handling. This is the ingest fast path's
/// gate: when it passes, the message CRC can be reconstituted from the
/// stored per-chunk CRCs ([`crate::wire::crc32::combine`]) and the chunk
/// bodies are only swept once, by [`decode_frame`]'s verified walk.
pub fn container_layout(payload: &[u8]) -> Option<ContainerLayout> {
    if payload.len() < CONTAINER_HEADER || read_u32(payload, 0) != CHUNK_MAGIC as usize {
        return None;
    }
    let n_chunks = read_u32(payload, 4);
    if n_chunks > (payload.len() - CONTAINER_HEADER) / PER_CHUNK_HEADER {
        return None;
    }
    let prefix_len = CONTAINER_HEADER + n_chunks * PER_CHUNK_HEADER;
    let mut off = prefix_len;
    for i in 0..n_chunks {
        off = off.checked_add(read_u32(payload, CONTAINER_HEADER + i * PER_CHUNK_HEADER))?;
        if off > payload.len() {
            return None;
        }
    }
    if off != payload.len() {
        return None;
    }
    Some(ContainerLayout { prefix_len, n_chunks })
}

/// Stored CRC and wire length of chunk `i`'s body. Caller guarantees the
/// layout came from [`container_layout`] over the same buffer.
pub fn chunk_crc_len(payload: &[u8], i: usize) -> (u32, u64) {
    let hdr = CONTAINER_HEADER + i * PER_CHUNK_HEADER;
    (
        read_u32(payload, hdr + 8) as u32,
        read_u32(payload, hdr) as u64,
    )
}

/// Byte range of chunk `index`'s wire payload inside a container — the
/// seam for chunk-level retransmission: the NACK responder extracts these
/// bytes from its retained clean copy, and the receiver patches them over
/// its corrupt copy. The spans are identical on both sides because the
/// container layout is a pure function of the encoded data.
pub fn chunk_payload_span(wire: &[u8], index: usize) -> Result<std::ops::Range<usize>> {
    let err = |m: String| DeferError::Codec(format!("chunk container: {m}"));
    if wire.len() < CONTAINER_HEADER || read_u32(wire, 0) != CHUNK_MAGIC as usize {
        return Err(err("not a chunk container".into()));
    }
    let n_chunks = read_u32(wire, 4);
    if n_chunks > (wire.len() - CONTAINER_HEADER) / PER_CHUNK_HEADER {
        return Err(err(format!(
            "{n_chunks} chunk(s) cannot fit in {} bytes",
            wire.len()
        )));
    }
    if index >= n_chunks {
        return Err(err(format!("chunk {index} of {n_chunks} out of range")));
    }
    let mut off = CONTAINER_HEADER + n_chunks * PER_CHUNK_HEADER;
    for i in 0..n_chunks {
        let wire_len = read_u32(wire, CONTAINER_HEADER + i * PER_CHUNK_HEADER);
        if wire.len() < off + wire_len {
            return Err(err(format!("chunk {i} truncated")));
        }
        if i == index {
            return Ok(off..off + wire_len);
        }
        off += wire_len;
    }
    unreachable!("index bounds checked above")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serial::Serialization;
    use crate::util::prng::Rng;

    fn rt(chunk_elems: usize, threads: usize) -> CodecRuntime {
        let pool = (threads > 0).then(|| Arc::new(CodecPool::new(threads)));
        CodecRuntime::chunked(chunk_elems, pool).unwrap()
    }

    #[test]
    fn chunk_size_validated() {
        assert!(CodecRuntime::chunked(0, None).is_err());
        assert!(CodecRuntime::chunked(6, None).is_err());
        assert!(CodecRuntime::chunked(MAX_CHUNK_ELEMS + 4, None).is_err());
        assert!(CodecRuntime::chunked(4, None).is_ok());
        assert!(!CodecRuntime::serial().is_chunked());
    }

    #[test]
    fn parallel_bytes_equal_sequential_bytes() {
        let data = Rng::new(91).normal_vec(10_000);
        for codec in Codec::paper_sweep() {
            let (seq, seq_mid) = encode_frame(&codec, &data, &rt(1024, 0), None);
            let (par, par_mid) = encode_frame(&codec, &data, &rt(1024, 4), None);
            assert_eq!(seq, par, "{}", codec.label());
            assert_eq!(seq_mid, par_mid);
        }
    }

    #[test]
    fn kernel_choice_does_not_change_container_bytes() {
        let data = Rng::new(97).normal_vec(5000);
        for codec in Codec::paper_sweep() {
            let (batched, m1) = encode_frame(&codec, &data, &rt(1024, 2), None);
            let scalar_rt = rt(1024, 2).with_kernel(CodecKernel::Scalar);
            let (scalar, m2) = encode_frame(&codec, &data, &scalar_rt, None);
            assert_eq!(batched, scalar, "{}", codec.label());
            assert_eq!(m1, m2);
            let a = decode_frame(&codec, &batched, m1, 5000, &rt(1024, 0), None).unwrap();
            let b = decode_frame(&codec, &batched, m1, 5000, &scalar_rt, None).unwrap();
            let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
            assert_eq!(ab, bb, "{}", codec.label());
        }
    }

    #[test]
    fn lz4_table_pool_warms_up() {
        // One runtime shared across frames: after the first frame the
        // scratch pool must serve every later compression without a
        // fresh table allocation.
        let data = Rng::new(98).normal_vec(4096);
        let codec = Codec::default(); // ZFP + LZ4
        let rt = CodecRuntime::chunked(1024, None).unwrap();
        let (first, mid) = encode_frame(&codec, &data, &rt, None);
        let after_first = rt.lz4_scratch().misses();
        assert!(after_first >= 1);
        for _ in 0..5 {
            let (again, m) = encode_frame(&codec, &data, &rt, None);
            assert_eq!(again, first);
            assert_eq!(m, mid);
        }
        assert_eq!(
            rt.lz4_scratch().misses(),
            after_first,
            "steady state must reuse pooled lz4 tables"
        );
    }

    #[test]
    fn single_chunk_degrades_to_legacy_payload() {
        let data = Rng::new(92).normal_vec(1000);
        for codec in Codec::paper_sweep() {
            let (legacy, legacy_mid) = codec.encode_f32s(&data, None);
            let (container, mid) = encode_frame(&codec, &data, &rt(4096, 0), None);
            assert_eq!(mid, legacy_mid);
            assert_eq!(
                &container[CONTAINER_HEADER + PER_CHUNK_HEADER..],
                &legacy[..],
                "{}: single-chunk payload must be the legacy bytes",
                codec.label()
            );
        }
    }

    #[test]
    fn round_trip_odd_sizes() {
        let pool = Some(Arc::new(CodecPool::new(3)));
        for n in [0usize, 1, 3, 4, 5, 1023, 1024, 1025, 4096 + 7] {
            let data = Rng::new(93 + n as u64).normal_vec(n);
            for codec in [
                Codec::new(Serialization::Binary, crate::compress::Compression::None),
                Codec::new(Serialization::Binary, crate::compress::Compression::Lz4),
            ] {
                let rt = CodecRuntime::chunked(256, pool.clone()).unwrap();
                let (wire, mid) = encode_frame(&codec, &data, &rt, None);
                let back = decode_frame(&codec, &wire, mid, n, &rt, None).unwrap();
                assert_eq!(back, data, "{} n={n}", codec.label());
            }
        }
    }

    #[test]
    fn corrupt_containers_rejected() {
        let data = Rng::new(94).normal_vec(600);
        let codec = Codec::default();
        let rt = rt(256, 0);
        let (wire, mid) = encode_frame(&codec, &data, &rt, None);
        // Truncations at every structural boundary.
        assert!(decode_frame(&codec, &wire[..4], mid, 600, &rt, None).is_err());
        assert!(decode_frame(&codec, &wire[..CONTAINER_HEADER], mid, 600, &rt, None).is_err());
        assert!(decode_frame(&codec, &wire[..wire.len() - 1], mid, 600, &rt, None).is_err());
        // Bad magic.
        let mut bad = wire.clone();
        bad[0] ^= 0xFF;
        assert!(decode_frame(&codec, &bad, mid, 600, &rt, None).is_err());
        // Count mismatch (wrong chunk_count expectation).
        assert!(decode_frame(&codec, &wire, mid, 601, &rt, None).is_err());
        // Serialized-length mismatch vs outer header.
        assert!(decode_frame(&codec, &wire, mid + 1, 600, &rt, None).is_err());
        // Trailing garbage.
        let mut noisy = wire;
        noisy.push(0);
        assert!(decode_frame(&codec, &noisy, mid, 600, &rt, None).is_err());
    }

    #[test]
    fn corrupt_chunk_is_named_by_index() {
        // 600 values at 256/chunk = 3 chunks. Flip one payload byte in
        // the middle chunk: the per-chunk CRC must catch it and name
        // chunk 1, not fail the whole frame opaquely.
        let data = Rng::new(96).normal_vec(600);
        let codec = Codec::new(Serialization::Binary, crate::compress::Compression::None);
        let rt = rt(256, 0);
        let (mut wire, mid) = encode_frame(&codec, &data, &rt, None);
        let wire_len0 =
            u32::from_le_bytes(wire[CONTAINER_HEADER..CONTAINER_HEADER + 4].try_into().unwrap())
                as usize;
        let payloads = CONTAINER_HEADER + 3 * PER_CHUNK_HEADER;
        wire[payloads + wire_len0 + 2] ^= 0xFF; // inside chunk 1
        let err = decode_frame(&codec, &wire, mid, 600, &rt, None).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("chunk 1 of 3"), "unindexed error: {msg}");
        assert!(msg.contains("crc"), "{msg}");
        // The other chunks still verify: flipping the byte back heals it.
        wire[payloads + wire_len0 + 2] ^= 0xFF;
        assert_eq!(decode_frame(&codec, &wire, mid, 600, &rt, None).unwrap(), data);
    }

    #[test]
    fn buffer_pool_recycles_across_frames() {
        let data = Rng::new(95).normal_vec(5000);
        let codec = Codec::default();
        let bufs = Arc::new(BufPool::new(8));
        let rt = CodecRuntime::chunked(1024, None)
            .unwrap()
            .with_buffers(Arc::clone(&bufs));
        let (first, mid) = encode_frame(&codec, &data, &rt, None);
        let baseline = decode_frame(&codec, &first, mid, 5000, &rt, None).unwrap();
        // Returning the payload makes the next frame reuse it.
        rt.buffers().unwrap().put(first);
        assert!(bufs.pooled() > 0);
        let (second, mid2) = encode_frame(&codec, &data, &rt, None);
        assert_eq!(mid, mid2);
        let again = decode_frame(&codec, &second, mid2, 5000, &rt, None).unwrap();
        assert_eq!(baseline, again);
    }
}
