//! JSON substrate: value model, recursive-descent parser, writer.
//!
//! Fills two roles (no serde in the offline environment):
//! 1. Parsing artifact metadata (`*.meta.json`, `manifest.json`).
//! 2. The paper's "JSON serialization of NumPy arrays" codec arm —
//!    `encode_f32s` / `decode_f32s` produce the same `[1.0, 2.5, ...]`
//!    wire format the reference implementation got from `json.dumps`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{DeferError, Result};

/// A JSON value. Numbers are f64 (JSON's native model).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(v) => Ok(*v),
            other => Err(DeferError::Json(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let v = self.as_f64()?;
        if v < 0.0 || v.fract() != 0.0 {
            return Err(DeferError::Json(format!("expected usize, got {v}")));
        }
        Ok(v as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(DeferError::Json(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(DeferError::Json(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(DeferError::Json(format!("expected object, got {other:?}"))),
        }
    }

    /// Fetch a required object field.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| DeferError::Json(format!("missing field {key:?}")))
    }

    /// Shape-style field: array of usize.
    pub fn get_usize_vec(&self, key: &str) -> Result<Vec<usize>> {
        self.get(key)?.as_arr()?.iter().map(Json::as_usize).collect()
    }
}

// ------------------------------------------------------------- parser

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> DeferError {
        DeferError::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let c = self.peek().ok_or_else(|| self.err("unexpected end"))?;
        self.pos += 1;
        Ok(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        self.skip_ws();
        if self.bump()? != c {
            return Err(self.err(&format!("expected {:?}", c as char)));
        }
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad \\u escape"))?;
                        }
                        // Surrogate pairs: join if a low surrogate follows.
                        let ch = if (0xD800..0xDC00).contains(&code) {
                            if self.bump()? != b'\\' || self.bump()? != b'u' {
                                return Err(self.err("lone high surrogate"));
                            }
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let c = self.bump()?;
                                low = low * 16
                                    + (c as char)
                                        .to_digit(16)
                                        .ok_or_else(|| self.err("bad \\u escape"))?;
                            }
                            0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                        } else {
                            code
                        };
                        out.push(
                            char::from_u32(ch).ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c if c < 0x80 => out.push(c as char),
                c => {
                    // Re-decode UTF-8 multibyte sequence.
                    let start = self.pos - 1;
                    let width = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf8")),
                    };
                    self.pos = start + width;
                    let s = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        b: text.as_bytes(),
        pos: 0,
    };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

// ------------------------------------------------------------- writer

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Serialize a value to compact JSON text.
pub fn to_string(v: &Json) -> String {
    let mut out = String::new();
    write_value(&mut out, v);
    out
}

fn write_value(out: &mut String, v: &Json) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => write_escaped(out, s),
        Json::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(out, item);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(out, k);
                out.push(':');
                write_value(out, val);
            }
            out.push('}');
        }
    }
}

// ------------------------------------------------- float-array codec arm

/// Encode an f32 slice as a JSON array — the paper's JSON serialization of
/// NumPy arrays. Uses shortest round-trip formatting (Rust's float Display),
/// giving the same ~2-3x inflation over raw binary that `json.dumps` shows.
pub fn encode_f32s(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 12 + 2);
    encode_f32s_into(data, &mut out);
    out
}

/// [`encode_f32s`] into a reused buffer (cleared first) — the
/// pooled-buffer variant for the per-frame hot path. Output bytes are
/// identical to [`encode_f32s`].
pub fn encode_f32s_into(data: &[f32], out: &mut Vec<u8>) {
    use std::io::Write as _;
    out.clear();
    out.push(b'[');
    for (i, v) in data.iter().enumerate() {
        if i > 0 {
            out.push(b',');
        }
        if v.fract() == 0.0 && v.abs() < 1e15 {
            let _ = write!(out, "{}.0", *v as i64);
        } else {
            let _ = write!(out, "{v}");
        }
    }
    out.push(b']');
}

/// Decode the JSON array form back to f32s.
pub fn decode_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    let text = std::str::from_utf8(bytes)
        .map_err(|e| DeferError::Json(format!("not utf8: {e}")))?;
    let v = parse(text)?;
    v.as_arr()?
        .iter()
        .map(|x| x.as_f64().map(|f| f as f32))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-2.5e3").unwrap(), Json::Num(-2500.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "\"\\q\""] {
            assert!(parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn unicode_round_trip() {
        let v = parse(r#""café 😀 ü""#).unwrap();
        assert_eq!(v, Json::Str("café 😀 ü".into()));
        let text = to_string(&v);
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn writer_round_trip() {
        let src = r#"{"meta": {"shape": [1, 32, 32, 3], "flops": 12345}, "ok": true}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }

    #[test]
    fn get_usize_vec() {
        let v = parse(r#"{"shape": [1, 8, 8, 16]}"#).unwrap();
        assert_eq!(v.get_usize_vec("shape").unwrap(), vec![1, 8, 8, 16]);
        assert!(v.get_usize_vec("missing").is_err());
    }

    #[test]
    fn f32_array_round_trip_exact() {
        let mut rng = Rng::new(5);
        let data: Vec<f32> = (0..2000).map(|_| rng.normal_f32() * 100.0).collect();
        let enc = encode_f32s(&data);
        let dec = decode_f32s(&enc).unwrap();
        assert_eq!(data, dec, "shortest round-trip must be exact");
    }

    #[test]
    fn f32_array_special_values() {
        let data = [0.0f32, -0.0, 1.0, -1.5, f32::MIN_POSITIVE, 3.4e38];
        let dec = decode_f32s(&encode_f32s(&data)).unwrap();
        assert_eq!(&data[..], &dec[..]);
    }

    #[test]
    fn json_inflation_factor_matches_paper_regime() {
        // Paper Table I: JSON weights are ~2-3x the binary size. Sanity-pin
        // the inflation factor of our encoder into that band.
        let mut rng = Rng::new(6);
        let data: Vec<f32> = (0..10_000).map(|_| rng.normal_f32()).collect();
        let enc = encode_f32s(&data);
        let ratio = enc.len() as f64 / (data.len() * 4) as f64;
        assert!((1.8..4.0).contains(&ratio), "ratio {ratio}");
    }
}
