//! Serialization substrate: the paper's JSON vs ZFP arms, plus raw binary.
//!
//! A [`Codec`] bundles a serialization scheme and a compression scheme for
//! one socket, mirroring the paper's per-socket configuration (architecture
//! socket, weights socket, inference-data socket). `encode_tensor_data` /
//! `decode_tensor_data` are what the chain hot path calls per frame.

pub mod bits;
pub mod chunked;
pub mod json;
pub mod zfp;

pub use chunked::CodecRuntime;

use crate::compress::{lz4, Compression};
use crate::error::{DeferError, Result};
use crate::util::bufpool::BufPool;
use crate::util::timer::SharedTimer;

/// Which ZFP kernel implementation codes blocks (`--codec-kernel`).
/// Both produce byte-identical streams — the flag exists for A/B speed
/// comparison and as a fallback; `Batched` is the default everywhere.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum CodecKernel {
    /// Reference block-at-a-time coder.
    Scalar,
    /// Lane-batched SIMD-friendly coder (groups of 16 blocks in
    /// structure-of-arrays form, transposed bit-plane emission).
    #[default]
    Batched,
}

impl CodecKernel {
    pub fn name(self) -> &'static str {
        match self {
            CodecKernel::Scalar => "scalar",
            CodecKernel::Batched => "batched",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Ok(CodecKernel::Scalar),
            "batched" => Ok(CodecKernel::Batched),
            other => Err(DeferError::Config(format!(
                "unknown codec kernel {other:?} (want scalar|batched)"
            ))),
        }
    }
}

/// How f32 payloads are serialized before (optional) compression.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Serialization {
    /// JSON array of numbers — the paper's `json.dumps(np.ndarray)` arm.
    Json,
    /// Fixed-rate ZFP (bits per value).
    Zfp(zfp::ZfpRate),
    /// Raw little-endian f32 — lossless baseline (not in the paper's sweep,
    /// used by tests and as the weights ground truth).
    Binary,
}

impl Serialization {
    pub fn name(self) -> &'static str {
        match self {
            Serialization::Json => "JSON",
            Serialization::Zfp(_) => "ZFP",
            Serialization::Binary => "Binary",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        let lower = s.to_ascii_lowercase();
        if lower == "json" {
            return Ok(Serialization::Json);
        }
        if lower == "binary" || lower == "bin" {
            return Ok(Serialization::Binary);
        }
        if let Some(rate) = lower.strip_prefix("zfp") {
            let rate = if rate.is_empty() {
                DEFAULT_ZFP_RATE
            } else {
                rate.trim_start_matches(':').parse::<u8>().map_err(|_| {
                    DeferError::Config(format!("bad zfp rate in {s:?}"))
                })?
            };
            return Ok(Serialization::Zfp(zfp::ZfpRate(rate).validate()?));
        }
        Err(DeferError::Config(format!(
            "unknown serialization {s:?} (want json|zfp[:RATE]|binary)"
        )))
    }

    /// Whether decode(encode(x)) == x bitwise. ZFP is lossy at every fixed
    /// rate (even 32 bits/value only bounds the error near 1e-6 of the
    /// block max).
    pub fn is_lossless(self) -> bool {
        !matches!(self, Serialization::Zfp(_))
    }
}

/// Default ZFP rate: near-lossless, still 20%+ smaller than raw f32 wire
/// (and far smaller than JSON), preserving the paper's codec ranking.
pub const DEFAULT_ZFP_RATE: u8 = 24;

/// Bulk-append an f32 slice to `out` as little-endian bytes. On
/// little-endian targets this is a single memcpy — it is the weights
/// ground-truth path for every config exchange, moving MBs at a time,
/// where the old per-element `extend_from_slice(&v.to_le_bytes())` loop
/// paid four-byte bookkeeping per value.
fn extend_f32s_le(out: &mut Vec<u8>, data: &[f32]) {
    #[cfg(target_endian = "little")]
    {
        // SAFETY: viewing initialized f32 storage as bytes is always
        // valid (alignment 1, no invalid byte patterns, exact length).
        let bytes =
            unsafe { std::slice::from_raw_parts(data.as_ptr().cast::<u8>(), data.len() * 4) };
        out.extend_from_slice(bytes);
    }
    #[cfg(not(target_endian = "little"))]
    {
        out.reserve(data.len() * 4);
        for v in data {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Bulk-decode little-endian bytes into f32s (inverse of
/// [`extend_f32s_le`]); rejects ragged lengths.
fn f32s_from_le(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        return Err(DeferError::Codec("binary: ragged length".into()));
    }
    #[cfg(target_endian = "little")]
    {
        let mut out = vec![0f32; bytes.len() / 4];
        // SAFETY: the destination spans exactly `bytes.len()` bytes of
        // f32 storage; every bit pattern is a valid f32.
        unsafe {
            std::ptr::copy_nonoverlapping(
                bytes.as_ptr(),
                out.as_mut_ptr().cast::<u8>(),
                bytes.len(),
            );
        }
        Ok(out)
    }
    #[cfg(not(target_endian = "little"))]
    {
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// A per-socket codec: serialization + compression.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Codec {
    pub serialization: Serialization,
    pub compression: Compression,
}

impl Codec {
    pub const fn new(serialization: Serialization, compression: Compression) -> Self {
        Codec {
            serialization,
            compression,
        }
    }

    /// The four configurations swept by Tables I and II.
    pub fn paper_sweep() -> Vec<Codec> {
        vec![
            Codec::new(Serialization::Json, Compression::Lz4),
            Codec::new(Serialization::Json, Compression::None),
            Codec::new(
                Serialization::Zfp(zfp::ZfpRate(DEFAULT_ZFP_RATE)),
                Compression::Lz4,
            ),
            Codec::new(
                Serialization::Zfp(zfp::ZfpRate(DEFAULT_ZFP_RATE)),
                Compression::None,
            ),
        ]
    }

    pub fn label(&self) -> String {
        format!("{}+{}", self.serialization.name(), self.compression.name())
    }

    /// Serialize `data` into `out` (cleared first), no compression.
    fn serialize_into(&self, data: &[f32], out: &mut Vec<u8>, kernel: CodecKernel) {
        match self.serialization {
            Serialization::Json => json::encode_f32s_into(data, out),
            Serialization::Zfp(rate) => {
                zfp::encode_into_kernel(data, rate, out, kernel).expect("validated rate")
            }
            Serialization::Binary => {
                out.clear();
                extend_f32s_le(out, data);
            }
        }
    }

    /// Serialize + compress an f32 payload. Returns the wire bytes and the
    /// intermediate (serialized, uncompressed) size for payload accounting.
    /// `overhead` accumulates formatting time (paper's "Overhead" metric).
    pub fn encode_f32s(
        &self,
        data: &[f32],
        overhead: Option<&SharedTimer>,
    ) -> (Vec<u8>, usize) {
        self.encode_f32s_pooled(data, None, overhead)
    }

    /// [`Codec::encode_f32s`] with scratch buffers drawn from (and
    /// returned to) `bufs` — the allocation-hygiene variant for the
    /// per-frame hot path. The caller owns the returned payload; handing
    /// it back to the same pool after the send completes closes the
    /// recycling loop. Output bytes are identical to `encode_f32s`.
    /// `Compression::None` passes the serialized buffer through without a
    /// copy.
    pub fn encode_f32s_pooled(
        &self,
        data: &[f32],
        bufs: Option<&BufPool>,
        overhead: Option<&SharedTimer>,
    ) -> (Vec<u8>, usize) {
        self.encode_inner(data, bufs, CodecKernel::default(), None, overhead)
    }

    /// [`Codec::encode_f32s_pooled`] under a [`CodecRuntime`]: draws the
    /// kernel selection, scratch buffers and the LZ4 table pool from the
    /// runtime the coordinator threads share. Byte-identical output.
    pub fn encode_f32s_rt(
        &self,
        data: &[f32],
        rt: &CodecRuntime,
        overhead: Option<&SharedTimer>,
    ) -> (Vec<u8>, usize) {
        self.encode_inner(data, rt.buffers(), rt.kernel(), Some(rt.lz4_scratch()), overhead)
    }

    fn encode_inner(
        &self,
        data: &[f32],
        bufs: Option<&BufPool>,
        kernel: CodecKernel,
        tables: Option<&lz4::ScratchPool>,
        overhead: Option<&SharedTimer>,
    ) -> (Vec<u8>, usize) {
        let work = || {
            let mut serialized = bufs.map(|p| p.take()).unwrap_or_default();
            self.serialize_into(data, &mut serialized, kernel);
            let mid = serialized.len();
            // Only Lz4 needs a second buffer; the None arm passes the
            // serialized buffer through untouched (zero-copy).
            let scratch = match self.compression {
                Compression::None => None,
                Compression::Lz4 => bufs.map(|p| p.take()),
            };
            let (payload, reclaimed) =
                self.compression.compress_vec_with(serialized, scratch, tables);
            if let (Some(p), Some(r)) = (bufs, reclaimed) {
                p.put(r);
            }
            (payload, mid)
        };
        match overhead {
            Some(t) => t.time(work),
            None => work(),
        }
    }

    /// Inverse of [`Codec::encode_f32s`]. `serialized_len` is the
    /// uncompressed-serialized size from the wire header; `count` the
    /// element count. The `Uncompressed` arm decodes straight from the
    /// wire buffer (zero-copy decompression).
    pub fn decode_f32s(
        &self,
        wire: &[u8],
        serialized_len: usize,
        count: usize,
        overhead: Option<&SharedTimer>,
    ) -> Result<Vec<f32>> {
        self.decode_inner(wire, serialized_len, count, CodecKernel::default(), overhead)
    }

    /// [`Codec::decode_f32s`] under a [`CodecRuntime`] (kernel selection
    /// travels with the runtime, not the wire — both kernels accept any
    /// stream). Identical output.
    pub fn decode_f32s_rt(
        &self,
        wire: &[u8],
        serialized_len: usize,
        count: usize,
        rt: &CodecRuntime,
        overhead: Option<&SharedTimer>,
    ) -> Result<Vec<f32>> {
        self.decode_inner(wire, serialized_len, count, rt.kernel(), overhead)
    }

    fn decode_inner(
        &self,
        wire: &[u8],
        serialized_len: usize,
        count: usize,
        kernel: CodecKernel,
        overhead: Option<&SharedTimer>,
    ) -> Result<Vec<f32>> {
        let work = || -> Result<Vec<f32>> {
            let serialized = self.compression.decompress_cow(wire, serialized_len)?;
            let out = match self.serialization {
                Serialization::Json => json::decode_f32s(&serialized)?,
                Serialization::Zfp(_) => zfp::decode_kernel(&serialized, kernel)?,
                Serialization::Binary => f32s_from_le(&serialized)?,
            };
            if out.len() != count {
                return Err(DeferError::Codec(format!(
                    "decoded {} values, expected {count}",
                    out.len()
                )));
            }
            Ok(out)
        };
        match overhead {
            Some(t) => t.time(work),
            None => work(),
        }
    }

    /// Frame-level encode: the hot-path entry the coordinator calls per
    /// frame. A serial [`CodecRuntime`] produces exactly the
    /// [`Codec::encode_f32s`] bytes; a chunked runtime produces the
    /// [`chunked`] container (identical bytes for any worker count).
    pub fn encode_frame(
        &self,
        data: &[f32],
        rt: &CodecRuntime,
        overhead: Option<&SharedTimer>,
    ) -> (Vec<u8>, usize) {
        if rt.is_chunked() {
            chunked::encode_frame(self, data, rt, overhead)
        } else {
            self.encode_f32s_rt(data, rt, overhead)
        }
    }

    /// Frame-level decode, inverse of [`Codec::encode_frame`] under the
    /// same runtime (both ends of a socket share one configuration).
    pub fn decode_frame(
        &self,
        wire: &[u8],
        serialized_len: usize,
        count: usize,
        rt: &CodecRuntime,
        overhead: Option<&SharedTimer>,
    ) -> Result<Vec<f32>> {
        if rt.is_chunked() {
            chunked::decode_frame(self, wire, serialized_len, count, rt, overhead)
        } else {
            self.decode_f32s_rt(wire, serialized_len, count, rt, overhead)
        }
    }
}

impl Default for Codec {
    /// The paper's winning configuration: ZFP + LZ4.
    fn default() -> Self {
        Codec::new(
            Serialization::Zfp(zfp::ZfpRate(DEFAULT_ZFP_RATE)),
            Compression::Lz4,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn payload(n: usize, seed: u64) -> Vec<f32> {
        Rng::new(seed).normal_vec(n)
    }

    #[test]
    fn all_codecs_round_trip() {
        let data = payload(4097, 41);
        let mut codecs = Codec::paper_sweep();
        codecs.push(Codec::new(Serialization::Binary, Compression::Lz4));
        codecs.push(Codec::new(Serialization::Binary, Compression::None));
        for codec in codecs {
            let (wire, mid) = codec.encode_f32s(&data, None);
            let dec = codec.decode_f32s(&wire, mid, data.len(), None).unwrap();
            assert_eq!(dec.len(), data.len());
            if codec.serialization.is_lossless() {
                assert_eq!(dec, data, "{}", codec.label());
            } else {
                // Lossy arm: zfp rate 24 keeps ~2^-14 of the block max.
                for (a, b) in data.iter().zip(&dec) {
                    assert!((a - b).abs() < 2e-3, "{}: {a} vs {b}", codec.label());
                }
            }
        }
    }

    #[test]
    fn parse_kernel_names() {
        assert_eq!(CodecKernel::parse("scalar").unwrap(), CodecKernel::Scalar);
        assert_eq!(CodecKernel::parse("Batched").unwrap(), CodecKernel::Batched);
        assert_eq!(CodecKernel::default(), CodecKernel::Batched);
        assert!(CodecKernel::parse("avx512").is_err());
        assert_eq!(CodecKernel::Scalar.name(), "scalar");
        assert_eq!(CodecKernel::Batched.name(), "batched");
    }

    #[test]
    fn runtime_kernel_selection_is_byte_invisible() {
        // Both kernels and both lz4 scratch modes must produce the
        // pooled/default bytes exactly.
        let data = payload(3000, 46);
        for codec in Codec::paper_sweep() {
            let (base, mid) = codec.encode_f32s(&data, None);
            for kernel in [CodecKernel::Scalar, CodecKernel::Batched] {
                let rt = CodecRuntime::serial().with_kernel(kernel);
                let (wire, m) = codec.encode_f32s_rt(&data, &rt, None);
                assert_eq!(wire, base, "{} {}", codec.label(), kernel.name());
                assert_eq!(m, mid);
                let dec = codec.decode_f32s_rt(&wire, m, data.len(), &rt, None).unwrap();
                let plain = codec.decode_f32s(&base, mid, data.len(), None).unwrap();
                let dec_bits: Vec<u32> = dec.iter().map(|x| x.to_bits()).collect();
                let plain_bits: Vec<u32> = plain.iter().map(|x| x.to_bits()).collect();
                assert_eq!(dec_bits, plain_bits, "{}", codec.label());
            }
        }
    }

    #[test]
    fn parse_codec_strings() {
        assert_eq!(Serialization::parse("json").unwrap(), Serialization::Json);
        assert_eq!(
            Serialization::parse("zfp:16").unwrap(),
            Serialization::Zfp(zfp::ZfpRate(16))
        );
        assert_eq!(
            Serialization::parse("ZFP").unwrap(),
            Serialization::Zfp(zfp::ZfpRate(DEFAULT_ZFP_RATE))
        );
        assert_eq!(Serialization::parse("binary").unwrap(), Serialization::Binary);
        assert!(Serialization::parse("zfp:77").is_err());
        assert!(Serialization::parse("protobuf").is_err());
    }

    #[test]
    fn zfp_beats_json_on_payload() {
        // Paper Table I row ordering: ZFP serialized weights are smaller
        // than JSON serialized weights.
        let data = payload(50_000, 42);
        let json = Codec::new(Serialization::Json, Compression::None);
        let zfpc = Codec::new(
            Serialization::Zfp(zfp::ZfpRate(DEFAULT_ZFP_RATE)),
            Compression::None,
        );
        let (jw, _) = json.encode_f32s(&data, None);
        let (zw, _) = zfpc.encode_f32s(&data, None);
        assert!(
            (zw.len() as f64) < 0.5 * jw.len() as f64,
            "zfp {} vs json {}",
            zw.len(),
            jw.len()
        );
    }

    #[test]
    fn lz4_reduces_json_payload() {
        // JSON text is highly compressible; LZ4 must shrink it.
        let data = payload(20_000, 43);
        let plain = Codec::new(Serialization::Json, Compression::None);
        let lz = Codec::new(Serialization::Json, Compression::Lz4);
        let (pw, _) = plain.encode_f32s(&data, None);
        let (lw, _) = lz.encode_f32s(&data, None);
        assert!(lw.len() < pw.len());
    }

    #[test]
    fn overhead_timer_accumulates() {
        let t = SharedTimer::new();
        let data = payload(10_000, 44);
        let codec = Codec::default();
        let (wire, mid) = codec.encode_f32s(&data, Some(&t));
        let _ = codec.decode_f32s(&wire, mid, data.len(), Some(&t)).unwrap();
        assert!(t.total() > std::time::Duration::ZERO);
    }

    #[test]
    fn decode_count_mismatch_rejected() {
        let data = payload(64, 45);
        let codec = Codec::new(Serialization::Binary, Compression::None);
        let (wire, mid) = codec.encode_f32s(&data, None);
        assert!(codec.decode_f32s(&wire, mid, 63, None).is_err());
    }
}
