//! Bit-level I/O for the ZFP codec: MSB-first writer/reader over a byte
//! buffer.

/// MSB-first bit writer.
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits already used in the trailing byte (0..8, 0 = byte boundary).
    used: u8,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// A writer that appends to an existing byte buffer (whose current
    /// contents are kept as whole bytes already written). Lets callers
    /// emit bits straight into a reused/pre-headered buffer instead of
    /// paying a fresh body allocation plus a copy; [`Self::bit_len`]
    /// counts the pre-existing bytes, so block accounting must be
    /// relative (the ZFP coder's is).
    pub fn over(buf: Vec<u8>) -> Self {
        BitWriter { buf, used: 0 }
    }

    /// Append the low `n` bits of `v`, most significant first. `n <= 64`.
    #[inline]
    pub fn write(&mut self, v: u64, n: u8) {
        debug_assert!(n <= 64);
        let mut remaining = n;
        while remaining > 0 {
            if self.used == 0 {
                self.buf.push(0);
            }
            let space = 8 - self.used;
            let take = space.min(remaining);
            let shift = remaining - take;
            let bits = ((v >> shift) & ((1u64 << take) - 1)) as u8;
            let last = self.buf.last_mut().unwrap();
            *last |= bits << (space - take);
            self.used = (self.used + take) % 8;
            remaining -= take;
        }
    }

    #[inline]
    pub fn write_bit(&mut self, bit: bool) {
        self.write(bit as u64, 1);
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> usize {
        if self.used == 0 {
            self.buf.len() * 8
        } else {
            (self.buf.len() - 1) * 8 + self.used as usize
        }
    }

    /// Zero-pad to exactly `target` bits (target >= bit_len).
    pub fn pad_to(&mut self, target: usize) {
        let cur = self.bit_len();
        debug_assert!(target >= cur, "pad_to going backwards: {cur} -> {target}");
        let mut missing = target - cur;
        while missing >= 64 {
            self.write(0, 64);
            missing -= 64;
        }
        if missing > 0 {
            self.write(0, missing as u8);
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// MSB-first bit reader.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // absolute bit position
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    /// Read `n` bits MSB-first; out-of-range reads return zeros (the ZFP
    /// decoder relies on implicit zero-fill past the fixed-rate budget).
    /// Byte-batched (§Perf: the per-bit loop was the decode bottleneck).
    #[inline]
    pub fn read(&mut self, n: u8) -> u64 {
        debug_assert!(n <= 64);
        let mut out = 0u64;
        let mut remaining = n as usize;
        while remaining > 0 {
            let byte = self.buf.get(self.pos / 8).copied().unwrap_or(0);
            let offset = self.pos % 8; // bits already consumed in this byte
            let avail = 8 - offset;
            let take = avail.min(remaining);
            // Extract `take` bits starting at `offset` (MSB-first).
            let bits = (byte >> (avail - take)) & ((1u16 << take) - 1) as u8;
            out = (out << take) | bits as u64;
            self.pos += take;
            remaining -= take;
        }
        out
    }

    #[inline]
    pub fn read_bit(&mut self) -> bool {
        self.read(1) == 1
    }

    /// Jump to an absolute bit offset (for fixed-rate block seeking).
    pub fn seek(&mut self, bit_pos: usize) {
        self.pos = bit_pos;
    }

    pub fn bit_pos(&self) -> usize {
        self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn single_bits() {
        let mut w = BitWriter::new();
        for b in [true, false, true, true, false, false, false, true, true] {
            w.write_bit(b);
        }
        assert_eq!(w.bit_len(), 9);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for b in [true, false, true, true, false, false, false, true, true] {
            assert_eq!(r.read_bit(), b);
        }
    }

    #[test]
    fn multi_bit_round_trip() {
        let mut rng = Rng::new(21);
        let mut vals: Vec<(u64, u8)> = Vec::new();
        let mut w = BitWriter::new();
        for _ in 0..500 {
            let n = rng.range(1, 64) as u8;
            let v = rng.next_u64() & if n == 64 { u64::MAX } else { (1 << n) - 1 };
            w.write(v, n);
            vals.push((v, n));
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for (v, n) in vals {
            assert_eq!(r.read(n), v, "width {n}");
        }
    }

    #[test]
    fn pad_and_seek() {
        let mut w = BitWriter::new();
        w.write(0b101, 3);
        w.pad_to(64);
        w.write(0xFF, 8);
        assert_eq!(w.bit_len(), 72);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(3), 0b101);
        r.seek(64);
        assert_eq!(r.read(8), 0xFF);
    }

    #[test]
    fn reads_past_end_are_zero() {
        let bytes = vec![0xFFu8];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(8), 0xFF);
        assert_eq!(r.read(16), 0);
    }
}
