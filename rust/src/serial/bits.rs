//! Bit-level I/O for the ZFP codec: MSB-first writer/reader over a byte
//! buffer.
//!
//! Both directions are word-level (§Perf): the writer accumulates into a
//! u64 and flushes eight bytes at a time, the reader serves most calls
//! from a single unaligned big-endian u64 load. The bit *stream* is a
//! pure function of the `write` call sequence — flush boundaries never
//! leak into the bytes — so these fast paths are byte-identical to the
//! per-byte loops they replaced (`tests/codec_kernels.rs` proves it
//! against a reference bit-at-a-time model).

/// MSB-first bit writer.
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Pending bits, right-aligned (the low `acc_bits` bits).
    acc: u64,
    /// Number of pending bits in `acc` (0..=63; 64 forces a flush).
    acc_bits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// A writer that appends to an existing byte buffer (whose current
    /// contents are kept as whole bytes already written). Lets callers
    /// emit bits straight into a reused/pre-headered buffer instead of
    /// paying a fresh body allocation plus a copy; [`Self::bit_len`]
    /// counts the pre-existing bytes, so block accounting must be
    /// relative (the ZFP coder's is).
    pub fn over(buf: Vec<u8>) -> Self {
        BitWriter {
            buf,
            acc: 0,
            acc_bits: 0,
        }
    }

    /// Append the low `n` bits of `v`, most significant first. `n <= 64`.
    #[inline]
    pub fn write(&mut self, v: u64, n: u8) {
        debug_assert!(n <= 64);
        if n == 0 {
            return;
        }
        let n = n as u32;
        let v = if n == 64 { v } else { v & ((1u64 << n) - 1) };
        let total = self.acc_bits + n;
        if total < 64 {
            self.acc = (self.acc << n) | v;
            self.acc_bits = total;
        } else {
            // Flush one full big-endian word: the pending bits left-aligned,
            // then the high `n - spill` bits of `v`.
            let spill = total - 64;
            let head = if self.acc_bits == 0 {
                0
            } else {
                self.acc << (64 - self.acc_bits)
            };
            let word = head | (v >> spill);
            self.buf.extend_from_slice(&word.to_be_bytes());
            self.acc = if spill == 0 { 0 } else { v & ((1u64 << spill) - 1) };
            self.acc_bits = spill;
        }
    }

    #[inline]
    pub fn write_bit(&mut self, bit: bool) {
        self.write(bit as u64, 1);
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.acc_bits as usize
    }

    /// Zero-pad to exactly `target` bits (target >= bit_len).
    pub fn pad_to(&mut self, target: usize) {
        let cur = self.bit_len();
        debug_assert!(target >= cur, "pad_to going backwards: {cur} -> {target}");
        let mut missing = target - cur;
        while missing >= 64 {
            self.write(0, 64);
            missing -= 64;
        }
        if missing > 0 {
            self.write(0, missing as u8);
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        let mut buf = self.buf;
        if self.acc_bits > 0 {
            // Left-align the pending bits and emit only the bytes they span.
            let word = (self.acc << (64 - self.acc_bits)).to_be_bytes();
            buf.extend_from_slice(&word[..(self.acc_bits as usize).div_ceil(8)]);
        }
        buf
    }
}

/// MSB-first bit reader.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // absolute bit position
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    /// Read `n` bits MSB-first; out-of-range reads return zeros (the ZFP
    /// decoder relies on implicit zero-fill past the fixed-rate budget).
    /// One unaligned u64 load serves the whole call whenever the request
    /// fits the word at the current byte (§Perf: the per-byte loop was
    /// the decode bottleneck).
    #[inline]
    pub fn read(&mut self, n: u8) -> u64 {
        debug_assert!(n <= 64);
        if n == 0 {
            return 0;
        }
        let n = n as usize;
        let byte = self.pos / 8;
        let offset = self.pos % 8;
        if offset + n <= 64 && byte + 8 <= self.buf.len() {
            let word = u64::from_be_bytes(self.buf[byte..byte + 8].try_into().unwrap());
            self.pos += n;
            return (word << offset) >> (64 - n);
        }
        self.read_slow(n)
    }

    /// Byte-at-a-time fallback: near the end of the buffer (zero-fill
    /// semantics) or a 64-bit read straddling nine bytes.
    #[cold]
    fn read_slow(&mut self, n: usize) -> u64 {
        let mut out = 0u64;
        let mut remaining = n;
        while remaining > 0 {
            let byte = self.buf.get(self.pos / 8).copied().unwrap_or(0);
            let offset = self.pos % 8; // bits already consumed in this byte
            let avail = 8 - offset;
            let take = avail.min(remaining);
            // Extract `take` bits starting at `offset` (MSB-first).
            let bits = (byte >> (avail - take)) & ((1u16 << take) - 1) as u8;
            out = (out << take) | bits as u64;
            self.pos += take;
            remaining -= take;
        }
        out
    }

    #[inline]
    pub fn read_bit(&mut self) -> bool {
        self.read(1) == 1
    }

    /// Jump to an absolute bit offset (for fixed-rate block seeking).
    pub fn seek(&mut self, bit_pos: usize) {
        self.pos = bit_pos;
    }

    pub fn bit_pos(&self) -> usize {
        self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn single_bits() {
        let mut w = BitWriter::new();
        for b in [true, false, true, true, false, false, false, true, true] {
            w.write_bit(b);
        }
        assert_eq!(w.bit_len(), 9);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for b in [true, false, true, true, false, false, false, true, true] {
            assert_eq!(r.read_bit(), b);
        }
    }

    #[test]
    fn multi_bit_round_trip() {
        let mut rng = Rng::new(21);
        let mut vals: Vec<(u64, u8)> = Vec::new();
        let mut w = BitWriter::new();
        for _ in 0..500 {
            let n = rng.range(1, 64) as u8;
            let v = rng.next_u64() & if n == 64 { u64::MAX } else { (1 << n) - 1 };
            w.write(v, n);
            vals.push((v, n));
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for (v, n) in vals {
            assert_eq!(r.read(n), v, "width {n}");
        }
    }

    #[test]
    fn full_width_writes_round_trip() {
        // 64-bit writes at every accumulator fill level (the flush path
        // with spill 0..=63), then reads straddling word boundaries.
        for lead in 0u8..=63 {
            let mut w = BitWriter::new();
            if lead > 0 {
                w.write(0x5555_5555_5555_5555 & ((1 << lead) - 1), lead);
            }
            w.write(0xDEAD_BEEF_CAFE_F00D, 64);
            w.write(0xABCD, 16);
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            if lead > 0 {
                r.read(lead);
            }
            assert_eq!(r.read(64), 0xDEAD_BEEF_CAFE_F00D, "lead {lead}");
            assert_eq!(r.read(16), 0xABCD, "lead {lead}");
        }
    }

    #[test]
    fn pad_and_seek() {
        let mut w = BitWriter::new();
        w.write(0b101, 3);
        w.pad_to(64);
        w.write(0xFF, 8);
        assert_eq!(w.bit_len(), 72);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(3), 0b101);
        r.seek(64);
        assert_eq!(r.read(8), 0xFF);
    }

    #[test]
    fn reads_past_end_are_zero() {
        let bytes = vec![0xFFu8];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(8), 0xFF);
        assert_eq!(r.read(16), 0);
    }

    #[test]
    fn over_preserves_prefix_bytes() {
        let mut w = BitWriter::over(vec![0xAA, 0xBB]);
        assert_eq!(w.bit_len(), 16);
        w.write(0x1F, 5);
        let bytes = w.into_bytes();
        assert_eq!(&bytes[..2], &[0xAA, 0xBB]);
        let mut r = BitReader::new(&bytes);
        r.seek(16);
        assert_eq!(r.read(5), 0x1F);
    }
}
