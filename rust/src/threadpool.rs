//! Minimal worker thread pool + bounded SPSC-style pipe.
//!
//! No tokio in the offline environment; DEFER's runtime model is threads +
//! blocking sockets anyway (the paper's Algorithms 1-2 are literally
//! "spawn THREAD-1 / THREAD-2 ... pipe data -> THREAD-2"). `Pipe` is that
//! pipe: a bounded MPSC channel with blocking send (backpressure) built on
//! Mutex + Condvar.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::error::{DeferError, Result};

// ------------------------------------------------------------------ Pipe

/// Outcome of a nonblocking [`PipeSender::try_send`]; the rejected item
/// comes back to the caller instead of being dropped.
pub enum TrySend<T> {
    Ok,
    Full(T),
    Closed(T),
}

/// Outcome of a nonblocking [`PipeReceiver::try_recv`].
pub enum TryRecv<T> {
    Item(T),
    Empty,
    Closed,
}

/// Edge-notification callback for the reactor data plane: fired (outside
/// the pipe's lock) when the event it watches may have occurred.
type PipeWaker = Arc<dyn Fn() + Send + Sync>;

struct PipeState<T> {
    queue: VecDeque<T>,
    closed: bool,
}

struct PipeShared<T> {
    state: Mutex<PipeState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
    /// Live `PipeSender` handles. Tracked explicitly (not via
    /// `Arc::strong_count`, which also counts the receiver and is racy
    /// to read before this handle's own decrement): the sender whose
    /// drop brings this to zero closes the pipe.
    senders: AtomicUsize,
    /// Fired when data arrives (or the pipe closes) — a receiver-side
    /// readiness hook for the reactor's virtual local sources.
    data_waker: Mutex<Option<PipeWaker>>,
    /// Fired when space frees up (or the pipe closes) — a sender-side
    /// hook so a parked nonblocking producer can retry.
    space_waker: Mutex<Option<PipeWaker>>,
}

impl<T> PipeShared<T> {
    /// Clone the waker out of its slot, then invoke it *after* releasing
    /// every pipe lock — wakers take their own locks (shard signal
    /// queues) and must never nest inside ours.
    fn fire(slot: &Mutex<Option<PipeWaker>>) {
        let waker = slot.lock().unwrap().clone();
        if let Some(w) = waker {
            w();
        }
    }

    fn fire_data(&self) {
        Self::fire(&self.data_waker);
    }

    fn fire_space(&self) {
        Self::fire(&self.space_waker);
    }
}

/// Sending half of a bounded pipe.
pub struct PipeSender<T> {
    shared: Arc<PipeShared<T>>,
}

/// Receiving half of a bounded pipe.
pub struct PipeReceiver<T> {
    shared: Arc<PipeShared<T>>,
}

impl<T> Clone for PipeSender<T> {
    fn clone(&self) -> Self {
        self.shared.senders.fetch_add(1, Ordering::Relaxed);
        PipeSender {
            shared: Arc::clone(&self.shared),
        }
    }
}

/// Create a bounded pipe with the given capacity (>= 1).
pub fn pipe<T>(capacity: usize) -> (PipeSender<T>, PipeReceiver<T>) {
    let shared = Arc::new(PipeShared {
        state: Mutex::new(PipeState {
            queue: VecDeque::new(),
            closed: false,
        }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
        capacity: capacity.max(1),
        senders: AtomicUsize::new(1),
        data_waker: Mutex::new(None),
        space_waker: Mutex::new(None),
    });
    (
        PipeSender {
            shared: Arc::clone(&shared),
        },
        PipeReceiver { shared },
    )
}

impl<T> PipeSender<T> {
    /// Blocking send; applies backpressure when the pipe is full.
    pub fn send(&self, item: T) -> Result<()> {
        {
            let mut st = self.shared.state.lock().unwrap();
            while st.queue.len() >= self.shared.capacity && !st.closed {
                st = self.shared.not_full.wait(st).unwrap();
            }
            if st.closed {
                return Err(DeferError::ChannelClosed("pipe send"));
            }
            st.queue.push_back(item);
            self.shared.not_empty.notify_one();
        }
        self.shared.fire_data();
        Ok(())
    }

    /// Nonblocking send: hands the item back instead of waiting when the
    /// pipe is full or closed.
    pub fn try_send(&self, item: T) -> TrySend<T> {
        {
            let mut st = self.shared.state.lock().unwrap();
            if st.closed {
                return TrySend::Closed(item);
            }
            if st.queue.len() >= self.shared.capacity {
                return TrySend::Full(item);
            }
            st.queue.push_back(item);
            self.shared.not_empty.notify_one();
        }
        self.shared.fire_data();
        TrySend::Ok
    }

    /// Current depth — the sender-side view of the queue, used by the
    /// adaptive batcher to size coalescing to what is already waiting.
    pub fn len(&self) -> usize {
        self.shared.state.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Register the callback fired whenever space may have freed up (an
    /// item was consumed, or the pipe closed). Replaces any previous
    /// waker; fired outside the pipe's locks.
    pub fn set_space_waker(&self, waker: Arc<dyn Fn() + Send + Sync>) {
        *self.shared.space_waker.lock().unwrap() = Some(waker);
    }

    /// Close the pipe; receivers drain whatever remains, then get `None`.
    pub fn close(&self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.closed = true;
            self.shared.not_empty.notify_all();
            self.shared.not_full.notify_all();
        }
        self.shared.fire_data();
        self.shared.fire_space();
    }
}

impl<T> Drop for PipeSender<T> {
    fn drop(&mut self) {
        // The decrement itself decides who closes: exactly one dropping
        // sender observes the count hit zero. (Reading a count *before*
        // decrementing — the old `Arc::strong_count` scheme — let two
        // concurrent drops each see "not last" and leave the receiver
        // blocked forever.)
        if self.shared.senders.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.close();
        }
    }
}

impl<T> Drop for PipeReceiver<T> {
    fn drop(&mut self) {
        // A dropped receiver can never drain the queue, so senders blocked
        // on a full pipe would otherwise wait forever. Mark the pipe
        // closed: pending and future `send`s fail fast with
        // `ChannelClosed`, which is how a downstream pipeline stage's
        // death unwinds its upstream.
        {
            let mut st = self.shared.state.lock().unwrap();
            st.closed = true;
            self.shared.not_full.notify_all();
            self.shared.not_empty.notify_all();
        }
        self.shared.fire_data();
        self.shared.fire_space();
    }
}

impl<T> PipeReceiver<T> {
    /// Blocking receive; `None` after close + drain.
    pub fn recv(&self) -> Option<T> {
        let item = {
            let mut st = self.shared.state.lock().unwrap();
            loop {
                if let Some(item) = st.queue.pop_front() {
                    self.shared.not_full.notify_one();
                    break item;
                }
                if st.closed {
                    return None;
                }
                st = self.shared.not_empty.wait(st).unwrap();
            }
        };
        self.shared.fire_space();
        Some(item)
    }

    /// Nonblocking receive: distinguishes "nothing yet" from "closed and
    /// drained" so a reactor state machine knows whether to park or end.
    pub fn try_recv(&self) -> TryRecv<T> {
        let item = {
            let mut st = self.shared.state.lock().unwrap();
            match st.queue.pop_front() {
                Some(item) => {
                    self.shared.not_full.notify_one();
                    item
                }
                None if st.closed => return TryRecv::Closed,
                None => return TryRecv::Empty,
            }
        };
        self.shared.fire_space();
        TryRecv::Item(item)
    }

    /// Block until data is queued, the pipe closes, or `timeout` elapses.
    /// Returns true when an item is ready or the pipe is closed (i.e. a
    /// `try_recv` now would not report `Empty`); false on timeout. Used by
    /// the recovery layer to poll a conn without consuming from it.
    pub fn wait_readable(&self, timeout: std::time::Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if !st.queue.is_empty() || st.closed {
                return true;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            let (next, res) = self
                .shared
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = next;
            if res.timed_out() && st.queue.is_empty() && !st.closed {
                return false;
            }
        }
    }

    /// Register the callback fired whenever data may have arrived (an
    /// item was queued, or the pipe closed). Replaces any previous
    /// waker; fired outside the pipe's locks.
    pub fn set_data_waker(&self, waker: Arc<dyn Fn() + Send + Sync>) {
        *self.shared.data_waker.lock().unwrap() = Some(waker);
    }

    /// Current depth (for pipeline-balance diagnostics).
    pub fn len(&self) -> usize {
        self.shared.state.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ------------------------------------------------------------- WorkerPool

/// A set of named worker threads joined on drop; panics propagate as errors.
pub struct WorkerPool {
    handles: Vec<(String, JoinHandle<Result<()>>)>,
}

impl Default for WorkerPool {
    fn default() -> Self {
        Self::new()
    }
}

impl WorkerPool {
    pub fn new() -> Self {
        WorkerPool {
            handles: Vec::new(),
        }
    }

    /// Spawn a named worker returning `Result<()>`.
    pub fn spawn<F>(&mut self, name: &str, f: F)
    where
        F: FnOnce() -> Result<()> + Send + 'static,
    {
        let handle = std::thread::Builder::new()
            .name(name.to_string())
            .spawn(f)
            .expect("spawn worker");
        self.handles.push((name.to_string(), handle));
    }

    /// Number of workers spawned (and not yet joined).
    pub fn len(&self) -> usize {
        self.handles.len()
    }

    /// True when no workers were spawned — e.g. the wiring layer's
    /// junction pool under worker-owned wiring, which the data-plane
    /// smoke tests assert on.
    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    /// Drop all handles without joining — used on error paths where a
    /// worker may be blocked on I/O that only unblocks once the caller
    /// releases its side of the connection.
    pub fn detach(mut self) {
        self.handles.clear();
    }

    /// Join all workers, collecting the first error (if any).
    pub fn join(self) -> Result<()> {
        let mut first_err = None;
        for (name, h) in self.handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(DeferError::Coordinator(format!(
                            "worker {name} panicked"
                        )));
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

// -------------------------------------------------------------- CodecPool

/// A boxed unit of work queued on a [`CodecPool`].
type PoolJob = Box<dyn FnOnce() + Send + 'static>;

struct CodecPoolShared {
    queue: Mutex<VecDeque<PoolJob>>,
    available: Condvar,
    shutdown: std::sync::atomic::AtomicBool,
}

/// A small persistent worker pool for data-parallel codec work.
///
/// Unlike [`WorkerPool`] (spawn-and-join, one closure per thread), this
/// pool keeps `threads` workers alive and feeds them short jobs — the
/// per-chunk encode/decode tasks of the chunk-parallel codec path
/// ([`crate::serial::chunked`]). One pool is shared by every worker
/// replica of a deployment, so total codec parallelism is bounded by the
/// configured `--codec-threads` regardless of stage count.
///
/// [`CodecPool::run_scoped`] provides structured fork-join over borrowed
/// data: it blocks until every submitted job has finished, which is what
/// makes handing non-`'static` closures to the workers sound.
pub struct CodecPool {
    shared: Arc<CodecPoolShared>,
    workers: Vec<JoinHandle<()>>,
    /// Total jobs executed (diagnostics / bench reporting).
    jobs_run: Arc<AtomicUsize>,
}

impl CodecPool {
    /// Spawn a pool with `threads` workers (>= 1).
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(CodecPoolShared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: std::sync::atomic::AtomicBool::new(false),
        });
        let jobs_run = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                let jobs_run = Arc::clone(&jobs_run);
                std::thread::Builder::new()
                    .name(format!("codec-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let mut q = shared.queue.lock().unwrap();
                            loop {
                                if let Some(job) = q.pop_front() {
                                    break job;
                                }
                                if shared.shutdown.load(Ordering::Acquire) {
                                    return;
                                }
                                q = shared.available.wait(q).unwrap();
                            }
                        };
                        job();
                        jobs_run.fetch_add(1, Ordering::Relaxed);
                    })
                    .expect("spawn codec worker")
            })
            .collect();
        CodecPool {
            shared,
            workers,
            jobs_run,
        }
    }

    /// Worker count.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Total jobs executed so far.
    pub fn jobs_run(&self) -> usize {
        self.jobs_run.load(Ordering::Relaxed)
    }

    /// Run `jobs` on the pool and block until all of them complete
    /// (structured fork-join). Jobs may borrow from the caller's stack:
    /// the barrier below guarantees no job outlives this call, which is
    /// what makes the lifetime erasure sound. A panicking job is caught
    /// on the worker (keeping the pool alive) and re-raised here after
    /// every sibling has finished.
    pub fn run_scoped<'scope>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if jobs.is_empty() {
            return;
        }
        struct Done {
            pending: Mutex<usize>,
            finished: Condvar,
            panicked: std::sync::atomic::AtomicBool,
        }
        let done = Arc::new(Done {
            pending: Mutex::new(jobs.len()),
            finished: Condvar::new(),
            panicked: std::sync::atomic::AtomicBool::new(false),
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            for job in jobs {
                // SAFETY: `run_scoped` blocks until `pending == 0`, i.e.
                // until this job has run to completion (or panicked and
                // been caught) on a worker — the borrowed data outlives
                // every use. The transmute only erases the lifetime.
                let job: PoolJob = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 'scope>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(job)
                };
                let done = Arc::clone(&done);
                q.push_back(Box::new(move || {
                    if std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)).is_err() {
                        done.panicked.store(true, Ordering::Release);
                    }
                    let mut pending = done.pending.lock().unwrap();
                    *pending -= 1;
                    if *pending == 0 {
                        done.finished.notify_all();
                    }
                }));
            }
            self.shared.available.notify_all();
        }
        let mut pending = done.pending.lock().unwrap();
        while *pending > 0 {
            pending = done.finished.wait(pending).unwrap();
        }
        drop(pending);
        if done.panicked.load(Ordering::Acquire) {
            panic!("codec pool job panicked");
        }
    }
}

impl Drop for CodecPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.available.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn pipe_fifo_order() {
        let (tx, rx) = pipe::<u32>(4);
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        tx.close();
        let got: Vec<u32> = std::iter::from_fn(|| rx.recv()).collect();
        assert_eq!(got, vec![0, 1, 2, 3]);
    }

    #[test]
    fn pipe_backpressure_blocks_sender() {
        let (tx, rx) = pipe::<u32>(2);
        let sent = Arc::new(AtomicUsize::new(0));
        let sent2 = Arc::clone(&sent);
        let h = std::thread::spawn(move || {
            for i in 0..10 {
                tx.send(i).unwrap();
                sent2.fetch_add(1, Ordering::SeqCst);
            }
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        // Sender must be stuck near the capacity.
        assert!(sent.load(Ordering::SeqCst) <= 3);
        let mut got = Vec::new();
        while got.len() < 10 {
            got.push(rx.recv().unwrap());
        }
        h.join().unwrap();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn pipe_close_drains_then_none() {
        let (tx, rx) = pipe::<u32>(8);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        tx.close();
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), None);
        assert!(tx.send(3).is_err());
    }

    #[test]
    fn sender_drop_closes() {
        let (tx, rx) = pipe::<u32>(8);
        tx.send(7).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Some(7));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn concurrent_sender_drops_always_unblock_receiver() {
        // Regression test for the drop race: two cloned senders dropping
        // concurrently could each read a stale count, decide "not last",
        // and leave the receiver blocked forever. The receiver thread
        // must observe `None` (close) on every iteration or this test
        // hangs.
        for round in 0..150 {
            let (tx, rx) = pipe::<u32>(8);
            let senders: Vec<_> = (0..4).map(|_| tx.clone()).collect();
            drop(tx);
            let recv = std::thread::spawn(move || {
                let mut got = 0usize;
                while rx.recv().is_some() {
                    got += 1;
                }
                got
            });
            let drops: Vec<_> = senders
                .into_iter()
                .enumerate()
                .map(|(i, s)| {
                    std::thread::spawn(move || {
                        s.send(i as u32).unwrap();
                        drop(s);
                    })
                })
                .collect();
            for h in drops {
                h.join().unwrap();
            }
            let got = recv.join().unwrap();
            assert_eq!(got, 4, "round {round}: receiver saw {got}/4 items");
        }
    }

    #[test]
    fn pool_joins_and_propagates_errors() {
        let mut pool = WorkerPool::new();
        pool.spawn("ok", || Ok(()));
        pool.spawn("bad", || {
            Err(DeferError::Coordinator("intentional".into()))
        });
        assert!(pool.join().is_err());

        let mut pool = WorkerPool::new();
        let hits = Arc::new(AtomicUsize::new(0));
        for _ in 0..4 {
            let hits = Arc::clone(&hits);
            pool.spawn("w", move || {
                hits.fetch_add(1, Ordering::SeqCst);
                Ok(())
            });
        }
        pool.join().unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn pool_reports_panic() {
        let mut pool = WorkerPool::new();
        pool.spawn("panics", || panic!("boom"));
        assert!(pool.join().is_err());
    }

    #[test]
    fn receiver_drop_unblocks_sender() {
        let (tx, rx) = pipe::<u32>(1);
        tx.send(1).unwrap();
        let h = std::thread::spawn(move || {
            // Pipe is full; this blocks until the receiver goes away,
            // then must fail instead of hanging.
            tx.send(2)
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        drop(rx);
        assert!(h.join().unwrap().is_err());
    }

    #[test]
    fn codec_pool_scoped_borrow() {
        let pool = CodecPool::new(3);
        let data: Vec<u64> = (0..100).collect();
        let mut out = vec![0u64; 100];
        {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
                .chunks_mut(7)
                .enumerate()
                .map(|(c, slot)| {
                    let data = &data;
                    Box::new(move || {
                        for (k, s) in slot.iter_mut().enumerate() {
                            *s = data[c * 7 + k] * 2;
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(jobs);
        }
        assert!(out.iter().enumerate().all(|(i, &v)| v == i as u64 * 2));
        assert!(pool.jobs_run() >= 15);
        assert_eq!(pool.threads(), 3);
    }

    #[test]
    fn codec_pool_survives_job_panic() {
        let pool = CodecPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_scoped(vec![
                Box::new(|| panic!("intentional")) as Box<dyn FnOnce() + Send>,
                Box::new(|| {}) as Box<dyn FnOnce() + Send>,
            ]);
        }));
        assert!(r.is_err());
        // The pool is still serviceable afterwards.
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        pool.run_scoped(vec![Box::new(move || {
            h.fetch_add(1, Ordering::SeqCst);
        }) as Box<dyn FnOnce() + Send>]);
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn try_send_and_try_recv_report_full_empty_closed() {
        let (tx, rx) = pipe::<u32>(1);
        assert!(matches!(rx.try_recv(), TryRecv::Empty));
        assert!(matches!(tx.try_send(1), TrySend::Ok));
        // Full: the rejected item comes back intact.
        match tx.try_send(2) {
            TrySend::Full(v) => assert_eq!(v, 2),
            _ => panic!("expected Full"),
        }
        assert_eq!(tx.len(), 1);
        assert!(matches!(rx.try_recv(), TryRecv::Item(1)));
        assert!(matches!(tx.try_send(3), TrySend::Ok));
        tx.close();
        // Close drains first, then reports Closed.
        assert!(matches!(rx.try_recv(), TryRecv::Item(3)));
        assert!(matches!(rx.try_recv(), TryRecv::Closed));
        match tx.try_send(4) {
            TrySend::Closed(v) => assert_eq!(v, 4),
            _ => panic!("expected Closed"),
        }
    }

    #[test]
    fn wakers_fire_on_data_space_and_close() {
        let (tx, rx) = pipe::<u32>(1);
        let data_hits = Arc::new(AtomicUsize::new(0));
        let space_hits = Arc::new(AtomicUsize::new(0));
        let d = Arc::clone(&data_hits);
        rx.set_data_waker(Arc::new(move || {
            d.fetch_add(1, Ordering::SeqCst);
        }));
        let s = Arc::clone(&space_hits);
        tx.set_space_waker(Arc::new(move || {
            s.fetch_add(1, Ordering::SeqCst);
        }));
        tx.send(1).unwrap();
        assert_eq!(data_hits.load(Ordering::SeqCst), 1);
        assert_eq!(space_hits.load(Ordering::SeqCst), 0);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(space_hits.load(Ordering::SeqCst), 1);
        // try_* paths fire the same hooks.
        assert!(matches!(tx.try_send(2), TrySend::Ok));
        assert_eq!(data_hits.load(Ordering::SeqCst), 2);
        assert!(matches!(rx.try_recv(), TryRecv::Item(2)));
        assert_eq!(space_hits.load(Ordering::SeqCst), 2);
        // Close fires both, so parked machines on either side wake.
        tx.close();
        assert!(data_hits.load(Ordering::SeqCst) >= 3);
        assert!(space_hits.load(Ordering::SeqCst) >= 3);
    }

    #[test]
    fn waker_reentrancy_safe_with_blocking_peer() {
        // A waker that immediately try_recv's on the same pipe must not
        // deadlock against the send that fired it (wakers run outside
        // the pipe's locks).
        let (tx, rx) = pipe::<u32>(4);
        let rx = Arc::new(rx);
        let seen = Arc::new(AtomicUsize::new(0));
        let rx2 = Arc::clone(&rx);
        let seen2 = Arc::clone(&seen);
        rx.set_data_waker(Arc::new(move || {
            if let TryRecv::Item(_) = rx2.try_recv() {
                seen2.fetch_add(1, Ordering::SeqCst);
            }
        }));
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        assert_eq!(seen.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn codec_pool_many_rounds_deterministic_completion() {
        let pool = CodecPool::new(4);
        for round in 0..50 {
            let total = Arc::new(AtomicUsize::new(0));
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..16)
                .map(|i| {
                    let total = Arc::clone(&total);
                    Box::new(move || {
                        total.fetch_add(i, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(jobs);
            assert_eq!(total.load(Ordering::SeqCst), 120, "round {round}");
        }
    }
}
