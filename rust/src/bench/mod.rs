//! Measurement harness (criterion substitute, offline environment).
//!
//! Provides warmup + repeated timing with mean/stddev/min/max, and an
//! aligned table printer used by every paper-table bench so that
//! `cargo bench` regenerates the rows of Tables I/II and the series of
//! Figs 2/3 in a stable, diffable format.

use std::time::{Duration, Instant};

/// Summary statistics over repeated measurements.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
    pub iters: usize,
}

impl Stats {
    /// Summarize a sample set. An empty slice yields zeroed stats with
    /// `iters == 0` (a bench harness that measured nothing must not
    /// panic the whole run — callers can see `iters` and skip the row).
    pub fn from_samples(samples: &[Duration]) -> Stats {
        if samples.is_empty() {
            return Stats {
                mean: Duration::ZERO,
                stddev: Duration::ZERO,
                min: Duration::ZERO,
                max: Duration::ZERO,
                iters: 0,
            };
        }
        let n = samples.len() as f64;
        let mean = samples.iter().map(Duration::as_secs_f64).sum::<f64>() / n;
        let var = samples
            .iter()
            .map(|s| {
                let d = s.as_secs_f64() - mean;
                d * d
            })
            .sum::<f64>()
            / n;
        Stats {
            mean: Duration::from_secs_f64(mean),
            stddev: Duration::from_secs_f64(var.sqrt()),
            min: *samples.iter().min().unwrap(),
            max: *samples.iter().max().unwrap(),
            iters: samples.len(),
        }
    }

    /// Throughput in ops/sec for one op per iteration.
    pub fn ops_per_sec(&self) -> f64 {
        let s = self.mean.as_secs_f64();
        if s > 0.0 {
            1.0 / s
        } else {
            f64::INFINITY
        }
    }

    /// MB/s for `bytes` processed per iteration.
    pub fn mb_per_sec(&self, bytes: usize) -> f64 {
        bytes as f64 / 1e6 / self.mean.as_secs_f64()
    }
}

/// Time `f` with warmup; returns stats over `iters` measured runs.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters.max(1));
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    Stats::from_samples(&samples)
}

/// Run `f` repeatedly for at least `budget`; returns (runs, elapsed) — the
/// paper's throughput methodology ("set a fixed time of execution ... and
/// record how many inference cycles could be done in that fixed time").
pub fn bench_for<T>(budget: Duration, mut f: impl FnMut() -> T) -> (u64, Duration) {
    let t0 = Instant::now();
    let mut runs = 0u64;
    while t0.elapsed() < budget {
        std::hint::black_box(f());
        runs += 1;
    }
    (runs, t0.elapsed())
}

/// Fixed-width table printer for paper-style output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join(" | ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(
            &widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("-+-"),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats::from_samples(&[
            Duration::from_millis(10),
            Duration::from_millis(20),
            Duration::from_millis(30),
        ]);
        assert_eq!(s.mean, Duration::from_millis(20));
        assert_eq!(s.min, Duration::from_millis(10));
        assert_eq!(s.max, Duration::from_millis(30));
        assert!((s.ops_per_sec() - 50.0).abs() < 1.0);
        assert!((s.mb_per_sec(20_000) - 1.0).abs() < 0.05);
    }

    #[test]
    fn empty_samples_yield_zeroed_stats() {
        // Regression: this used to panic via min()/max().unwrap().
        let s = Stats::from_samples(&[]);
        assert_eq!(s.iters, 0);
        assert_eq!(s.mean, Duration::ZERO);
        assert_eq!(s.stddev, Duration::ZERO);
        assert_eq!(s.min, Duration::ZERO);
        assert_eq!(s.max, Duration::ZERO);
        // Derived rates stay well-defined (no divide-by-zero panic).
        assert!(s.ops_per_sec().is_infinite());
    }

    #[test]
    fn bench_runs_and_measures() {
        let mut count = 0u64;
        let s = bench(2, 5, || {
            count += 1;
            std::thread::sleep(Duration::from_millis(1));
        });
        assert_eq!(count, 7);
        assert_eq!(s.iters, 5);
        assert!(s.mean >= Duration::from_millis(1));
    }

    #[test]
    fn bench_for_respects_budget() {
        let (runs, elapsed) = bench_for(Duration::from_millis(30), || {
            std::thread::sleep(Duration::from_millis(2));
        });
        assert!(runs >= 5, "runs {runs}");
        assert!(elapsed >= Duration::from_millis(30));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Model", "Nodes", "Throughput"]);
        t.row(&["resnet50".into(), "8".into(), "0.673".into()]);
        t.row(&["vgg16".into(), "4".into(), "12.5".into()]);
        let out = t.render();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("Model"));
        assert!(lines[2].contains("resnet50"));
        // All rows equal width.
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
