//! # DEFER: Distributed Edge Inference for Deep Neural Networks
//!
//! Rust + JAX + Pallas reproduction of Parthasarathy & Krishnamachari,
//! COMSNETS 2022 (DOI 10.1109/COMSNETS53615.2022.9668515).
//!
//! DEFER partitions a DNN layer-wise into sequential sub-networks and
//! pipelines inference through a chain of compute nodes coordinated by a
//! dispatcher. This crate is Layer 3 of the three-layer architecture:
//!
//! * **L1/L2 (build time, Python)** — `python/compile/` holds the Pallas
//!   kernels and JAX models; `make artifacts` AOT-lowers every model
//!   partition to HLO text under `artifacts/`.
//! * **L3 (this crate)** — loads the artifacts via the PJRT C API
//!   ([`runtime`]), derives a declarative deployment [`topology`]
//!   (stages × replicas, per-hop links) — hand-written, emitted by the
//!   [`placement`] planner from stage costs and device budgets, or
//!   jointly re-cut by the [`repartition`] planner, which fuses the
//!   finest-granularity partition set into balanced
//!   [`model::StageSpec`] stages and chooses replica counts in the same
//!   pass — distributes fused stages and
//!   weights to worker replicas ([`coordinator::dispatcher`]), and
//!   pipelines frames through the deployment ([`coordinator`]) with the
//!   paper's serialization/compression sweep ([`serial`], [`compress`]),
//!   network emulation ([`netem`]), energy model ([`energy`]) and
//!   metrics ([`metrics`]).
//!
//! Python never runs on the request path; after `make artifacts` the
//! `defer` binary is self-contained.

pub mod bench;
pub mod cli;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod error;
pub mod metrics;
pub mod model;
pub mod netem;
pub mod netio;
pub mod placement;
pub mod repartition;
pub mod runtime;
pub mod serial;
pub mod tensor;
pub mod threadpool;
pub mod topology;
pub mod util;
pub mod wire;

pub use error::{DeferError, Result};
