//! Metrics substrate: byte counters (the `nload` role), cycle counters,
//! latency histograms and throughput clocks feeding every paper metric.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Monotonic byte counter, shared across threads — measures network payload
/// at the wire layer exactly where the paper pointed `nload`.
#[derive(Clone, Default, Debug)]
pub struct ByteCounter {
    bytes: Arc<AtomicU64>,
}

impl ByteCounter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.bytes.fetch_add(n, Ordering::Relaxed);
    }

    pub fn total(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn total_mb(&self) -> f64 {
        self.total() as f64 / 1e6
    }

    pub fn reset(&self) {
        self.bytes.store(0, Ordering::Relaxed);
    }
}

/// Latency histogram with fixed log-spaced buckets (1 us .. 100 s) plus
/// exact min/max/sum — enough for p50/p95/p99 on chain latencies.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    bounds: Vec<f64>,
    count: AtomicU64,
    sum_nanos: AtomicU64,
    min_nanos: AtomicU64,
    max_nanos: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        // Log-spaced (x1.4) bucket upper bounds from 1 us to 100 s,
        // plus one overflow bucket for anything slower.
        let mut bounds = Vec::new();
        let mut b = 1e-6f64;
        while b <= 100.0 {
            bounds.push(b);
            b *= 1.4;
        }
        let n = bounds.len() + 1; // +overflow
        Histogram {
            buckets: (0..n).map(|_| AtomicU64::new(0)).collect(),
            bounds,
            count: AtomicU64::new(0),
            sum_nanos: AtomicU64::new(0),
            min_nanos: AtomicU64::new(u64::MAX),
            max_nanos: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        let secs = d.as_secs_f64();
        let idx = self
            .bounds
            .iter()
            .position(|b| secs <= *b)
            .unwrap_or(self.bounds.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let nanos = d.as_nanos() as u64;
        self.sum_nanos.fetch_add(nanos, Ordering::Relaxed);
        self.min_nanos.fetch_min(nanos, Ordering::Relaxed);
        self.max_nanos.fetch_max(nanos, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sum_nanos.load(Ordering::Relaxed) / c)
    }

    pub fn min(&self) -> Duration {
        let v = self.min_nanos.load(Ordering::Relaxed);
        if v == u64::MAX {
            Duration::ZERO
        } else {
            Duration::from_nanos(v)
        }
    }

    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_nanos.load(Ordering::Relaxed))
    }

    /// Approximate quantile from bucket upper bounds (q in [0, 1]),
    /// clamped to the exactly-tracked max so a sample in the overflow
    /// bucket reports its real magnitude rather than the 100 s bound.
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = ((total as f64) * q).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, bucket) in self.buckets.iter().enumerate() {
            seen += bucket.load(Ordering::Relaxed);
            if seen >= target {
                return match self.bounds.get(i) {
                    // A bucket's upper bound can exceed every recorded
                    // sample; never report above the observed max.
                    Some(secs) => Duration::from_secs_f64(*secs).min(self.max()),
                    // Overflow bucket: no upper bound, use the max.
                    None => self.max(),
                };
            }
        }
        self.max()
    }
}

/// Queue-depth gauge: records the instantaneous depth of a bounded pipe
/// every time someone observes it, keeping both the latest sample and
/// the high-water mark. This is how batching backpressure becomes
/// visible (a pipe pinned at capacity = the stage behind it is the
/// gate) and what the adaptive batcher reads to size its next batch.
#[derive(Clone, Default, Debug)]
pub struct QueueDepthGauge {
    last: Arc<AtomicU64>,
    high: Arc<AtomicU64>,
}

impl QueueDepthGauge {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one observation of a queue's current depth.
    #[inline]
    pub fn observe(&self, depth: usize) {
        let d = depth as u64;
        self.last.store(d, Ordering::Relaxed);
        self.high.fetch_max(d, Ordering::Relaxed);
    }

    /// Most recently observed depth.
    pub fn last(&self) -> usize {
        self.last.load(Ordering::Relaxed) as usize
    }

    /// Largest depth ever observed.
    pub fn high_water(&self) -> usize {
        self.high.load(Ordering::Relaxed) as usize
    }
}

/// Throughput clock: counts completed inference cycles over a wall-clock
/// window — the paper's "inference cycles per second".
#[derive(Clone)]
pub struct ThroughputClock {
    start: Instant,
    cycles: Arc<AtomicU64>,
}

impl Default for ThroughputClock {
    fn default() -> Self {
        Self::new()
    }
}

impl ThroughputClock {
    pub fn new() -> Self {
        ThroughputClock {
            start: Instant::now(),
            cycles: Arc::new(AtomicU64::new(0)),
        }
    }

    pub fn record_cycle(&self) {
        self.cycles.fetch_add(1, Ordering::Relaxed);
    }

    pub fn cycles(&self) -> u64 {
        self.cycles.load(Ordering::Relaxed)
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Cycles per second since construction.
    pub fn throughput(&self) -> f64 {
        let secs = self.elapsed().as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.cycles() as f64 / secs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_counter_shared() {
        let c = ByteCounter::new();
        let c2 = c.clone();
        c.add(100);
        c2.add(50);
        assert_eq!(c.total(), 150);
        assert!((c.total_mb() - 0.00015).abs() < 1e-12);
        c.reset();
        assert_eq!(c2.total(), 0);
    }

    #[test]
    fn histogram_stats() {
        let h = Histogram::new();
        for ms in [1u64, 2, 3, 4, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.mean(), Duration::from_millis(22));
        assert_eq!(h.min(), Duration::from_millis(1));
        assert_eq!(h.max(), Duration::from_millis(100));
        // p50 should land near 3 ms (log buckets: within 40%).
        let p50 = h.quantile(0.5).as_secs_f64();
        assert!((0.002..0.006).contains(&p50), "p50 {p50}");
        // p100 near max.
        assert!(h.quantile(1.0) >= Duration::from_millis(70));
    }

    #[test]
    fn quantile_clamps_overflow_bucket_to_observed_max() {
        // A sample beyond the last bucket bound (100 s) used to report a
        // flat 100 s; it must report the exactly-tracked max instead.
        let h = Histogram::new();
        h.record(Duration::from_secs(150));
        h.record(Duration::from_millis(1));
        assert_eq!(h.quantile(1.0), Duration::from_secs(150));
        assert_eq!(h.quantile(0.99), Duration::from_secs(150));
        // In-range quantiles stay at their bucket bound, <= max.
        assert!(h.quantile(0.25) <= Duration::from_millis(2));
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.min(), Duration::ZERO);
    }

    #[test]
    fn error_sink_survives_poisoned_mutex() {
        // A worker panicking while holding the error-sink lock poisons
        // it; later pushes from healthy threads must still land instead
        // of cascading the panic.
        let m = RunMetrics::new();
        let errors = Arc::clone(&m.errors);
        let _ = std::thread::spawn(move || {
            let _guard = errors.lock().unwrap();
            panic!("poison the error sink");
        })
        .join();
        assert!(m.errors.lock().is_err(), "mutex should be poisoned");
        m.push_error("recorded after poisoning".into());
        let guard = m.errors.lock().unwrap_or_else(|e| e.into_inner());
        assert_eq!(guard.len(), 1);
        assert_eq!(guard[0], "recorded after poisoning");
    }

    #[test]
    fn push_error_collapses_consecutive_duplicates() {
        let m = RunMetrics::new();
        m.push_error("frame 3 corrupt".into());
        m.push_error("frame 3 corrupt".into());
        m.push_error("frame 3 corrupt".into());
        m.push_error("peer dead".into());
        m.push_error("frame 3 corrupt".into());
        let guard = m.errors.lock().unwrap();
        assert_eq!(
            *guard,
            vec![
                "frame 3 corrupt (x3)".to_string(),
                "peer dead".to_string(),
                "frame 3 corrupt".to_string(),
            ]
        );
    }

    #[test]
    fn queue_depth_gauge_tracks_last_and_high_water() {
        let g = QueueDepthGauge::new();
        assert_eq!(g.last(), 0);
        assert_eq!(g.high_water(), 0);
        g.observe(3);
        g.observe(7);
        g.observe(2);
        assert_eq!(g.last(), 2);
        assert_eq!(g.high_water(), 7);
        // Clones share state — one gauge per queue, observed anywhere.
        let g2 = g.clone();
        g2.observe(9);
        assert_eq!(g.high_water(), 9);
    }

    #[test]
    fn io_plane_stats_share_state_across_clones() {
        let m = RunMetrics::new();
        let io = m.io.clone();
        io.set_threads(3);
        io.set_shards(vec![(10, 40), (7, 25)]);
        assert_eq!(m.io.threads(), 3);
        assert_eq!(m.io.shards(), vec![(10, 40), (7, 25)]);
        assert_eq!(m.io.dispatches(), 65);
        assert_eq!(RunMetrics::new().io.shards(), Vec::new());
    }

    #[test]
    fn zerocopy_snapshot_deltas_scope_the_global_counters() {
        // Counters are process-global (other tests may bump them
        // concurrently), so assert on deltas being at least our own
        // contribution rather than on absolute values.
        let before = zerocopy::snapshot();
        zerocopy::count_payload_copy();
        zerocopy::count_egress_syscall();
        zerocopy::count_egress_syscall();
        zerocopy::count_pool_hit();
        zerocopy::count_pool_miss();
        let delta = zerocopy::snapshot().since(&before);
        assert!(delta.payload_copies >= 1, "{delta:?}");
        assert!(delta.egress_syscalls >= 2, "{delta:?}");
        assert!(delta.pool_hits >= 1, "{delta:?}");
        assert!(delta.pool_misses >= 1, "{delta:?}");
        // A snapshot subtracted from itself is zero movement.
        let now = zerocopy::snapshot();
        assert_eq!(now.since(&now), zerocopy::Snapshot::default());
    }

    #[test]
    fn throughput_clock() {
        let t = ThroughputClock::new();
        for _ in 0..10 {
            t.record_cycle();
        }
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(t.cycles(), 10);
        let tput = t.throughput();
        assert!(tput > 0.0 && tput < 500.0, "{tput}");
    }
}

/// Zero-copy data-plane counters (process-global).
///
/// The §Perf zero-copy frame path makes two claims the run report must
/// be able to prove: steady-state frame traffic performs **zero**
/// serialize copies (the encoder's container is the buffer every
/// consumer shares, refcounted), and each reactor-plane frame leaves in
/// **one** `writev` syscall. These counters are bumped at the exact
/// sites where the old plane paid — a payload memcpy, a wire write, a
/// pool allocation — so a test or report can snapshot before a run and
/// assert on the delta.
pub mod zerocopy {
    use std::sync::atomic::{AtomicU64, Ordering};

    static PAYLOAD_COPIES: AtomicU64 = AtomicU64::new(0);
    static EGRESS_SYSCALLS: AtomicU64 = AtomicU64::new(0);
    static POOL_HITS: AtomicU64 = AtomicU64::new(0);
    static POOL_MISSES: AtomicU64 = AtomicU64::new(0);

    /// A full payload was memcpy'd on the serialize/egress path (legacy
    /// `Message` bridging, shared-frame materialization, …). Zero at
    /// steady state on the zero-copy path.
    #[inline]
    pub fn count_payload_copy() {
        PAYLOAD_COPIES.fetch_add(1, Ordering::Relaxed);
    }

    /// One wire-write syscall (`writev`/`write`) retired on an egress
    /// connection.
    #[inline]
    pub fn count_egress_syscall() {
        EGRESS_SYSCALLS.fetch_add(1, Ordering::Relaxed);
    }

    /// A `BufPool::take*` was served from the free list.
    #[inline]
    pub fn count_pool_hit() {
        POOL_HITS.fetch_add(1, Ordering::Relaxed);
    }

    /// A `BufPool::take*` had to allocate fresh.
    #[inline]
    pub fn count_pool_miss() {
        POOL_MISSES.fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time reading of every counter. Subtract two snapshots to
    /// scope the counters to one run (they are process-global and only
    /// ever increase).
    #[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
    pub struct Snapshot {
        pub payload_copies: u64,
        pub egress_syscalls: u64,
        pub pool_hits: u64,
        pub pool_misses: u64,
    }

    pub fn snapshot() -> Snapshot {
        Snapshot {
            payload_copies: PAYLOAD_COPIES.load(Ordering::Relaxed),
            egress_syscalls: EGRESS_SYSCALLS.load(Ordering::Relaxed),
            pool_hits: POOL_HITS.load(Ordering::Relaxed),
            pool_misses: POOL_MISSES.load(Ordering::Relaxed),
        }
    }

    impl Snapshot {
        /// Counter movement since `earlier` (saturating, so a stale
        /// snapshot cannot underflow).
        pub fn since(&self, earlier: &Snapshot) -> Snapshot {
            Snapshot {
                payload_copies: self.payload_copies.saturating_sub(earlier.payload_copies),
                egress_syscalls: self
                    .egress_syscalls
                    .saturating_sub(earlier.egress_syscalls),
                pool_hits: self.pool_hits.saturating_sub(earlier.pool_hits),
                pool_misses: self.pool_misses.saturating_sub(earlier.pool_misses),
            }
        }
    }
}

/// A labelled set of per-socket byte counters (tx per message class), used
/// by the Table I payload breakdown.
#[derive(Clone, Default)]
pub struct TrafficBreakdown {
    pub architecture: ByteCounter,
    pub weights: ByteCounter,
    pub data: ByteCounter,
}

impl TrafficBreakdown {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn total(&self) -> u64 {
        self.architecture.total() + self.weights.total() + self.data.total()
    }

    /// Shared guard for rows printed by the benches.
    pub fn row(&self, class: &str) -> u64 {
        match class {
            "architecture" => self.architecture.total(),
            "weights" => self.weights.total(),
            "data" => self.data.total(),
            _ => 0,
        }
    }
}

/// Data-plane I/O accounting: how many dedicated I/O threads the run
/// spawned (parked per-connection readers/writers on the blocking plane,
/// reactor shards otherwise) plus each reactor shard's final
/// `(wakeups, dispatches)` counters. Clones share state, like
/// [`ByteCounter`].
#[derive(Clone, Default)]
pub struct IoPlaneStats {
    threads: Arc<AtomicU64>,
    shards: Arc<Mutex<Vec<(u64, u64)>>>,
}

impl IoPlaneStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record how many data-plane threads the run spawned.
    pub fn set_threads(&self, n: u64) {
        self.threads.store(n, Ordering::Relaxed);
    }

    pub fn threads(&self) -> u64 {
        self.threads.load(Ordering::Relaxed)
    }

    /// Record the final `(wakeups, dispatches)` snapshot per reactor
    /// shard (empty on the blocking plane).
    pub fn set_shards(&self, snapshot: Vec<(u64, u64)>) {
        *self.shards.lock().unwrap_or_else(|e| e.into_inner()) = snapshot;
    }

    pub fn shards(&self) -> Vec<(u64, u64)> {
        self.shards
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    /// Total machine steps dispatched across all shards.
    pub fn dispatches(&self) -> u64 {
        self.shards().iter().map(|(_, d)| d).sum()
    }
}

/// Aggregated per-run metrics snapshot used by examples and benches.
pub struct RunMetrics {
    pub clock: ThroughputClock,
    pub latency: Arc<Histogram>,
    pub traffic: TrafficBreakdown,
    /// Serialization/deserialization time (paper's "overhead").
    pub overhead: crate::util::timer::SharedTimer,
    /// High-water depth of the dispatcher's bounded send queue — the
    /// observable backpressure signal behind micro-batching.
    pub queue_depth: QueueDepthGauge,
    /// Data-plane thread count and per-shard reactor counters.
    pub io: IoPlaneStats,
    /// Results that failed integrity/shape checks.
    pub errors: Arc<Mutex<Vec<String>>>,
}

impl Default for RunMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl RunMetrics {
    pub fn new() -> Self {
        RunMetrics {
            clock: ThroughputClock::new(),
            latency: Arc::new(Histogram::new()),
            traffic: TrafficBreakdown::new(),
            overhead: crate::util::timer::SharedTimer::new(),
            queue_depth: QueueDepthGauge::new(),
            io: IoPlaneStats::new(),
            errors: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// Record a failed-result message. Recovers a poisoned mutex (a
    /// worker that panicked mid-push during shutdown teardown must not
    /// cascade the panic into every other thread's error reporting).
    /// Identical consecutive messages collapse into one entry with a
    /// repetition count — fault-injection runs can emit the same
    /// per-frame error hundreds of times.
    pub fn push_error(&self, msg: String) {
        let mut errors = self.errors.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(last) = errors.last_mut() {
            if *last == msg {
                *last = format!("{msg} (x2)");
                return;
            }
            if let Some((head, tail)) = last.rsplit_once(" (x") {
                if head == msg {
                    if let Some(n) = tail.strip_suffix(')').and_then(|n| n.parse::<u64>().ok())
                    {
                        *last = format!("{msg} (x{})", n + 1);
                        return;
                    }
                }
            }
        }
        errors.push(msg);
    }
}
