//! Network emulation: the CORE-emulator substitute.
//!
//! The paper ran DEFER inside the CORE network emulator, which shapes
//! loopback traffic with per-link bandwidth/latency disciplines. This
//! module reproduces that: a [`Link`] is a token-bucket rate limiter plus
//! a fixed one-way latency and optional jitter, applied to every wire
//! chunk at the framing layer (see `wire::write_message`). It works
//! identically for in-process channels and real TCP sockets on loopback.
//!
//! `Link::ideal()` is the paper's "close-to-zero latency environment";
//! `LinkSpec` presets model typical edge networks for the ablations.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::prng::Rng;

/// Declarative link parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkSpec {
    /// Bandwidth in bits/second; `None` = unlimited.
    pub bandwidth_bps: Option<u64>,
    /// One-way latency added per message chunk train.
    pub latency: Duration,
    /// Uniform jitter in `[0, jitter]` added to the latency.
    pub jitter: Duration,
}

impl LinkSpec {
    /// The paper's evaluation setting: local, close-to-zero latency.
    pub const fn ideal() -> Self {
        LinkSpec {
            bandwidth_bps: None,
            latency: Duration::ZERO,
            jitter: Duration::ZERO,
        }
    }

    /// Gigabit Ethernet LAN (the paper's energy model assumes Ethernet).
    pub const fn gigabit_lan() -> Self {
        LinkSpec {
            bandwidth_bps: Some(1_000_000_000),
            latency: Duration::from_micros(200),
            jitter: Duration::ZERO,
        }
    }

    /// 100 Mbit edge/fog link.
    pub const fn fast_edge() -> Self {
        LinkSpec {
            bandwidth_bps: Some(100_000_000),
            latency: Duration::from_millis(1),
            jitter: Duration::from_micros(200),
        }
    }

    /// Constrained wireless edge (802.11-ish).
    pub const fn wifi() -> Self {
        LinkSpec {
            bandwidth_bps: Some(50_000_000),
            latency: Duration::from_millis(3),
            jitter: Duration::from_millis(1),
        }
    }

    /// Stable display name: the preset keyword when the spec matches
    /// one (so `parse(label())` round-trips), a parameter summary
    /// otherwise. Used by placement-plan rendering.
    pub fn label(&self) -> String {
        if *self == Self::ideal() {
            return "ideal".into();
        }
        if *self == Self::gigabit_lan() {
            return "gigabit".into();
        }
        if *self == Self::fast_edge() {
            return "edge".into();
        }
        if *self == Self::wifi() {
            return "wifi".into();
        }
        let bw = match self.bandwidth_bps {
            Some(bps) => format!("{:.1}Mbps", bps as f64 / 1e6),
            None => "unlimited".into(),
        };
        let mut label = format!("{bw}/{:.1}ms", self.latency.as_secs_f64() * 1e3);
        if !self.jitter.is_zero() {
            label.push_str(&format!("~{:.1}ms", self.jitter.as_secs_f64() * 1e3));
        }
        label
    }

    pub fn parse(s: &str) -> crate::error::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "ideal" | "core" => Ok(Self::ideal()),
            "gigabit" | "lan" => Ok(Self::gigabit_lan()),
            "edge" | "100mbit" => Ok(Self::fast_edge()),
            "wifi" => Ok(Self::wifi()),
            other => Err(crate::error::DeferError::Config(format!(
                "unknown link spec {other:?} (want ideal|gigabit|edge|wifi)"
            ))),
        }
    }
}

struct Bucket {
    /// Time when the link becomes free again (virtual clock).
    free_at: Instant,
    rng: Rng,
}

/// A shaped link. Cloneable handles share the same bucket.
pub struct Link {
    spec: LinkSpec,
    bucket: Mutex<Bucket>,
}

impl Link {
    pub fn new(spec: LinkSpec) -> Self {
        Link {
            spec,
            bucket: Mutex::new(Bucket {
                free_at: Instant::now(),
                rng: Rng::new(0xDEFE),
            }),
        }
    }

    pub fn ideal() -> Self {
        Link::new(LinkSpec::ideal())
    }

    pub fn spec(&self) -> LinkSpec {
        self.spec
    }

    /// Block the caller as the emulated link would for `bytes` more bytes.
    ///
    /// Serialization delay = bytes * 8 / bandwidth, accumulated on a virtual
    /// clock so back-to-back chunks queue correctly; propagation delay =
    /// latency + jitter per call.
    pub fn shape(&self, bytes: usize) {
        if self.spec.bandwidth_bps.is_none()
            && self.spec.latency.is_zero()
            && self.spec.jitter.is_zero()
        {
            return; // ideal link: free
        }
        let mut sleep_until = None;
        {
            let mut b = self.bucket.lock().unwrap();
            let now = Instant::now();
            let mut delay = self.spec.latency;
            if !self.spec.jitter.is_zero() {
                let j = b.rng.f32() as f64 * self.spec.jitter.as_secs_f64();
                delay += Duration::from_secs_f64(j);
            }
            if let Some(bps) = self.spec.bandwidth_bps {
                let tx = Duration::from_secs_f64((bytes as f64 * 8.0) / bps as f64);
                let start = b.free_at.max(now);
                b.free_at = start + tx;
                sleep_until = Some(b.free_at + delay);
            } else if !delay.is_zero() {
                sleep_until = Some(now + delay);
            }
        }
        if let Some(t) = sleep_until {
            let now = Instant::now();
            if t > now {
                std::thread::sleep(t - now);
            }
        }
    }
}

// ------------------------------------------------------------- Faults
//
// `netem` can shape traffic; with fault schedules it can also *break*
// it, deterministically, so the recovery layer is testable without real
// hardware churn. A schedule is parsed from `--fault` specs:
//
// ```text
// kill:node1.1@frame=40        replica node1.1 dies when it observes
//                              global frame >= 40 (conns dropped, thread
//                              exits — peers see EOF / closed pipes)
// truncate:node1.1@frame=40    same trigger, but the replica's egress
//                              writes half of one wire message first, so
//                              peers see a mid-message EOF
// corrupt-chunk:p=0.01         each received DFCK container is corrupted
//                              (one payload byte flipped) with
//                              probability p, seeded; detected by the
//                              per-chunk CRC and repaired by NACK/retry
// corrupt-chunk:p=0.01,seed=7  explicit seed for the corruption PRNG
// ```
//
// All decisions are pure functions of (spec, node name, frame id), so a
// fault run is reproducible across transports and I/O planes.

/// One parsed `--fault` spec.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultSpec {
    /// Node dies when it observes global frame >= `frame`.
    Kill { node: String, frame: u64 },
    /// Node truncates one egress message mid-write at `frame`, then dies.
    Truncate { node: String, frame: u64 },
    /// Flip one byte per received chunk container with probability `p`.
    CorruptChunk { p: f64, seed: u64 },
}

fn parse_target(kind: &str, rest: &str) -> crate::error::Result<(String, u64)> {
    let bad = |m: String| crate::error::DeferError::Config(m);
    let (node, cond) = rest.split_once('@').ok_or_else(|| {
        bad(format!("{kind} fault wants {kind}:NODE@frame=N, got {rest:?}"))
    })?;
    let frame = cond
        .strip_prefix("frame=")
        .and_then(|v| v.parse::<u64>().ok())
        .ok_or_else(|| bad(format!("{kind} fault wants @frame=N, got {cond:?}")))?;
    if node.is_empty() {
        return Err(bad(format!("{kind} fault wants a node name before '@'")));
    }
    Ok((node.to_string(), frame))
}

impl FaultSpec {
    pub fn parse(s: &str) -> crate::error::Result<Self> {
        let bad = |m: String| crate::error::DeferError::Config(m);
        let (kind, rest) = s
            .split_once(':')
            .ok_or_else(|| bad(format!("fault spec {s:?} wants kind:params")))?;
        match kind {
            "kill" => {
                let (node, frame) = parse_target("kill", rest)?;
                Ok(FaultSpec::Kill { node, frame })
            }
            "truncate" => {
                let (node, frame) = parse_target("truncate", rest)?;
                Ok(FaultSpec::Truncate { node, frame })
            }
            "corrupt-chunk" => {
                let mut p = None;
                let mut seed = 0xC0DEu64;
                for part in rest.split(',') {
                    match part.split_once('=') {
                        Some(("p", v)) => {
                            p = Some(v.parse::<f64>().map_err(|_| {
                                bad(format!("corrupt-chunk p wants a number, got {v:?}"))
                            })?)
                        }
                        Some(("seed", v)) => {
                            seed = v.parse::<u64>().map_err(|_| {
                                bad(format!("corrupt-chunk seed wants an int, got {v:?}"))
                            })?
                        }
                        _ => {
                            return Err(bad(format!(
                                "corrupt-chunk wants p=0.01[,seed=N], got {part:?}"
                            )))
                        }
                    }
                }
                let p = p.ok_or_else(|| bad("corrupt-chunk wants p=...".into()))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(bad(format!("corrupt-chunk p must be in [0,1], got {p}")));
                }
                Ok(FaultSpec::CorruptChunk { p, seed })
            }
            other => Err(bad(format!(
                "unknown fault kind {other:?} (want kill|truncate|corrupt-chunk)"
            ))),
        }
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

fn fnv1a(s: &str) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for b in s.as_bytes() {
        h = (h ^ *b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// A full fault schedule: every parsed spec, queryable by node + frame.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub specs: Vec<FaultSpec>,
}

impl FaultPlan {
    pub fn parse(specs: &[String]) -> crate::error::Result<Self> {
        Ok(FaultPlan {
            specs: specs
                .iter()
                .map(|s| FaultSpec::parse(s))
                .collect::<crate::error::Result<Vec<_>>>()?,
        })
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Frame at which `node` is scheduled to die (kill fault).
    pub fn kill_frame(&self, node: &str) -> Option<u64> {
        self.specs.iter().find_map(|s| match s {
            FaultSpec::Kill { node: n, frame } if n == node => Some(*frame),
            _ => None,
        })
    }

    /// Frame at which `node` truncates one egress write, then dies.
    pub fn truncate_frame(&self, node: &str) -> Option<u64> {
        self.specs.iter().find_map(|s| match s {
            FaultSpec::Truncate { node: n, frame } if n == node => Some(*frame),
            _ => None,
        })
    }

    /// Deterministic corruption roll for a container received by `node`
    /// for `frame`: `Some(entropy)` when this (node, frame) is corrupted,
    /// with entropy bits for picking the byte to flip. A pure function of
    /// the spec, so both I/O planes corrupt the same frames.
    pub fn corrupt_roll(&self, node: &str, frame: u64) -> Option<u64> {
        let (p, seed) = self.specs.iter().find_map(|s| match s {
            FaultSpec::CorruptChunk { p, seed } => Some((*p, *seed)),
            _ => None,
        })?;
        let h = splitmix64(seed ^ fnv1a(node) ^ frame.wrapping_mul(0x9E37_79B9));
        // Top 53 bits -> uniform in [0, 1).
        let roll = (h >> 11) as f64 / (1u64 << 53) as f64;
        (roll < p).then(|| splitmix64(h))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_link_is_free() {
        let link = Link::ideal();
        let t0 = Instant::now();
        for _ in 0..1000 {
            link.shape(512 * 1024);
        }
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn bandwidth_limit_enforced() {
        // 8 Mbit/s -> 1 MB takes ~1 s; send 200 kB and expect ~200 ms.
        let link = Link::new(LinkSpec {
            bandwidth_bps: Some(8_000_000),
            latency: Duration::ZERO,
            jitter: Duration::ZERO,
        });
        let t0 = Instant::now();
        for _ in 0..4 {
            link.shape(50_000);
        }
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(180), "too fast: {dt:?}");
        assert!(dt < Duration::from_millis(500), "too slow: {dt:?}");
    }

    #[test]
    fn latency_applied_per_call() {
        let link = Link::new(LinkSpec {
            bandwidth_bps: None,
            latency: Duration::from_millis(5),
            jitter: Duration::ZERO,
        });
        let t0 = Instant::now();
        for _ in 0..4 {
            link.shape(100);
        }
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn presets_parse() {
        assert_eq!(LinkSpec::parse("ideal").unwrap(), LinkSpec::ideal());
        assert_eq!(LinkSpec::parse("gigabit").unwrap(), LinkSpec::gigabit_lan());
        assert_eq!(LinkSpec::parse("edge").unwrap(), LinkSpec::fast_edge());
        assert_eq!(LinkSpec::parse("wifi").unwrap(), LinkSpec::wifi());
        assert!(LinkSpec::parse("5g").is_err());
    }
}
