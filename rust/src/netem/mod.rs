//! Network emulation: the CORE-emulator substitute.
//!
//! The paper ran DEFER inside the CORE network emulator, which shapes
//! loopback traffic with per-link bandwidth/latency disciplines. This
//! module reproduces that: a [`Link`] is a token-bucket rate limiter plus
//! a fixed one-way latency and optional jitter, applied to every wire
//! chunk at the framing layer (see `wire::write_message`). It works
//! identically for in-process channels and real TCP sockets on loopback.
//!
//! `Link::ideal()` is the paper's "close-to-zero latency environment";
//! `LinkSpec` presets model typical edge networks for the ablations.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::prng::Rng;

/// Declarative link parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkSpec {
    /// Bandwidth in bits/second; `None` = unlimited.
    pub bandwidth_bps: Option<u64>,
    /// One-way latency added per message chunk train.
    pub latency: Duration,
    /// Uniform jitter in `[0, jitter]` added to the latency.
    pub jitter: Duration,
}

impl LinkSpec {
    /// The paper's evaluation setting: local, close-to-zero latency.
    pub const fn ideal() -> Self {
        LinkSpec {
            bandwidth_bps: None,
            latency: Duration::ZERO,
            jitter: Duration::ZERO,
        }
    }

    /// Gigabit Ethernet LAN (the paper's energy model assumes Ethernet).
    pub const fn gigabit_lan() -> Self {
        LinkSpec {
            bandwidth_bps: Some(1_000_000_000),
            latency: Duration::from_micros(200),
            jitter: Duration::ZERO,
        }
    }

    /// 100 Mbit edge/fog link.
    pub const fn fast_edge() -> Self {
        LinkSpec {
            bandwidth_bps: Some(100_000_000),
            latency: Duration::from_millis(1),
            jitter: Duration::from_micros(200),
        }
    }

    /// Constrained wireless edge (802.11-ish).
    pub const fn wifi() -> Self {
        LinkSpec {
            bandwidth_bps: Some(50_000_000),
            latency: Duration::from_millis(3),
            jitter: Duration::from_millis(1),
        }
    }

    /// Stable display name: the preset keyword when the spec matches
    /// one (so `parse(label())` round-trips), a parameter summary
    /// otherwise. Used by placement-plan rendering.
    pub fn label(&self) -> String {
        if *self == Self::ideal() {
            return "ideal".into();
        }
        if *self == Self::gigabit_lan() {
            return "gigabit".into();
        }
        if *self == Self::fast_edge() {
            return "edge".into();
        }
        if *self == Self::wifi() {
            return "wifi".into();
        }
        let bw = match self.bandwidth_bps {
            Some(bps) => format!("{:.1}Mbps", bps as f64 / 1e6),
            None => "unlimited".into(),
        };
        let mut label = format!("{bw}/{:.1}ms", self.latency.as_secs_f64() * 1e3);
        if !self.jitter.is_zero() {
            label.push_str(&format!("~{:.1}ms", self.jitter.as_secs_f64() * 1e3));
        }
        label
    }

    pub fn parse(s: &str) -> crate::error::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "ideal" | "core" => Ok(Self::ideal()),
            "gigabit" | "lan" => Ok(Self::gigabit_lan()),
            "edge" | "100mbit" => Ok(Self::fast_edge()),
            "wifi" => Ok(Self::wifi()),
            other => Err(crate::error::DeferError::Config(format!(
                "unknown link spec {other:?} (want ideal|gigabit|edge|wifi)"
            ))),
        }
    }
}

struct Bucket {
    /// Time when the link becomes free again (virtual clock).
    free_at: Instant,
    rng: Rng,
}

/// A shaped link. Cloneable handles share the same bucket.
pub struct Link {
    spec: LinkSpec,
    bucket: Mutex<Bucket>,
}

impl Link {
    pub fn new(spec: LinkSpec) -> Self {
        Link {
            spec,
            bucket: Mutex::new(Bucket {
                free_at: Instant::now(),
                rng: Rng::new(0xDEFE),
            }),
        }
    }

    pub fn ideal() -> Self {
        Link::new(LinkSpec::ideal())
    }

    pub fn spec(&self) -> LinkSpec {
        self.spec
    }

    /// Block the caller as the emulated link would for `bytes` more bytes.
    ///
    /// Serialization delay = bytes * 8 / bandwidth, accumulated on a virtual
    /// clock so back-to-back chunks queue correctly; propagation delay =
    /// latency + jitter per call.
    pub fn shape(&self, bytes: usize) {
        if self.spec.bandwidth_bps.is_none()
            && self.spec.latency.is_zero()
            && self.spec.jitter.is_zero()
        {
            return; // ideal link: free
        }
        let mut sleep_until = None;
        {
            let mut b = self.bucket.lock().unwrap();
            let now = Instant::now();
            let mut delay = self.spec.latency;
            if !self.spec.jitter.is_zero() {
                let j = b.rng.f32() as f64 * self.spec.jitter.as_secs_f64();
                delay += Duration::from_secs_f64(j);
            }
            if let Some(bps) = self.spec.bandwidth_bps {
                let tx = Duration::from_secs_f64((bytes as f64 * 8.0) / bps as f64);
                let start = b.free_at.max(now);
                b.free_at = start + tx;
                sleep_until = Some(b.free_at + delay);
            } else if !delay.is_zero() {
                sleep_until = Some(now + delay);
            }
        }
        if let Some(t) = sleep_until {
            let now = Instant::now();
            if t > now {
                std::thread::sleep(t - now);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_link_is_free() {
        let link = Link::ideal();
        let t0 = Instant::now();
        for _ in 0..1000 {
            link.shape(512 * 1024);
        }
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn bandwidth_limit_enforced() {
        // 8 Mbit/s -> 1 MB takes ~1 s; send 200 kB and expect ~200 ms.
        let link = Link::new(LinkSpec {
            bandwidth_bps: Some(8_000_000),
            latency: Duration::ZERO,
            jitter: Duration::ZERO,
        });
        let t0 = Instant::now();
        for _ in 0..4 {
            link.shape(50_000);
        }
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(180), "too fast: {dt:?}");
        assert!(dt < Duration::from_millis(500), "too slow: {dt:?}");
    }

    #[test]
    fn latency_applied_per_call() {
        let link = Link::new(LinkSpec {
            bandwidth_bps: None,
            latency: Duration::from_millis(5),
            jitter: Duration::ZERO,
        });
        let t0 = Instant::now();
        for _ in 0..4 {
            link.shape(100);
        }
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn presets_parse() {
        assert_eq!(LinkSpec::parse("ideal").unwrap(), LinkSpec::ideal());
        assert_eq!(LinkSpec::parse("gigabit").unwrap(), LinkSpec::gigabit_lan());
        assert_eq!(LinkSpec::parse("edge").unwrap(), LinkSpec::fast_edge());
        assert_eq!(LinkSpec::parse("wifi").unwrap(), LinkSpec::wifi());
        assert!(LinkSpec::parse("5g").is_err());
    }
}
