//! Artifact registry: locate and parse the AOT outputs of `make artifacts`.
//!
//! Per (profile, model, n-parts) the Python compile path emits, for each
//! partition `i`, a `p<i>of<N>.hlo.txt` (partition compute graph with
//! weights as HLO parameters), `p<i>of<N>.meta.json` (boundary shapes +
//! weight manifest), and `p<i>of<N>.weights.bin` (raw f32 LE). This module
//! loads those into [`PartitionSpec`]s — the "model architecture" payload
//! the dispatcher ships during the configuration step.

use std::path::{Path, PathBuf};

use crate::error::{DeferError, Result};
use crate::serial::json::{self, Json};

/// One weight array in a partition's manifest (apply order).
#[derive(Clone, Debug, PartialEq)]
pub struct WeightSpec {
    pub node: String,
    pub param: String,
    pub shape: Vec<usize>,
    pub elements: usize,
}

/// Parsed partition metadata + artifact paths.
#[derive(Clone, Debug)]
pub struct PartitionSpec {
    pub model: String,
    pub profile: String,
    pub part_index: usize,
    pub part_count: usize,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub flops: u64,
    pub layers: Vec<String>,
    pub weights: Vec<WeightSpec>,
    pub weights_bytes: usize,
    pub hlo_path: PathBuf,
    pub weights_path: PathBuf,
}

impl PartitionSpec {
    /// Parse a `p<i>of<N>.meta.json` file.
    pub fn from_meta_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| DeferError::Model(format!("{}: {e}", path.display())))?;
        let v = json::parse(&text)?;
        let dir = path
            .parent()
            .ok_or_else(|| DeferError::Model("meta file has no parent dir".into()))?;
        let weights = v
            .get("weights")?
            .as_arr()?
            .iter()
            .map(|w| {
                Ok(WeightSpec {
                    node: w.get("node")?.as_str()?.to_string(),
                    param: w.get("param")?.as_str()?.to_string(),
                    shape: w.get_usize_vec("shape")?,
                    elements: w.get("elements")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let layers = v
            .get("layers")?
            .as_arr()?
            .iter()
            .map(|l| Ok(l.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        let spec = PartitionSpec {
            model: v.get("model")?.as_str()?.to_string(),
            profile: v.get("profile")?.as_str()?.to_string(),
            part_index: v.get("part_index")?.as_usize()?,
            part_count: v.get("part_count")?.as_usize()?,
            input_shape: v.get_usize_vec("input_shape")?,
            output_shape: v.get_usize_vec("output_shape")?,
            flops: v.get("flops")?.as_f64()? as u64,
            layers,
            weights,
            weights_bytes: v.get("weights_bytes")?.as_usize()?,
            hlo_path: dir.join(v.get("hlo_file")?.as_str()?),
            weights_path: dir.join(v.get("weights_file")?.as_str()?),
        };
        spec.validate()?;
        Ok(spec)
    }

    fn validate(&self) -> Result<()> {
        let manifest_elems: usize = self.weights.iter().map(|w| w.elements).sum();
        if manifest_elems * 4 != self.weights_bytes {
            return Err(DeferError::Model(format!(
                "weights manifest ({} elements) disagrees with weights_bytes {}",
                manifest_elems, self.weights_bytes
            )));
        }
        for w in &self.weights {
            let n: usize = w.shape.iter().product();
            if n != w.elements {
                return Err(DeferError::Model(format!(
                    "{}.{}: shape {:?} != elements {}",
                    w.node, w.param, w.shape, w.elements
                )));
            }
        }
        if self.part_index >= self.part_count {
            return Err(DeferError::Model("part_index >= part_count".into()));
        }
        Ok(())
    }

    /// Total f32 element count of the input activation.
    pub fn input_elements(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn output_elements(&self) -> usize {
        self.output_shape.iter().product()
    }

    /// Uncompressed bytes of one input activation frame (f32), the
    /// boundary cost the placement planner charges to the ingress hop.
    pub fn input_bytes(&self) -> u64 {
        (self.input_elements() * 4) as u64
    }

    /// Uncompressed bytes of one output activation frame (f32).
    pub fn output_bytes(&self) -> u64 {
        (self.output_elements() * 4) as u64
    }

    /// Read the HLO text.
    pub fn read_hlo(&self) -> Result<String> {
        std::fs::read_to_string(&self.hlo_path)
            .map_err(|e| DeferError::Model(format!("{}: {e}", self.hlo_path.display())))
    }

    /// Read the raw weights, split per manifest entry.
    pub fn read_weights(&self) -> Result<Vec<Vec<f32>>> {
        let raw = std::fs::read(&self.weights_path)
            .map_err(|e| DeferError::Model(format!("{}: {e}", self.weights_path.display())))?;
        if raw.len() != self.weights_bytes {
            return Err(DeferError::Model(format!(
                "{}: {} bytes on disk, manifest says {}",
                self.weights_path.display(),
                raw.len(),
                self.weights_bytes
            )));
        }
        let mut out = Vec::with_capacity(self.weights.len());
        let mut off = 0usize;
        for w in &self.weights {
            let bytes = &raw[off..off + w.elements * 4];
            out.push(
                bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            );
            off += w.elements * 4;
        }
        Ok(out)
    }
}

impl PartitionSpec {
    /// Serialize for the configuration-step architecture socket (no local
    /// file paths — the receiving node reconstructs everything from this).
    pub fn to_config_json(&self, next_hop: &str) -> Json {
        use std::collections::BTreeMap;
        let mut obj = BTreeMap::new();
        obj.insert("model".into(), Json::Str(self.model.clone()));
        obj.insert("profile".into(), Json::Str(self.profile.clone()));
        obj.insert("part_index".into(), Json::Num(self.part_index as f64));
        obj.insert("part_count".into(), Json::Num(self.part_count as f64));
        obj.insert(
            "input_shape".into(),
            Json::Arr(self.input_shape.iter().map(|d| Json::Num(*d as f64)).collect()),
        );
        obj.insert(
            "output_shape".into(),
            Json::Arr(self.output_shape.iter().map(|d| Json::Num(*d as f64)).collect()),
        );
        obj.insert("flops".into(), Json::Num(self.flops as f64));
        obj.insert(
            "layers".into(),
            Json::Arr(self.layers.iter().map(|l| Json::Str(l.clone())).collect()),
        );
        obj.insert(
            "weights".into(),
            Json::Arr(
                self.weights
                    .iter()
                    .map(|w| {
                        let mut wo = BTreeMap::new();
                        wo.insert("node".into(), Json::Str(w.node.clone()));
                        wo.insert("param".into(), Json::Str(w.param.clone()));
                        wo.insert(
                            "shape".into(),
                            Json::Arr(w.shape.iter().map(|d| Json::Num(*d as f64)).collect()),
                        );
                        wo.insert("elements".into(), Json::Num(w.elements as f64));
                        Json::Obj(wo)
                    })
                    .collect(),
            ),
        );
        obj.insert("weights_bytes".into(), Json::Num(self.weights_bytes as f64));
        obj.insert("next".into(), Json::Str(next_hop.to_string()));
        Json::Obj(obj)
    }

    /// Parse the architecture-socket JSON back into a spec (paths empty).
    /// Returns (spec, next_hop).
    pub fn from_config_json(v: &Json) -> Result<(Self, String)> {
        let weights = v
            .get("weights")?
            .as_arr()?
            .iter()
            .map(|w| {
                Ok(WeightSpec {
                    node: w.get("node")?.as_str()?.to_string(),
                    param: w.get("param")?.as_str()?.to_string(),
                    shape: w.get_usize_vec("shape")?,
                    elements: w.get("elements")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let layers = v
            .get("layers")?
            .as_arr()?
            .iter()
            .map(|l| Ok(l.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        let spec = PartitionSpec {
            model: v.get("model")?.as_str()?.to_string(),
            profile: v.get("profile")?.as_str()?.to_string(),
            part_index: v.get("part_index")?.as_usize()?,
            part_count: v.get("part_count")?.as_usize()?,
            input_shape: v.get_usize_vec("input_shape")?,
            output_shape: v.get_usize_vec("output_shape")?,
            flops: v.get("flops")?.as_f64()? as u64,
            layers,
            weights,
            weights_bytes: v.get("weights_bytes")?.as_usize()?,
            hlo_path: PathBuf::new(),
            weights_path: PathBuf::new(),
        };
        spec.validate()?;
        let next = v.get("next")?.as_str()?.to_string();
        Ok((spec, next))
    }
}

/// A fused run of contiguous partitions served by one pipeline stage.
///
/// PR 3 makes stage boundaries a *planning output*: the repartition pass
/// ([`crate::repartition`]) loads the finest-granularity partition set
/// and fuses contiguous runs into stages. A `StageSpec` is that fused
/// run — the unit the dispatcher ships in one configuration exchange and
/// a compute node executes in-process, back to back. Fusion accounting:
///
/// * **FLOPs sum** — the stage costs the sum of its partitions' FLOPs;
/// * **inner boundaries elide** — only the first partition's input and
///   the last partition's output ever touch the network, the activation
///   bytes between fused partitions stay in process memory;
/// * **weights concatenate** — the stage's weights payload is each
///   partition's flat weights array back to back, in partition order
///   (the manifest order every split on the receiving side relies on).
#[derive(Clone, Debug)]
pub struct StageSpec {
    /// Contiguous partitions, ascending `part_index`, boundary-chained.
    pub parts: Vec<PartitionSpec>,
}

impl StageSpec {
    /// Fuse a contiguous run of partitions into one stage. Rejects empty
    /// runs, mixed (model, profile, part_count) artifacts, non-contiguous
    /// indices and boundary-shape mismatches.
    pub fn fuse(parts: Vec<PartitionSpec>) -> Result<StageSpec> {
        let first = parts
            .first()
            .ok_or_else(|| DeferError::Model("cannot fuse an empty partition run".into()))?;
        for p in &parts {
            if p.model != first.model
                || p.profile != first.profile
                || p.part_count != first.part_count
            {
                return Err(DeferError::Model(format!(
                    "cannot fuse across artifact sets: p{} is {}/{} ({} parts), \
                     p{} is {}/{} ({} parts)",
                    first.part_index,
                    first.profile,
                    first.model,
                    first.part_count,
                    p.part_index,
                    p.profile,
                    p.model,
                    p.part_count
                )));
            }
        }
        for (a, b) in parts.iter().zip(parts.iter().skip(1)) {
            if b.part_index != a.part_index + 1 {
                return Err(DeferError::Model(format!(
                    "fused run is not contiguous: p{} followed by p{}",
                    a.part_index, b.part_index
                )));
            }
            if a.output_shape != b.input_shape {
                return Err(DeferError::Model(format!(
                    "fused boundary mismatch p{}: {:?} -> p{}: {:?}",
                    a.part_index, a.output_shape, b.part_index, b.input_shape
                )));
            }
        }
        Ok(StageSpec { parts })
    }

    /// A single-partition stage (the unfused, paper-chain case).
    pub fn single(spec: PartitionSpec) -> StageSpec {
        StageSpec { parts: vec![spec] }
    }

    pub fn num_parts(&self) -> usize {
        self.parts.len()
    }

    /// Summed FLOPs of the fused run.
    pub fn flops(&self) -> u64 {
        self.parts.iter().map(|p| p.flops).sum()
    }

    /// The stage's network-visible input: the first partition's input.
    pub fn input_shape(&self) -> &[usize] {
        &self.parts[0].input_shape
    }

    /// The stage's network-visible output: the last partition's output.
    pub fn output_shape(&self) -> &[usize] {
        &self.parts[self.parts.len() - 1].output_shape
    }

    /// Uncompressed bytes of one input activation frame (f32).
    pub fn input_bytes(&self) -> u64 {
        self.parts[0].input_bytes()
    }

    /// Uncompressed bytes of one output activation frame (f32).
    pub fn output_bytes(&self) -> u64 {
        self.parts[self.parts.len() - 1].output_bytes()
    }

    /// Activation bytes of the *inner* boundaries the fusion elides from
    /// the network (they stay in process memory on the worker).
    pub fn elided_boundary_bytes(&self) -> u64 {
        self.parts
            .iter()
            .take(self.parts.len() - 1)
            .map(|p| p.output_bytes())
            .sum()
    }

    /// Total resident weights of the fused run in bytes (the memory a
    /// worker hosting this stage must hold).
    pub fn weights_bytes(&self) -> usize {
        self.parts.iter().map(|p| p.weights_bytes).sum()
    }

    /// Total f32 weight elements across the fused run — the element
    /// count of the concatenated weights payload.
    pub fn weight_elements(&self) -> usize {
        self.parts
            .iter()
            .flat_map(|p| p.weights.iter())
            .map(|w| w.elements)
            .sum()
    }

    /// The concatenated weight manifest, in partition order then each
    /// partition's own manifest order — exactly the layout of the fused
    /// weights payload on the wire.
    pub fn weight_manifest(&self) -> Vec<&WeightSpec> {
        self.parts.iter().flat_map(|p| p.weights.iter()).collect()
    }

    /// Stable stage label, e.g. `p2of4` or `p1..p3of8`.
    pub fn label(&self) -> String {
        let first = &self.parts[0];
        if self.parts.len() == 1 {
            format!("p{}of{}", first.part_index, first.part_count)
        } else {
            format!(
                "p{}..p{}of{}",
                first.part_index,
                self.parts[self.parts.len() - 1].part_index,
                first.part_count
            )
        }
    }
}

/// A full partition plan: all N stages of one (profile, model, N) config.
#[derive(Clone, Debug)]
pub struct PartitionPlan {
    pub parts: Vec<PartitionSpec>,
}

impl PartitionPlan {
    /// Load `p0ofN .. p{N-1}ofN` from `artifacts/<profile>/<model>/`.
    pub fn load(artifacts: &Path, profile: &str, model: &str, n: usize) -> Result<Self> {
        let dir = artifacts.join(profile).join(model);
        let mut parts = Vec::with_capacity(n);
        for i in 0..n {
            let meta = dir.join(format!("p{i}of{n}.meta.json"));
            if !meta.exists() {
                return Err(DeferError::Model(format!(
                    "missing artifact {} — run `make artifacts` (profile {profile})",
                    meta.display()
                )));
            }
            parts.push(PartitionSpec::from_meta_file(&meta)?);
        }
        let plan = PartitionPlan { parts };
        plan.validate()?;
        Ok(plan)
    }

    /// Boundary shapes must chain and indices must be consistent.
    pub fn validate(&self) -> Result<()> {
        if self.parts.is_empty() {
            return Err(DeferError::Model("empty plan".into()));
        }
        let n = self.parts[0].part_count;
        if self.parts.len() != n {
            return Err(DeferError::Model(format!(
                "plan has {} parts, metadata says {n}",
                self.parts.len()
            )));
        }
        for (i, p) in self.parts.iter().enumerate() {
            if p.part_index != i || p.part_count != n {
                return Err(DeferError::Model(format!(
                    "partition {i} has index {}/{}",
                    p.part_index, p.part_count
                )));
            }
        }
        for (a, b) in self.parts.iter().zip(self.parts.iter().skip(1)) {
            if a.output_shape != b.input_shape {
                return Err(DeferError::Model(format!(
                    "boundary mismatch p{}: {:?} -> p{}: {:?}",
                    a.part_index, a.output_shape, b.part_index, b.input_shape
                )));
            }
        }
        Ok(())
    }

    pub fn input_shape(&self) -> &[usize] {
        &self.parts[0].input_shape
    }

    pub fn output_shape(&self) -> &[usize] {
        &self.parts[self.parts.len() - 1].output_shape
    }

    pub fn total_flops(&self) -> u64 {
        self.parts.iter().map(|p| p.flops).sum()
    }

    /// Fuse the plan into stages at the given cut points. `cuts` must be
    /// strictly increasing, start at 0 and end at `parts.len()`; stage
    /// `s` is the contiguous run `parts[cuts[s]..cuts[s+1]]`.
    pub fn fuse(&self, cuts: &[usize]) -> Result<Vec<StageSpec>> {
        let n = self.parts.len();
        if cuts.len() < 2 || cuts[0] != 0 || *cuts.last().unwrap() != n {
            return Err(DeferError::Model(format!(
                "cut points {cuts:?} must run from 0 to {n}"
            )));
        }
        if cuts.windows(2).any(|w| w[0] >= w[1]) {
            return Err(DeferError::Model(format!(
                "cut points {cuts:?} are not strictly increasing"
            )));
        }
        cuts.windows(2)
            .map(|w| StageSpec::fuse(self.parts[w[0]..w[1]].to_vec()))
            .collect()
    }

    /// One single-partition stage per plan entry — the paper's chain.
    pub fn singleton_stages(&self) -> Vec<StageSpec> {
        self.parts.iter().cloned().map(StageSpec::single).collect()
    }
}

/// Reference vectors (`ref_input.bin`, `ref_output.bin`) for end-to-end
/// numerical validation of a chain against the Python ground truth.
pub struct ReferenceVectors {
    pub input: crate::tensor::Tensor,
    pub output: crate::tensor::Tensor,
}

impl ReferenceVectors {
    pub fn load(artifacts: &Path, profile: &str, model: &str) -> Result<Self> {
        let dir = artifacts.join(profile).join(model);
        let meta = json::parse(&std::fs::read_to_string(dir.join("ref_meta.json"))?)?;
        let in_shape = meta.get_usize_vec("input_shape")?;
        let out_shape = meta.get_usize_vec("output_shape")?;
        let input = crate::tensor::Tensor::from_le_bytes(
            in_shape,
            &std::fs::read(dir.join("ref_input.bin"))?,
        )?;
        let output = crate::tensor::Tensor::from_le_bytes(
            out_shape,
            &std::fs::read(dir.join("ref_output.bin"))?,
        )?;
        Ok(ReferenceVectors { input, output })
    }
}

/// List (model, part_count) combos available under a profile, from
/// `manifest.json` — used by the bench harnesses to discover sweeps.
pub fn available_configs(artifacts: &Path, profile: &str) -> Result<Vec<(String, usize)>> {
    let manifest = json::parse(&std::fs::read_to_string(artifacts.join("manifest.json"))?)?;
    let mut out = Vec::new();
    for row in manifest.get("artifacts")?.as_arr()? {
        if row.get("profile")?.as_str()? != profile {
            continue;
        }
        let model = row.get("model")?.as_str()?.to_string();
        let n = row.get("part_count")?.as_usize()?;
        if !out.contains(&(model.clone(), n)) {
            out.push((model, n));
        }
    }
    out.sort();
    Ok(out)
}

/// The finest partition granularity built for (profile, model) — the
/// largest `N` in the artifact manifest. This is the partition set the
/// repartition planner fuses; stage boundaries then come from planning,
/// not from which `(model, n)` artifact happened to be requested.
pub fn finest_part_count(artifacts: &Path, profile: &str, model: &str) -> Result<usize> {
    let configs = available_configs(artifacts, profile).map_err(|e| {
        DeferError::Model(format!(
            "cannot read artifact manifest under {} — run `make artifacts`: {e}",
            artifacts.display()
        ))
    })?;
    configs
        .iter()
        .filter(|(m, _)| m == model)
        .map(|(_, n)| *n)
        .max()
        .ok_or_else(|| {
            DeferError::Model(format!(
                "no artifacts for {model:?} under profile {profile:?} — run `make artifacts`"
            ))
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn meta_json_parse_error_paths() {
        // Synthetic meta with inconsistent byte count must be rejected.
        let dir = std::env::temp_dir().join(format!("defer_meta_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let meta = dir.join("bad.meta.json");
        std::fs::write(
            &meta,
            r#"{"model":"m","profile":"tiny","part_index":0,"part_count":1,
               "input_shape":[1,4],"output_shape":[1,2],"flops":10,
               "layers":["a"],"weights":[{"node":"a","param":"w","shape":[4,2],"elements":8}],
               "weights_bytes":999,"hlo_file":"x.hlo.txt","weights_file":"x.weights.bin"}"#,
        )
        .unwrap();
        assert!(PartitionSpec::from_meta_file(&meta).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_tiny_resnet_plan() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        for n in [1usize, 2, 4] {
            let plan = PartitionPlan::load(&artifacts_dir(), "tiny", "resnet50", n).unwrap();
            assert_eq!(plan.parts.len(), n);
            assert_eq!(plan.input_shape(), &[1, 32, 32, 3]);
            assert!(plan.total_flops() > 0);
            // Weight files load and match manifests.
            let w = plan.parts[0].read_weights().unwrap();
            assert_eq!(w.len(), plan.parts[0].weights.len());
            for (arr, spec) in w.iter().zip(&plan.parts[0].weights) {
                assert_eq!(arr.len(), spec.elements);
            }
            // HLO loads and looks like HLO.
            assert!(plan.parts[0].read_hlo().unwrap().starts_with("HloModule"));
        }
    }

    #[test]
    fn missing_artifact_is_explained() {
        let err = PartitionPlan::load(Path::new("/nonexistent"), "tiny", "resnet50", 2)
            .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
    }

    #[test]
    fn reference_vectors_load() {
        if !have_artifacts() {
            return;
        }
        let rv = ReferenceVectors::load(&artifacts_dir(), "tiny", "resnet50").unwrap();
        assert_eq!(rv.input.shape(), &[1, 32, 32, 3]);
        assert!(rv.output.len() > 0);
    }

    #[test]
    fn available_configs_lists_tiny() {
        if !have_artifacts() {
            return;
        }
        let configs = available_configs(&artifacts_dir(), "tiny").unwrap();
        assert!(configs.contains(&("resnet50".to_string(), 4)));
    }
}
