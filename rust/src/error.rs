//! Crate-wide error type.
//!
//! One enum covering every subsystem so the coordinator's hot path can
//! propagate failures without boxing; `thiserror` derives the displays.

use thiserror::Error;

/// Errors produced anywhere in the DEFER stack.
#[derive(Error, Debug)]
pub enum DeferError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    #[error("json: {0}")]
    Json(String),

    #[error("codec: {0}")]
    Codec(String),

    #[error("wire protocol: {0}")]
    Wire(String),

    #[error("tensor: {0}")]
    Tensor(String),

    #[error("model registry: {0}")]
    Model(String),

    #[error("runtime (PJRT): {0}")]
    Runtime(String),

    #[error("coordinator: {0}")]
    Coordinator(String),

    #[error("config: {0}")]
    Config(String),

    #[error("cli: {0}")]
    Cli(String),

    #[error("channel closed: {0}")]
    ChannelClosed(&'static str),

    /// A DFCK chunk failed its CRC — structured so the recovery layer can
    /// NACK exactly that chunk by index instead of string-matching the
    /// rendered text. Display stays byte-compatible with the legacy
    /// `Codec` message.
    #[error("codec: chunk container: chunk {chunk} of {of} corrupt ({detail})")]
    CorruptChunk {
        chunk: usize,
        of: usize,
        detail: String,
    },

    /// A deliberate `--fault` trigger fired (replica kill, conn
    /// truncation). Distinguished from real failures so the chain runner
    /// treats the planned death as survivable instead of a root cause.
    #[error("fault injected: {0}")]
    FaultInjected(String),
}

impl DeferError {
    /// True for errors raised by the fault injector itself (not by the
    /// damage it causes downstream).
    pub fn is_fault_injection(&self) -> bool {
        matches!(self, DeferError::FaultInjected(_))
    }
}

impl From<xla::Error> for DeferError {
    fn from(e: xla::Error) -> Self {
        DeferError::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, DeferError>;
