//! Argument parser substrate (clap substitute): subcommands + `--key value`
//! flags + `--switch` booleans, with generated usage text.

use std::collections::BTreeMap;

use crate::error::{DeferError, Result};

/// Parsed command line: subcommand, options, positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from raw arguments (without argv[0]). `switch_names` lists
    /// value-less flags; everything else starting with `--` takes a value.
    pub fn parse(raw: &[String], switch_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if switch_names.contains(&name) {
                    out.switches.push(name.to_string());
                } else {
                    let val = it.next().ok_or_else(|| {
                        DeferError::Cli(format!("--{name} requires a value"))
                    })?;
                    // A following `--flag` is almost certainly a typo
                    // (`--workers-budget --auto-place` would silently
                    // store "--auto-place" as the budget); reject it,
                    // naming both flags. Values may still start with a
                    // single dash (e.g. negative numbers).
                    if val.starts_with("--") {
                        return Err(DeferError::Cli(format!(
                            "--{name} requires a value, but the next argument is \
                             the flag {val:?} — pass the value after --{name} or \
                             drop it"
                        )));
                    }
                    out.opts.insert(name.to_string(), val.clone());
                }
            } else if out.command.is_none() && out.positionals.is_empty() {
                out.command = Some(arg.clone());
            } else {
                out.positionals.push(arg.clone());
            }
        }
        Ok(out)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| DeferError::Cli(format!("--{key} wants an integer, got {v:?}"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| DeferError::Cli(format!("--{key} wants a number, got {v:?}"))),
        }
    }

    /// Comma-separated list of strings (`--links wifi,gigabit,gigabit`);
    /// `None` when the flag is absent.
    pub fn get_list(&self, key: &str) -> Option<Vec<String>> {
        self.get(key)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    }

    /// Comma-separated list of integers (`--parts 4,6,8`).
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse().map_err(|_| {
                        DeferError::Cli(format!("--{key}: bad integer {p:?}"))
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        let raw: Vec<String> = v.iter().map(|s| s.to_string()).collect();
        Args::parse(&raw, &["verbose", "tcp"]).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["run", "--model", "resnet50", "--nodes", "8", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.get("model"), Some("resnet50"));
        assert_eq!(a.get_usize("nodes", 1).unwrap(), 8);
        assert!(a.has("verbose"));
        assert!(!a.has("tcp"));
    }

    #[test]
    fn defaults_and_lists() {
        let a = parse(&["bench", "--parts", "4,6,8"]);
        assert_eq!(a.get_or("model", "vgg16"), "vgg16");
        assert_eq!(a.get_usize_list("parts", &[1]).unwrap(), vec![4, 6, 8]);
        assert_eq!(a.get_usize_list("missing", &[1, 2]).unwrap(), vec![1, 2]);
        assert_eq!(a.get_f64("tdp", 15.0).unwrap(), 15.0);
    }

    #[test]
    fn string_lists() {
        let a = parse(&["run", "--links", "wifi, gigabit,gigabit"]);
        assert_eq!(
            a.get_list("links").unwrap(),
            vec!["wifi".to_string(), "gigabit".to_string(), "gigabit".to_string()]
        );
        assert!(a.get_list("missing").is_none());
    }

    #[test]
    fn errors() {
        let raw = vec!["run".to_string(), "--model".to_string()];
        assert!(Args::parse(&raw, &[]).is_err());
        let a = parse(&["run", "--nodes", "eight"]);
        assert!(a.get_usize("nodes", 1).is_err());
        let a = parse(&["run", "--parts", "4,x"]);
        assert!(a.get_usize_list("parts", &[]).is_err());
    }

    #[test]
    fn option_refuses_to_swallow_a_following_flag() {
        // `--workers-budget --auto-place` used to store "--auto-place"
        // as the budget; it must error, naming both flags.
        let raw: Vec<String> = ["run", "--workers-budget", "--auto-place"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let err = Args::parse(&raw, &["auto-place"]).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--workers-budget"), "bad error: {msg}");
        assert!(msg.contains("--auto-place"), "bad error: {msg}");
        // Same when the following flag is an option rather than a switch.
        let raw: Vec<String> = ["run", "--model", "--nodes", "4"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let err = Args::parse(&raw, &[]).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--model") && msg.contains("--nodes"), "{msg}");
        // Single-dash values (negative numbers) still pass through.
        let a = parse(&["run", "--tdp", "-1.5"]);
        assert_eq!(a.get_f64("tdp", 0.0).unwrap(), -1.5);
    }

    #[test]
    fn positionals() {
        let a = parse(&["run", "pos1", "pos2"]);
        assert_eq!(a.positionals, vec!["pos1", "pos2"]);
    }
}
