//! Turn a [`Topology`] into live per-node connection bundles.
//!
//! This is the connection-establishment layer extracted from the old
//! inline builder in `coordinator::chain`. It supports both transports:
//!
//! * **in-process** — every edge is a bounded byte pipe;
//! * **TCP loopback** — every edge is a real kernel socket. Listeners
//!   bind ephemeral ports (`127.0.0.1:0`) by default and the *actual*
//!   addresses flow through the wiring, so parallel runs never collide;
//!   `base_port` remains as an optional override for CORE-style
//!   deployments that need predictable ports (allocated sequentially:
//!   three ports per worker in stage-major order, then the dispatcher
//!   return port, then junction ingress ports per replicated boundary).
//!
//! Replicated stage boundaries are wired through a **junction**: a relay
//! thread that merges the upstream endpoints round-robin and deals to
//! the downstream endpoints round-robin. Merge rotation mirrors deal
//! rotation over FIFO connections, so global frame order is preserved
//! (see the module doc of [`crate::topology`]). Boundaries with one
//! endpoint on each side are connected directly — an unreplicated chain
//! has zero junctions and is wired exactly like the pre-topology
//! coordinator.
//!
//! Byte accounting: a hop's bytes are counted once, by the original
//! sender, against its shaped link. Junctions are routing fabric, not
//! network elements — they relay over an ideal link into a throwaway
//! counter, so `RunReport` byte totals are replication-invariant per
//! frame delivered.

use std::net::{SocketAddr, TcpListener};

use crate::coordinator::transport::Conn;
use crate::error::{DeferError, Result};
use crate::metrics::ByteCounter;
use crate::netem::Link;
use crate::threadpool::WorkerPool;
use crate::topology::{StageView, Topology};
use crate::wire::{Message, MessageType};

/// How to realize the topology's edges.
pub struct TransportOptions {
    /// Real TCP loopback sockets instead of in-process pipes.
    pub tcp: bool,
    /// Fixed first port for TCP listeners; `None` = ephemeral binds.
    pub base_port: Option<u16>,
    /// Bounded depth of in-process pipes (backpressure window).
    pub pipe_depth: usize,
}

/// Everything one worker replica needs: its view plus the four
/// established connections (config, weights, data-in, data-out).
pub struct WorkerConns {
    pub view: StageView,
    pub config: Conn,
    pub weights: Conn,
    pub data_in: Conn,
    pub data_out: Conn,
}

/// A fully wired deployment, ready to spawn.
pub struct Wiring {
    /// Dispatcher-side (config, weights) pair per worker, in the same
    /// stage-major order as `workers`.
    pub control: Vec<(Conn, Conn)>,
    /// Dispatcher's data uplink into stage 0 (hop 0).
    pub to_first: Conn,
    /// Dispatcher's return link from the last stage (hop S).
    pub from_last: Conn,
    /// Per-worker bundles, stage-major.
    pub workers: Vec<WorkerConns>,
    /// Junction relay threads for replicated boundaries; join after the
    /// run drains (no-op for uniform chains).
    pub junctions: WorkerPool,
}

/// Establish every connection the topology needs, for either transport.
pub fn build(topo: &Topology, opts: &TransportOptions) -> Result<Wiring> {
    if opts.tcp {
        build_tcp(topo, opts.base_port)
    } else {
        build_local(topo, opts.pipe_depth)
    }
}

/// Round-robin merge + deal relay for one replicated stage boundary.
///
/// Reads inputs in rotation (skipping drained ones) and forwards each
/// frame to the next output in rotation. A `Shutdown` closes its input;
/// once every input has shut down, `Shutdown` is broadcast downstream.
/// Exposed for the wiring property tests.
pub fn run_junction(mut inputs: Vec<Conn>, mut outputs: Vec<Conn>) -> Result<()> {
    let null = ByteCounter::new(); // hop bytes were counted by the sender
    let link = Link::ideal();
    let n_in = inputs.len();
    let mut open = vec![true; n_in];
    let mut open_count = n_in;
    let mut in_idx = 0usize;
    let mut out_idx = 0usize;
    while open_count > 0 {
        if open[in_idx] {
            let msg = inputs[in_idx].recv(&null)?;
            if msg.msg_type == MessageType::Shutdown {
                open[in_idx] = false;
                open_count -= 1;
            } else {
                outputs[out_idx].send(&msg, &link, &null)?;
                out_idx = (out_idx + 1) % outputs.len();
            }
        }
        in_idx = (in_idx + 1) % n_in;
    }
    for out in outputs.iter_mut() {
        out.send(&Message::control(MessageType::Shutdown), &link, &null)?;
    }
    Ok(())
}

fn spawn_junction(pool: &mut WorkerPool, boundary: usize, inputs: Vec<Conn>, outputs: Vec<Conn>) {
    pool.spawn(&format!("junction-hop{boundary}"), move || {
        run_junction(inputs, outputs)
    });
}

/// Endpoint counts at boundary `b` of an `s`-stage topology: upstream
/// (sender) side and downstream (receiver) side. The dispatcher is the
/// sole endpoint outside the chain.
fn boundary_fan(topo: &Topology, b: usize) -> (usize, usize) {
    let s = topo.num_stages();
    let u = if b == 0 { 1 } else { topo.replicas(b - 1) };
    let d = if b == s { 1 } else { topo.replicas(b) };
    (u, d)
}

// ------------------------------------------------------------ in-process

fn build_local(topo: &Topology, depth: usize) -> Result<Wiring> {
    let views = topo.worker_views();
    let s = topo.num_stages();
    let mut junctions = WorkerPool::new();

    // Per-worker data endpoints, keyed (stage, replica).
    let mut data_in: Vec<Vec<Option<Conn>>> = topo
        .stages()
        .iter()
        .map(|st| (0..st.replicas).map(|_| None).collect())
        .collect();
    let mut data_out: Vec<Vec<Option<Conn>>> = topo
        .stages()
        .iter()
        .map(|st| (0..st.replicas).map(|_| None).collect())
        .collect();
    let mut to_first = None;
    let mut from_last = None;

    for b in 0..=s {
        let (u, d) = boundary_fan(topo, b);
        let (outs, ins): (Vec<Conn>, Vec<Conn>) = if u == 1 && d == 1 {
            let (o, i) = Conn::local_pair(depth);
            (vec![o], vec![i])
        } else {
            let mut outs = Vec::with_capacity(u);
            let mut jin = Vec::with_capacity(u);
            for _ in 0..u {
                let (o, i) = Conn::local_pair(depth);
                outs.push(o);
                jin.push(i);
            }
            let mut jout = Vec::with_capacity(d);
            let mut ins = Vec::with_capacity(d);
            for _ in 0..d {
                let (o, i) = Conn::local_pair(depth);
                jout.push(o);
                ins.push(i);
            }
            spawn_junction(&mut junctions, b, jin, jout);
            (outs, ins)
        };
        for (r, o) in outs.into_iter().enumerate() {
            if b == 0 {
                to_first = Some(o);
            } else {
                data_out[b - 1][r] = Some(o);
            }
        }
        for (r, i) in ins.into_iter().enumerate() {
            if b == s {
                from_last = Some(i);
            } else {
                data_in[b][r] = Some(i);
            }
        }
    }

    let mut control = Vec::with_capacity(views.len());
    let mut workers = Vec::with_capacity(views.len());
    for view in views {
        let (cfg_d, cfg_n) = Conn::local_pair(2);
        let (w_d, w_n) = Conn::local_pair(2);
        control.push((cfg_d, w_d));
        let din = data_in[view.stage][view.replica]
            .take()
            .expect("boundary wiring covered every stage ingress");
        let dout = data_out[view.stage][view.replica]
            .take()
            .expect("boundary wiring covered every stage egress");
        workers.push(WorkerConns {
            view,
            config: cfg_n,
            weights: w_n,
            data_in: din,
            data_out: dout,
        });
    }

    Ok(Wiring {
        control,
        to_first: to_first.expect("boundary 0 wired"),
        from_last: from_last.expect("last boundary wired"),
        workers,
        junctions,
    })
}

// ----------------------------------------------------------- TCP loopback

/// Sequential-or-ephemeral port allocator.
struct PortAlloc {
    next: Option<u16>,
}

impl PortAlloc {
    fn bind(&mut self) -> Result<(TcpListener, SocketAddr)> {
        let port = match self.next {
            Some(p) => {
                self.next = Some(p.checked_add(1).ok_or_else(|| {
                    DeferError::Config("base_port allocation overflowed u16".into())
                })?);
                p
            }
            None => 0,
        };
        let l = TcpListener::bind(("127.0.0.1", port))
            .map_err(|e| DeferError::Coordinator(format!("bind 127.0.0.1:{port}: {e}")))?;
        let addr = l.local_addr()?;
        Ok((l, addr))
    }
}

struct WorkerListeners {
    config: TcpListener,
    config_addr: SocketAddr,
    weights: TcpListener,
    weights_addr: SocketAddr,
    data: TcpListener,
    data_addr: SocketAddr,
}

/// All listeners are bound before any connect, so every `connect` below
/// completes through the kernel's listen backlog even before the
/// matching `accept` runs — no acceptor-thread dance, no deadlock, and
/// each listener serves exactly one inbound connection.
fn build_tcp(topo: &Topology, base_port: Option<u16>) -> Result<Wiring> {
    let views = topo.worker_views();
    let s = topo.num_stages();
    let mut alloc = PortAlloc { next: base_port };
    let mut junctions = WorkerPool::new();

    // Worker index offsets per stage (stage-major layout).
    let mut off = Vec::with_capacity(s);
    let mut acc = 0usize;
    for st in topo.stages() {
        off.push(acc);
        acc += st.replicas;
    }

    // Bind everything first.
    let mut listeners = Vec::with_capacity(views.len());
    for _ in &views {
        let (config, config_addr) = alloc.bind()?;
        let (weights, weights_addr) = alloc.bind()?;
        let (data, data_addr) = alloc.bind()?;
        listeners.push(WorkerListeners {
            config,
            config_addr,
            weights,
            weights_addr,
            data,
            data_addr,
        });
    }
    let (ret_listener, ret_addr) = alloc.bind()?;

    // Control plane: dispatcher dials each worker's config + weights.
    let mut control = Vec::with_capacity(views.len());
    for (view, l) in views.iter().zip(&listeners) {
        let c = Conn::tcp_connect(
            &l.config_addr.to_string(),
            &format!("{} config socket", view.name),
        )?;
        let w = Conn::tcp_connect(
            &l.weights_addr.to_string(),
            &format!("{} weights socket", view.name),
        )?;
        control.push((c, w));
    }

    // Data plane, boundary by boundary.
    let mut data_out: Vec<Option<Conn>> = (0..views.len()).map(|_| None).collect();
    let mut to_first = None;
    for b in 0..=s {
        let (u, d) = boundary_fan(topo, b);
        // Downstream ingress addresses (+ peer labels for errors).
        let down: Vec<(String, String)> = if b == s {
            vec![(ret_addr.to_string(), "dispatcher return socket".to_string())]
        } else {
            (0..d)
                .map(|r| {
                    let widx = off[b] + r;
                    (
                        listeners[widx].data_addr.to_string(),
                        format!("{} data socket", views[widx].name),
                    )
                })
                .collect()
        };
        let outs: Vec<Conn> = if u == 1 && d == 1 {
            vec![Conn::tcp_connect(&down[0].0, &down[0].1)?]
        } else {
            let mut jls = Vec::with_capacity(u);
            for _ in 0..u {
                jls.push(alloc.bind()?);
            }
            let mut outs = Vec::with_capacity(u);
            for (r, (_, addr)) in jls.iter().enumerate() {
                outs.push(Conn::tcp_connect(
                    &addr.to_string(),
                    &format!("hop {b} junction input {r}"),
                )?);
            }
            let mut jin = Vec::with_capacity(u);
            for (l, _) in &jls {
                jin.push(Conn::tcp_accept(l)?);
            }
            let mut jout = Vec::with_capacity(d);
            for (addr, peer) in &down {
                jout.push(Conn::tcp_connect(addr, peer)?);
            }
            spawn_junction(&mut junctions, b, jin, jout);
            outs
        };
        for (r, o) in outs.into_iter().enumerate() {
            if b == 0 {
                to_first = Some(o);
            } else {
                data_out[off[b - 1] + r] = Some(o);
            }
        }
    }

    // Every inbound connection is now pending; accept them all.
    let mut workers = Vec::with_capacity(views.len());
    for (widx, view) in views.into_iter().enumerate() {
        let l = &listeners[widx];
        let config = Conn::tcp_accept(&l.config)?;
        let weights = Conn::tcp_accept(&l.weights)?;
        let data_in = Conn::tcp_accept(&l.data)?;
        let dout = data_out[widx]
            .take()
            .expect("boundary wiring covered every stage egress");
        workers.push(WorkerConns {
            view,
            config,
            weights,
            data_in,
            data_out: dout,
        });
    }
    let from_last = Conn::tcp_accept(&ret_listener)?;

    Ok(Wiring {
        control,
        to_first: to_first.expect("boundary 0 wired"),
        from_last,
        workers,
        junctions,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netem::LinkSpec;

    fn data_msg(frame: u64) -> Message {
        Message {
            msg_type: MessageType::Data,
            frame,
            serialized_len: 4,
            count: 0,
            payload: vec![frame as u8; 4],
        }
    }

    #[test]
    fn junction_restores_round_robin_order() {
        // Deal 7 frames over 3 inputs by hand, then let the junction
        // merge them back into one ordered stream.
        let u = 3;
        let mut up = Vec::new();
        let mut jin = Vec::new();
        for _ in 0..u {
            let (a, b) = Conn::local_pair(8);
            up.push(a);
            jin.push(b);
        }
        let (jout, mut down) = Conn::local_pair(16);
        let link = Link::ideal();
        let c = ByteCounter::new();
        for f in 0..7u64 {
            up[(f as usize) % u].send(&data_msg(f), &link, &c).unwrap();
        }
        for conn in up.iter_mut() {
            conn.send(&Message::control(MessageType::Shutdown), &link, &c)
                .unwrap();
        }
        run_junction(jin, vec![jout]).unwrap();
        for f in 0..7u64 {
            assert_eq!(down.recv(&c).unwrap().frame, f);
        }
        assert_eq!(
            down.recv(&c).unwrap().msg_type,
            MessageType::Shutdown
        );
    }

    #[test]
    fn uniform_local_wiring_has_no_junctions() {
        let topo = Topology::uniform_chain(3, LinkSpec::ideal()).unwrap();
        let w = build(
            &topo,
            &TransportOptions {
                tcp: false,
                base_port: None,
                pipe_depth: 4,
            },
        )
        .unwrap();
        assert_eq!(w.workers.len(), 3);
        assert_eq!(w.control.len(), 3);
        // No replication => relay pool joins immediately.
        w.junctions.join().unwrap();
    }
}
