//! Turn a [`Topology`] into live per-node connection bundles.
//!
//! This is the connection-establishment layer extracted from the old
//! inline builder in `coordinator::chain`. It supports both transports:
//!
//! * **in-process** — every edge is a bounded byte pipe;
//! * **TCP loopback** — every edge is a real kernel socket. Listeners
//!   bind ephemeral ports (`127.0.0.1:0`) by default and the *actual*
//!   addresses flow through the wiring, so parallel runs never collide;
//!   `base_port` remains as an optional override for CORE-style
//!   deployments that need predictable ports (allocated sequentially:
//!   three ports per worker in stage-major order, then the dispatcher
//!   return port; legacy relay mode additionally allocates junction
//!   ingress ports per replicated boundary).
//!
//! # Worker-owned deal/merge (the default data plane)
//!
//! Each replica **owns its own fan-out and fan-in**. At a boundary
//! between a `u`-replica stage and a `d`-replica stage, every upstream
//! replica holds one connection to every downstream replica (`u x d`
//! edges), and both sides run a deterministic round-robin schedule
//! derived from nothing but `(u, d, own index)`:
//!
//! * frame `f` is produced by upstream replica `f mod u` and consumed by
//!   downstream replica `f mod d` (the global deal invariant);
//! * a sender's `m`-th output frame is global frame `i + m*u`, so its
//!   [`DealSender`] rotates over the `d` successors starting at
//!   `i mod d` with step `u mod d`;
//! * a receiver's `k`-th input frame is global frame `j + k*d`, so its
//!   [`MergeReceiver`] rotates over the `u` predecessors starting at
//!   `j mod u` with step `d mod u`, blocking on the connection that owns
//!   the next frame in sequence.
//!
//! Every connection is FIFO and every frame takes exactly one network
//! hop, so global frame order is preserved end to end with **no relay
//! process in the path** — on a multi-host deployment a replicated
//! boundary costs one replica-to-replica crossing, not a round-trip
//! through the dispatcher host. Shutdown is a broadcast: a sender
//! forwards `Shutdown` to *all* successors after its last data frame,
//! and a receiver that meets `Shutdown` on the scheduled connection
//! drains the (provably data-free) remaining connections before
//! reporting end of stream.
//!
//! # Legacy relay mode (`--relay-junctions`)
//!
//! The pre-refactor data plane is kept behind
//! [`TransportOptions::relay_junctions`] for A/B comparison: replicated
//! boundaries are wired through a **junction** — a relay thread in the
//! coordinator process that merges the upstream endpoints round-robin
//! and deals to the downstream endpoints round-robin ([`run_junction`]).
//! Boundaries with one endpoint on each side are connected directly in
//! both modes — an unreplicated chain has zero junctions and identical
//! wiring whichever mode is selected.
//!
//! # Byte accounting
//!
//! A hop's bytes are counted once, by the original sender, against its
//! shaped link. Junctions are routing fabric, not network elements —
//! they relay over an ideal link into a throwaway counter. The
//! worker-owned shutdown broadcast keeps the same invariant: one
//! `Shutdown` per sender is counted/shaped, the extra fan-out copies
//! travel over an ideal link into a throwaway counter. `RunReport` byte
//! totals are therefore replication-invariant per frame delivered, and
//! identical across both data planes.

use std::collections::HashSet;
use std::net::{SocketAddr, TcpListener};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::transport::Conn;
use crate::error::{DeferError, Result};
use crate::metrics::{zerocopy, ByteCounter};
use crate::netem::Link;
use crate::netio::DealSink;
use crate::runtime::recovery::{
    spawn_nack_responder, ChunkRetryClient, RecoverySupervisor, RetentionRing,
};
use crate::threadpool::{pipe, PipeReceiver, WorkerPool};
use crate::topology::{StageView, Topology};
use crate::wire::{Message, MessageType, SharedPayload, WireFrame};

/// How to realize the topology's edges.
pub struct TransportOptions {
    /// Real TCP loopback sockets instead of in-process pipes.
    pub tcp: bool,
    /// Fixed first port for TCP listeners; `None` = ephemeral binds.
    pub base_port: Option<u16>,
    /// Bounded depth of in-process pipes (backpressure window).
    pub pipe_depth: usize,
    /// Restore the legacy coordinator-side junction relays for
    /// replicated boundaries (A/B escape hatch). Default wiring is
    /// worker-owned deal/merge with no relay threads.
    pub relay_junctions: bool,
    /// Self-healing mode: attach every endpoint to this supervisor
    /// ([`enable_recovery`]) so replica death degrades the mesh instead
    /// of failing the run, and wire the chunk-retry control mesh.
    /// Incompatible with `relay_junctions`. `None` = fail-fast wiring,
    /// byte-identical to pre-recovery builds.
    pub recovery: Option<Arc<RecoverySupervisor>>,
}

impl Default for TransportOptions {
    fn default() -> Self {
        TransportOptions {
            tcp: false,
            base_port: None,
            pipe_depth: 4,
            relay_junctions: false,
            recovery: None,
        }
    }
}

/// Data containers each sender retains for chunk-level re-send. Sized
/// comfortably past any pipe depth in use, so a corrupt chunk detected
/// one backpressure window downstream is still patchable.
pub const RETENTION_FRAMES: usize = 16;

/// ` (after frame N)` suffix for dead-peer errors: the last global
/// frame this endpoint moved successfully, so a mid-run death is
/// locatable in the frame stream without any log correlation.
pub(crate) fn frame_context(last: Option<u64>) -> String {
    match last {
        Some(f) => format!(" (after frame {f})"),
        None => String::new(),
    }
}

/// Round-robin dealing side of a worker-owned boundary: one FIFO
/// connection per successor, advanced by a deterministic schedule (see
/// the module docs). A single-connection sender degrades to plain
/// passthrough, so unreplicated chains pay nothing.
pub struct DealSender {
    conns: Vec<Conn>,
    /// Peer labels, index-aligned with `conns` (error reporting).
    labels: Vec<String>,
    next: usize,
    step: usize,
    /// Self-healing mode: dead successors are skipped and their
    /// unacknowledged frames queued for re-dispatch. `None` = fail-fast.
    recovery: Option<Arc<RecoverySupervisor>>,
    /// Recent containers retained for chunk-level re-send.
    ring: Option<Arc<RetentionRing>>,
    /// Last global frame dealt successfully (error context).
    last_frame: Option<u64>,
}

impl DealSender {
    /// A deal set over `conns` (labelled index-wise by `labels`),
    /// starting at `start` and advancing by `step` per data frame.
    pub fn new(conns: Vec<Conn>, labels: Vec<String>, start: usize, step: usize) -> DealSender {
        assert!(!conns.is_empty(), "deal sender needs at least one conn");
        assert_eq!(conns.len(), labels.len(), "one label per conn");
        let n = conns.len();
        DealSender {
            conns,
            labels,
            next: start % n,
            step: step % n,
            recovery: None,
            ring: None,
            last_frame: None,
        }
    }

    /// Attach the self-healing supervisor (see [`enable_recovery`]).
    pub fn set_recovery(&mut self, sup: Arc<RecoverySupervisor>) {
        self.recovery = Some(sup);
    }

    /// Attach the retention ring serving chunk re-sends.
    pub fn set_retention(&mut self, ring: Arc<RetentionRing>) {
        self.ring = Some(ring);
    }

    /// The attached supervisor, if any (the reactor plane extracts it
    /// before [`DealSender::into_parts`]).
    pub fn recovery_handle(&self) -> Option<Arc<RecoverySupervisor>> {
        self.recovery.clone()
    }

    /// The attached retention ring, if any.
    pub fn retention_handle(&self) -> Option<Arc<RetentionRing>> {
        self.ring.clone()
    }

    /// Wrap one connection (the unreplicated / relay-mode case).
    pub fn single(conn: Conn, label: &str) -> DealSender {
        DealSender::new(vec![conn], vec![label.to_string()], 0, 0)
    }

    /// Number of successor connections.
    pub fn fan(&self) -> usize {
        self.conns.len()
    }

    /// Send one data message to the successor the schedule owns, then
    /// advance the rotation. The unit of dealing is the *message*: a
    /// batched message (wire `batch > 1`) moves all its member frames
    /// to one replica and advances the rotation once, so batches are
    /// dealt round-robin exactly like single frames and the merge side
    /// restores FIFO order positionally, batch-size-blind. Errors name
    /// the dead peer.
    ///
    /// With a supervisor attached, a dead scheduled successor is skipped
    /// (first live conn scanning forward from the scheduled slot), a
    /// send that fails marks the peer dead and fails the message over to
    /// the next live successor, and only when no successor survives does
    /// the error surface. Routing and retention are reported so the
    /// supervisor can reconstruct what a dead peer still owed.
    pub fn send_data(&mut self, msg: &Message, link: &Link, counter: &ByteCounter) -> Result<()> {
        let scheduled = self.next;
        self.next = (self.next + self.step) % self.conns.len();
        match self.recovery.clone() {
            None => {
                self.conns[scheduled].send(msg, link, counter).map_err(|e| {
                    DeferError::Coordinator(format!(
                        "send to {}{}: {e}",
                        self.labels[scheduled],
                        frame_context(self.last_frame)
                    ))
                })?;
            }
            Some(sup) => {
                let n = self.conns.len();
                let mut at = scheduled;
                let mut last_err: Option<DeferError> = None;
                loop {
                    // Scan +1 (not +step: the schedule step can be 0)
                    // for the first live successor.
                    let live = (0..n)
                        .map(|k| (at + k) % n)
                        .find(|&j| !sup.is_dead(&self.labels[j]));
                    let Some(j) = live else {
                        let detail = last_err
                            .map(|e| format!(": {e}"))
                            .unwrap_or_default();
                        return Err(DeferError::Coordinator(format!(
                            "send to {}{}: all {n} successors dead{detail}",
                            self.labels[scheduled],
                            frame_context(self.last_frame)
                        )));
                    };
                    match self.conns[j].send(msg, link, counter) {
                        Ok(()) => {
                            if msg.msg_type == MessageType::Data {
                                sup.note_routed(&self.labels[j], msg.frame, msg.batch);
                                if let Some(ring) = &self.ring {
                                    zerocopy::count_payload_copy();
                                    ring.push(
                                        msg.frame,
                                        SharedPayload::from_vec(msg.payload.clone(), None),
                                    );
                                }
                            }
                            break;
                        }
                        Err(e) => {
                            // Death detected mid-send: the supervisor
                            // queues whatever this peer still owed for
                            // re-dispatch; this message fails over now.
                            sup.mark_dead(&self.labels[j]);
                            last_err = Some(e);
                            at = (j + 1) % n;
                        }
                    }
                }
            }
        }
        if msg.msg_type == MessageType::Data {
            self.last_frame = Some(msg.frame + u64::from(msg.batch.saturating_sub(1)));
        }
        Ok(())
    }

    /// Zero-copy counterpart of [`DealSender::send_data`]: the encoder
    /// already produced the frame's wire form once, so the scheduled
    /// conn gather-writes the shared buffer directly (shaping and byte
    /// accounting charge the identical byte sequence). The retention
    /// ring retains another reference to the same payload instead of a
    /// clone; failover re-attempts bump the refcount only.
    pub fn send_frame(&mut self, wf: WireFrame, link: &Link, counter: &ByteCounter) -> Result<()> {
        let scheduled = self.next;
        self.next = (self.next + self.step) % self.conns.len();
        let is_data = wf.msg_type() == MessageType::Data;
        let (frame, batch) = (wf.frame(), wf.batch());
        match self.recovery.clone() {
            None => {
                self.conns[scheduled]
                    .send_frame(wf, link, counter)
                    .map_err(|e| {
                        DeferError::Coordinator(format!(
                            "send to {}{}: {e}",
                            self.labels[scheduled],
                            frame_context(self.last_frame)
                        ))
                    })?;
            }
            Some(sup) => {
                let n = self.conns.len();
                let mut at = scheduled;
                let mut last_err: Option<DeferError> = None;
                loop {
                    let live = (0..n)
                        .map(|k| (at + k) % n)
                        .find(|&j| !sup.is_dead(&self.labels[j]));
                    let Some(j) = live else {
                        let detail = last_err
                            .map(|e| format!(": {e}"))
                            .unwrap_or_default();
                        return Err(DeferError::Coordinator(format!(
                            "send to {}{}: all {n} successors dead{detail}",
                            self.labels[scheduled],
                            frame_context(self.last_frame)
                        )));
                    };
                    match self.conns[j].send_frame(wf.clone(), link, counter) {
                        Ok(()) => {
                            if is_data {
                                sup.note_routed(&self.labels[j], frame, batch);
                                if let Some(ring) = &self.ring {
                                    ring.push(frame, wf.shared_payload().clone());
                                }
                            }
                            break;
                        }
                        Err(e) => {
                            sup.mark_dead(&self.labels[j]);
                            last_err = Some(e);
                            at = (j + 1) % n;
                        }
                    }
                }
            }
        }
        if is_data {
            self.last_frame = Some(frame + u64::from(batch.saturating_sub(1)));
        }
        Ok(())
    }

    /// Broadcast `Shutdown` to every successor. Exactly one copy is
    /// shaped and counted (the logical end-of-stream marker crossing the
    /// hop); the fan-out replicas are wiring fabric and travel over an
    /// ideal link into a throwaway counter, keeping byte totals
    /// replication-invariant and identical to the relay data plane.
    /// With a supervisor attached, dead successors are skipped (the
    /// first *live* successor carries the counted copy) and a send that
    /// fails marks the peer dead instead of failing the broadcast.
    pub fn broadcast_shutdown(&mut self, link: &Link, counter: &ByteCounter) -> Result<()> {
        let msg = Message::control(MessageType::Shutdown);
        let null = ByteCounter::new();
        let ideal = Link::ideal();
        let mut counted = false;
        for (idx, conn) in self.conns.iter_mut().enumerate() {
            if let Some(sup) = &self.recovery {
                if sup.is_dead(&self.labels[idx]) {
                    continue;
                }
            }
            let (l, c) = if counted { (&ideal, &null) } else { (link, counter) };
            match conn.send(&msg, l, c) {
                Ok(()) => counted = true,
                Err(e) => match &self.recovery {
                    Some(sup) => sup.mark_dead(&self.labels[idx]),
                    None => {
                        return Err(DeferError::Coordinator(format!(
                            "shutdown to {}: {e}",
                            self.labels[idx]
                        )))
                    }
                },
            }
        }
        Ok(())
    }

    /// Fault injection: write the first `n` bytes of `msg` to the
    /// scheduled successor, then stop (see [`Conn::send_truncated`]) —
    /// the caller is about to die and the peer must observe a
    /// mid-message EOF.
    pub fn send_truncated(&mut self, msg: &Message, n: usize) -> Result<()> {
        self.conns[self.next].send_truncated(msg, n)
    }

    /// Decompose into `(conns, labels, start, step)` so the reactor data
    /// plane can adopt the connections and re-run the identical schedule
    /// as a write state machine.
    pub fn into_parts(self) -> (Vec<Conn>, Vec<String>, usize, usize) {
        (self.conns, self.labels, self.next, self.step)
    }
}

/// FIFO-restoring merging side of a worker-owned boundary: one FIFO
/// connection per predecessor, read in the deterministic schedule that
/// mirrors the upstream deal (see the module docs), so frames are
/// returned in global order without any frame buffering — the receiver
/// simply blocks on the connection that owns the next frame.
pub struct MergeReceiver {
    conns: Vec<Conn>,
    /// Peer labels, index-aligned with `conns` (error reporting).
    labels: Vec<String>,
    next: usize,
    step: usize,
    /// End of stream already reported (every predecessor shut down).
    drained: bool,
    /// Self-healing mode: a dead predecessor degrades the merge to
    /// arrival order instead of failing the run. `None` = fail-fast.
    recovery: Option<Arc<RecoverySupervisor>>,
    /// Chunk-retry client for this consuming endpoint (provenance is
    /// noted per frame so a corrupt chunk can be NACKed to its producer).
    client: Option<Arc<ChunkRetryClient>>,
    /// Frames already delivered — re-dispatch can duplicate frames, and
    /// duplicates must not be delivered twice. Only populated in
    /// recovery mode on replicated merges.
    seen: HashSet<u64>,
    /// Arrival-order pump state, entered on the first observed death.
    degraded: Option<DegradedMerge>,
    /// Last global frame merged successfully (error context).
    last_frame: Option<u64>,
}

/// Arrival-order merge: one detached pump thread per predecessor conn
/// feeding a shared pipe. Entered when any replica dies — a death
/// anywhere in the mesh detours frames around the dead peer, so global
/// arrival order no longer matches the positional schedule and blocking
/// on the scheduled conn would deadlock. FIFO *delivery* order is
/// restored downstream by the dispatcher's completion tracking.
struct DegradedMerge {
    rx: PipeReceiver<(usize, Result<Message>)>,
    /// Conns still expected to resolve (Shutdown or death).
    open: usize,
    /// Clean Shutdowns seen so far.
    shutdowns: usize,
}

impl MergeReceiver {
    /// A merge set over `conns` (labelled index-wise by `labels`),
    /// starting at `start` and advancing by `step` per data frame.
    pub fn new(conns: Vec<Conn>, labels: Vec<String>, start: usize, step: usize) -> MergeReceiver {
        assert!(!conns.is_empty(), "merge receiver needs at least one conn");
        assert_eq!(conns.len(), labels.len(), "one label per conn");
        let n = conns.len();
        MergeReceiver {
            conns,
            labels,
            next: start % n,
            step: step % n,
            drained: false,
            recovery: None,
            client: None,
            seen: HashSet::new(),
            degraded: None,
            last_frame: None,
        }
    }

    /// Attach the self-healing supervisor (see [`enable_recovery`]).
    pub fn set_recovery(&mut self, sup: Arc<RecoverySupervisor>) {
        self.recovery = Some(sup);
    }

    /// Attach this endpoint's chunk-retry client.
    pub fn set_chunk_client(&mut self, client: Arc<ChunkRetryClient>) {
        self.client = Some(client);
    }

    /// The attached supervisor, if any (the reactor plane extracts it
    /// before [`MergeReceiver::into_parts`]).
    pub fn recovery_handle(&self) -> Option<Arc<RecoverySupervisor>> {
        self.recovery.clone()
    }

    /// The attached chunk-retry client, if any (shared with the decode
    /// stage, which issues the NACKs).
    pub fn chunk_client(&self) -> Option<Arc<ChunkRetryClient>> {
        self.client.clone()
    }

    /// Wrap one connection (the unreplicated / relay-mode case).
    pub fn single(conn: Conn, label: &str) -> MergeReceiver {
        MergeReceiver::new(vec![conn], vec![label.to_string()], 0, 0)
    }

    /// Number of predecessor connections.
    pub fn fan(&self) -> usize {
        self.conns.len()
    }

    /// Receive the next in-order message. Data frames advance the
    /// rotation; a `Shutdown` on the scheduled connection means the
    /// global stream ended (no later frame can exist — see module docs),
    /// so the remaining predecessors' pending `Shutdown`s are drained
    /// and a single merged `Shutdown` is returned. Errors name the dead
    /// peer.
    pub fn recv(&mut self, counter: &ByteCounter) -> Result<Message> {
        self.recv_pooled(counter, None)
    }

    /// [`MergeReceiver::recv`] with payload buffers drawn from `pool`.
    pub fn recv_pooled(
        &mut self,
        counter: &ByteCounter,
        pool: Option<&crate::util::bufpool::BufPool>,
    ) -> Result<Message> {
        if self.drained {
            return Err(DeferError::ChannelClosed("merge receiver drained"));
        }
        if self.degraded.is_some() {
            return self.recv_degraded();
        }
        if let Some(sup) = self.recovery.clone() {
            if self.conns.len() > 1 {
                // Poll the scheduled conn with a timeout so a death
                // anywhere in the mesh is noticed even while blocked on
                // a quiet peer: frames detour around a dead replica, so
                // the positional schedule stops matching arrival order
                // and the merge must switch to arrival order or
                // deadlock.
                loop {
                    if sup.death_epoch() > 0 {
                        self.enter_degraded();
                        return self.recv_degraded();
                    }
                    if self.conns[self.next].wait_readable(Duration::from_millis(50)) {
                        break;
                    }
                }
            }
        }
        let idx = self.next;
        let msg = match self.conns[idx].recv_pooled(counter, pool) {
            Ok(m) => m,
            Err(e) => {
                if let Some(sup) = self.recovery.clone() {
                    if self.conns.len() > 1 {
                        // The scheduled predecessor died: survivable.
                        sup.mark_dead(&self.labels[idx]);
                        self.enter_degraded();
                        return self.recv_degraded();
                    }
                }
                return Err(DeferError::Coordinator(format!(
                    "recv from {}{}: {e}",
                    self.labels[idx],
                    frame_context(self.last_frame)
                )));
            }
        };
        if msg.msg_type == MessageType::Shutdown {
            // The deal is round-robin: a missing frame at this slot means
            // no later slot's frame exists either, so every other conn
            // holds exactly one pending Shutdown. Drain them so peers
            // never block on an unread socket at teardown.
            let labels = &self.labels;
            let last_frame = self.last_frame;
            let recovering = self.recovery.is_some();
            for (i, conn) in self.conns.iter_mut().enumerate() {
                if i == idx {
                    continue;
                }
                loop {
                    let trailing = match conn.recv(counter) {
                        Ok(t) => t,
                        Err(e) => {
                            // With a supervisor a peer may die between
                            // its last frame and its Shutdown; the
                            // stream is already complete, so just
                            // report the death.
                            if let Some(sup) = &self.recovery {
                                sup.mark_dead(&labels[i]);
                                break;
                            }
                            return Err(DeferError::Coordinator(format!(
                                "recv from {}{}: {e}",
                                labels[i],
                                frame_context(last_frame)
                            )));
                        }
                    };
                    if trailing.msg_type == MessageType::Shutdown {
                        break;
                    }
                    if recovering {
                        // A re-dispatched duplicate still in flight when
                        // the stream completed: drop it and keep
                        // draining toward this conn's Shutdown.
                        continue;
                    }
                    return Err(DeferError::Coordinator(format!(
                        "{} sent {:?} after the merged stream ended",
                        labels[i], trailing.msg_type
                    )));
                }
            }
            self.drained = true;
            return Ok(msg);
        }
        self.next = (self.next + self.step) % self.conns.len();
        if self.recovery.is_some() && self.conns.len() > 1 {
            // Record delivery so a later degraded phase can recognize
            // re-dispatched duplicates of frames already merged.
            self.seen.insert(msg.frame);
        }
        if let Some(client) = &self.client {
            client.note_provenance(msg.frame, &self.labels[idx]);
        }
        self.last_frame = Some(msg.frame + u64::from(msg.batch.saturating_sub(1)));
        Ok(msg)
    }

    /// Switch to arrival-order merging: move every conn into a detached
    /// pump thread feeding one shared pipe. Pumps exit on Shutdown, on
    /// conn death, or when the receiver side is dropped.
    fn enter_degraded(&mut self) {
        let n = self.conns.len();
        let (tx, rx) = pipe::<(usize, Result<Message>)>(n.max(4));
        for (i, mut conn) in self.conns.drain(..).enumerate() {
            let tx = tx.clone();
            let name = format!("merge-pump-{}", self.labels[i]);
            std::thread::Builder::new()
                .name(name)
                .spawn(move || {
                    let counter = ByteCounter::new();
                    loop {
                        match conn.recv(&counter) {
                            Ok(msg) => {
                                let stop = msg.msg_type == MessageType::Shutdown;
                                if tx.send((i, Ok(msg))).is_err() || stop {
                                    return;
                                }
                            }
                            Err(e) => {
                                let _ = tx.send((i, Err(e)));
                                return;
                            }
                        }
                    }
                })
                .expect("spawn merge pump thread");
        }
        self.degraded = Some(DegradedMerge {
            rx,
            open: n,
            shutdowns: 0,
        });
    }

    /// Arrival-order receive: next frame from any live predecessor,
    /// deduplicated against everything already merged. End of stream is
    /// one merged `Shutdown` once every conn resolved (Shutdown or
    /// death) with at least one clean Shutdown; all predecessors dying
    /// without one is fatal (nothing can still deliver the stream).
    fn recv_degraded(&mut self) -> Result<Message> {
        loop {
            let d = self.degraded.as_mut().expect("degraded merge state");
            let Some((i, res)) = d.rx.recv() else {
                return Err(DeferError::ChannelClosed("merge pumps exited"));
            };
            match res {
                Ok(msg) if msg.msg_type == MessageType::Shutdown => {
                    d.open -= 1;
                    d.shutdowns += 1;
                    if d.open == 0 {
                        self.drained = true;
                        return Ok(msg);
                    }
                }
                Ok(msg) => {
                    if !self.seen.insert(msg.frame) {
                        continue; // re-dispatched duplicate
                    }
                    if let Some(client) = &self.client {
                        client.note_provenance(msg.frame, &self.labels[i]);
                    }
                    self.last_frame = Some(msg.frame + u64::from(msg.batch.saturating_sub(1)));
                    return Ok(msg);
                }
                Err(e) => {
                    if let Some(sup) = &self.recovery {
                        sup.mark_dead(&self.labels[i]);
                    }
                    d.open -= 1;
                    if d.open == 0 {
                        self.drained = true;
                        if d.shutdowns == 0 {
                            return Err(DeferError::Coordinator(format!(
                                "recv from {}{}: {e} (no live predecessor remains)",
                                self.labels[i],
                                frame_context(self.last_frame)
                            )));
                        }
                        // Every surviving predecessor already delivered
                        // its Shutdown; this death ends the stream.
                        return Ok(Message::control(MessageType::Shutdown));
                    }
                }
            }
        }
    }

    /// Decompose into `(conns, labels, start, step)` so the reactor data
    /// plane can adopt the connections and re-run the identical schedule
    /// as a read state machine. Only a fresh (undrained) receiver may be
    /// handed over.
    pub fn into_parts(self) -> (Vec<Conn>, Vec<String>, usize, usize) {
        debug_assert!(!self.drained, "cannot adopt a drained merge receiver");
        (self.conns, self.labels, self.next, self.step)
    }
}

/// Producer-facing egress handle: either the blocking [`DealSender`]
/// (thread-per-connection plane, writes complete inline) or a
/// reactor-backed [`DealSink`] (serialization, shaping and byte
/// accounting stay on the producer thread; the wire writes move to the
/// shared event loop). Call sites take `impl Into<FrameSink>` so both
/// planes flow through the same code unchanged.
pub enum FrameSink {
    Direct(DealSender),
    Queued(DealSink),
}

impl FrameSink {
    /// Send one data message per the deal schedule (see
    /// [`DealSender::send_data`]).
    pub fn send_data(&mut self, msg: &Message, link: &Link, counter: &ByteCounter) -> Result<()> {
        match self {
            FrameSink::Direct(s) => s.send_data(msg, link, counter),
            FrameSink::Queued(s) => s.send_data(msg, link, counter),
        }
    }

    /// Send one pre-encoded frame per the deal schedule with no
    /// serialize copy (see [`DealSender::send_frame`] /
    /// [`DealSink::send_frame`]).
    pub fn send_frame(&mut self, wf: WireFrame, link: &Link, counter: &ByteCounter) -> Result<()> {
        match self {
            FrameSink::Direct(s) => s.send_frame(wf, link, counter),
            FrameSink::Queued(s) => s.send_frame(wf, link, counter),
        }
    }

    /// Broadcast `Shutdown` to every successor (see
    /// [`DealSender::broadcast_shutdown`]).
    pub fn broadcast_shutdown(&mut self, link: &Link, counter: &ByteCounter) -> Result<()> {
        match self {
            FrameSink::Direct(s) => s.broadcast_shutdown(link, counter),
            FrameSink::Queued(s) => s.broadcast_shutdown(link, counter),
        }
    }

    /// Messages serialized but not yet on the wire. The blocking plane
    /// reports 0 — its sends complete inline — so adaptive batching can
    /// add this to its pipe-depth signal without changing behaviour
    /// there.
    pub fn queue_len(&self) -> usize {
        match self {
            FrameSink::Direct(_) => 0,
            FrameSink::Queued(s) => s.queue_len(),
        }
    }

    /// Fault injection: emit the first `n` bytes of `msg` toward the
    /// scheduled successor, then stop mid-message (the caller dies next).
    pub fn send_truncated(&mut self, msg: &Message, n: usize) -> Result<()> {
        match self {
            FrameSink::Direct(s) => s.send_truncated(msg, n),
            FrameSink::Queued(s) => s.send_truncated(msg, n),
        }
    }
}

impl From<DealSender> for FrameSink {
    fn from(s: DealSender) -> FrameSink {
        FrameSink::Direct(s)
    }
}

impl From<DealSink> for FrameSink {
    fn from(s: DealSink) -> FrameSink {
        FrameSink::Queued(s)
    }
}

/// Consumer-facing ingress handle: either the blocking
/// [`MergeReceiver`] or the message pipe fed by a reactor ingress
/// machine. Both deliver the identical merged FIFO stream ending in one
/// `Shutdown`; the reactor side surfaces its machine's failure (if any)
/// through the shared error slot once the pipe closes.
pub enum FrameSource {
    Direct(MergeReceiver),
    Queued {
        rx: PipeReceiver<Message>,
        err: Arc<Mutex<Option<DeferError>>>,
    },
}

impl FrameSource {
    /// Receive the next in-order message (see [`MergeReceiver::recv`]).
    pub fn recv(&mut self, counter: &ByteCounter) -> Result<Message> {
        self.recv_pooled(counter, None)
    }

    /// [`FrameSource::recv`] with payload buffers drawn from `pool`.
    /// The queued variant ignores both arguments: bytes were counted by
    /// the original sender (the receive side always uses a throwaway
    /// counter) and its payloads were pooled by the ingress machine.
    pub fn recv_pooled(
        &mut self,
        counter: &ByteCounter,
        pool: Option<&crate::util::bufpool::BufPool>,
    ) -> Result<Message> {
        match self {
            FrameSource::Direct(m) => m.recv_pooled(counter, pool),
            FrameSource::Queued { rx, err } => match rx.recv() {
                Some(msg) => Ok(msg),
                None => {
                    if let Some(e) = err.lock().unwrap().take() {
                        return Err(e);
                    }
                    Err(DeferError::ChannelClosed("merge receiver drained"))
                }
            },
        }
    }
}

impl From<MergeReceiver> for FrameSource {
    fn from(m: MergeReceiver) -> FrameSource {
        FrameSource::Direct(m)
    }
}

/// Everything one worker replica needs: its view plus the established
/// control connections (config, weights) and its owned data-plane sets
/// (merge from every predecessor, deal to every successor).
pub struct WorkerConns {
    pub view: StageView,
    pub config: Conn,
    pub weights: Conn,
    pub data_in: MergeReceiver,
    pub data_out: DealSender,
}

/// A fully wired deployment, ready to spawn.
pub struct Wiring {
    /// Dispatcher-side (config, weights) pair per worker, in the same
    /// stage-major order as `workers`.
    pub control: Vec<(Conn, Conn)>,
    /// Dispatcher's data uplink: a deal set over the stage-0 replicas.
    pub to_first: DealSender,
    /// Dispatcher's return path: a merge set over the last stage's
    /// replicas.
    pub from_last: MergeReceiver,
    /// Per-worker bundles, stage-major.
    pub workers: Vec<WorkerConns>,
    /// Junction relay threads — empty under worker-owned wiring; only
    /// legacy relay mode ([`TransportOptions::relay_junctions`]) spawns
    /// one per replicated boundary. Join after the run drains.
    pub junctions: WorkerPool,
}

/// Establish every connection the topology needs, for either transport.
pub fn build(topo: &Topology, opts: &TransportOptions) -> Result<Wiring> {
    if opts.recovery.is_some() && opts.relay_junctions {
        return Err(DeferError::Config(
            "recovery needs the worker-owned data plane; drop --relay-junctions".into(),
        ));
    }
    let mut w = if opts.tcp {
        build_tcp(topo, opts.base_port, opts.relay_junctions)?
    } else {
        build_local(topo, opts.pipe_depth, opts.relay_junctions)?
    };
    if let Some(sup) = &opts.recovery {
        enable_recovery(&mut w, topo, sup, opts.pipe_depth);
    }
    Ok(w)
}

/// Self-healing post-pass over an assembled worker-owned wiring: attach
/// the supervisor to every deal/merge endpoint and build the
/// chunk-retry control mesh.
///
/// Per boundary, every sender entity gets one [`RetentionRing`] (its
/// recent containers, serving re-sends) plus one NACK responder thread
/// per downstream consumer, and every receiver entity gets a
/// [`ChunkRetryClient`] holding one control conn per upstream producer.
/// Control conns are in-process pipes even under TCP — the control
/// plane is coordinator fabric like the config/weights exchange, not
/// part of the measured data path (NACK traffic is neither shaped nor
/// counted). Responder threads live in `Wiring::junctions` and exit
/// when their client side drops at run teardown.
fn enable_recovery(w: &mut Wiring, topo: &Topology, sup: &Arc<RecoverySupervisor>, depth: usize) {
    let s = topo.num_stages();
    // Worker index offsets per stage (stage-major layout).
    let mut off = Vec::with_capacity(s);
    let mut acc = 0usize;
    for st in topo.stages() {
        off.push(acc);
        acc += st.replicas;
    }
    for b in 0..=s {
        let (u, d) = boundary_fan(topo, b);
        let up_labels = upstream_labels(topo, b);
        let mut rings = Vec::with_capacity(u);
        for i in 0..u {
            let ring = RetentionRing::new(RETENTION_FRAMES);
            let sender = if b == 0 {
                &mut w.to_first
            } else {
                &mut w.workers[off[b - 1] + i].data_out
            };
            sender.set_recovery(Arc::clone(sup));
            sender.set_retention(Arc::clone(&ring));
            rings.push(ring);
        }
        for j in 0..d {
            let client = ChunkRetryClient::new(Arc::clone(sup));
            for (i, label) in up_labels.iter().enumerate() {
                let (responder_end, client_end) = Conn::local_pair(depth.max(2));
                client.add_upstream(label, client_end);
                spawn_nack_responder(
                    &mut w.junctions,
                    &format!("nack-b{b}u{i}d{j}"),
                    responder_end,
                    Arc::clone(&rings[i]),
                );
            }
            let receiver = if b == s {
                &mut w.from_last
            } else {
                &mut w.workers[off[b] + j].data_in
            };
            receiver.set_recovery(Arc::clone(sup));
            receiver.set_chunk_client(client);
        }
    }
}

/// Round-robin merge + deal relay for one replicated stage boundary
/// (legacy relay mode only).
///
/// Reads inputs in rotation (skipping drained ones) and forwards each
/// frame to the next output in rotation. A `Shutdown` closes its input;
/// once every input has shut down, `Shutdown` is broadcast downstream.
/// Exposed for the wiring property tests.
pub fn run_junction(mut inputs: Vec<Conn>, mut outputs: Vec<Conn>) -> Result<()> {
    let null = ByteCounter::new(); // hop bytes were counted by the sender
    let link = Link::ideal();
    let n_in = inputs.len();
    let mut open = vec![true; n_in];
    let mut open_count = n_in;
    let mut in_idx = 0usize;
    let mut out_idx = 0usize;
    while open_count > 0 {
        if open[in_idx] {
            let msg = inputs[in_idx].recv(&null)?;
            if msg.msg_type == MessageType::Shutdown {
                open[in_idx] = false;
                open_count -= 1;
            } else {
                outputs[out_idx].send(&msg, &link, &null)?;
                out_idx = (out_idx + 1) % outputs.len();
            }
        }
        in_idx = (in_idx + 1) % n_in;
    }
    for out in outputs.iter_mut() {
        out.send(&Message::control(MessageType::Shutdown), &link, &null)?;
    }
    Ok(())
}

fn spawn_junction(pool: &mut WorkerPool, boundary: usize, inputs: Vec<Conn>, outputs: Vec<Conn>) {
    pool.spawn(&format!("junction-hop{boundary}"), move || {
        run_junction(inputs, outputs)
    });
}

/// Endpoint counts at boundary `b` of an `s`-stage topology: upstream
/// (sender) side and downstream (receiver) side. The dispatcher is the
/// sole endpoint outside the chain.
fn boundary_fan(topo: &Topology, b: usize) -> (usize, usize) {
    let s = topo.num_stages();
    let u = if b == 0 { 1 } else { topo.replicas(b - 1) };
    let d = if b == s { 1 } else { topo.replicas(b) };
    (u, d)
}

/// Labels of the endpoints upstream of boundary `b` (senders into it).
fn upstream_labels(topo: &Topology, b: usize) -> Vec<String> {
    if b == 0 {
        vec!["dispatcher".to_string()]
    } else {
        (0..topo.replicas(b - 1))
            .map(|r| format!("{} data socket", topo.worker_name(b - 1, r)))
            .collect()
    }
}

/// Labels of the endpoints downstream of boundary `b` (receivers of it).
fn downstream_labels(topo: &Topology, b: usize) -> Vec<String> {
    if b == topo.num_stages() {
        vec!["dispatcher return socket".to_string()]
    } else {
        (0..topo.replicas(b))
            .map(|r| format!("{} data socket", topo.worker_name(b, r)))
            .collect()
    }
}

/// Deal-schedule parameters for upstream endpoint `i` of a `u -> d`
/// boundary: start and step over the `d` successors (module docs).
fn deal_schedule(i: usize, u: usize, d: usize) -> (usize, usize) {
    (i % d, u % d)
}

/// Merge-schedule parameters for downstream endpoint `j` of a `u -> d`
/// boundary: start and step over the `u` predecessors (module docs).
fn merge_schedule(j: usize, u: usize, d: usize) -> (usize, usize) {
    (j % u, d % u)
}

/// Boundary endpoint sets under construction: `outs[i]` collects sender
/// `i`'s conns in successor order, `ins[j]` collects receiver `j`'s
/// conns in predecessor order.
struct BoundaryConns {
    outs: Vec<Vec<Conn>>,
    ins: Vec<Vec<Conn>>,
}

// ------------------------------------------------------------ in-process

fn build_local(topo: &Topology, depth: usize, relay: bool) -> Result<Wiring> {
    let views = topo.worker_views();
    let s = topo.num_stages();
    let mut junctions = WorkerPool::new();

    // Per-worker data endpoint sets, keyed (stage, replica).
    let mut data_in: Vec<Vec<Option<MergeReceiver>>> = topo
        .stages()
        .iter()
        .map(|st| (0..st.replicas).map(|_| None).collect())
        .collect();
    let mut data_out: Vec<Vec<Option<DealSender>>> = topo
        .stages()
        .iter()
        .map(|st| (0..st.replicas).map(|_| None).collect())
        .collect();
    let mut to_first = None;
    let mut from_last = None;

    for b in 0..=s {
        let (u, d) = boundary_fan(topo, b);
        let up_labels = upstream_labels(topo, b);
        let down_labels = downstream_labels(topo, b);
        let bc = if relay && (u > 1 || d > 1) {
            // Legacy relay: one junction thread per replicated boundary;
            // every endpoint sees a single connection to the relay.
            let mut outs = Vec::with_capacity(u);
            let mut jin = Vec::with_capacity(u);
            for _ in 0..u {
                let (o, i) = Conn::local_pair(depth);
                outs.push(vec![o]);
                jin.push(i);
            }
            let mut jout = Vec::with_capacity(d);
            let mut ins = Vec::with_capacity(d);
            for _ in 0..d {
                let (o, i) = Conn::local_pair(depth);
                jout.push(o);
                ins.push(vec![i]);
            }
            spawn_junction(&mut junctions, b, jin, jout);
            BoundaryConns { outs, ins }
        } else {
            // Worker-owned: a full u x d mesh of direct pipes.
            let mut outs: Vec<Vec<Conn>> = (0..u).map(|_| Vec::with_capacity(d)).collect();
            let mut ins: Vec<Vec<Conn>> = (0..d).map(|_| Vec::with_capacity(u)).collect();
            // Each sender's out list is in receiver order; each
            // receiver's in list accumulates in sender order (senders
            // iterate outermost).
            for sender_conns in outs.iter_mut() {
                for receiver_conns in ins.iter_mut() {
                    let (o, inn) = Conn::local_pair(depth);
                    sender_conns.push(o);
                    receiver_conns.push(inn);
                }
            }
            BoundaryConns { outs, ins }
        };
        for (i, conns) in bc.outs.into_iter().enumerate() {
            let labels = if relay && (u > 1 || d > 1) {
                vec![format!("hop {b} junction")]
            } else {
                down_labels.clone()
            };
            let (start, step) = if conns.len() == 1 {
                (0, 0)
            } else {
                deal_schedule(i, u, d)
            };
            let sender = DealSender::new(conns, labels, start, step);
            if b == 0 {
                to_first = Some(sender);
            } else {
                data_out[b - 1][i] = Some(sender);
            }
        }
        for (j, conns) in bc.ins.into_iter().enumerate() {
            let labels = if relay && (u > 1 || d > 1) {
                vec![format!("hop {b} junction")]
            } else {
                up_labels.clone()
            };
            let (start, step) = if conns.len() == 1 {
                (0, 0)
            } else {
                merge_schedule(j, u, d)
            };
            let recv = MergeReceiver::new(conns, labels, start, step);
            if b == s {
                from_last = Some(recv);
            } else {
                data_in[b][j] = Some(recv);
            }
        }
    }

    let mut control = Vec::with_capacity(views.len());
    let mut workers = Vec::with_capacity(views.len());
    for view in views {
        let (cfg_d, cfg_n) = Conn::local_pair(2);
        let (w_d, w_n) = Conn::local_pair(2);
        control.push((cfg_d, w_d));
        let din = data_in[view.stage][view.replica]
            .take()
            .expect("boundary wiring covered every stage ingress");
        let dout = data_out[view.stage][view.replica]
            .take()
            .expect("boundary wiring covered every stage egress");
        workers.push(WorkerConns {
            view,
            config: cfg_n,
            weights: w_n,
            data_in: din,
            data_out: dout,
        });
    }

    Ok(Wiring {
        control,
        to_first: to_first.expect("boundary 0 wired"),
        from_last: from_last.expect("last boundary wired"),
        workers,
        junctions,
    })
}

// ----------------------------------------------------------- TCP loopback

/// How often a transiently failing bind is retried before giving up
/// (EADDRINUSE races between parallel test runs resolve in well under
/// this many backoff rounds).
const BIND_ATTEMPTS: u32 = 5;

/// Sequential-or-ephemeral port allocator.
struct PortAlloc {
    next: Option<u16>,
}

impl PortAlloc {
    /// Bind the next port, retrying a bounded number of times with
    /// backoff on transient failures (a fixed `base_port` range can race
    /// a just-released socket in TIME_WAIT or a parallel test run). The
    /// final error names the port that never came free.
    fn bind(&mut self) -> Result<(TcpListener, SocketAddr)> {
        let port = match self.next {
            Some(p) => {
                self.next = Some(p.checked_add(1).ok_or_else(|| {
                    DeferError::Config("base_port allocation overflowed u16".into())
                })?);
                p
            }
            None => 0,
        };
        let mut backoff = std::time::Duration::from_millis(5);
        let mut last_err = None;
        for attempt in 0..BIND_ATTEMPTS {
            match TcpListener::bind(("127.0.0.1", port)) {
                Ok(l) => {
                    let addr = l.local_addr()?;
                    return Ok((l, addr));
                }
                // Only EADDRINUSE is a transient race worth waiting out;
                // anything else (EACCES on a privileged port, EADDRNOTAVAIL)
                // is permanent and must fail fast.
                Err(e) if e.kind() == std::io::ErrorKind::AddrInUse => last_err = Some(e),
                Err(e) => {
                    return Err(DeferError::Coordinator(format!(
                        "bind 127.0.0.1:{port}: {e}"
                    )))
                }
            }
            if attempt + 1 < BIND_ATTEMPTS {
                std::thread::sleep(backoff);
                backoff *= 2;
            }
        }
        Err(DeferError::Coordinator(format!(
            "bind 127.0.0.1:{port} still in use after {BIND_ATTEMPTS} attempts: {}",
            last_err.expect("at least one bind attempt ran")
        )))
    }
}

struct WorkerListeners {
    config: TcpListener,
    config_addr: SocketAddr,
    weights: TcpListener,
    weights_addr: SocketAddr,
    data: TcpListener,
    data_addr: SocketAddr,
}

/// All listeners are bound before any connect, so every `connect` below
/// completes through the kernel's listen backlog even before the
/// matching `accept` runs — no acceptor-thread dance, no deadlock. A
/// worker's data listener serves one inbound connection per predecessor
/// replica; connects to one listener are issued sequentially, so accept
/// order equals dial order (loopback connects complete synchronously)
/// and each accepted connection is attributable to its sender index.
fn build_tcp(topo: &Topology, base_port: Option<u16>, relay: bool) -> Result<Wiring> {
    let views = topo.worker_views();
    let s = topo.num_stages();
    let mut alloc = PortAlloc { next: base_port };
    let mut junctions = WorkerPool::new();

    // Worker index offsets per stage (stage-major layout).
    let mut off = Vec::with_capacity(s);
    let mut acc = 0usize;
    for st in topo.stages() {
        off.push(acc);
        acc += st.replicas;
    }

    // Bind everything first.
    let mut listeners = Vec::with_capacity(views.len());
    for _ in &views {
        let (config, config_addr) = alloc.bind()?;
        let (weights, weights_addr) = alloc.bind()?;
        let (data, data_addr) = alloc.bind()?;
        listeners.push(WorkerListeners {
            config,
            config_addr,
            weights,
            weights_addr,
            data,
            data_addr,
        });
    }
    let (ret_listener, ret_addr) = alloc.bind()?;

    // Control plane: dispatcher dials each worker's config + weights.
    let mut control = Vec::with_capacity(views.len());
    for (view, l) in views.iter().zip(&listeners) {
        let c = Conn::tcp_connect(
            &l.config_addr.to_string(),
            &format!("{} config socket", view.name),
        )?;
        let w = Conn::tcp_connect(
            &l.weights_addr.to_string(),
            &format!("{} weights socket", view.name),
        )?;
        control.push((c, w));
    }

    // Data plane, boundary by boundary. Senders' out-sets are fully
    // dialed here; receivers' in-sets are accepted afterwards (every
    // inbound connection is already pending in a listen backlog).
    let mut data_out: Vec<Option<DealSender>> = (0..views.len()).map(|_| None).collect();
    let mut to_first = None;
    for b in 0..=s {
        let (u, d) = boundary_fan(topo, b);
        let down_labels = downstream_labels(topo, b);
        // Downstream ingress addresses, receiver order.
        let down_addrs: Vec<String> = if b == s {
            vec![ret_addr.to_string()]
        } else {
            (0..d)
                .map(|r| listeners[off[b] + r].data_addr.to_string())
                .collect()
        };
        let outs: Vec<DealSender> = if relay && (u > 1 || d > 1) {
            // Legacy relay: per-sender junction ingress ports, one relay
            // thread dealing onto the downstream data listeners.
            let mut jls = Vec::with_capacity(u);
            for _ in 0..u {
                jls.push(alloc.bind()?);
            }
            let mut outs = Vec::with_capacity(u);
            for (r, (_, addr)) in jls.iter().enumerate() {
                outs.push(DealSender::single(
                    Conn::tcp_connect(&addr.to_string(), &format!("hop {b} junction input {r}"))?,
                    &format!("hop {b} junction"),
                ));
            }
            let mut jin = Vec::with_capacity(u);
            for (r, (l, _)) in jls.iter().enumerate() {
                jin.push(Conn::tcp_accept_with_deadline(
                    l,
                    &format!("hop {b} junction input {r}"),
                    Conn::CONNECT_DEADLINE,
                )?);
            }
            let mut jout = Vec::with_capacity(d);
            for (addr, peer) in down_addrs.iter().zip(&down_labels) {
                jout.push(Conn::tcp_connect(addr, peer)?);
            }
            spawn_junction(&mut junctions, b, jin, jout);
            outs
        } else {
            // Worker-owned: sender i dials every receiver j. Dialing
            // with the sender index outermost keeps each receiver
            // listener's backlog in sender order, which is the order
            // the accept loop below attributes connections in.
            let mut out_conns: Vec<Vec<Conn>> = (0..u).map(|_| Vec::with_capacity(d)).collect();
            for (i, sender_conns) in out_conns.iter_mut().enumerate() {
                for (addr, peer) in down_addrs.iter().zip(&down_labels) {
                    sender_conns.push(Conn::tcp_connect(addr, peer)?);
                }
                debug_assert_eq!(sender_conns.len(), d, "sender {i} dialed every successor");
            }
            out_conns
                .into_iter()
                .enumerate()
                .map(|(i, conns)| {
                    let (start, step) = deal_schedule(i, u, d);
                    DealSender::new(conns, down_labels.clone(), start, step)
                })
                .collect()
        };
        for (i, o) in outs.into_iter().enumerate() {
            if b == 0 {
                to_first = Some(o);
            } else {
                data_out[off[b - 1] + i] = Some(o);
            }
        }
    }

    // Every inbound connection is now pending; accept them all. A
    // receiver at a worker-owned replicated boundary accepts one
    // connection per predecessor, in sender order (see above).
    let mut workers = Vec::with_capacity(views.len());
    for (widx, view) in views.into_iter().enumerate() {
        let l = &listeners[widx];
        let config = Conn::tcp_accept_with_deadline(
            &l.config,
            &format!("dispatcher ({} config dial)", view.name),
            Conn::CONNECT_DEADLINE,
        )?;
        let weights = Conn::tcp_accept_with_deadline(
            &l.weights,
            &format!("dispatcher ({} weights dial)", view.name),
            Conn::CONNECT_DEADLINE,
        )?;
        let b = view.stage;
        let (u, d) = boundary_fan(topo, b);
        let data_in = if relay && (u > 1 || d > 1) {
            MergeReceiver::single(
                Conn::tcp_accept_with_deadline(
                    &l.data,
                    &format!("hop {b} junction"),
                    Conn::CONNECT_DEADLINE,
                )?,
                &format!("hop {b} junction"),
            )
        } else {
            // Accepts attribute connections in dial order, so the
            // expected peer for the k-th accept is upstream endpoint k.
            let up_labels = upstream_labels(topo, b);
            let mut conns = Vec::with_capacity(u);
            for peer in &up_labels {
                conns.push(Conn::tcp_accept_with_deadline(
                    &l.data,
                    peer,
                    Conn::CONNECT_DEADLINE,
                )?);
            }
            let (start, step) = merge_schedule(view.replica, u, d);
            MergeReceiver::new(conns, up_labels, start, step)
        };
        let dout = data_out[widx]
            .take()
            .expect("boundary wiring covered every stage egress");
        workers.push(WorkerConns {
            view,
            config,
            weights,
            data_in,
            data_out: dout,
        });
    }
    let (u, d) = boundary_fan(topo, s);
    let from_last = if relay && (u > 1 || d > 1) {
        MergeReceiver::single(
            Conn::tcp_accept_with_deadline(
                &ret_listener,
                &format!("hop {s} junction"),
                Conn::CONNECT_DEADLINE,
            )?,
            &format!("hop {s} junction"),
        )
    } else {
        let up_labels = upstream_labels(topo, s);
        let mut conns = Vec::with_capacity(u);
        for peer in &up_labels {
            conns.push(Conn::tcp_accept_with_deadline(
                &ret_listener,
                peer,
                Conn::CONNECT_DEADLINE,
            )?);
        }
        let (start, step) = merge_schedule(0, u, d);
        MergeReceiver::new(conns, up_labels, start, step)
    };

    Ok(Wiring {
        control,
        to_first: to_first.expect("boundary 0 wired"),
        from_last,
        workers,
        junctions,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netem::LinkSpec;

    fn data_msg(frame: u64) -> Message {
        Message {
            msg_type: MessageType::Data,
            frame,
            serialized_len: 4,
            count: 0,
            batch: 1,
            payload: vec![frame as u8; 4],
        }
    }

    #[test]
    fn junction_restores_round_robin_order() {
        // Legacy relay mode: deal 7 frames over 3 inputs by hand, then
        // let the junction merge them back into one ordered stream.
        let u = 3;
        let mut up = Vec::new();
        let mut jin = Vec::new();
        for _ in 0..u {
            let (a, b) = Conn::local_pair(8);
            up.push(a);
            jin.push(b);
        }
        let (jout, mut down) = Conn::local_pair(16);
        let link = Link::ideal();
        let c = ByteCounter::new();
        for f in 0..7u64 {
            up[(f as usize) % u].send(&data_msg(f), &link, &c).unwrap();
        }
        for conn in up.iter_mut() {
            conn.send(&Message::control(MessageType::Shutdown), &link, &c)
                .unwrap();
        }
        run_junction(jin, vec![jout]).unwrap();
        for f in 0..7u64 {
            assert_eq!(down.recv(&c).unwrap().frame, f);
        }
        assert_eq!(down.recv(&c).unwrap().msg_type, MessageType::Shutdown);
    }

    #[test]
    fn worker_owned_merge_restores_round_robin_order() {
        // The same property with no relay thread anywhere: 3 senders
        // each hold their round-robin share of 7 frames; a single merge
        // receiver (the dispatcher's return path) restores global order.
        let u = 3;
        let mut up = Vec::new();
        let mut ins = Vec::new();
        for _ in 0..u {
            let (a, b) = Conn::local_pair(8);
            up.push(a);
            ins.push(b);
        }
        let labels = (0..u).map(|i| format!("peer{i}")).collect();
        let (start, step) = merge_schedule(0, u, 1);
        let mut merge = MergeReceiver::new(ins, labels, start, step);
        let link = Link::ideal();
        let c = ByteCounter::new();
        for f in 0..7u64 {
            up[(f as usize) % u].send(&data_msg(f), &link, &c).unwrap();
        }
        for conn in up.iter_mut() {
            conn.send(&Message::control(MessageType::Shutdown), &link, &c)
                .unwrap();
        }
        for f in 0..7u64 {
            assert_eq!(merge.recv(&c).unwrap().frame, f);
        }
        // One merged shutdown; the receiver drained every peer.
        assert_eq!(merge.recv(&c).unwrap().msg_type, MessageType::Shutdown);
        assert!(merge.recv(&c).is_err(), "stream already drained");
    }

    #[test]
    fn deal_sender_rotates_by_schedule() {
        // A sole upstream (the dispatcher) dealing to 3 replicas: frame
        // f must land on replica f mod 3, shutdown broadcast to all.
        let d = 3;
        let mut downs = Vec::new();
        let mut outs = Vec::new();
        for _ in 0..d {
            let (a, b) = Conn::local_pair(8);
            outs.push(a);
            downs.push(b);
        }
        let labels = (0..d).map(|j| format!("replica{j}")).collect();
        let (start, step) = deal_schedule(0, 1, d);
        let mut deal = DealSender::new(outs, labels, start, step);
        let link = Link::ideal();
        let c = ByteCounter::new();
        for f in 0..7u64 {
            deal.send_data(&data_msg(f), &link, &c).unwrap();
        }
        deal.broadcast_shutdown(&link, &c).unwrap();
        for (j, down) in downs.iter_mut().enumerate() {
            let mut expect = j as u64;
            loop {
                let m = down.recv(&ByteCounter::new()).unwrap();
                if m.msg_type == MessageType::Shutdown {
                    break;
                }
                assert_eq!(m.frame, expect, "replica {j}");
                expect += d as u64;
            }
            assert!(expect >= 7, "replica {j} starved");
        }
        // Exactly one shutdown was shaped/counted: 7 data frames + 1
        // control marker, not 1 per successor.
        let shutdown_wire = Message::control(MessageType::Shutdown).wire_size();
        let data_wire = data_msg(0).wire_size();
        assert_eq!(c.total(), 7 * data_wire + shutdown_wire);
    }

    #[test]
    fn dead_peer_is_named_by_label() {
        let (a, b) = Conn::local_pair(2);
        let mut deal = DealSender::single(a, "node1.1 data socket");
        drop(b);
        let err = deal
            .send_data(&data_msg(0), &Link::ideal(), &ByteCounter::new())
            .unwrap_err();
        assert!(
            format!("{err}").contains("node1.1 data socket"),
            "unlabelled error: {err}"
        );

        let (a, b) = Conn::local_pair(2);
        let mut merge = MergeReceiver::single(b, "node0 data socket");
        drop(a);
        let err = merge.recv(&ByteCounter::new()).unwrap_err();
        assert!(
            format!("{err}").contains("node0 data socket"),
            "unlabelled error: {err}"
        );
    }

    #[test]
    fn frame_sink_and_source_wrap_the_blocking_endpoints() {
        let (a, b) = Conn::local_pair(4);
        let mut sink: FrameSink = DealSender::single(a, "downstream").into();
        let mut source: FrameSource = MergeReceiver::single(b, "upstream").into();
        assert_eq!(sink.queue_len(), 0, "blocking sends complete inline");
        let link = Link::ideal();
        let c = ByteCounter::new();
        sink.send_data(&data_msg(3), &link, &c).unwrap();
        sink.broadcast_shutdown(&link, &c).unwrap();
        assert_eq!(source.recv(&c).unwrap().frame, 3);
        assert_eq!(source.recv(&c).unwrap().msg_type, MessageType::Shutdown);
        assert!(source.recv(&c).is_err(), "stream already drained");
    }

    #[test]
    fn into_parts_returns_the_schedule_verbatim() {
        let mut conns = Vec::new();
        let mut peers = Vec::new();
        for _ in 0..3 {
            let (a, b) = Conn::local_pair(2);
            conns.push(a);
            peers.push(b);
        }
        let labels: Vec<String> = (0..3).map(|i| format!("peer{i}")).collect();
        let sender = DealSender::new(conns, labels.clone(), 2, 1);
        let (conns, got_labels, start, step) = sender.into_parts();
        assert_eq!(conns.len(), 3);
        assert_eq!(got_labels, labels);
        assert_eq!((start, step), (2, 1));
        drop(peers);
    }

    #[test]
    fn uniform_local_wiring_has_no_junctions() {
        let topo = Topology::uniform_chain(3, LinkSpec::ideal()).unwrap();
        let w = build(&topo, &TransportOptions::default()).unwrap();
        assert_eq!(w.workers.len(), 3);
        assert_eq!(w.control.len(), 3);
        assert!(w.junctions.is_empty());
        for wc in &w.workers {
            assert_eq!(wc.data_in.fan(), 1);
            assert_eq!(wc.data_out.fan(), 1);
        }
        w.junctions.join().unwrap();
    }

    #[test]
    fn replicated_wiring_is_junction_free_by_default() {
        let topo = Topology::new(&[1, 3, 2], vec![LinkSpec::ideal(); 4]).unwrap();
        let w = build(&topo, &TransportOptions::default()).unwrap();
        assert!(
            w.junctions.is_empty(),
            "worker-owned wiring must spawn zero relay threads"
        );
        // Fan sets match the topology: stage 1 replicas each merge from
        // the sole stage-0 worker and deal to both stage-2 replicas.
        let node1_0 = w
            .workers
            .iter()
            .find(|wc| wc.view.name == "node1.0")
            .unwrap();
        assert_eq!(node1_0.data_in.fan(), 1);
        assert_eq!(node1_0.data_out.fan(), 2);
        assert_eq!(w.to_first.fan(), 3);
        assert_eq!(w.from_last.fan(), 2);
        w.junctions.join().unwrap();
    }

    #[test]
    fn relay_mode_still_spawns_junctions() {
        let topo = Topology::new(&[1, 3, 1], vec![LinkSpec::ideal(); 4]).unwrap();
        let w = build(
            &topo,
            &TransportOptions {
                relay_junctions: true,
                ..TransportOptions::default()
            },
        )
        .unwrap();
        // Boundaries 1 and 2 are replicated -> two relay threads; every
        // endpoint sees a single connection.
        assert_eq!(w.junctions.len(), 2);
        assert_eq!(w.to_first.fan(), 1);
        assert_eq!(w.from_last.fan(), 1);
        for wc in &w.workers {
            assert_eq!(wc.data_in.fan(), 1);
            assert_eq!(wc.data_out.fan(), 1);
        }
        // Drive a frame through so the junctions exit cleanly.
        let mut to_first = w.to_first;
        let mut from_last = w.from_last;
        let link = Link::ideal();
        let c = ByteCounter::new();
        let mut pool = WorkerPool::new();
        for wc in w.workers {
            pool.spawn(&format!("relay-{}", wc.view.name), move || {
                let WorkerConns {
                    mut data_in,
                    mut data_out,
                    ..
                } = wc;
                let null = ByteCounter::new();
                let link = Link::ideal();
                loop {
                    let msg = data_in.recv(&null)?;
                    if msg.msg_type == MessageType::Shutdown {
                        data_out.broadcast_shutdown(&link, &null)?;
                        return Ok(());
                    }
                    data_out.send_data(&msg, &link, &null)?;
                }
            });
        }
        for f in 0..5u64 {
            to_first.send_data(&data_msg(f), &link, &c).unwrap();
        }
        to_first.broadcast_shutdown(&link, &c).unwrap();
        for f in 0..5u64 {
            assert_eq!(from_last.recv(&c).unwrap().frame, f);
        }
        assert_eq!(from_last.recv(&c).unwrap().msg_type, MessageType::Shutdown);
        pool.join().unwrap();
        w.junctions.join().unwrap();
    }

    #[test]
    fn deal_sender_fails_over_to_live_successor() {
        use crate::netem::FaultPlan;
        let sup = crate::runtime::recovery::RecoverySupervisor::new(8, FaultPlan::default());
        let (a0, mut b0) = Conn::local_pair(16);
        let (a1, b1) = Conn::local_pair(16);
        let labels = vec!["r0".to_string(), "r1".to_string()];
        let mut deal = DealSender::new(vec![a0, a1], labels, 0, 1);
        deal.set_recovery(Arc::clone(&sup));
        let link = Link::ideal();
        let c = ByteCounter::new();
        deal.send_data(&data_msg(0), &link, &c).unwrap();
        // r1 dies; frame 1 (scheduled to it) must fail over to r0, and
        // the death must be reported exactly once.
        drop(b1);
        for f in 1..5u64 {
            deal.send_data(&data_msg(f), &link, &c).unwrap();
        }
        assert!(sup.is_dead("r1"));
        assert!(!sup.is_dead("r0"));
        assert_eq!(sup.replicas_lost(), 1);
        deal.broadcast_shutdown(&link, &c).unwrap();
        // Every frame arrived at r0 exactly once, in send order.
        for f in 0..5u64 {
            assert_eq!(b0.recv(&c).unwrap().frame, f);
        }
        assert_eq!(b0.recv(&c).unwrap().msg_type, MessageType::Shutdown);
    }

    #[test]
    fn deal_sender_without_survivors_reports_all_dead() {
        use crate::netem::FaultPlan;
        let sup = crate::runtime::recovery::RecoverySupervisor::new(8, FaultPlan::default());
        let (a0, b0) = Conn::local_pair(4);
        let mut deal = DealSender::new(vec![a0], vec!["r0".to_string()], 0, 0);
        deal.set_recovery(sup);
        drop(b0);
        let err = deal
            .send_data(&data_msg(0), &Link::ideal(), &ByteCounter::new())
            .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("all 1 successors dead"), "{msg}");
        assert!(msg.contains("r0"), "{msg}");
    }

    #[test]
    fn degraded_merge_survives_a_dead_predecessor() {
        use crate::netem::FaultPlan;
        let sup = crate::runtime::recovery::RecoverySupervisor::new(8, FaultPlan::default());
        let (mut a0, b0) = Conn::local_pair(16);
        let (a1, b1) = Conn::local_pair(16);
        let labels = vec!["p0".to_string(), "p1".to_string()];
        let mut merge = MergeReceiver::new(vec![b0, b1], labels, 0, 1);
        merge.set_recovery(Arc::clone(&sup));
        let link = Link::ideal();
        let c = ByteCounter::new();
        // Frame 0 arrives positionally from p0.
        a0.send(&data_msg(0), &link, &c).unwrap();
        assert_eq!(merge.recv(&c).unwrap().frame, 0);
        // p1 dies before delivering frame 1; the re-dispatched frames
        // (plus a duplicate of frame 0) detour via p0.
        drop(a1);
        for f in [1u64, 2, 0, 3] {
            a0.send(&data_msg(f), &link, &c).unwrap();
        }
        a0.send(&Message::control(MessageType::Shutdown), &link, &c)
            .unwrap();
        // Degraded merge: frames in arrival order, duplicate dropped,
        // one merged Shutdown, no error.
        for f in [1u64, 2, 3] {
            assert_eq!(merge.recv(&c).unwrap().frame, f);
        }
        assert_eq!(merge.recv(&c).unwrap().msg_type, MessageType::Shutdown);
        assert!(sup.is_dead("p1"));
        assert!(merge.recv(&c).is_err(), "stream already drained");
    }

    #[test]
    fn degraded_merge_with_no_survivors_is_fatal() {
        use crate::netem::FaultPlan;
        let sup = crate::runtime::recovery::RecoverySupervisor::new(8, FaultPlan::default());
        let (a0, b0) = Conn::local_pair(4);
        let (a1, b1) = Conn::local_pair(4);
        let labels = vec!["p0".to_string(), "p1".to_string()];
        let mut merge = MergeReceiver::new(vec![b0, b1], labels, 0, 1);
        merge.set_recovery(sup);
        drop(a0);
        drop(a1);
        let err = merge.recv(&ByteCounter::new()).unwrap_err();
        assert!(
            format!("{err}").contains("no live predecessor remains"),
            "{err}"
        );
    }

    #[test]
    fn recovery_wiring_attaches_endpoints_and_control_mesh() {
        use crate::netem::FaultPlan;
        let sup = crate::runtime::recovery::RecoverySupervisor::new(8, FaultPlan::default());
        let topo = Topology::new(&[1, 2], vec![LinkSpec::ideal(); 3]).unwrap();
        let w = build(
            &topo,
            &TransportOptions {
                recovery: Some(Arc::clone(&sup)),
                ..TransportOptions::default()
            },
        )
        .unwrap();
        assert!(w.to_first.recovery_handle().is_some());
        assert!(w.to_first.retention_handle().is_some());
        assert!(w.from_last.chunk_client().is_some());
        for wc in &w.workers {
            assert!(wc.data_out.recovery_handle().is_some());
            assert!(wc.data_in.chunk_client().is_some());
        }
        // One NACK responder per (producer, consumer) pair per
        // boundary: 1x1 + 1x2 + 2x1 = 5.
        assert_eq!(w.junctions.len(), 5);
        // Responders exit once every client end drops.
        let Wiring {
            control,
            to_first,
            from_last,
            workers,
            junctions,
        } = w;
        drop((control, to_first, from_last, workers));
        junctions.join().unwrap();
    }

    #[test]
    fn recovery_rejects_relay_junctions() {
        use crate::netem::FaultPlan;
        let sup = crate::runtime::recovery::RecoverySupervisor::new(8, FaultPlan::default());
        let topo = Topology::new(&[1, 2], vec![LinkSpec::ideal(); 3]).unwrap();
        let err = build(
            &topo,
            &TransportOptions {
                recovery: Some(sup),
                relay_junctions: true,
                ..TransportOptions::default()
            },
        )
        .unwrap_err();
        assert!(format!("{err}").contains("relay-junctions"), "{err}");
    }

    #[test]
    fn bind_retry_error_names_the_port() {
        // Occupy a port, then ask the allocator for exactly it: the
        // bounded retry must give up and name the port.
        let holder = TcpListener::bind("127.0.0.1:0").unwrap();
        let port = holder.local_addr().unwrap().port();
        let mut alloc = PortAlloc { next: Some(port) };
        let err = alloc.bind().unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains(&format!("127.0.0.1:{port}")), "{msg}");
        assert!(msg.contains("attempts"), "{msg}");
    }
}
