//! Declarative deployment topology: named stages, per-stage replication,
//! and per-hop link specifications.
//!
//! The paper's DEFER deployment is a fixed chain — dispatcher → node0 →
//! node1 → … → dispatcher — with one [`LinkSpec`] shared by every hop.
//! The authors' follow-up work (SEIFER, arXiv 2210.12218; throughput-
//! maximizing placement, arXiv 2210.12219) generalizes exactly two
//! things: links become heterogeneous per hop (e.g. a wifi uplink into
//! the cluster, gigabit Ethernet inside it), and bottleneck stages are
//! replicated across R workers. [`Topology`] captures both
//! declaratively; the [`wiring`] module turns a topology into live
//! connection bundles for either transport, and the coordinator consumes
//! the result without knowing how it was wired. The placement optimizer
//! ([`crate::placement`]) is exactly the promised pure planning pass
//! that emits a `Topology` from stage costs and device budgets, and the
//! repartition planner ([`crate::repartition`]) goes one step further:
//! a "stage" here need not be one artifact partition — it may be a fused
//! run of them ([`crate::model::StageSpec`]), with the cut points chosen
//! jointly with the replica counts. The topology layer is agnostic: it
//! describes stages × replicas × links, whoever decided them.
//!
//! Frame ordering with replication: frame `f` is always produced by
//! replica `f mod u` of a stage and consumed by replica `f mod d` of
//! the next — the endpoints themselves run the matching round-robin
//! deal ([`wiring::DealSender`]) and FIFO-restoring merge
//! ([`wiring::MergeReceiver`]) schedules, derived purely from
//! `(u, d, own index)`. Because every connection is FIFO and the merge
//! rotation mirrors the deal rotation, global frame order is preserved
//! end to end regardless of per-replica compute jitter (a merge simply
//! blocks on the connection that owns the next frame in sequence), with
//! no relay process between stages. The legacy coordinator-side
//! junction relays remain available behind `--relay-junctions` for A/B
//! comparison.

pub mod wiring;

use crate::config::DeferConfig;
use crate::error::{DeferError, Result};
use crate::netem::LinkSpec;

/// One pipeline stage's replication slot: a stage (one partition, or a
/// fused run of them — see [`crate::model::StageSpec`]) served by
/// `replicas` workers.
#[derive(Clone, Debug)]
pub struct StageReplicas {
    /// Stage label; worker labels derive from it (`node1`, `node1.0`).
    pub name: String,
    /// Worker replicas serving this stage (>= 1), fed round-robin.
    pub replicas: usize,
}

/// A worker's view of its place in the topology: which partition it
/// serves, which replica it is, and where its output goes. This is what
/// the dispatcher and compute nodes see instead of "my index in a chain".
#[derive(Clone, Debug)]
pub struct StageView {
    /// Stage (= partition) index this worker serves.
    pub stage: usize,
    /// Which replica of the stage this worker is.
    pub replica: usize,
    /// Total replicas of this stage.
    pub replicas: usize,
    /// Worker label, e.g. `node1` (sole replica) or `node1.0`.
    pub name: String,
    /// Labels of the downstream endpoints this worker's output reaches
    /// (`dispatcher` for the last stage).
    pub successors: Vec<String>,
}

impl StageView {
    /// A 1-replica view for harnesses that drive a single node directly.
    pub fn standalone(stage: usize) -> StageView {
        StageView {
            stage,
            replica: 0,
            replicas: 1,
            name: format!("node{stage}"),
            successors: vec!["dispatcher".to_string()],
        }
    }
}

/// Declarative chain topology: S stages and S+1 hops.
///
/// Hop `h` is the link from stage `h-1` into stage `h`; hop `0` is the
/// dispatcher uplink into stage 0 and hop `S` the return link from the
/// last stage back to the dispatcher. Each replica of a stage owns an
/// independent instance of its hop's link — replication adds physical
/// links, not shared capacity.
#[derive(Clone, Debug)]
pub struct Topology {
    stages: Vec<StageReplicas>,
    hop_links: Vec<LinkSpec>,
}

impl Topology {
    /// Build from per-stage replica counts and exactly `stages + 1`
    /// per-hop link specs.
    pub fn new(replicas: &[usize], hop_links: Vec<LinkSpec>) -> Result<Topology> {
        if replicas.is_empty() {
            return Err(DeferError::Config("topology needs at least one stage".into()));
        }
        if let Some(i) = replicas.iter().position(|&r| r == 0) {
            return Err(DeferError::Config(format!(
                "stage {i}: replicas must be >= 1"
            )));
        }
        if hop_links.len() != replicas.len() + 1 {
            return Err(DeferError::Config(format!(
                "{} stages need {} hop links, got {}",
                replicas.len(),
                replicas.len() + 1,
                hop_links.len()
            )));
        }
        Ok(Topology {
            stages: replicas
                .iter()
                .enumerate()
                .map(|(i, &r)| StageReplicas {
                    name: format!("node{i}"),
                    replicas: r,
                })
                .collect(),
            hop_links,
        })
    }

    /// The paper's topology: `stages` single-replica stages, one link
    /// spec everywhere.
    pub fn uniform_chain(stages: usize, link: LinkSpec) -> Result<Topology> {
        Topology::new(&vec![1; stages], vec![link; stages + 1])
    }

    /// Derive the topology a [`DeferConfig`] describes: `nodes` stages,
    /// `replicas` (default 1 each), and `per_hop_links` (empty = uniform
    /// `link`; a single entry is splatted across all hops).
    pub fn from_config(cfg: &DeferConfig) -> Result<Topology> {
        let n = cfg.nodes;
        // Validate shapes against `nodes` up front, naming the offending
        // config key — handing a wrong-length `replicas` to
        // `Topology::new` used to surface as a baffling hop-link count
        // mismatch instead.
        let replicas: Vec<usize> = if cfg.replicas.is_empty() {
            vec![1; n]
        } else {
            if cfg.replicas.len() != n {
                return Err(DeferError::Config(format!(
                    "config key `replicas` lists {} stages but `nodes` is {n}",
                    cfg.replicas.len()
                )));
            }
            cfg.replicas.clone()
        };
        let hop_links: Vec<LinkSpec> = match cfg.per_hop_links.len() {
            0 => vec![cfg.link; n + 1],
            1 => vec![cfg.per_hop_links[0]; n + 1],
            l if l == n + 1 => cfg.per_hop_links.clone(),
            l => {
                return Err(DeferError::Config(format!(
                    "config key `per_hop_links` has {l} entries; {n} stages need \
                     {} (dispatcher uplink, inter-stage hops, return) or 1 to \
                     apply everywhere",
                    n + 1
                )))
            }
        };
        Topology::new(&replicas, hop_links)
    }

    pub fn stages(&self) -> &[StageReplicas] {
        &self.stages
    }

    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    /// Total worker replicas across all stages.
    pub fn num_workers(&self) -> usize {
        self.stages.iter().map(|s| s.replicas).sum()
    }

    pub fn num_hops(&self) -> usize {
        self.hop_links.len()
    }

    pub fn replicas(&self, stage: usize) -> usize {
        self.stages[stage].replicas
    }

    pub fn hop_link(&self, hop: usize) -> LinkSpec {
        self.hop_links[hop]
    }

    /// True when every stage has exactly one replica (the paper's chain).
    pub fn is_uniform(&self) -> bool {
        self.stages.iter().all(|s| s.replicas == 1)
    }

    /// Worker label. Sole replicas keep the bare stage name so the wire
    /// payloads of an unreplicated chain are byte-identical to the
    /// pre-topology coordinator.
    pub fn worker_name(&self, stage: usize, replica: usize) -> String {
        let st = &self.stages[stage];
        if st.replicas == 1 {
            st.name.clone()
        } else {
            format!("{}.{replica}", st.name)
        }
    }

    /// Labels of the endpoints downstream of `stage`.
    pub fn successor_labels(&self, stage: usize) -> Vec<String> {
        if stage + 1 == self.stages.len() {
            vec!["dispatcher".to_string()]
        } else {
            let s = stage + 1;
            (0..self.stages[s].replicas)
                .map(|r| self.worker_name(s, r))
                .collect()
        }
    }

    /// All worker views in canonical (stage-major, then replica) order —
    /// the order every per-worker collection in the coordinator uses.
    pub fn worker_views(&self) -> Vec<StageView> {
        let mut out = Vec::with_capacity(self.num_workers());
        for (i, st) in self.stages.iter().enumerate() {
            let successors = self.successor_labels(i);
            for r in 0..st.replicas {
                out.push(StageView {
                    stage: i,
                    replica: r,
                    replicas: st.replicas,
                    name: self.worker_name(i, r),
                    successors: successors.clone(),
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_chain_matches_legacy_naming() {
        let t = Topology::uniform_chain(3, LinkSpec::ideal()).unwrap();
        assert_eq!(t.num_stages(), 3);
        assert_eq!(t.num_workers(), 3);
        assert_eq!(t.num_hops(), 4);
        assert!(t.is_uniform());
        let views = t.worker_views();
        assert_eq!(views.len(), 3);
        assert_eq!(views[0].name, "node0");
        assert_eq!(views[0].successors, vec!["node1".to_string()]);
        assert_eq!(views[2].name, "node2");
        assert_eq!(views[2].successors, vec!["dispatcher".to_string()]);
    }

    #[test]
    fn replicated_stage_views() {
        let t = Topology::new(&[1, 3, 1], vec![LinkSpec::ideal(); 4]).unwrap();
        assert_eq!(t.num_workers(), 5);
        assert!(!t.is_uniform());
        let views = t.worker_views();
        assert_eq!(views[0].name, "node0");
        assert_eq!(
            views[0].successors,
            vec!["node1.0".to_string(), "node1.1".to_string(), "node1.2".to_string()]
        );
        assert_eq!(views[1].name, "node1.0");
        assert_eq!(views[1].replica, 0);
        assert_eq!(views[3].name, "node1.2");
        assert_eq!(views[3].stage, 1);
        assert_eq!(views[3].successors, vec!["node2".to_string()]);
        assert_eq!(views[4].name, "node2");
    }

    #[test]
    fn validation_rejects_bad_shapes() {
        assert!(Topology::new(&[], vec![LinkSpec::ideal()]).is_err());
        assert!(Topology::new(&[1, 0], vec![LinkSpec::ideal(); 3]).is_err());
        assert!(Topology::new(&[1, 1], vec![LinkSpec::ideal(); 2]).is_err());
    }

    #[test]
    fn from_config_names_offending_key() {
        // A wrong-length `replicas` must be reported as such, not as a
        // downstream hop-link count mismatch.
        let mut cfg = DeferConfig::default();
        cfg.nodes = 3;
        cfg.replicas = vec![1, 1, 1, 1, 1];
        let msg = format!("{}", Topology::from_config(&cfg).unwrap_err());
        assert!(msg.contains("`replicas`"), "bad error: {msg}");
        assert!(msg.contains("5") && msg.contains("3"), "bad error: {msg}");

        let mut cfg = DeferConfig::default();
        cfg.nodes = 3;
        cfg.per_hop_links = vec![LinkSpec::ideal(); 3];
        let msg = format!("{}", Topology::from_config(&cfg).unwrap_err());
        assert!(msg.contains("`per_hop_links`"), "bad error: {msg}");
    }

    #[test]
    fn from_config_splats_links() {
        let mut cfg = DeferConfig::default();
        cfg.nodes = 3;
        cfg.per_hop_links = vec![LinkSpec::wifi()];
        let t = Topology::from_config(&cfg).unwrap();
        assert_eq!(t.num_hops(), 4);
        for h in 0..4 {
            assert_eq!(t.hop_link(h), LinkSpec::wifi());
        }
        cfg.per_hop_links = vec![
            LinkSpec::wifi(),
            LinkSpec::gigabit_lan(),
            LinkSpec::gigabit_lan(),
            LinkSpec::gigabit_lan(),
        ];
        let t = Topology::from_config(&cfg).unwrap();
        assert_eq!(t.hop_link(0), LinkSpec::wifi());
        assert_eq!(t.hop_link(1), LinkSpec::gigabit_lan());
    }
}
