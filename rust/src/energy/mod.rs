//! Energy model — the paper's §IV "Energy Consumption" methodology:
//!
//! * compute/codec energy = busy wall-time x TDP (Thermal Design Power)
//! * network energy       = transmitted bits x per-bit cost
//!   (10 pJ/bit for Ethernet, after W. Jiang, "Energy to transmit one bit")
//!
//! An [`EnergyMeter`] is attached to each node (and to the dispatcher);
//! readers pull a [`EnergyReport`] per inference cycle or per run.

use std::time::Duration;

/// Ethernet per-bit transmit energy used by the paper: 10 pJ/bit.
pub const ETHERNET_JOULES_PER_BIT: f64 = 10e-12;

/// Default TDP: 15 W, a Raspberry-Pi-4-class edge board under load
/// (the paper does not name its per-node TDP; this is configurable).
pub const DEFAULT_TDP_WATTS: f64 = 15.0;

/// Static parameters of the energy model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EnergyModel {
    pub tdp_watts: f64,
    pub joules_per_bit: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            tdp_watts: DEFAULT_TDP_WATTS,
            joules_per_bit: ETHERNET_JOULES_PER_BIT,
        }
    }
}

impl EnergyModel {
    /// Energy for `busy` seconds of compute at TDP.
    pub fn compute_energy(&self, busy: Duration) -> f64 {
        busy.as_secs_f64() * self.tdp_watts
    }

    /// Energy to push `bytes` over the network medium.
    pub fn network_energy(&self, bytes: u64) -> f64 {
        bytes as f64 * 8.0 * self.joules_per_bit
    }
}

/// A per-node energy accounting snapshot.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyReport {
    /// Joules spent running inference (model execute time x TDP).
    pub compute_j: f64,
    /// Joules spent serializing/compressing (overhead time x TDP).
    pub codec_j: f64,
    /// Joules spent transmitting bytes.
    pub network_j: f64,
}

impl EnergyReport {
    pub fn total(&self) -> f64 {
        self.compute_j + self.codec_j + self.network_j
    }

    /// Average over `cycles` inference cycles.
    pub fn per_cycle(&self, cycles: u64) -> EnergyReport {
        if cycles == 0 {
            return EnergyReport::default();
        }
        let c = cycles as f64;
        EnergyReport {
            compute_j: self.compute_j / c,
            codec_j: self.codec_j / c,
            network_j: self.network_j / c,
        }
    }

    pub fn add(&mut self, other: &EnergyReport) {
        self.compute_j += other.compute_j;
        self.codec_j += other.codec_j;
        self.network_j += other.network_j;
    }
}

/// Live meter combining the model with a node's timers and counters.
pub struct EnergyMeter {
    pub model: EnergyModel,
    /// Inference busy time.
    pub compute: crate::util::timer::SharedTimer,
    /// Serialization/compression time.
    pub codec: crate::util::timer::SharedTimer,
    /// Bytes sent by this node.
    pub tx_bytes: crate::metrics::ByteCounter,
}

impl EnergyMeter {
    pub fn new(model: EnergyModel) -> Self {
        EnergyMeter {
            model,
            compute: crate::util::timer::SharedTimer::new(),
            codec: crate::util::timer::SharedTimer::new(),
            tx_bytes: crate::metrics::ByteCounter::new(),
        }
    }

    pub fn report(&self) -> EnergyReport {
        EnergyReport {
            compute_j: self.model.compute_energy(self.compute.total()),
            codec_j: self.model.compute_energy(self.codec.total()),
            network_j: self.model.network_energy(self.tx_bytes.total()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_network_energy_formula() {
        let m = EnergyModel::default();
        // 1 MB at 10 pJ/bit = 8e6 bits * 1e-11 J = 8e-5 J.
        let e = m.network_energy(1_000_000);
        assert!((e - 8e-5).abs() < 1e-12);
    }

    #[test]
    fn compute_energy_scales_with_tdp() {
        let m = EnergyModel {
            tdp_watts: 30.0,
            ..Default::default()
        };
        assert!((m.compute_energy(Duration::from_millis(500)) - 15.0).abs() < 1e-9);
    }

    #[test]
    fn report_totals_and_per_cycle() {
        let mut r = EnergyReport {
            compute_j: 4.0,
            codec_j: 1.0,
            network_j: 0.5,
        };
        assert!((r.total() - 5.5).abs() < 1e-12);
        let pc = r.per_cycle(10);
        assert!((pc.compute_j - 0.4).abs() < 1e-12);
        assert_eq!(EnergyReport::default().per_cycle(0), EnergyReport::default());
        r.add(&pc);
        assert!((r.compute_j - 4.4).abs() < 1e-12);
    }

    #[test]
    fn meter_integrates_timers_and_bytes() {
        let meter = EnergyMeter::new(EnergyModel::default());
        meter.compute.add(Duration::from_secs(1));
        meter.codec.add(Duration::from_millis(100));
        meter.tx_bytes.add(1_000_000);
        let r = meter.report();
        assert!((r.compute_j - 15.0).abs() < 1e-9);
        assert!((r.codec_j - 1.5).abs() < 1e-9);
        assert!((r.network_j - 8e-5).abs() < 1e-12);
    }
}
