//! `defer` — DEFER launcher CLI.
//!
//! Subcommands:
//! * `run`      — run a DEFER chain (or the single-device baseline with
//!                `--nodes 1 --baseline`) and print the run report.
//! * `plan`     — print the placement planner's topology for a config
//!                without running it; with `--auto-partition` the joint
//!                repartition plan (chosen stage boundaries + replicas),
//!                from artifacts or from `--synthetic` stage costs.
//! * `sweep`    — Fig. 2-style sweep over node counts for one model.
//! * `codecs`   — Table I/II-style codec sweep.
//! * `info`     — show available artifacts and PJRT platform info.
//!
//! Examples:
//! ```text
//! defer run --model resnet50 --profile edge --nodes 8 --frames 32
//! defer run --model resnet50 --nodes 4 --tcp --link gigabit
//! defer run --nodes 4 --auto-place --workers-budget 6 --emulated-mflops 50
//! defer plan --nodes 4 --auto-place --workers-budget 6 --emulated-mflops 50
//! defer plan --auto-partition --synthetic 100,400,100 --workers-budget 5 \
//!            --emulated-mflops 100 --links wifi,gigabit
//! defer sweep --model vgg16 --parts 1,4,6,8 --frames 16
//! defer info
//! ```

use defer::bench::Table;
use defer::cli::Args;
use defer::config::DeferConfig;
use defer::coordinator::baseline::SingleDevice;
use defer::coordinator::chain::ChainRunner;
use defer::coordinator::RunReport;
use defer::error::Result;
use defer::runtime::Engine;
use defer::util::{fmt_bytes, fmt_duration};

const SWITCHES: &[&str] = &[
    "tcp",
    "baseline",
    "verbose",
    "help",
    "auto-place",
    "auto-partition",
    "inline-codec",
    "codec-measure",
    "relay-junctions",
    "batch-adaptive",
    "blocking-io",
    "recovery",
];

fn usage() -> &'static str {
    "defer — Distributed Edge Inference (COMSNETS 2022 reproduction)

USAGE:
  defer <run|plan|sweep|codecs|info> [options]

COMMON OPTIONS:
  --artifacts DIR          artifact root (default: artifacts)
  --profile tiny|edge|full scale profile (default: edge)
  --model NAME             resnet50|vgg16|vgg19 (default: resnet50)
  --config FILE            JSON config file (CLI flags override it)

RUN OPTIONS:
  --nodes N                pipeline stages (default: 4)
  --replicas R0,R1,...     worker replicas per stage, fed round-robin with
                           FIFO merge (default: 1 per stage)
  --frames N               inference cycles (default: 16)
  --baseline               single-device run (ignores --nodes)
  --tcp                    real TCP loopback sockets (ephemeral ports)
  --base-port P            fixed first TCP port instead of ephemeral binds
  --link ideal|gigabit|edge|wifi   uniform link for every hop
  --links L0,L1,...        per-hop links, N+1 entries (dispatcher uplink,
                           inter-stage hops, return link); one entry = all
  --auto-place             let the placement planner choose replicas and
                           per-hop links from stage FLOPs + boundary bytes
                           (--replicas is ignored; --links feeds the planner:
                           first entry pins the uplink, the rest are the
                           interconnect candidates. Needs a device model via
                           --device-profile or --emulated-mflops)
  --auto-partition         plan the stage *boundaries* too: fuse the finest-
                           granularity artifact set into balanced stages,
                           jointly with replica placement (--nodes stops
                           mattering; --links lists uplink + interconnect
                           candidates. Needs a device model like --auto-place)
  --workers-budget N       max worker replicas auto-place may use
                           (default: device-profile size, else --nodes)
  --device-memory BYTES    max resident weight bytes per worker; bounds how
                           much of the model --auto-partition fuses into one
                           stage (0 = unlimited, favors few wide stages)
  --device-profile FILE    device pool JSON for auto-place:
                           {\"devices\": [{\"name\": \"jetson\", \"mflops\": 200}]}
  --pipe-depth N           chain backpressure window (default: 4)
  --codec-threads N        chunk-parallel codec: split data payloads into
                           block-aligned chunks encoded/decoded on N shared
                           worker threads (0 = legacy single-buffer codec)
  --codec-chunk-elems N    f32 values per codec chunk (default 131072 =
                           512 KiB raw; must be a multiple of 4)
  --codec-kernel K         ZFP kernel: batched (default, lane-parallel)
                           or scalar (reference A/B fallback); both emit
                           byte-identical wire streams
  --inline-codec           disable codec/compute software pipelining (run
                           the paper's decode+compute+encode inline loop)
  --codec-gbps R           planner codec rate in GB/s of raw activation
                           bytes (0 = charge no codec time; default: the
                           built-in per-codec calibration table)
  --codec-measure          calibrate the planner codec rate with a live
                           micro-benchmark instead of the built-in table
  --relay-junctions        legacy data plane: route replicated stage
                           boundaries through coordinator-side relay
                           threads (and price the extra relay hop in the
                           planners) instead of worker-owned deal/merge
  --batch B                coalesce up to B input frames into one batched
                           wire message end-to-end (default: 1 = unbatched,
                           byte-identical legacy wire format)
  --batch-latency-ms T     latency budget for filling a batch; the planner
                           rejects batch sizes whose extra wait exceeds T
                           (0 = unbounded)
  --batch-adaptive         size each batch to the dispatcher's live send
                           queue depth (up to --batch) instead of always
                           filling to the cap
  --batch-overhead-us U    per-frame fixed overhead at B=1 for the planner's
                           batch pricing, amortized as U/B (0 = batching
                           not priced, planner keeps B=1)
  --io-threads N           reactor I/O shards for the data plane (default:
                           0 = auto, min(2, cores))
  --blocking-io            legacy data plane: one parked thread per mesh
                           connection instead of the sharded reactor
  --recovery               self-healing data plane: replica death degrades
                           the mesh and lost frames are re-dispatched;
                           corrupt chunks are repaired by NACK/retry
  --recovery-window N      max unacknowledged dispatched messages (default 8)
  --fault SPEC[;SPEC...]   deterministic fault schedule (implies --recovery):
                           kill:NODE@frame=N | truncate:NODE@frame=N |
                           corrupt-chunk:p=P[,seed=S]
                           e.g. --fault \"kill:node1.1@frame=40\"
  --emulated-mflops R      deterministic edge-device emulation: floor each
                           stage's compute to stage_flops/R us (0 = off)
  --slowdown F             legacy multiplicative compute emulation (>=1)
  --tdp W                  node TDP for the energy model (default: 15)
  --data-serialization json|zfp[:RATE]|binary
  --data-compression  none|lz4
  --weights-serialization / --weights-compression  (same values)

PLAN OPTIONS (with --auto-partition):
  --synthetic M0,M1,...    plan from synthetic per-partition MFLOPs instead
                           of artifacts (no artifact read at all)
  --synthetic-bytes B0,..,BN  boundary activation bytes, one more entry than
                           partitions (model input, inner boundaries, model
                           output; default 4096 each)
  --synthetic-weights W0,W1,...  per-partition weight bytes (default 0 each;
                           pair with --device-memory to force multi-stage)

SWEEP OPTIONS:
  --parts 1,4,6,8          node counts to sweep
"
}

fn load_config(args: &Args) -> Result<DeferConfig> {
    let base = match args.get("config") {
        Some(path) => DeferConfig::from_file(std::path::Path::new(path))?,
        None => DeferConfig::default(),
    };
    base.apply_args(args)
}

fn print_report(r: &RunReport) {
    println!("== {} / {} / {} node(s) ==", r.model, r.profile, r.nodes);
    if r.workers != r.nodes {
        println!("  workers:           {} ({} stages, replicated)", r.workers, r.nodes);
    }
    println!("  cycles:            {}", r.cycles);
    println!("  elapsed:           {}", fmt_duration(r.elapsed));
    println!("  throughput:        {:.4} cycles/s", r.throughput);
    println!(
        "  latency mean/p50/p99: {} / {} / {}",
        fmt_duration(r.latency_mean),
        fmt_duration(r.latency_p50),
        fmt_duration(r.latency_p99)
    );
    println!("  config time:       {}", fmt_duration(r.config_time));
    println!(
        "  payload (arch/weights/data): {} / {} / {}",
        fmt_bytes(r.architecture_bytes),
        fmt_bytes(r.weights_bytes),
        fmt_bytes(r.data_bytes)
    );
    println!(
        "  overhead (config/data): {} / {}",
        fmt_duration(r.config_overhead),
        fmt_duration(r.data_overhead)
    );
    println!(
        "  energy/node/cycle: {:.6} J",
        r.energy_per_node_per_cycle()
    );
    if r.queue_high_water > 0 {
        println!("  send queue high water: {}", r.queue_high_water);
    }
    if r.data_plane_threads > 0 {
        println!("  data-plane threads: {}", r.data_plane_threads);
    }
    if !r.io_shards.is_empty() {
        let shards: Vec<String> = r
            .io_shards
            .iter()
            .map(|(w, d)| format!("{w}w/{d}d"))
            .collect();
        println!("  io shards (wakeups/dispatches): {}", shards.join(", "));
    }
    if r.zerocopy != defer::metrics::zerocopy::Snapshot::default() {
        println!(
            "  zero-copy: {} payload copies, {} egress syscalls, \
             pool {} hit(s) / {} miss(es)",
            r.zerocopy.payload_copies,
            r.zerocopy.egress_syscalls,
            r.zerocopy.pool_hits,
            r.zerocopy.pool_misses
        );
    }
    if r.replicas_lost > 0 || r.frames_redispatched > 0 || r.chunks_retried > 0 {
        println!(
            "  recovery: {} replica(s) lost, {} frame(s) re-dispatched, \
             {} chunk(s) retried",
            r.replicas_lost, r.frames_redispatched, r.chunks_retried
        );
    }
    if let Some(err) = r.reference_error {
        println!("  max |err| vs python reference: {err:.3e}");
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let frames = args.get_usize("frames", 16)? as u64;
    let report = if args.has("baseline") {
        SingleDevice::new(cfg)?.run_frames(frames)?
    } else {
        let runner = ChainRunner::new(cfg)?;
        // Surface what the planner decided (the runner deploys exactly
        // this topology — planning happened once, at construction).
        if let Some(render) = runner.plan_render() {
            print!("{render}");
        }
        runner.run_frames(frames)?
    };
    print_report(&report);
    Ok(())
}

/// Parse the `--synthetic*` flags into repartition partition costs.
fn synthetic_parts(args: &Args) -> Result<Option<Vec<defer::repartition::PartCost>>> {
    use defer::error::DeferError;
    let mflops = match args.get_list("synthetic") {
        None => return Ok(None),
        Some(items) => items
            .iter()
            .map(|s| {
                let m = s.parse::<f64>().map_err(|_| {
                    DeferError::Cli(format!("--synthetic: bad MFLOP count {s:?}"))
                })?;
                // A finite, positive cost only — `-100`, `nan` or `inf`
                // would otherwise saturate the u64 cast into a silent
                // zero-cost partition.
                if !(m > 0.0 && m.is_finite()) {
                    return Err(DeferError::Cli(format!(
                        "--synthetic: MFLOP count must be a positive finite number, got {s:?}"
                    )));
                }
                Ok(m)
            })
            .collect::<Result<Vec<f64>>>()?,
    };
    let n = mflops.len();
    let bytes = args.get_usize_list("synthetic-bytes", &vec![4096; n + 1])?;
    if bytes.len() != n + 1 {
        return Err(DeferError::Cli(format!(
            "--synthetic-bytes wants {} entries for {n} partitions (model input, \
             inner boundaries, model output), got {}",
            n + 1,
            bytes.len()
        )));
    }
    let weights = args.get_usize_list("synthetic-weights", &vec![0; n])?;
    if weights.len() != n {
        return Err(DeferError::Cli(format!(
            "--synthetic-weights wants {n} entries, got {}",
            weights.len()
        )));
    }
    Ok(Some(
        (0..n)
            .map(|i| defer::repartition::PartCost {
                flops: (mflops[i] * 1e6) as u64,
                input_bytes: bytes[i] as u64,
                output_bytes: bytes[i + 1] as u64,
                weights_bytes: weights[i] as u64,
            })
            .collect(),
    ))
}

fn cmd_plan(args: &Args) -> Result<()> {
    use defer::model::PartitionPlan;
    use defer::placement;
    use defer::repartition;
    let cfg = load_config(args)?;
    if cfg.auto_partition {
        let problem = match synthetic_parts(args)? {
            Some(parts) => repartition::RepartitionProblem::from_parts(&cfg, parts)?,
            None => {
                let finest = defer::model::finest_part_count(
                    &cfg.artifacts_dir,
                    &cfg.profile,
                    &cfg.model,
                )?;
                let plan = PartitionPlan::load(
                    &cfg.artifacts_dir,
                    &cfg.profile,
                    &cfg.model,
                    finest,
                )?;
                repartition::RepartitionProblem::from_config(&cfg, &plan)?
            }
        };
        print!("{}", repartition::plan(&problem)?.render());
        println!("(rerun as `defer run --auto-partition` with the same flags to deploy it)");
        return Ok(());
    }
    let plan = PartitionPlan::load(&cfg.artifacts_dir, &cfg.profile, &cfg.model, cfg.nodes)?;
    let problem = placement::PlacementProblem::from_config(&cfg, &plan)?;
    let placed = placement::plan(&problem)?;
    print!("{}", placed.render());
    println!("(rerun as `defer run --auto-place` with the same flags to deploy it)");
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let frames = args.get_usize("frames", 16)? as u64;
    let parts = args.get_usize_list("parts", &[1, 4, 6, 8])?;
    let engine = Engine::cpu()?;
    let mut table = Table::new(&[
        "model",
        "nodes",
        "throughput (cycles/s)",
        "energy/node/cycle (J)",
        "p50 latency",
    ]);
    for n in parts {
        let mut c = cfg.clone();
        c.nodes = n.max(1);
        let report = if n <= 1 {
            SingleDevice::with_engine(c, engine.clone())?.run_frames(frames)?
        } else {
            ChainRunner::with_engine(c, engine.clone())?.run_frames(frames)?
        };
        table.row(&[
            report.model.clone(),
            if n <= 1 { "1 (single)".into() } else { n.to_string() },
            format!("{:.4}", report.throughput),
            format!("{:.6}", report.energy_per_node_per_cycle()),
            fmt_duration(report.latency_p50),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}

fn cmd_codecs(args: &Args) -> Result<()> {
    use defer::serial::Codec;
    let cfg = load_config(args)?;
    let frames = args.get_usize("frames", 8)? as u64;
    let engine = Engine::cpu()?;
    let mut table = Table::new(&[
        "serialization",
        "compression",
        "throughput (cycles/s)",
        "data payload",
        "data overhead",
    ]);
    for codec in Codec::paper_sweep() {
        let mut c = cfg.clone();
        c.codecs.data = codec;
        c.codecs.weights = codec;
        let report = ChainRunner::with_engine(c, engine.clone())?.run_frames(frames)?;
        table.row(&[
            codec.serialization.name().to_string(),
            codec.compression.name().to_string(),
            format!("{:.4}", report.throughput),
            fmt_bytes(report.data_bytes),
            fmt_duration(report.data_overhead),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let engine = Engine::cpu()?;
    println!(
        "PJRT platform: {} ({} device(s))",
        engine.platform(),
        engine.device_count()
    );
    for profile in ["tiny", "edge", "full"] {
        match defer::model::available_configs(&cfg.artifacts_dir, profile) {
            Ok(configs) if !configs.is_empty() => {
                println!("profile {profile}:");
                for (model, n) in configs {
                    println!("  {model} x {n} partitions");
                }
            }
            _ => println!("profile {profile}: (not built)"),
        }
    }
    Ok(())
}

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&raw, SWITCHES) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", usage());
            std::process::exit(2);
        }
    };
    if args.has("help") || args.command.is_none() {
        print!("{}", usage());
        return;
    }
    let result = match args.command.as_deref() {
        Some("run") => cmd_run(&args),
        Some("plan") => cmd_plan(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("codecs") => cmd_codecs(&args),
        Some("info") => cmd_info(&args),
        Some(other) => {
            eprintln!("unknown command {other:?}\n\n{}", usage());
            std::process::exit(2);
        }
        None => unreachable!(),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
