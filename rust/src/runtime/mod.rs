//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute on the
//! request path.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin). The interchange format
//! is HLO *text* — jax >= 0.5 serialized protos use 64-bit instruction ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! One [`Engine`] per process; one [`Executable`] per model partition. The
//! partition functions were lowered as `fn(x, *weights) -> (y,)`
//! (`return_tuple=True`), so execution passes the input activation followed
//! by every weight literal in manifest order and unwraps a 1-tuple.

pub mod engine;
pub mod recovery;

pub use engine::{Engine, Executable};
