//! Failure-recovery supervisor: replica death detection, frame
//! re-dispatch bookkeeping, and chunk-level retry plumbing.
//!
//! DEFER replicates each partition across `u` nodes and deals frames
//! round-robin (`f mod u`), so losing one replica loses a deterministic,
//! reconstructible subset of in-flight frames. This module turns the
//! data plane's dead-peer signals (EOF / ECONNRESET, labelled per conn
//! since PR 7) into recovery instead of abort:
//!
//! * **[`RecoverySupervisor`]** — shared run-wide state. Deal/merge
//!   endpoints report dead peers ([`RecoverySupervisor::mark_dead`]);
//!   senders report actual routing ([`RecoverySupervisor::note_routed`])
//!   so the lost set is *exact* (routing under degraded rotations is no
//!   longer pure `f mod u` math); the dispatcher tracks per-message
//!   completion and drains the re-dispatch queue. A bounded in-flight
//!   window ([`RecoverySupervisor::acquire_slot`]) keeps the number of
//!   unacknowledged frames small so a re-send burst is bounded too.
//! * **[`RetentionRing`] + [`spawn_nack_responder`]** — the sender side
//!   of chunk retry: each node retains its last few outbound DFCK
//!   containers and answers `ChunkNack` control frames with the exact
//!   chunk span re-sent as `ChunkRetry`.
//! * **[`ChunkRetryClient`] + [`decode_with_retry`]** — the receiver
//!   side: a CRC-failed chunk (detected as
//!   [`DeferError::CorruptChunk`]) is NACKed back to the upstream that
//!   produced the frame, the span is patched in place, and decode is
//!   retried within [`CHUNK_RETRY_BUDGET`]; exhaustion escalates to
//!   whole-frame re-dispatch.
//!
//! Frame identity makes ordering survivable: every message carries its
//! first frame id and batch, so degraded merges deliver arrival order
//! with dedup by frame id, and re-dispatched messages are byte-identical
//! re-encodes of the originals (same `(first_frame, batch)` grouping).

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::transport::Conn;
use crate::error::{DeferError, Result};
use crate::metrics::ByteCounter;
use crate::netem::{FaultPlan, Link};
use crate::serial::chunked::chunk_payload_span;
use crate::threadpool::WorkerPool;
use crate::wire::{chunk_nack, chunk_retry, parse_chunk_control, MessageType, SharedPayload};

/// Re-decodes attempted per corrupt frame before escalating to frame
/// re-dispatch.
pub const CHUNK_RETRY_BUDGET: u32 = 3;

/// Default bounded in-flight window (dispatched, unacknowledged
/// messages) when recovery is enabled.
pub const DEFAULT_WINDOW: usize = 8;

/// How long `acquire_slot`/`wait_progress` may park with zero progress
/// before declaring the run wedged.
const STALL_TIMEOUT: Duration = Duration::from_secs(30);

#[derive(Default)]
struct SupervisorState {
    /// Labels of peers known dead (e.g. `node1.1 data socket`).
    dead: HashSet<String>,
    /// Actual routing: conn label -> messages sent on it, as
    /// `(first_frame, batch)`. Exact, not schedule-reconstructed.
    routed: HashMap<String, Vec<(u64, u32)>>,
    /// Messages the dispatcher has sent and not yet seen complete.
    sent: HashMap<u64, u32>,
    /// First-frame ids of completed messages (dedup for duplicates).
    completed: HashSet<u64>,
    /// Messages awaiting re-dispatch.
    redispatch: VecDeque<(u64, u32)>,
}

/// Run-wide recovery state shared by the dispatcher, every deal/merge
/// endpoint, and both I/O planes. All methods are `&self`; one `Arc` is
/// threaded through the wiring.
pub struct RecoverySupervisor {
    state: Mutex<SupervisorState>,
    progress: Condvar,
    /// Bumped on every death — cheap "did the topology change?" probe
    /// for loops that must not take the lock per frame.
    death_epoch: AtomicU64,
    /// Readiness callbacks (reactor shard signals) fired on death so
    /// parked machines re-poll their conn sets.
    wakers: Mutex<Vec<Arc<dyn Fn() + Send + Sync>>>,
    window: usize,
    faults: FaultPlan,
    /// Monotonic progress counter: completions, deaths, and escalations
    /// bump it. Recovery loops snapshot it to enforce stall timeouts.
    probe: AtomicU64,
    frames_redispatched: AtomicU64,
    chunks_retried: AtomicU64,
    replicas_lost: AtomicU64,
}

impl RecoverySupervisor {
    pub fn new(window: usize, faults: FaultPlan) -> Arc<RecoverySupervisor> {
        Arc::new(RecoverySupervisor {
            state: Mutex::new(SupervisorState::default()),
            progress: Condvar::new(),
            death_epoch: AtomicU64::new(0),
            wakers: Mutex::new(Vec::new()),
            window: window.max(1),
            faults,
            probe: AtomicU64::new(0),
            frames_redispatched: AtomicU64::new(0),
            chunks_retried: AtomicU64::new(0),
            replicas_lost: AtomicU64::new(0),
        })
    }

    /// The fault schedule for this run (empty when only recovery — not
    /// injection — is enabled).
    pub fn faults(&self) -> &FaultPlan {
        &self.faults
    }

    /// Bumped on every `mark_dead`; loops compare against a cached value
    /// to notice topology changes without locking.
    pub fn death_epoch(&self) -> u64 {
        self.death_epoch.load(Ordering::Acquire)
    }

    pub fn is_dead(&self, label: &str) -> bool {
        self.state.lock().unwrap().dead.contains(label)
    }

    /// Report a dead peer. Everything routed to it and not yet completed
    /// moves to the re-dispatch queue; registered wakers fire so parked
    /// reactor machines re-examine their conn sets. Idempotent per label.
    pub fn mark_dead(&self, label: &str) {
        {
            let mut st = self.state.lock().unwrap();
            if !st.dead.insert(label.to_string()) {
                return;
            }
            let lost: Vec<(u64, u32)> = st
                .routed
                .get(label)
                .map(|v| {
                    v.iter()
                        .filter(|(f, _)| !st.completed.contains(f))
                        .copied()
                        .collect()
                })
                .unwrap_or_default();
            for lf in lost {
                if !st.redispatch.contains(&lf) {
                    st.redispatch.push_back(lf);
                }
            }
            self.replicas_lost.fetch_add(1, Ordering::Relaxed);
            self.death_epoch.fetch_add(1, Ordering::Release);
            self.probe.fetch_add(1, Ordering::Relaxed);
            self.progress.notify_all();
        }
        let wakers: Vec<_> = self.wakers.lock().unwrap().clone();
        for w in wakers {
            w();
        }
    }

    /// Register a readiness callback fired (outside the lock) whenever a
    /// peer dies — the reactor shards hang their signal queues here.
    pub fn register_waker(&self, w: Arc<dyn Fn() + Send + Sync>) {
        self.wakers.lock().unwrap().push(w);
    }

    /// Dispatcher: record a dispatched message awaiting completion.
    pub fn note_sent(&self, frame: u64, batch: u32) {
        self.state.lock().unwrap().sent.insert(frame, batch);
    }

    /// Deal layer: record which conn actually carried a message, so a
    /// later death of that conn's peer re-dispatches exactly these.
    ///
    /// A send can succeed into a peer's kernel buffer in the instant
    /// after another endpoint reported that peer dead (TCP accepts
    /// writes to a half-closed socket); such a message was not in the
    /// routed set `mark_dead` drained, so it is queued for re-dispatch
    /// here instead of leaking.
    pub fn note_routed(&self, label: &str, frame: u64, batch: u32) {
        let mut st = self.state.lock().unwrap();
        if st.dead.contains(label) {
            if !st.completed.contains(&frame) && !st.redispatch.contains(&(frame, batch)) {
                st.redispatch.push_back((frame, batch));
                self.probe.fetch_add(1, Ordering::Relaxed);
                self.progress.notify_all();
            }
            return;
        }
        st.routed
            .entry(label.to_string())
            .or_default()
            .push((frame, batch));
    }

    /// Dispatcher result path: mark a message complete. Returns true when
    /// newly completed (false = duplicate delivery, ignore it).
    pub fn mark_frame_done(&self, frame: u64) -> bool {
        let mut st = self.state.lock().unwrap();
        let fresh = st.completed.insert(frame);
        if fresh {
            self.probe.fetch_add(1, Ordering::Relaxed);
            self.progress.notify_all();
        }
        fresh
    }

    /// Monotonic progress counter (completions, deaths, escalations).
    /// Recovery loops compare snapshots to enforce a stall timeout.
    pub fn progress_probe(&self) -> u64 {
        self.probe.load(Ordering::Relaxed)
    }

    pub fn is_frame_done(&self, frame: u64) -> bool {
        self.state.lock().unwrap().completed.contains(&frame)
    }

    /// Chunk retry exhausted (or the frame is otherwise unrecoverable in
    /// place): queue the whole message for re-dispatch.
    pub fn escalate_frame(&self, frame: u64, batch: u32) {
        let mut st = self.state.lock().unwrap();
        if !st.completed.contains(&frame) && !st.redispatch.contains(&(frame, batch)) {
            st.redispatch.push_back((frame, batch));
            self.probe.fetch_add(1, Ordering::Relaxed);
            self.progress.notify_all();
        }
    }

    /// Pop the next message needing re-dispatch, skipping any that
    /// completed while queued.
    pub fn take_redispatch(&self) -> Option<(u64, u32)> {
        let mut st = self.state.lock().unwrap();
        while let Some((f, b)) = st.redispatch.pop_front() {
            if !st.completed.contains(&f) {
                return Some((f, b));
            }
        }
        None
    }

    /// True once every `note_sent` message has completed.
    pub fn all_complete(&self) -> bool {
        let st = self.state.lock().unwrap();
        st.sent.keys().all(|f| st.completed.contains(f)) && st.redispatch.is_empty()
    }

    /// Bounded in-flight window: block until fewer than `window`
    /// dispatched messages are unacknowledged. Errors if nothing makes
    /// progress for [`STALL_TIMEOUT`] (a wedged run must not hang the
    /// process forever).
    pub fn acquire_slot(&self) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        let mut last_progress = Instant::now();
        loop {
            let in_flight = st
                .sent
                .keys()
                .filter(|f| !st.completed.contains(f))
                .count();
            if in_flight < self.window {
                return Ok(());
            }
            let (next, res) = self
                .progress
                .wait_timeout(st, Duration::from_millis(200))
                .unwrap();
            st = next;
            if !res.timed_out() {
                last_progress = Instant::now();
            } else if last_progress.elapsed() > STALL_TIMEOUT {
                return Err(DeferError::Coordinator(format!(
                    "recovery window stalled: {} messages unacknowledged for {:?}",
                    self.window, STALL_TIMEOUT
                )));
            }
        }
    }

    /// Dispatcher recovery loop: park until there is a message to
    /// re-dispatch, everything completed, or `timeout` elapsed.
    pub fn wait_progress(&self, timeout: Duration) {
        let st = self.state.lock().unwrap();
        if !st.redispatch.is_empty() || st.sent.keys().all(|f| st.completed.contains(f)) {
            return;
        }
        let _ = self.progress.wait_timeout(st, timeout).unwrap();
    }

    pub fn count_frame_redispatched(&self, frames: u64) {
        self.frames_redispatched.fetch_add(frames, Ordering::Relaxed);
    }

    pub fn count_chunk_retried(&self) {
        self.chunks_retried.fetch_add(1, Ordering::Relaxed);
    }

    pub fn frames_redispatched(&self) -> u64 {
        self.frames_redispatched.load(Ordering::Relaxed)
    }

    pub fn chunks_retried(&self) -> u64 {
        self.chunks_retried.load(Ordering::Relaxed)
    }

    pub fn replicas_lost(&self) -> u64 {
        self.replicas_lost.load(Ordering::Relaxed)
    }
}

// -------------------------------------------------------- Chunk retry

/// Sender-side retention: the last `cap` outbound DFCK containers of one
/// node, keyed by first frame id. The NACK responders cut chunk spans
/// out of these to answer retries.
pub struct RetentionRing {
    /// Payloads are [`SharedPayload`]s: the zero-copy send path retains
    /// another reference to the encoder's pooled buffer instead of a
    /// clone, so retention costs refcounts, not memcpys.
    inner: Mutex<VecDeque<(u64, SharedPayload)>>,
    cap: usize,
}

impl RetentionRing {
    pub fn new(cap: usize) -> Arc<RetentionRing> {
        Arc::new(RetentionRing {
            inner: Mutex::new(VecDeque::new()),
            cap: cap.max(1),
        })
    }

    /// Retain a just-sent container (evicting the oldest beyond `cap`).
    pub fn push(&self, frame: u64, payload: SharedPayload) {
        let mut q = self.inner.lock().unwrap();
        q.push_back((frame, payload));
        while q.len() > self.cap {
            q.pop_front();
        }
    }

    /// The wire bytes of chunk `idx` of the retained container for
    /// `frame`, if still retained.
    pub fn chunk(&self, frame: u64, idx: u32) -> Option<Vec<u8>> {
        let q = self.inner.lock().unwrap();
        let (_, payload) = q.iter().rev().find(|(f, _)| *f == frame)?;
        let payload = payload.as_slice();
        let span = chunk_payload_span(payload, idx as usize).ok()?;
        Some(payload[span].to_vec())
    }
}

/// Spawn the sender-side half of chunk retry: a thread that serves
/// `ChunkNack` requests arriving on `conn` from retained containers,
/// exiting cleanly when the control conn closes (run teardown).
pub fn spawn_nack_responder(
    pool: &mut WorkerPool,
    name: &str,
    mut conn: Conn,
    ring: Arc<RetentionRing>,
) {
    let counter = ByteCounter::new();
    let link = Link::ideal();
    pool.spawn(name, move || {
        loop {
            let req = match conn.recv(&counter) {
                Ok(m) => m,
                // Control conn closed: the run is tearing down (or the
                // requester died) — either way this responder is done.
                Err(_) => return Ok(()),
            };
            if req.msg_type != MessageType::ChunkNack {
                continue;
            }
            let Ok((idx, _)) = parse_chunk_control(&req) else {
                continue;
            };
            let reply = match ring.chunk(req.frame, idx) {
                Some(bytes) => chunk_retry(req.frame, idx, &bytes),
                // Evicted or unknown: empty retry — the requester treats
                // a length mismatch as escalation to frame re-dispatch.
                None => chunk_retry(req.frame, idx, &[]),
            };
            if conn.send(&reply, &link, &counter).is_err() {
                return Ok(());
            }
        }
    });
}

/// Receiver-side half of chunk retry: one per consuming endpoint,
/// holding a control conn per upstream producer plus the provenance map
/// saying which upstream produced each frame.
pub struct ChunkRetryClient {
    conns: Mutex<HashMap<String, Conn>>,
    provenance: Mutex<HashMap<u64, String>>,
    supervisor: Arc<RecoverySupervisor>,
}

impl ChunkRetryClient {
    pub fn new(supervisor: Arc<RecoverySupervisor>) -> Arc<ChunkRetryClient> {
        Arc::new(ChunkRetryClient {
            conns: Mutex::new(HashMap::new()),
            provenance: Mutex::new(HashMap::new()),
            supervisor,
        })
    }

    pub fn supervisor(&self) -> &Arc<RecoverySupervisor> {
        &self.supervisor
    }

    /// Wiring: register the control conn to upstream `label`.
    pub fn add_upstream(&self, label: &str, conn: Conn) {
        self.conns.lock().unwrap().insert(label.to_string(), conn);
    }

    /// Merge/ingress: remember which upstream produced `frame`, so a
    /// later NACK goes to the right producer.
    pub fn note_provenance(&self, frame: u64, label: &str) {
        self.provenance
            .lock()
            .unwrap()
            .insert(frame, label.to_string());
    }

    /// NACK chunk `idx` of `frame` to its producer and return the
    /// re-sent span bytes (empty when the producer no longer retains it).
    pub fn request_chunk(&self, frame: u64, idx: u32) -> Result<Vec<u8>> {
        let label = self
            .provenance
            .lock()
            .unwrap()
            .get(&frame)
            .cloned()
            .ok_or_else(|| {
                DeferError::Coordinator(format!("no provenance for frame {frame}"))
            })?;
        let mut conns = self.conns.lock().unwrap();
        let conn = conns.get_mut(&label).ok_or_else(|| {
            DeferError::Coordinator(format!("no control conn to {label}"))
        })?;
        let counter = ByteCounter::new();
        conn.send_frame(chunk_nack(frame, idx), &Link::ideal(), &counter)?;
        let reply = conn.recv(&counter)?;
        if reply.msg_type != MessageType::ChunkRetry || reply.frame != frame {
            return Err(DeferError::Wire(format!(
                "unexpected chunk retry reply: {:?} frame {}",
                reply.msg_type, reply.frame
            )));
        }
        let (got_idx, bytes) = parse_chunk_control(&reply)?;
        if got_idx != idx {
            return Err(DeferError::Wire(format!(
                "chunk retry answered index {got_idx}, wanted {idx}"
            )));
        }
        Ok(bytes.to_vec())
    }
}

/// Decode a DFCK container with chunk-level retry: a
/// [`DeferError::CorruptChunk`] NACKs exactly that chunk to the frame's
/// producer, patches the span in place, and re-decodes, up to
/// [`CHUNK_RETRY_BUDGET`] times. Exhaustion (or a missing client /
/// unpatchable span) returns the corrupt-chunk error for the caller to
/// escalate to frame re-dispatch.
pub fn decode_with_retry<T>(
    client: Option<&ChunkRetryClient>,
    frame: u64,
    payload: &mut Vec<u8>,
    decode: impl Fn(&[u8]) -> Result<T>,
) -> Result<T> {
    let mut budget = CHUNK_RETRY_BUDGET;
    loop {
        let err = match decode(payload) {
            Ok(v) => return Ok(v),
            Err(e) => e,
        };
        let (Some(client), DeferError::CorruptChunk { chunk, .. }) = (client, &err) else {
            return Err(err);
        };
        if budget == 0 {
            return Err(err);
        }
        budget -= 1;
        let span = chunk_payload_span(payload, *chunk)?;
        let fresh = client.request_chunk(frame, *chunk as u32)?;
        if fresh.len() != span.len() {
            // Producer no longer retains the container (or disagrees on
            // geometry): unpatchable, escalate.
            return Err(err);
        }
        payload[span].copy_from_slice(&fresh);
        client.supervisor().count_chunk_retried();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn death_moves_uncompleted_routed_frames_to_redispatch() {
        let sup = RecoverySupervisor::new(8, FaultPlan::default());
        for f in 0..6u64 {
            sup.note_sent(f, 1);
        }
        // Frames 0,2,4 went to node1.0; 1,3,5 to node1.1.
        for f in [0u64, 2, 4] {
            sup.note_routed("node1.0 data socket", f, 1);
        }
        for f in [1u64, 3, 5] {
            sup.note_routed("node1.1 data socket", f, 1);
        }
        assert!(sup.mark_frame_done(1));
        assert!(!sup.mark_frame_done(1), "duplicate completion detected");

        sup.mark_dead("node1.1 data socket");
        assert!(sup.is_dead("node1.1 data socket"));
        assert_eq!(sup.death_epoch(), 1);
        assert_eq!(sup.replicas_lost(), 1);

        // Only the *uncompleted* frames routed to the dead peer queue up.
        let mut lost = Vec::new();
        while let Some(fb) = sup.take_redispatch() {
            lost.push(fb);
        }
        assert_eq!(lost, vec![(3, 1), (5, 1)]);
    }

    #[test]
    fn routing_to_an_already_dead_peer_queues_redispatch() {
        // The send raced mark_dead: the liveness check passed, the write
        // landed in a doomed kernel buffer, and the routing report came
        // in after the dead peer's owed frames were drained. The report
        // itself must queue the frame or it leaks (run stalls).
        let sup = RecoverySupervisor::new(8, FaultPlan::default());
        sup.note_sent(4, 1);
        sup.mark_dead("node1.0 data socket");
        sup.note_routed("node1.0 data socket", 4, 1);
        assert_eq!(sup.take_redispatch(), Some((4, 1)));
        // Completed frames are not resurrected.
        sup.mark_frame_done(4);
        sup.note_routed("node1.0 data socket", 4, 1);
        assert_eq!(sup.take_redispatch(), None);
    }

    #[test]
    fn mark_dead_is_idempotent_and_fires_wakers() {
        let sup = RecoverySupervisor::new(8, FaultPlan::default());
        let hits = Arc::new(AtomicU64::new(0));
        let h = Arc::clone(&hits);
        sup.register_waker(Arc::new(move || {
            h.fetch_add(1, Ordering::SeqCst);
        }));
        sup.mark_dead("node2.0 data socket");
        sup.mark_dead("node2.0 data socket");
        assert_eq!(sup.death_epoch(), 1);
        assert_eq!(sup.replicas_lost(), 1);
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn escalation_skips_completed_frames() {
        let sup = RecoverySupervisor::new(8, FaultPlan::default());
        sup.note_sent(7, 2);
        sup.escalate_frame(7, 2);
        sup.escalate_frame(7, 2); // dedup
        assert!(!sup.all_complete());
        assert_eq!(sup.take_redispatch(), Some((7, 2)));
        assert_eq!(sup.take_redispatch(), None);
        // Completed while queued: take skips it.
        sup.escalate_frame(7, 2);
        sup.mark_frame_done(7);
        assert_eq!(sup.take_redispatch(), None);
        assert!(sup.all_complete());
    }

    #[test]
    fn window_blocks_until_completion() {
        let sup = RecoverySupervisor::new(2, FaultPlan::default());
        sup.note_sent(0, 1);
        sup.note_sent(1, 1);
        // Window full: a slot frees once a frame completes.
        let s2 = Arc::clone(&sup);
        let h = std::thread::spawn(move || s2.acquire_slot());
        std::thread::sleep(Duration::from_millis(30));
        sup.mark_frame_done(0);
        h.join().unwrap().unwrap();
    }

    /// A lossless chunked codec + container for `data`, returning
    /// `(runtime, wire bytes, serialized_len)`.
    fn container(
        data: &[f32],
        chunk_elems: usize,
    ) -> (crate::serial::Codec, crate::serial::CodecRuntime, Vec<u8>, usize) {
        let codec = crate::serial::Codec::new(
            crate::serial::Serialization::Binary,
            crate::compress::Compression::None,
        );
        let rt = crate::serial::CodecRuntime::chunked(chunk_elems, None).unwrap();
        let (wire, serialized_len) = codec.encode_frame(data, &rt, None);
        (codec, rt, wire, serialized_len)
    }

    #[test]
    fn retention_ring_serves_and_evicts() {
        // Build a real container so chunk spans are meaningful.
        let data: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let (_, _, wire, _) = container(&data, 256);
        let ring = RetentionRing::new(2);
        ring.push(10, SharedPayload::from_vec(wire.clone(), None));
        let span = chunk_payload_span(&wire, 1).unwrap();
        assert_eq!(ring.chunk(10, 1).unwrap(), wire[span].to_vec());
        assert!(ring.chunk(11, 0).is_none());
        // Eviction beyond capacity drops the oldest.
        ring.push(11, SharedPayload::from_vec(wire.clone(), None));
        ring.push(12, SharedPayload::from_vec(wire, None));
        assert!(ring.chunk(10, 0).is_none());
        assert!(ring.chunk(12, 0).is_some());
    }

    #[test]
    fn nack_responder_round_trip_and_decode_retry() {
        // A full receiver-side retry: corrupt one chunk byte, decode via
        // decode_with_retry against a live responder, expect the
        // original data and one counted retry.
        let data: Vec<f32> = (0..5000).map(|i| (i % 71) as f32).collect();
        let (codec, rt, wire, serialized_len) = container(&data, 1024);

        let sup = RecoverySupervisor::new(8, FaultPlan::default());
        let ring = RetentionRing::new(4);
        ring.push(3, SharedPayload::from_vec(wire.clone(), None));
        let (resp_conn, client_conn) = Conn::local_pair(4);
        let mut pool = WorkerPool::new();
        spawn_nack_responder(&mut pool, "nack-responder", resp_conn, Arc::clone(&ring));

        let client = ChunkRetryClient::new(Arc::clone(&sup));
        client.add_upstream("node0 data socket", client_conn);
        client.note_provenance(3, "node0 data socket");

        let mut corrupted = wire.clone();
        let span = chunk_payload_span(&wire, 2).unwrap();
        // Flip a byte inside chunk 2's body (past its per-chunk header).
        corrupted[span.start + 12 + 5] ^= 0xA5;
        assert!(codec
            .decode_frame(&corrupted, serialized_len, data.len(), &rt, None)
            .is_err());

        let decoded = decode_with_retry(Some(&client), 3, &mut corrupted, |bytes| {
            codec.decode_frame(bytes, serialized_len, data.len(), &rt, None)
        })
        .unwrap();
        assert_eq!(decoded, data);
        assert_eq!(sup.chunks_retried(), 1);
        assert_eq!(corrupted, wire, "patched container is byte-identical");

        drop(client); // closes the control conn; responder exits
        pool.join().unwrap();
    }

    #[test]
    fn decode_retry_budget_escalates() {
        // A responder that always re-sends the same corrupt span: the
        // client must give up after CHUNK_RETRY_BUDGET attempts.
        let data: Vec<f32> = (0..2000).map(|i| i as f32).collect();
        let (codec, rt, wire, serialized_len) = container(&data, 512);
        let mut corrupted = wire.clone();
        let span = chunk_payload_span(&wire, 0).unwrap();
        corrupted[span.start + 12] ^= 0xFF;

        let sup = RecoverySupervisor::new(8, FaultPlan::default());
        let ring = RetentionRing::new(4);
        // retains the *corrupt* bytes
        ring.push(9, SharedPayload::from_vec(corrupted.clone(), None));
        let (resp_conn, client_conn) = Conn::local_pair(4);
        let mut pool = WorkerPool::new();
        spawn_nack_responder(&mut pool, "nack-responder", resp_conn, ring);
        let client = ChunkRetryClient::new(Arc::clone(&sup));
        client.add_upstream("up", client_conn);
        client.note_provenance(9, "up");

        let err = decode_with_retry(Some(&client), 9, &mut corrupted, |bytes| {
            codec.decode_frame(bytes, serialized_len, data.len(), &rt, None)
        })
        .unwrap_err();
        assert!(matches!(err, DeferError::CorruptChunk { .. }));
        assert_eq!(sup.chunks_retried(), CHUNK_RETRY_BUDGET as u64);

        drop(client);
        pool.join().unwrap();
    }
}
