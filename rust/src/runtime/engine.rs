//! PJRT engine + compiled partition executables.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::error::{DeferError, Result};
use crate::model::PartitionSpec;
use crate::tensor::Tensor;
use crate::util::timer::SharedTimer;

// The `xla` crate wraps raw PJRT pointers without Send/Sync markers. The
// PJRT C API is documented thread-safe: clients may compile/execute from
// multiple threads, literals are plain host buffers. Each `Executable` is
// owned and used by exactly one chain-node thread; the client is shared
// behind an Arc. These wrappers make that contract explicit.
struct ClientHandle(xla::PjRtClient);
// SAFETY: PJRT CPU client operations (compile, execute, buffer transfer)
// are internally synchronized; see PJRT C API docs.
unsafe impl Send for ClientHandle {}
unsafe impl Sync for ClientHandle {}

struct ExeHandle(xla::PjRtLoadedExecutable);
// SAFETY: executables are immutable after compilation; PJRT execution is
// thread-safe. We additionally confine each ExeHandle to one thread.
unsafe impl Send for ExeHandle {}
unsafe impl Sync for ExeHandle {}

struct LiteralHandle(xla::Literal);
// SAFETY: a Literal is an owned host-memory buffer; moving it between
// threads is moving a heap allocation.
unsafe impl Send for LiteralHandle {}
unsafe impl Sync for LiteralHandle {}

/// Process-wide PJRT client handle (CPU plugin). Cheap to clone.
#[derive(Clone)]
pub struct Engine {
    client: Arc<ClientHandle>,
}

impl Engine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client: Arc::new(ClientHandle(client)),
        })
    }

    pub fn platform(&self) -> String {
        self.client.0.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.0.device_count()
    }

    /// Compile HLO text into an executable.
    pub fn compile_hlo_text(&self, hlo: &str, name: &str) -> Result<CompiledHlo> {
        // The xla crate only exposes a file-based text parser; stage through
        // a temp file. Compile happens once per partition at configuration
        // time, never on the per-frame path.
        let tmp = std::env::temp_dir().join(format!(
            "defer_hlo_{}_{}_{}.txt",
            std::process::id(),
            name,
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::write(&tmp, hlo)?;
        let result = self.compile_hlo_file(&tmp);
        std::fs::remove_file(&tmp).ok();
        result
    }

    /// Compile an HLO text file into an executable.
    pub fn compile_hlo_file(&self, path: &Path) -> Result<CompiledHlo> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| DeferError::Runtime("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.0.compile(&comp)?;
        Ok(CompiledHlo {
            exe: ExeHandle(exe),
            compile_time: t0.elapsed(),
        })
    }
}

/// A compiled HLO module (not yet bound to partition metadata).
pub struct CompiledHlo {
    exe: ExeHandle,
    pub compile_time: std::time::Duration,
}

/// A ready-to-run model partition: compiled HLO + resident weight literals.
///
/// Weights live on-device (CPU PJRT: host memory) from configuration time;
/// per frame only the activation tensor crosses into PJRT.
pub struct Executable {
    compiled: CompiledHlo,
    weights: Vec<LiteralHandle>,
    input_shape: Vec<usize>,
    output_shape: Vec<usize>,
    /// Accumulated on-device execute time (drives compute energy).
    pub exec_timer: SharedTimer,
    name: String,
}

fn literal_from_f32s(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
    let lit = xla::Literal::vec1(data);
    Ok(lit.reshape(&dims)?)
}

impl Executable {
    /// Build from a partition spec, reading HLO + weights from artifacts.
    pub fn load(engine: &Engine, spec: &PartitionSpec) -> Result<Self> {
        let hlo_compiled = engine.compile_hlo_file(&spec.hlo_path)?;
        let weight_arrays = spec.read_weights()?;
        Self::assemble(hlo_compiled, spec, weight_arrays)
    }

    /// Build from already-transferred architecture + weights (the compute
    /// node side of the configuration step, where both arrived by socket).
    pub fn from_parts(
        engine: &Engine,
        hlo_text: &str,
        spec: &PartitionSpec,
        weight_arrays: Vec<Vec<f32>>,
    ) -> Result<Self> {
        let compiled = engine.compile_hlo_text(
            hlo_text,
            &format!("{}_p{}", spec.model, spec.part_index),
        )?;
        Self::assemble(compiled, spec, weight_arrays)
    }

    fn assemble(
        compiled: CompiledHlo,
        spec: &PartitionSpec,
        weight_arrays: Vec<Vec<f32>>,
    ) -> Result<Self> {
        if weight_arrays.len() != spec.weights.len() {
            return Err(DeferError::Runtime(format!(
                "{} weight arrays for {} manifest entries",
                weight_arrays.len(),
                spec.weights.len()
            )));
        }
        let mut weights = Vec::with_capacity(weight_arrays.len());
        for (arr, wspec) in weight_arrays.iter().zip(&spec.weights) {
            if arr.len() != wspec.elements {
                return Err(DeferError::Runtime(format!(
                    "{}.{}: got {} elements, manifest says {}",
                    wspec.node,
                    wspec.param,
                    arr.len(),
                    wspec.elements
                )));
            }
            weights.push(LiteralHandle(literal_from_f32s(arr, &wspec.shape)?));
        }
        Ok(Executable {
            compiled,
            weights,
            input_shape: spec.input_shape.clone(),
            output_shape: spec.output_shape.clone(),
            exec_timer: SharedTimer::new(),
            name: format!("{}/p{}of{}", spec.model, spec.part_index, spec.part_count),
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    pub fn output_shape(&self) -> &[usize] {
        &self.output_shape
    }

    pub fn compile_time(&self) -> std::time::Duration {
        self.compiled.compile_time
    }

    /// Run one frame through this partition.
    pub fn run(&self, input: &Tensor) -> Result<Tensor> {
        if input.shape() != self.input_shape.as_slice() {
            return Err(DeferError::Runtime(format!(
                "{}: input shape {:?}, expected {:?}",
                self.name,
                input.shape(),
                self.input_shape
            )));
        }
        let t0 = Instant::now();
        let x = literal_from_f32s(input.data(), input.shape())?;
        // Arguments: activation first, then weights in manifest order —
        // matching the `fn(x, *weights)` lowering.
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(1 + self.weights.len());
        args.push(&x);
        args.extend(self.weights.iter().map(|w| &w.0));
        let result = self.compiled.exe.0.execute(&args)?[0][0].to_literal_sync()?;
        // Lowered with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        self.exec_timer.add(t0.elapsed());
        Tensor::new(self.output_shape.clone(), values)
    }
}
