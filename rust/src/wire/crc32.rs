//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320), slicing-by-8.
//! Integrity check for every wire payload; §Perf upgraded the classic
//! byte-at-a-time loop (~0.4 GB/s) to slicing-by-8 (~2-3 GB/s) since the
//! wire layer was CRC-bound.

use std::sync::OnceLock;

fn tables() -> &'static [[u32; 256]; 8] {
    static TABLES: OnceLock<[[u32; 256]; 8]> = OnceLock::new();
    TABLES.get_or_init(|| {
        let mut t = [[0u32; 256]; 8];
        for i in 0..256usize {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            t[0][i] = c;
        }
        for k in 1..8 {
            for i in 0..256usize {
                let prev = t[k - 1][i];
                t[k][i] = t[0][(prev & 0xFF) as usize] ^ (prev >> 8);
            }
        }
        t
    })
}

fn table() -> &'static [u32; 256] {
    &tables()[0]
}

/// CRC-32 of a buffer.
pub fn crc32(data: &[u8]) -> u32 {
    finish(update(init(), data))
}

/// Streaming API: `init() -> update()* -> finish()`. Lets the wire layer
/// checksum header + payload without concatenating them (§Perf: saves a
/// full payload copy per message).
#[inline]
pub fn init() -> u32 {
    0xFFFF_FFFF
}

pub fn update(mut state: u32, data: &[u8]) -> u32 {
    let t8 = tables();
    let mut chunks = data.chunks_exact(8);
    for c in &mut chunks {
        let lo = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) ^ state;
        let hi = u32::from_le_bytes([c[4], c[5], c[6], c[7]]);
        state = t8[7][(lo & 0xFF) as usize]
            ^ t8[6][((lo >> 8) & 0xFF) as usize]
            ^ t8[5][((lo >> 16) & 0xFF) as usize]
            ^ t8[4][(lo >> 24) as usize]
            ^ t8[3][(hi & 0xFF) as usize]
            ^ t8[2][((hi >> 8) & 0xFF) as usize]
            ^ t8[1][((hi >> 16) & 0xFF) as usize]
            ^ t8[0][(hi >> 24) as usize];
    }
    let t = table();
    for &b in chunks.remainder() {
        state = t[((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    state
}

#[inline]
pub fn finish(state: u32) -> u32 {
    state ^ 0xFFFF_FFFF
}

/// Multiply the GF(2) 32x32 matrix `mat` by the bit-vector `vec`.
fn gf2_matrix_times(mat: &[u32; 32], mut vec: u32) -> u32 {
    let mut sum = 0u32;
    let mut i = 0;
    while vec != 0 {
        if vec & 1 != 0 {
            sum ^= mat[i];
        }
        vec >>= 1;
        i += 1;
    }
    sum
}

/// `square = mat * mat` over GF(2).
fn gf2_matrix_square(square: &mut [u32; 32], mat: &[u32; 32]) {
    for n in 0..32 {
        square[n] = gf2_matrix_times(mat, mat[n]);
    }
}

/// CRC of a concatenation from the parts' CRCs (zlib's `crc32_combine`):
/// `combine(crc(A), crc(B), B.len()) == crc(A || B)`, both inputs and the
/// result in the *finished* domain ([`crc32`] outputs). Appending `len2`
/// zero bits is a linear operator over GF(2); it is applied to `crc1` by
/// repeated matrix squaring, so the cost is `O(log len2)` 32x32 matrix
/// ops — independent of the payload size. This is what lets the ingest
/// path verify a message CRC from the container's stored per-chunk CRCs
/// without a second pass over the payload bytes (§Perf).
pub fn combine(crc1: u32, crc2: u32, len2: u64) -> u32 {
    if len2 == 0 {
        return crc1;
    }
    let mut even = [0u32; 32]; // operator for 2 zero bits
    let mut odd = [0u32; 32]; // operator for 1 zero bit
    odd[0] = 0xEDB8_8320; // the poly itself: shifting out a 1 bit
    let mut row = 1u32;
    for slot in odd.iter_mut().skip(1) {
        *slot = row;
        row <<= 1;
    }
    gf2_matrix_square(&mut even, &odd); // 2 bits
    gf2_matrix_square(&mut odd, &even); // 4 bits
    let mut crc1 = crc1;
    let mut len2 = len2;
    // Apply len2 zero *bytes*: square up through the bits of len2,
    // alternating which matrix holds the current power of the operator.
    loop {
        gf2_matrix_square(&mut even, &odd);
        if len2 & 1 != 0 {
            crc1 = gf2_matrix_times(&even, crc1);
        }
        len2 >>= 1;
        if len2 == 0 {
            break;
        }
        gf2_matrix_square(&mut odd, &even);
        if len2 & 1 != 0 {
            crc1 = gf2_matrix_times(&odd, crc1);
        }
        len2 >>= 1;
        if len2 == 0 {
            break;
        }
    }
    crc1 ^ crc2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard test vectors for CRC-32/IEEE.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn sensitive_to_single_bit() {
        let a = crc32(b"hello world");
        let b = crc32(b"hello worle");
        assert_ne!(a, b);
    }
}
