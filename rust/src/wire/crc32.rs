//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320), slicing-by-8.
//! Integrity check for every wire payload; §Perf upgraded the classic
//! byte-at-a-time loop (~0.4 GB/s) to slicing-by-8 (~2-3 GB/s) since the
//! wire layer was CRC-bound.

use std::sync::OnceLock;

fn tables() -> &'static [[u32; 256]; 8] {
    static TABLES: OnceLock<[[u32; 256]; 8]> = OnceLock::new();
    TABLES.get_or_init(|| {
        let mut t = [[0u32; 256]; 8];
        for i in 0..256usize {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            t[0][i] = c;
        }
        for k in 1..8 {
            for i in 0..256usize {
                let prev = t[k - 1][i];
                t[k][i] = t[0][(prev & 0xFF) as usize] ^ (prev >> 8);
            }
        }
        t
    })
}

fn table() -> &'static [u32; 256] {
    &tables()[0]
}

/// CRC-32 of a buffer.
pub fn crc32(data: &[u8]) -> u32 {
    finish(update(init(), data))
}

/// Streaming API: `init() -> update()* -> finish()`. Lets the wire layer
/// checksum header + payload without concatenating them (§Perf: saves a
/// full payload copy per message).
#[inline]
pub fn init() -> u32 {
    0xFFFF_FFFF
}

pub fn update(mut state: u32, data: &[u8]) -> u32 {
    let t8 = tables();
    let mut chunks = data.chunks_exact(8);
    for c in &mut chunks {
        let lo = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) ^ state;
        let hi = u32::from_le_bytes([c[4], c[5], c[6], c[7]]);
        state = t8[7][(lo & 0xFF) as usize]
            ^ t8[6][((lo >> 8) & 0xFF) as usize]
            ^ t8[5][((lo >> 16) & 0xFF) as usize]
            ^ t8[4][(lo >> 24) as usize]
            ^ t8[3][(hi & 0xFF) as usize]
            ^ t8[2][((hi >> 8) & 0xFF) as usize]
            ^ t8[1][((hi >> 16) & 0xFF) as usize]
            ^ t8[0][(hi >> 24) as usize];
    }
    let t = table();
    for &b in chunks.remainder() {
        state = t[((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    state
}

#[inline]
pub fn finish(state: u32) -> u32 {
    state ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard test vectors for CRC-32/IEEE.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn sensitive_to_single_bit() {
        let a = crc32(b"hello world");
        let b = crc32(b"hello worle");
        assert_ne!(a, b);
    }
}
