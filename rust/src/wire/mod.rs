//! Wire protocol: typed, length-prefixed, CRC-checked messages with the
//! paper's 512 kB chunked transfer.
//!
//! DEFER's sockets carry four kinds of traffic: the model architecture
//! (meta JSON + HLO text), the weights array, intermediate inference
//! results, and control messages (chain wiring, shutdown). One header
//! layout covers all of them:
//!
//! ```text
//! magic   u32le  0x44454652 ("DEFR")
//! type    u8     MessageType
//! batch   u24le  frames coalesced in this message, minus one (0 = single)
//! frame   u64le  frame id (inference cycle number; 0 for config traffic)
//! wire    u64le  payload length on the wire (post-compression)
//! serial  u64le  serialized length (pre-compression, for decompressor)
//! count   u64le  f32 element count (0 for non-tensor payloads)
//! crc     u32le  CRC-32 over header bytes [0..40) + the wire payload
//! ```
//!
//! The batch field lives in what used to be the header pad bytes and is
//! stored biased by one, so an unbatched message (`batch == 1`) writes
//! zeros there — byte-identical to the pre-batching wire format. A
//! batched `Data`/`ResultMsg` carries the stacked activations of frames
//! `frame .. frame + batch` in one payload (one header, one container),
//! which is what amortizes the per-frame fixed costs.
//!
//! The payload follows in chunks of at most [`CHUNK_SIZE`] bytes — the
//! paper's "chunked data transfer (with a default size of 512kB per chunk)".
//! Chunking is observable by the link model: every chunk passes through the
//! configured [`crate::netem::Link`] shaper and the per-socket byte
//! counters, which is exactly where `nload` measured the paper's payloads.

pub mod crc32;

use std::io::{Read, Write};

use crate::error::{DeferError, Result};
use crate::metrics::ByteCounter;
use crate::netem::Link;

/// Paper's default chunk size: 512 kB.
pub const CHUNK_SIZE: usize = 512 * 1024;
pub const MAGIC: u32 = 0x4445_4652; // "DEFR"
/// Refuse absurd payloads (corrupt headers) before allocating.
pub const MAX_PAYLOAD: u64 = 8 * 1024 * 1024 * 1024;
/// Max frames one message may coalesce (the header stores `batch - 1`
/// in 3 bytes).
pub const MAX_BATCH: u32 = 1 << 24;

/// Message discriminants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum MessageType {
    /// Model architecture: meta JSON + HLO text (configuration step).
    ModelConfig = 1,
    /// Weights array (configuration step).
    Weights = 2,
    /// Intermediate activation (distributed inference step).
    Data = 3,
    /// Final result returning to the dispatcher.
    ResultMsg = 4,
    /// Orderly shutdown of the chain.
    Shutdown = 5,
    /// Configuration acknowledged; node is ready.
    Ready = 6,
    /// Recovery control: "re-send chunk `i` of frame `f`" (CRC failed).
    /// Rides the control mesh only — never appears on a fault-free wire.
    ChunkNack = 7,
    /// Recovery control: the re-sent chunk bytes answering a NACK.
    ChunkRetry = 8,
}

impl MessageType {
    fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            1 => MessageType::ModelConfig,
            2 => MessageType::Weights,
            3 => MessageType::Data,
            4 => MessageType::ResultMsg,
            5 => MessageType::Shutdown,
            6 => MessageType::Ready,
            7 => MessageType::ChunkNack,
            8 => MessageType::ChunkRetry,
            other => return Err(DeferError::Wire(format!("bad message type {other}"))),
        })
    }
}

/// Build a chunk NACK: "frame `frame`, chunk `chunk` failed its CRC —
/// re-send it". The chunk index travels in the payload (4 bytes LE) so
/// the header keeps its standard layout.
pub fn chunk_nack(frame: u64, chunk: u32) -> Message {
    Message {
        msg_type: MessageType::ChunkNack,
        frame,
        serialized_len: 0,
        count: 0,
        batch: 1,
        payload: chunk.to_le_bytes().to_vec(),
    }
}

/// Build the reply to a NACK: the retained wire bytes of exactly that
/// chunk (per-chunk header + body, as cut by
/// [`crate::serial::chunked::chunk_payload_span`]).
pub fn chunk_retry(frame: u64, chunk: u32, bytes: &[u8]) -> Message {
    let mut payload = Vec::with_capacity(4 + bytes.len());
    payload.extend_from_slice(&chunk.to_le_bytes());
    payload.extend_from_slice(bytes);
    Message {
        msg_type: MessageType::ChunkRetry,
        frame,
        serialized_len: bytes.len() as u64,
        count: 0,
        batch: 1,
        payload,
    }
}

/// Parse a `ChunkNack`/`ChunkRetry` payload into (chunk index, trailing
/// bytes). For a NACK the trailing slice is empty; for a retry it is the
/// re-sent chunk span. Anything else is a protocol violation.
pub fn parse_chunk_control(msg: &Message) -> Result<(u32, &[u8])> {
    if !matches!(
        msg.msg_type,
        MessageType::ChunkNack | MessageType::ChunkRetry
    ) {
        return Err(DeferError::Wire(format!(
            "expected chunk control frame, got {:?}",
            msg.msg_type
        )));
    }
    if msg.payload.len() < 4 {
        return Err(DeferError::Wire(format!(
            "chunk control payload too short: {} bytes",
            msg.payload.len()
        )));
    }
    let chunk = u32::from_le_bytes(msg.payload[0..4].try_into().unwrap());
    Ok((chunk, &msg.payload[4..]))
}

/// A framed message (header + owned payload).
#[derive(Clone, Debug, PartialEq)]
pub struct Message {
    pub msg_type: MessageType,
    /// First member frame id; a batched message carries frames
    /// `frame .. frame + batch`.
    pub frame: u64,
    /// Pre-compression serialized size (decompressor input).
    pub serialized_len: u64,
    /// f32 element count for tensor payloads (total across the batch).
    pub count: u64,
    /// Logical frames coalesced in the payload (>= 1; 1 = unbatched).
    pub batch: u32,
    pub payload: Vec<u8>,
}

impl Message {
    pub fn control(msg_type: MessageType) -> Self {
        Message {
            msg_type,
            frame: 0,
            serialized_len: 0,
            count: 0,
            batch: 1,
            payload: Vec::new(),
        }
    }

    /// Header + payload size on the wire (what nload would count).
    pub fn wire_size(&self) -> u64 {
        HEADER_SIZE as u64 + self.payload.len() as u64
    }
}

pub const HEADER_SIZE: usize = 4 + 1 + 3 + 8 + 8 + 8 + 8 + 4;

fn encode_header(msg: &Message) -> [u8; HEADER_SIZE] {
    let mut h = [0u8; HEADER_SIZE];
    h[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    h[4] = msg.msg_type as u8;
    // Batch count, biased by one, in the former pad bytes: an unbatched
    // message writes zeros, keeping the legacy wire bytes exactly.
    h[5..8].copy_from_slice(&(msg.batch - 1).to_le_bytes()[..3]);
    h[8..16].copy_from_slice(&msg.frame.to_le_bytes());
    h[16..24].copy_from_slice(&(msg.payload.len() as u64).to_le_bytes());
    h[24..32].copy_from_slice(&msg.serialized_len.to_le_bytes());
    h[32..40].copy_from_slice(&msg.count.to_le_bytes());
    // CRC covers the header fields too — a flipped frame id or length must
    // not pass silently (frame ids order the FIFO results). Streamed, so
    // header + payload are never concatenated (§Perf).
    let crc = crc32::finish(crc32::update(
        crc32::update(crc32::init(), &h[0..40]),
        &msg.payload,
    ));
    h[40..44].copy_from_slice(&crc.to_le_bytes());
    h
}

/// Write one message: header, then the payload in <=512 kB chunks, each
/// chunk passing through the link shaper and byte counter.
pub fn write_message(
    w: &mut impl Write,
    msg: &Message,
    link: &Link,
    counter: &ByteCounter,
) -> Result<()> {
    if msg.batch == 0 || msg.batch > MAX_BATCH {
        return Err(DeferError::Wire(format!(
            "batch {} out of range 1..={MAX_BATCH}",
            msg.batch
        )));
    }
    let header = encode_header(msg);
    link.shape(header.len());
    w.write_all(&header)?;
    counter.add(header.len() as u64);
    for chunk in msg.payload.chunks(CHUNK_SIZE.max(1)) {
        link.shape(chunk.len());
        w.write_all(chunk)?;
        counter.add(chunk.len() as u64);
    }
    w.flush()?;
    Ok(())
}

/// A parsed-and-validated message header whose payload has not been
/// read yet. Magic, type and size-cap checks happen in [`Header::parse`]
/// (before any payload allocation); the CRC — which covers the payload —
/// is verified in [`Header::into_message`]. Both the blocking reader and
/// the reactor's [`FrameAssembler`] build messages through this type, so
/// the two planes validate identically by construction.
#[derive(Clone, Debug)]
pub struct Header {
    pub msg_type: MessageType,
    pub frame: u64,
    /// Payload length on the wire (post-compression).
    pub wire_len: u64,
    pub serialized_len: u64,
    pub count: u64,
    pub batch: u32,
    crc_expect: u32,
    /// The raw header bytes, kept because the CRC covers bytes [0..40).
    raw: [u8; HEADER_SIZE],
}

impl Header {
    /// Parse and validate the fixed-size header: magic, message type,
    /// and the payload-size cap (refused before anything allocates).
    pub fn parse(raw: &[u8; HEADER_SIZE]) -> Result<Header> {
        let magic = u32::from_le_bytes(raw[0..4].try_into().unwrap());
        if magic != MAGIC {
            return Err(DeferError::Wire(format!("bad magic {magic:#x}")));
        }
        let msg_type = MessageType::from_u8(raw[4])?;
        let batch = 1 + u32::from_le_bytes([raw[5], raw[6], raw[7], 0]);
        let frame = u64::from_le_bytes(raw[8..16].try_into().unwrap());
        let wire_len = u64::from_le_bytes(raw[16..24].try_into().unwrap());
        let serialized_len = u64::from_le_bytes(raw[24..32].try_into().unwrap());
        let count = u64::from_le_bytes(raw[32..40].try_into().unwrap());
        let crc_expect = u32::from_le_bytes(raw[40..44].try_into().unwrap());
        if wire_len > MAX_PAYLOAD {
            return Err(DeferError::Wire(format!("payload {wire_len} exceeds cap")));
        }
        Ok(Header {
            msg_type,
            frame,
            wire_len,
            serialized_len,
            count,
            batch,
            crc_expect,
            raw: *raw,
        })
    }

    /// Verify the CRC over header + payload and assemble the message.
    pub fn into_message(self, payload: Vec<u8>) -> Result<Message> {
        let crc_actual = crc32::finish(crc32::update(
            crc32::update(crc32::init(), &self.raw[0..40]),
            &payload,
        ));
        if crc_actual != self.crc_expect {
            return Err(DeferError::Wire(format!(
                "crc mismatch: {crc_actual:#x} != {:#x}",
                self.crc_expect
            )));
        }
        Ok(Message {
            msg_type: self.msg_type,
            frame: self.frame,
            serialized_len: self.serialized_len,
            count: self.count,
            batch: self.batch,
            payload,
        })
    }
}

/// Read one message written by [`write_message`]. Validates magic, type,
/// size sanity and CRC.
pub fn read_message(r: &mut impl Read, counter: &ByteCounter) -> Result<Message> {
    read_message_pooled(r, counter, None)
}

/// [`read_message`] drawing the payload buffer from `pool` when given —
/// the allocation-hygiene variant for per-frame traffic. The consumer
/// should hand `Message::payload` back to the same pool once decoded,
/// closing the recycling loop (the old path paid a fresh
/// `vec![0u8; wire_len]` per frame).
pub fn read_message_pooled(
    r: &mut impl Read,
    counter: &ByteCounter,
    pool: Option<&crate::util::bufpool::BufPool>,
) -> Result<Message> {
    let mut header = [0u8; HEADER_SIZE];
    r.read_exact(&mut header)?;
    counter.add(HEADER_SIZE as u64);
    let h = Header::parse(&header)?;
    let wire_len = h.wire_len;
    let mut payload = match pool {
        Some(p) => p.take_len(wire_len as usize),
        None => vec![0u8; wire_len as usize],
    };
    r.read_exact(&mut payload)?;
    counter.add(wire_len);
    h.into_message(payload)
}

/// Incremental message parser for nonblocking sockets: feed it whatever
/// bytes are available and it resumes mid-header or mid-payload across
/// readiness windows. The reactor's ingress machines drive one assembler
/// per TCP connection; validation is [`Header::parse`] +
/// [`Header::into_message`], i.e. exactly the blocking reader's.
pub struct FrameAssembler {
    state: AsmState,
}

enum AsmState {
    Header {
        buf: [u8; HEADER_SIZE],
        filled: usize,
    },
    Payload {
        header: Header,
        buf: Vec<u8>,
        filled: usize,
    },
    /// Transient marker while ownership moves between states.
    Swapping,
}

impl Default for FrameAssembler {
    fn default() -> Self {
        Self::new()
    }
}

impl FrameAssembler {
    pub fn new() -> FrameAssembler {
        FrameAssembler {
            state: AsmState::Header {
                buf: [0u8; HEADER_SIZE],
                filled: 0,
            },
        }
    }

    /// True when no bytes of the next message have arrived yet — i.e. a
    /// peer closing now is a mid-stream EOF only if this is false.
    pub fn at_boundary(&self) -> bool {
        matches!(self.state, AsmState::Header { filled: 0, .. })
    }

    /// Pull bytes from `read` (a nonblocking source: returns how many
    /// bytes it wrote into the slice) until a full message assembles,
    /// the source would block, or it errors.
    ///
    /// * `Ok(Some(msg))` — one complete, CRC-verified message.
    /// * `Ok(None)` — the source would block mid-message; call again on
    ///   the next readiness event (`WouldBlock` is absorbed here,
    ///   `Interrupted` is retried).
    /// * `Err(..)` — protocol violation, I/O error, or EOF (a peer that
    ///   closes mid-stream surfaces as `UnexpectedEof`; clean shutdown
    ///   in this protocol is an explicit `Shutdown` message, so EOF is
    ///   always an error for the data plane).
    pub fn poll<R>(
        &mut self,
        read: &mut R,
        pool: Option<&crate::util::bufpool::BufPool>,
    ) -> Result<Option<Message>>
    where
        R: FnMut(&mut [u8]) -> std::io::Result<usize>,
    {
        loop {
            match &mut self.state {
                AsmState::Header { buf, filled } => {
                    while *filled < HEADER_SIZE {
                        match read(&mut buf[*filled..]) {
                            Ok(0) => {
                                return Err(std::io::Error::from(
                                    std::io::ErrorKind::UnexpectedEof,
                                )
                                .into())
                            }
                            Ok(n) => *filled += n,
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                return Ok(None)
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                            Err(e) => return Err(e.into()),
                        }
                    }
                    let header = Header::parse(buf)?;
                    let wire_len = header.wire_len as usize;
                    let payload = match pool {
                        Some(p) => p.take_len(wire_len),
                        None => vec![0u8; wire_len],
                    };
                    self.state = AsmState::Payload {
                        header,
                        buf: payload,
                        filled: 0,
                    };
                }
                AsmState::Payload { buf, filled, .. } => {
                    while *filled < buf.len() {
                        match read(&mut buf[*filled..]) {
                            Ok(0) => {
                                return Err(std::io::Error::from(
                                    std::io::ErrorKind::UnexpectedEof,
                                )
                                .into())
                            }
                            Ok(n) => *filled += n,
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                return Ok(None)
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                            Err(e) => return Err(e.into()),
                        }
                    }
                    let state = std::mem::replace(&mut self.state, AsmState::Swapping);
                    let AsmState::Payload { header, buf, .. } = state else {
                        unreachable!()
                    };
                    self.state = AsmState::Header {
                        buf: [0u8; HEADER_SIZE],
                        filled: 0,
                    };
                    return Ok(Some(header.into_message(buf)?));
                }
                AsmState::Swapping => unreachable!("assembler observed mid-swap"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn round_trip(msg: &Message) -> Message {
        let mut buf = Vec::new();
        let link = Link::ideal();
        let tx = ByteCounter::new();
        write_message(&mut buf, msg, &link, &tx).unwrap();
        assert_eq!(tx.total(), msg.wire_size());
        let rx = ByteCounter::new();
        let got = read_message(&mut buf.as_slice(), &rx).unwrap();
        assert_eq!(rx.total(), msg.wire_size());
        got
    }

    #[test]
    fn control_message_round_trip() {
        let msg = Message::control(MessageType::Shutdown);
        assert_eq!(round_trip(&msg), msg);
    }

    #[test]
    fn tensor_message_round_trip() {
        let mut rng = Rng::new(51);
        let msg = Message {
            msg_type: MessageType::Data,
            frame: 1234,
            serialized_len: 999,
            count: 250,
            batch: 1,
            payload: rng.bytes(1000),
        };
        assert_eq!(round_trip(&msg), msg);
    }

    #[test]
    fn batched_message_round_trip() {
        let mut rng = Rng::new(53);
        let msg = Message {
            msg_type: MessageType::Data,
            frame: 64,
            serialized_len: 4000,
            count: 1000,
            batch: 8,
            payload: rng.bytes(4000),
        };
        let got = round_trip(&msg);
        assert_eq!(got.batch, 8);
        assert_eq!(got, msg);
    }

    #[test]
    fn batch_one_is_byte_identical_to_legacy_wire_format() {
        // batch == 1 must write zeros in the former pad bytes — the
        // whole encoded stream is the pre-batching format, bit for bit.
        let msg = Message {
            msg_type: MessageType::Data,
            frame: 7,
            serialized_len: 16,
            count: 4,
            batch: 1,
            payload: vec![1, 2, 3, 4],
        };
        let mut buf = Vec::new();
        write_message(&mut buf, &msg, &Link::ideal(), &ByteCounter::new()).unwrap();
        assert_eq!(&buf[5..8], &[0u8, 0, 0], "pad bytes must stay zero");
    }

    #[test]
    fn zero_and_oversize_batch_rejected_before_write() {
        let mut msg = Message::control(MessageType::Data);
        msg.batch = 0;
        let mut buf = Vec::new();
        assert!(write_message(&mut buf, &msg, &Link::ideal(), &ByteCounter::new()).is_err());
        msg.batch = MAX_BATCH + 1;
        assert!(write_message(&mut buf, &msg, &Link::ideal(), &ByteCounter::new()).is_err());
        msg.batch = MAX_BATCH;
        assert!(write_message(&mut buf, &msg, &Link::ideal(), &ByteCounter::new()).is_ok());
    }

    #[test]
    fn multi_chunk_payload() {
        let mut rng = Rng::new(52);
        // > 2 chunks of 512 kB
        let msg = Message {
            msg_type: MessageType::Weights,
            frame: 0,
            serialized_len: 0,
            count: 0,
            batch: 1,
            payload: rng.bytes(CHUNK_SIZE * 2 + 777),
        };
        assert_eq!(round_trip(&msg), msg);
    }

    #[test]
    fn chunk_control_round_trip() {
        let nack = chunk_nack(42, 7);
        let got = round_trip(&nack);
        assert_eq!(got, nack);
        let (idx, rest) = parse_chunk_control(&got).unwrap();
        assert_eq!((idx, rest.len()), (7, 0));

        let retry = chunk_retry(42, 7, &[9, 8, 7, 6, 5]);
        let got = round_trip(&retry);
        let (idx, bytes) = parse_chunk_control(&got).unwrap();
        assert_eq!(idx, 7);
        assert_eq!(bytes, &[9, 8, 7, 6, 5]);
    }

    #[test]
    fn chunk_control_rejects_wrong_type_and_short_payload() {
        let msg = Message::control(MessageType::Data);
        assert!(parse_chunk_control(&msg).is_err());
        let mut short = Message::control(MessageType::ChunkNack);
        short.payload = vec![1, 2];
        assert!(parse_chunk_control(&short).is_err());
    }

    #[test]
    fn corrupt_payload_detected() {
        let msg = Message {
            msg_type: MessageType::Data,
            frame: 1,
            serialized_len: 8,
            count: 2,
            batch: 1,
            payload: vec![1, 2, 3, 4, 5, 6, 7, 8],
        };
        let mut buf = Vec::new();
        write_message(&mut buf, &msg, &Link::ideal(), &ByteCounter::new()).unwrap();
        let n = buf.len();
        buf[n - 3] ^= 0xFF; // flip payload byte
        assert!(read_message(&mut buf.as_slice(), &ByteCounter::new()).is_err());
    }

    #[test]
    fn bad_magic_and_type_detected() {
        let msg = Message::control(MessageType::Ready);
        let mut buf = Vec::new();
        write_message(&mut buf, &msg, &Link::ideal(), &ByteCounter::new()).unwrap();
        let mut bad = buf.clone();
        bad[0] ^= 1;
        assert!(read_message(&mut bad.as_slice(), &ByteCounter::new()).is_err());
        let mut bad_type = buf;
        bad_type[4] = 77;
        assert!(read_message(&mut bad_type.as_slice(), &ByteCounter::new()).is_err());
    }

    #[test]
    fn truncated_stream_detected() {
        let msg = Message {
            msg_type: MessageType::Data,
            frame: 1,
            serialized_len: 0,
            count: 0,
            batch: 1,
            payload: vec![9; 100],
        };
        let mut buf = Vec::new();
        write_message(&mut buf, &msg, &Link::ideal(), &ByteCounter::new()).unwrap();
        buf.truncate(buf.len() - 10);
        assert!(read_message(&mut buf.as_slice(), &ByteCounter::new()).is_err());
    }

    #[test]
    fn oversize_header_rejected_before_alloc() {
        let msg = Message::control(MessageType::Data);
        let mut buf = Vec::new();
        write_message(&mut buf, &msg, &Link::ideal(), &ByteCounter::new()).unwrap();
        // Forge a huge length field.
        buf[16..24].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(read_message(&mut buf.as_slice(), &ByteCounter::new()).is_err());
    }

    /// A nonblocking byte source that hands out `stream` in fixed-size
    /// dribbles, reporting `WouldBlock` between every delivery — the
    /// worst-case readiness pattern a real socket can produce.
    struct Dribble {
        stream: Vec<u8>,
        pos: usize,
        step: usize,
        /// Alternate deliveries with WouldBlock.
        starve: bool,
        parity: bool,
    }

    impl Dribble {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.starve {
                self.parity = !self.parity;
                if self.parity {
                    return Err(std::io::ErrorKind::WouldBlock.into());
                }
            }
            let n = self.step.min(out.len()).min(self.stream.len() - self.pos);
            out[..n].copy_from_slice(&self.stream[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    #[test]
    fn assembler_resumes_across_arbitrary_split_points() {
        let mut rng = Rng::new(59);
        let msgs: Vec<Message> = (0..4)
            .map(|i| Message {
                msg_type: MessageType::Data,
                frame: i,
                serialized_len: 100 + i,
                count: 25,
                batch: 1 + i as u32,
                payload: rng.bytes(100 + i as usize * 37),
            })
            .collect();
        let mut stream = Vec::new();
        for m in &msgs {
            write_message(&mut stream, m, &Link::ideal(), &ByteCounter::new()).unwrap();
        }
        // Every dribble size, including pathological 1-byte deliveries,
        // with and without interleaved WouldBlock starvation.
        for step in [1usize, 3, 7, HEADER_SIZE, 1000] {
            for starve in [false, true] {
                let mut src = Dribble {
                    stream: stream.clone(),
                    pos: 0,
                    step,
                    starve,
                    parity: false,
                };
                let mut asm = FrameAssembler::new();
                let mut got = Vec::new();
                while got.len() < msgs.len() {
                    match asm.poll(&mut |buf: &mut [u8]| src.read(buf), None).unwrap() {
                        Some(m) => got.push(m),
                        None => continue, // starved; "readiness" loops us back
                    }
                }
                assert_eq!(got, msgs, "step={step} starve={starve}");
                assert!(asm.at_boundary());
            }
        }
    }

    #[test]
    fn assembler_reports_eof_and_corruption_like_the_blocking_reader() {
        let msg = Message {
            msg_type: MessageType::Data,
            frame: 3,
            serialized_len: 8,
            count: 2,
            batch: 1,
            payload: vec![1, 2, 3, 4, 5, 6, 7, 8],
        };
        let mut stream = Vec::new();
        write_message(&mut stream, &msg, &Link::ideal(), &ByteCounter::new()).unwrap();

        // Truncated mid-payload: EOF must surface as an error.
        let mut cut = stream.clone();
        cut.truncate(cut.len() - 3);
        let mut pos = 0usize;
        let mut asm = FrameAssembler::new();
        let err = asm
            .poll(
                &mut |buf: &mut [u8]| {
                    let n = buf.len().min(cut.len() - pos);
                    buf[..n].copy_from_slice(&cut[pos..pos + n]);
                    pos += n;
                    Ok(n)
                },
                None,
            )
            .unwrap_err();
        assert!(format!("{err}").contains("io"), "{err}");
        assert!(!asm.at_boundary(), "EOF hit mid-message");

        // Flipped payload byte: same CRC error as read_message.
        let mut bad = stream.clone();
        let n = bad.len();
        bad[n - 2] ^= 0x10;
        let mut pos = 0usize;
        let mut asm = FrameAssembler::new();
        let err = asm
            .poll(
                &mut |buf: &mut [u8]| {
                    let take = buf.len().min(bad.len() - pos);
                    buf[..take].copy_from_slice(&bad[pos..pos + take]);
                    pos += take;
                    Ok(take)
                },
                None,
            )
            .unwrap_err();
        assert!(format!("{err}").contains("crc mismatch"), "{err}");
    }
}
